"""Event-driven server map: applies ObjectEvents straight onto an
ObjectStore, bypassing the rendering/mapping frontend.

The scenario engine's focus is the update/query/network loop, so the world
is authoritative and exact: spawns write fully-observed objects (class-basis
embedding, primitive point cloud, obs_count past the transient filter),
moves translate geometry with a version bump, removes tombstone through
``store.remove_objects`` — the same protocol path a mapping frontend's prune
would take.  All randomness is a per-object ``default_rng(seed, oid)``
stream, so a world replayed from the same Scenario is bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core.knobs import Knobs
from repro.core.store import (ObjectStore, deleted_mask, release_tombstones,
                              remove_objects, store_from_knobs)
from repro.data.scenes import _object_cloud
from repro.perception.embedder import OracleEmbedder
from repro.sim.scenario import ObjectEvent


@dataclass
class WorldState:
    knobs: Knobs
    embed_dim: int
    seed: int = 0
    store: ObjectStore = None
    embedder: OracleEmbedder = None
    labels: dict = field(default_factory=dict)       # oid -> class_id
    removed_at: dict = field(default_factory=dict)   # oid -> removal tick
    spawned: int = 0
    moved: int = 0
    removed: int = 0

    def __post_init__(self):
        if self.store is None:
            self.store = store_from_knobs(self.knobs, self.embed_dim)
        if self.embedder is None:
            # noiseless oracle: the world's embeddings ARE the class basis,
            # so query ground truth is exact and replay is deterministic
            self.embedder = OracleEmbedder(embed_dim=self.embed_dim,
                                           noise=0.0)

    # ------------------------------------------------------------------
    def _slot_of(self, oid: int) -> int | None:
        ids = np.asarray(self.store.ids)
        act = np.asarray(self.store.active)
        hits = np.nonzero((ids == oid) & act)[0]
        return int(hits[0]) if len(hits) else None

    def apply(self, ev: ObjectEvent, *, tick: int) -> None:
        if ev.kind == "spawn":
            self._spawn(ev)
        elif ev.kind == "move":
            self._move(ev)
        elif ev.kind == "remove":
            if self._slot_of(ev.oid) is not None:
                self.store = remove_objects(self.store, [ev.oid])
                self.removed_at[ev.oid] = tick
                self.removed += 1
        else:
            raise ValueError(f"unknown event kind {ev.kind!r}")

    def _spawn(self, ev: ObjectEvent) -> None:
        st = self.store
        occupied = np.asarray(st.active) | np.asarray(deleted_mask(st))
        free = np.nonzero(~occupied)[0]
        if not len(free) or self._slot_of(ev.oid) is not None:
            return
        s = int(free[0])
        rng = np.random.default_rng((self.seed, ev.oid))
        P = st.points.shape[1]
        n = int(min(ev.n_points, P))
        cloud = _object_cloud(rng, ev.class_id % 3, 0.5, n) \
            + np.asarray(ev.pos, np.float32)
        pts = np.zeros((P, 3), np.float32)
        pts[:n] = cloud
        emb = np.asarray(self.embedder.embed_text(ev.class_id))
        self.labels[ev.oid] = ev.class_id
        self.spawned += 1
        self.store = st._replace(
            ids=st.ids.at[s].set(ev.oid),
            active=st.active.at[s].set(True),
            embed=st.embed.at[s].set(jnp.asarray(emb)),
            label=st.label.at[s].set(ev.class_id),
            points=st.points.at[s].set(jnp.asarray(pts)),
            n_points=st.n_points.at[s].set(n),
            centroid=st.centroid.at[s].set(
                jnp.asarray(cloud.mean(axis=0))),
            bbox_min=st.bbox_min.at[s].set(jnp.asarray(cloud.min(axis=0))),
            bbox_max=st.bbox_max.at[s].set(jnp.asarray(cloud.max(axis=0))),
            obs_count=st.obs_count.at[s].set(
                max(self.knobs.min_obs_before_sync, 1) + 1),
            version=st.version.at[s].set(1),
            next_id=jnp.maximum(st.next_id, ev.oid + 1))

    def _move(self, ev: ObjectEvent) -> None:
        s = self._slot_of(ev.oid)
        if s is None:
            return
        st = self.store
        d = jnp.asarray(ev.delta, jnp.float32)
        P = st.points.shape[1]
        mask = (jnp.arange(P) < st.n_points[s])[:, None]
        self.moved += 1
        self.store = st._replace(
            points=st.points.at[s].set(
                jnp.where(mask, st.points[s] + d, 0.0)),
            centroid=st.centroid.at[s].set(st.centroid[s] + d),
            bbox_min=st.bbox_min.at[s].set(st.bbox_min[s] + d),
            bbox_max=st.bbox_max.at[s].set(st.bbox_max[s] + d),
            version=st.version.at[s].add(1))

    # ------------------------------------------------------------------
    def gc(self, *, tick: int, ttl: int, protected=frozenset()) -> int:
        """Release tombstones older than ``ttl`` ticks AND not in
        ``protected`` — the oids the FleetServer reports blocked because
        some subscriber's ACKED sync version does not yet cover the
        deletion (`FleetServer.blocked_tombstone_oids`, lease-capped).
        release_tombstones' precondition is that the deletion has been
        CONFIRMED everywhere; age alone is NOT sufficient: a client
        offline longer than the TTL would otherwise keep the ghost object
        forever.  Returns how many slots were retired; the zone mirror /
        sync layers observe the retirement on the next refresh."""
        ids = np.asarray(self.store.ids)
        dele = np.asarray(deleted_mask(self.store))
        slots = [s for s in np.nonzero(dele)[0]
                 if tick - self.removed_at.get(int(ids[s]), tick) >= ttl
                 and int(ids[s]) not in protected]
        if slots:
            self.store = release_tombstones(self.store, slots)
            for s in slots:
                self.removed_at.pop(int(ids[s]), None)
        return len(slots)

    # ------------------------------------------------------------------
    def live_ids(self) -> set:
        st = self.store
        return set(int(i) for i in
                   np.asarray(st.ids)[np.asarray(st.active)])

    def live_classes(self) -> np.ndarray:
        st = self.store
        return np.unique(np.asarray(st.label)[np.asarray(st.active)])
