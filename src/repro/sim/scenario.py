"""Declarative scenario specs: everything a dynamic-scene session needs,
as plain seeded data — no callables, no hidden state — so a scenario can be
replayed bit-identically, committed as a golden workload, or generated
randomly under hypothesis.

A ``Scenario`` bundles:
  * object lifecycle events   spawn / move / remove per tick (ObjectEvent)
  * user trajectories         parametric orbit tracks per client (PoseTrack)
  * network traces            RTT / bandwidth / outage windows (NetTrace)
  * fleet churn               join/leave ticks per client (ClientSpec)
  * knob schedule             per-client min-obs / radius changes (KnobEvent)
  * query plan                seeded per-tick query probability (QueryPlan)

``churn_scenario`` is the canonical generator: a seeded dynamic scene with
spawns, motion, and >= ``remove_frac`` of objects tombstoned mid-run — the
workload behind the golden-replay test, the property suite, and
benchmarks/scenario_suite.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.knobs import Knobs


@dataclass(frozen=True)
class NetTrace:
    """One client's link: fixed RTT/bandwidth + scheduled outage windows."""
    rtt_ms: float = 20.0
    bandwidth_mbps: float = 200.0
    outages: tuple = ()           # ((t_start, t_end) seconds, ...)


@dataclass(frozen=True)
class PoseTrack:
    """Parametric user trajectory: an orbit around an anchor (declarative
    stand-in for a head-pose trace; zone subscriptions follow it)."""
    anchor: tuple = (0.0, 1.5, 0.0)
    orbit_radius: float = 0.8
    angular_rate: float = 0.15    # rad / s
    phase: float = 0.0

    def pose_at(self, t: float) -> np.ndarray:
        ang = self.angular_rate * t + self.phase
        return np.asarray(self.anchor, np.float32) + np.array(
            [self.orbit_radius * np.cos(ang), 0.0,
             self.orbit_radius * np.sin(ang)], np.float32)


@dataclass(frozen=True)
class ClientSpec:
    cid: int
    net: NetTrace = NetTrace()
    track: PoseTrack = PoseTrack()
    join_tick: int = 0
    leave_tick: int = 10**9
    subscribe_radius: float = 1.5


@dataclass(frozen=True)
class ObjectEvent:
    """One object lifecycle event, applied at the START of ``tick``.

    kind='spawn'   place object ``oid`` of ``class_id`` at ``pos`` with
                   ``n_points`` points
    kind='move'    translate object ``oid`` by ``delta`` (version bump)
    kind='remove'  tombstone object ``oid`` (server prune -> version-bumped
                   tombstone row -> client slot freed on delivery)
    """
    tick: int
    kind: str                     # 'spawn' | 'move' | 'remove'
    oid: int
    class_id: int = 0
    pos: tuple = (0.0, 1.0, 0.0)
    n_points: int = 64
    delta: tuple = (0.0, 0.0, 0.0)


@dataclass(frozen=True)
class KnobEvent:
    """Knob-schedule entry, applied at the start of ``tick`` (control
    plane: per-client transient filter / subscription radius)."""
    tick: int
    cid: int | None = None        # None = every client
    min_obs: int | None = None
    subscribe_radius: float | None = None


@dataclass(frozen=True)
class CrashEvent:
    """Client crash/restart: at the start of ``tick`` client ``cid`` loses
    all volatile state (local map, in-flight packets, protocol position)
    and stays down for ``down_ticks`` ticks, then rejoins — the server
    hands it a fresh sync epoch and a full catch-up instead of silently
    replaying stale per-client sync state."""
    tick: int
    cid: int
    down_ticks: int = 2


@dataclass(frozen=True)
class QueryPlan:
    """Seeded per-tick query schedule: each active client queries with
    probability ``prob`` for a uniformly drawn live class; SQ specs carry a
    radius-around-pose spatial predicate."""
    prob: float = 0.5
    radius: float = 6.0
    k: int = 3


@dataclass(frozen=True)
class GridSpec:
    """Zone-grid shape (declarative mirror of zones.ZoneGrid.for_room)."""
    room: float = 8.0
    nx: int = 1
    nz: int = 1


@dataclass(frozen=True)
class Scenario:
    seed: int = 0
    n_ticks: int = 20
    tick_s: float = 1.0
    embed_dim: int = 32
    knobs: Knobs = None
    grid: GridSpec = GridSpec()
    budget: int = 32              # per-client objects shipped per tick/zone
    clients: tuple = ()           # ClientSpec, ...
    events: tuple = ()            # ObjectEvent, ...  (sorted by tick)
    knob_events: tuple = ()       # KnobEvent, ...
    query: QueryPlan = QueryPlan()
    drain_ticks: int = 0          # extra event-free ticks appended at the
    #                               end with every link up (packets drain)
    tombstone_ttl: int | None = None   # release tombstones this many ticks
    #                               after removal (None = never in-run)
    faults: object = None         # core.runtime.FaultModel — seeded packet
    #                               loss/dup/reorder/corruption (None =
    #                               clean legacy transport)
    crash_events: tuple = ()      # CrashEvent, ... — client crash/restart
    lease_ticks: int | None = None     # tombstone-retirement lease: a
    #                               partitioned client that owes deletion
    #                               acks forfeits its hold after this many
    #                               ack-free ticks (fresh epoch on return)

    def client(self, cid: int) -> ClientSpec:
        for c in self.clients:
            if c.cid == cid:
                return c
        raise KeyError(cid)

    @property
    def total_ticks(self) -> int:
        return self.n_ticks + self.drain_ticks


# ---------------------------------------------------------------------------
def churn_scenario(*, seed: int = 0, n_objects: int = 24, n_ticks: int = 24,
                   n_clients: int = 3, remove_frac: float = 0.25,
                   move_frac: float = 0.25, spawn_late: int = 4,
                   outage_frac: float = 0.5, drain_ticks: int = 6,
                   knobs: Knobs | None = None, embed_dim: int = 32,
                   grid: GridSpec = GridSpec(), n_labels: int = 12,
                   query_prob: float = 0.5,
                   tombstone_ttl: int | None = None,
                   faults: object = None, crash_events: tuple = (),
                   lease_ticks: int | None = None) -> Scenario:
    """The canonical dynamic-scene workload, fully determined by ``seed``.

    * ``n_objects`` spawn up front (tick 0) plus ``spawn_late`` more spread
      over the first half of the run;
    * ``move_frac`` of objects get one translation event mid-run;
    * >= ``remove_frac`` of all spawned objects are tombstoned mid-run
      (between 1/3 and 2/3 of the way through);
    * each client gets a heterogeneous link (mixed RTT/bw tiers,
      ``outage_frac`` chance of one mid-run outage) and a join tick that
      staggers the fleet; ``drain_ticks`` outage-free ticks close the run
      so every packet lands.
    """
    rng = np.random.default_rng(seed)
    kn = knobs or Knobs(server_capacity=128, client_capacity=64,
                        max_object_points_server=64,
                        max_object_points_client=16, min_obs_before_sync=1)
    half = grid.room / 2
    events = []
    oids = list(range(1, n_objects + spawn_late + 1))
    for i, oid in enumerate(oids):
        tick = 0 if i < n_objects else int(rng.integers(1, max(n_ticks // 2,
                                                               2)))
        events.append(ObjectEvent(
            tick=tick, kind="spawn", oid=oid,
            class_id=int(rng.integers(0, n_labels)),
            pos=tuple(float(x) for x in
                      (rng.uniform(-half * 0.9, half * 0.9),
                       rng.uniform(0.2, 2.0),
                       rng.uniform(-half * 0.9, half * 0.9))),
            n_points=int(rng.integers(8, kn.max_object_points_server))))
    n_move = int(round(move_frac * len(oids)))
    for oid in rng.choice(oids, size=n_move, replace=False):
        events.append(ObjectEvent(
            tick=int(rng.integers(max(n_ticks // 4, 1),
                                  max(3 * n_ticks // 4, 2))),
            kind="move", oid=int(oid),
            delta=tuple(float(x) for x in rng.uniform(-0.6, 0.6, 3))))
    n_remove = max(1, int(round(remove_frac * len(oids))))
    removed = rng.choice(oids, size=n_remove, replace=False)
    for oid in removed:
        events.append(ObjectEvent(
            tick=int(rng.integers(max(n_ticks // 3, 1),
                                  max(2 * n_ticks // 3, 2))),
            kind="remove", oid=int(oid)))
    events.sort(key=lambda e: (e.tick, e.kind, e.oid))

    clients = []
    horizon = n_ticks  # outages end before the drain phase
    for c in range(n_clients):
        outages = ()
        if rng.random() < outage_frac:
            start = float(rng.uniform(1, horizon * 0.7))
            outages = ((start, min(start + float(rng.uniform(2, 5)),
                                   float(horizon))),)
        clients.append(ClientSpec(
            cid=c,
            net=NetTrace(rtt_ms=float(rng.choice([20.0, 40.0, 66.0])),
                         bandwidth_mbps=float(rng.choice([50.0, 100.0,
                                                          200.0])),
                         outages=outages),
            track=PoseTrack(anchor=(float(rng.uniform(-half * 0.6,
                                                      half * 0.6)), 1.5,
                                    float(rng.uniform(-half * 0.6,
                                                      half * 0.6))),
                            phase=0.7 * c),
            join_tick=0 if c == 0 else int(rng.integers(0, max(n_ticks // 3,
                                                               1) + 1)),
            subscribe_radius=max(grid.room, 2.0) if grid.nx * grid.nz == 1
            else 1.5))
    return Scenario(seed=seed, n_ticks=n_ticks, embed_dim=embed_dim,
                    knobs=kn, grid=grid, clients=tuple(clients),
                    events=tuple(events), query=QueryPlan(prob=query_prob),
                    drain_ticks=drain_ticks, tombstone_ttl=tombstone_ttl,
                    faults=faults, crash_events=tuple(crash_events),
                    lease_ticks=lease_ticks)
