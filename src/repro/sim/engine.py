"""The discrete-event session loop: one engine for every scenario.

Subsumes the two ad-hoc drivers (examples/network_drop_session.py and
server.fleet.FleetSimulator are thin wrappers): per tick it applies the
scenario's knob + object events to the world (or steps a mapping frontend
over rendered frames), mirrors the store into the zone-sharded fleet
server, advances client churn/poses, runs ONE vmapped fleet collect,
delivers packets through the outage-aware ``ClientSession`` step, executes
the seeded query plan (SQ/LQ mode switching on observed latency), and logs
everything into a structured ``MetricsLog``.

Determinism is the contract: the loop touches no wall clock and draws no
unseeded randomness, so the same Scenario replays to a bit-identical
MetricsLog — the golden-replay test (tests/test_scenario_engine.py) and the
committed metrics snapshot catch silent protocol drift.  Latency and power
are MODELs (NetworkModel transfer times, PowerModel coefficients — see
EXPERIMENTS.md), never measurements.
"""
from __future__ import annotations

import json
from collections import defaultdict
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.local_map import local_map_nbytes
from repro.core.query import Query
from repro.core.runtime import (ClientSession, DeviceClient, NetworkModel,
                                PowerModel)
from repro.obs import metrics as obs_metrics
from repro.obs.trace import span as obs_span
from repro.server.fleet import FleetServer
from repro.server.zones import ZoneGrid
from repro.sim.scenario import Scenario
from repro.sim.world import WorldState

# modeled on-device query cost (ms): FALLBACK only — the engine derives the
# LQ latency MODEL from the measured BENCH_query_engine.json full_mix curve
# interpolated at the client's actual map size (see lq_model_ms); this
# constant applies only when no measured curve is on disk
LQ_MODEL_MS = 3.5
_LQ_CURVE_PATH = (Path(__file__).resolve().parents[3]
                  / "BENCH_query_engine.json")
# SQ wire model: fp16 query embedding up, k result rows (id+score+slot) down
_SQ_ROW_B = 16


def load_lq_curve(path=None):
    """(sizes [K], full_mix ms [K]) from a committed BENCH_query_engine.json
    — the measured declarative-engine latency curve — or None when the file
    is missing/unparseable (callers fall back to ``LQ_MODEL_MS``)."""
    try:
        data = json.loads(Path(path or _LQ_CURVE_PATH).read_text())
    except (OSError, ValueError):
        return None
    pts = sorted((int(k), float(v["full_mix"])) for k, v in data.items()
                 if isinstance(v, dict) and str(k).isdigit()
                 and "full_mix" in v)
    if not pts:
        return None
    return (np.asarray([p[0] for p in pts], np.float64),
            np.asarray([p[1] for p in pts], np.float64))


def lq_model_ms(n_objects: int, curve=None) -> float:
    """Modeled on-device (LQ) query latency at the client's actual map
    size: log-size linear interpolation over the measured full_mix curve,
    clamped to the measured range.  Still a MODEL — the interpolant is a
    pure function of (committed curve file, object count), so replays stay
    bit-deterministic; no curve -> the legacy ``LQ_MODEL_MS`` constant."""
    if curve is None:
        return LQ_MODEL_MS
    ns, ms = curve
    n = min(max(float(max(n_objects, 1)), float(ns[0])), float(ns[-1]))
    return float(np.interp(np.log(n), np.log(ns), ms))


@dataclass
class MetricsLog:
    """Per-tick structured metrics, all [T] or [T, C] numpy arrays.

    Every field is reproducible bit-for-bit from the Scenario alone —
    ``equals`` is exact array equality (NaN-aware), which is what the
    golden-replay test asserts.  ``summary`` splits exact counters/byte
    totals from MODELed float metrics so a committed snapshot can hold the
    former to the digit and the latter to a tolerance.
    """
    tick: np.ndarray            # [T] int32
    events: np.ndarray          # [T, 3] int32 — spawned, moved, removed
    gc_released: np.ndarray     # [T] int32 tombstone slots retired
    server_live: np.ndarray     # [T] int32
    server_tombstones: np.ndarray   # [T] int32
    sent_bytes: np.ndarray      # [T, C] int64 — wire bytes sent this tick
    sent_tomb_bytes: np.ndarray  # [T, C] int64 — the tombstone-row share
    #                              of sent_bytes (measured, not estimated)
    recv_bytes: np.ndarray      # [T, C] int64 — bytes ingested this tick
    delivered: np.ndarray       # [T, C] int32 — packets ingested this tick
    delayed: np.ndarray         # [T, C] int32 — packets delayed this tick
    client_active: np.ndarray   # [T, C] bool — joined and not left
    client_live: np.ndarray     # [T, C] int32 — local-map live objects
    client_nbytes: np.ndarray   # [T, C] int64 — local-map bytes (fixed cap)
    mode_sq: np.ndarray         # [T, C] int8 — 1 SQ, 0 LQ, -1 inactive
    queried: np.ndarray         # [T, C] int8 — 1 if a query ran this tick
    query_hit: np.ndarray       # [T, C] int8 — top-1 label correct
    #                             (1/0, -1 = no query or no ground truth)
    query_ms: np.ndarray        # [T, C] f64 MODELed latency (NaN = none)
    power_w: np.ndarray         # [T, C] f64 MODELed device power
    up_bytes: np.ndarray        # [T, C] int64 — upstream control bytes
    #                             (acks + resync requests; hardened only)
    faults: np.ndarray          # [T, C, 4] int32 — packets lost, duplicate
    #                             drops, corrupt drops, resync requests
    wall_ms: list = None        # [T] measured tick wall time — NOT part of
    #                             the determinism contract: excluded from
    #                             _FIELDS/equals, surfaced only in the
    #                             summary's ``wall`` section

    _FIELDS = ("tick", "events", "gc_released", "server_live",
               "server_tombstones", "sent_bytes", "sent_tomb_bytes",
               "recv_bytes", "delivered", "delayed", "client_active",
               "client_live", "client_nbytes", "mode_sq", "queried",
               "query_hit", "query_ms", "power_w", "up_bytes", "faults")

    @property
    def n_ticks(self) -> int:
        return len(self.tick)

    @property
    def n_clients(self) -> int:
        return self.sent_bytes.shape[1]

    def equals(self, other: "MetricsLog") -> bool:
        """Bit-exact equality (the golden-replay invariant)."""
        return all(np.array_equal(getattr(self, f), getattr(other, f),
                                  equal_nan=True) for f in self._FIELDS)

    def diff(self, other: "MetricsLog") -> list:
        return [f for f in self._FIELDS
                if not np.array_equal(getattr(self, f), getattr(other, f),
                                      equal_nan=True)]

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """JSON-able snapshot: ``exact`` (counts + byte totals, compared to
        the digit) and ``approx`` (MODELed latency/power, compared within
        tolerance)."""
        sq = self.queried * (self.mode_sq == 1)
        lq = self.queried * (self.mode_sq == 0)
        q_ms = self.query_ms[~np.isnan(self.query_ms)]
        exact = {
            "n_ticks": int(self.n_ticks),
            "n_clients": int(self.n_clients),
            "spawned": int(self.events[:, 0].sum()),
            "moved": int(self.events[:, 1].sum()),
            "removed": int(self.events[:, 2].sum()),
            "gc_released": int(self.gc_released.sum()),
            "server_live_final": int(self.server_live[-1]),
            "server_tombstones_final": int(self.server_tombstones[-1]),
            "sent_bytes_total": int(self.sent_bytes.sum()),
            "sent_bytes_per_client": [int(x) for x in
                                      self.sent_bytes.sum(axis=0)],
            "tombstone_bytes_total": int(self.sent_tomb_bytes.sum()),
            "recv_bytes_total": int(self.recv_bytes.sum()),
            "delivered_total": int(self.delivered.sum()),
            "delayed_total": int(self.delayed.sum()),
            "client_live_final": [int(x) for x in self.client_live[-1]],
            "sq_queries": int(sq.sum()),
            "lq_queries": int(lq.sum()),
            "query_hits": int((self.query_hit == 1).sum()),
            "idle_zero_byte_ticks": int((self.sent_bytes.sum(axis=1)
                                         == 0).sum()),
            "up_bytes_total": int(self.up_bytes.sum()),
            "packets_lost": int(self.faults[:, :, 0].sum()),
            "dup_drops": int(self.faults[:, :, 1].sum()),
            "corrupt_drops": int(self.faults[:, :, 2].sum()),
            "resync_requests": int(self.faults[:, :, 3].sum()),
        }
        approx = {
            "query_ms_mean": float(q_ms.mean()) if len(q_ms) else 0.0,
            "query_ms_max": float(q_ms.max()) if len(q_ms) else 0.0,
            "power_w_mean": float(self.power_w.mean()),
        }
        out = {"exact": exact, "approx": approx}
        if self.wall_ms:
            # measured wall clock: informational only, never part of the
            # golden compare (assert_matches_snapshot reads exact/approx)
            out["wall"] = obs_metrics.exact_percentiles(self.wall_ms)
        return out

    def assert_matches_snapshot(self, snapshot: dict,
                                rel_tol: float = 0.25) -> None:
        """Compare against a committed ``summary()`` dict: exact fields to
        the digit, approx fields within ``rel_tol`` relative tolerance."""
        got = self.summary()
        for k, want in snapshot["exact"].items():
            assert got["exact"][k] == want, \
                f"snapshot drift: {k}: got {got['exact'][k]}, want {want}"
        for k, want in snapshot["approx"].items():
            g = got["approx"][k]
            assert abs(g - want) <= rel_tol * max(abs(want), 1e-9), \
                f"snapshot drift: {k}: got {g}, want {want} ±{rel_tol:.0%}"


# ---------------------------------------------------------------------------
@dataclass
class ScenarioEngine:
    """Run a Scenario through the full device-cloud loop.

    ``mapper``/``frames``/``classes`` switch the map source from the
    event-driven WorldState to a real mapping frontend.  With ``scene``
    set, object events mutate the Scene and the tick's frame is
    RE-RENDERED from the changed geometry before the mapper sees it —
    spawn and move become visible through the perception path exactly
    like remove (pre-PR-10 only 'remove' acted, by tombstoning the store
    directly; a moved or spawned object stayed invisible until an
    unrelated refresh).  'remove' still tombstones the mapper's store
    directly too: re-rendering stops new observations, the tombstone
    propagates the deletion.
    ``query_hook(cid, t, spec)`` externalizes SQ execution (the
    FleetSimulator routes through serving.BatchScheduler); ``tick_hook(t)``
    runs after every tick (scheduler pumping).
    """
    scenario: Scenario
    mapper: object = None
    frames: list = None
    scene: object = None               # data.scenes.Scene behind ``frames``
    #                                    (enables dynamic-scene re-render)
    classes: dict = None
    embedder: object = None            # query-side embeddings (mapper path)
    query_hook: object = None
    tick_hook: object = None
    async_loop: bool = False           # overlapped server tick: issue every
    #                                    dirty zone's collect before any
    #                                    packet materializes, with the sync
    #                                    state donated.  Replay stays bit-
    #                                    identical (asserted in tests) —
    #                                    only the dispatch schedule changes.
    power: PowerModel = field(default_factory=PowerModel)
    # built state (exposed for wrappers/tests)
    server: FleetServer = None
    world: WorldState = None
    sessions: dict = None              # cid -> ClientSession
    joined: dict = None                # cid -> bool

    def __post_init__(self):
        sc = self.scenario
        assert sc.knobs is not None, "Scenario.knobs must be set"
        cids = [c.cid for c in sc.clients]
        assert cids == list(range(len(cids))), \
            "ClientSpec.cid must be 0..C-1 (FleetServer indexing)"
        # hardened mode: fault-injection transport + protocol framing bytes
        self._hardened = sc.faults is not None or bool(sc.crash_events)
        grid = ZoneGrid.for_room(sc.grid.room, sc.grid.nx, sc.grid.nz)
        if self.server is None:
            self.server = FleetServer(knobs=sc.knobs,
                                      embed_dim=sc.embed_dim,
                                      n_clients=len(sc.clients), grid=grid,
                                      budget=sc.budget,
                                      proto=self._hardened,
                                      donate=None if self.async_loop
                                      else False)
        if self.mapper is None and self.world is None:
            self.world = WorldState(knobs=sc.knobs, embed_dim=sc.embed_dim,
                                    seed=sc.seed)
        self.sessions = {
            c.cid: ClientSession(
                dev=DeviceClient(knobs=sc.knobs, embed_dim=sc.embed_dim),
                net=NetworkModel(rtt_ms=c.net.rtt_ms,
                                 bandwidth_mbps=c.net.bandwidth_mbps,
                                 outages=c.net.outages),
                knobs=sc.knobs, dt=sc.tick_s, cid=c.cid, faults=sc.faults)
            for c in sc.clients}
        self.joined = {c.cid: False for c in sc.clients}
        self._radius = {c.cid: c.subscribe_radius for c in sc.clients}
        self._events = defaultdict(list)
        for ev in sc.events:
            self._events[ev.tick].append(ev)
        self._knob_events = defaultdict(list)
        for ev in sc.knob_events:
            self._knob_events[ev.tick].append(ev)
        self._crashes = defaultdict(list)
        for ev in sc.crash_events:
            self._crashes[ev.tick].append(ev)
        self._crashed_until = {}           # cid -> first tick back up
        self._scene_dirty = False          # a scene event happened: frames
        #                                    rendered before it are stale —
        #                                    re-render each tick's frame at
        #                                    use time (sticky: the change is
        #                                    permanent, every later
        #                                    pre-rendered frame predates it)
        # measured LQ latency curve (None -> LQ_MODEL_MS fallback); loaded
        # once so every tick interpolates the same committed artifact
        self._lq_curve = load_lq_curve()

    # ------------------------------------------------------------------
    def _store(self):
        return self.mapper.store if self.mapper is not None \
            else self.world.store

    def _query_embed(self, class_id: int):
        if self.world is not None:
            return self.world.embedder.embed_text(class_id)
        if self.embedder is not None:
            return self.embedder.embed_text(class_id)
        return None

    def _live_classes(self) -> np.ndarray:
        if self.world is not None:
            return self.world.live_classes()
        st = self.mapper.store
        return np.unique(np.asarray(st.label)[np.asarray(st.active)])

    def _apply_events(self, i: int) -> tuple:
        from repro.core.store import deleted_mask, remove_objects
        spawned = moved = removed = 0
        for ev in self._events.get(i, ()):
            if self.mapper is not None:
                s, m = self._apply_scene_event(ev)
                spawned += s
                moved += m
                if ev.kind == "remove":
                    before = int(np.asarray(
                        deleted_mask(self.mapper.store)).sum())
                    self.mapper.store = remove_objects(self.mapper.store,
                                                       [ev.oid])
                    removed += int(np.asarray(
                        deleted_mask(self.mapper.store)).sum()) - before
                continue
            before = (self.world.spawned, self.world.moved,
                      self.world.removed)
            self.world.apply(ev, tick=i)
            spawned += self.world.spawned - before[0]
            moved += self.world.moved - before[1]
            removed += self.world.removed - before[2]
        return spawned, moved, removed

    def _apply_scene_event(self, ev) -> tuple:
        """Mutate the mapper-backed Scene for one object event so the
        tick's RE-RENDERED frame shows it (see ``scene``).  Returns
        (spawned, moved) deltas; 'remove' geometry is dropped here but
        counted by the store-tombstone path in ``_apply_events``.
        Deterministic: spawn geometry is seeded by (scene seed, oid),
        mirroring WorldState.spawn."""
        if self.scene is None:
            return 0, 0
        from repro.data.scenes import SceneObject, _object_cloud
        objs = self.scene.objects
        if ev.kind == "spawn":
            if any(o.oid == ev.oid for o in objs):
                return 0, 0
            rng = np.random.default_rng((self.scene.rng_seed, ev.oid))
            center = np.asarray(ev.pos, np.float32)
            pts = (_object_cloud(rng, ev.class_id % 3, 0.5, ev.n_points)
                   + center).astype(np.float32)
            objs.append(SceneObject(oid=ev.oid, class_id=ev.class_id,
                                    center=center, points=pts))
            if self.classes is not None:
                self.classes[ev.oid] = ev.class_id
            self._scene_dirty = True
            return 1, 0
        if ev.kind == "move":
            for o in objs:
                if o.oid == ev.oid:
                    d = np.asarray(ev.delta, np.float32)
                    o.points = o.points + d
                    o.center = o.center + d
                    self._scene_dirty = True
                    return 0, 1
            return 0, 0
        if ev.kind == "remove":
            keep = [o for o in objs if o.oid != ev.oid]
            if len(keep) != len(objs):
                self.scene.objects = keep
                self._scene_dirty = True
        return 0, 0

    def _apply_knob_events(self, i: int) -> None:
        for ev in self._knob_events.get(i, ()):
            targets = [ev.cid] if ev.cid is not None \
                else [c.cid for c in self.scenario.clients]
            for cid in targets:
                if ev.min_obs is not None:
                    for s in self.server.sessions:
                        s.set_client(cid, min_obs=ev.min_obs)
                if ev.subscribe_radius is not None:
                    self._radius[cid] = ev.subscribe_radius

    # ------------------------------------------------------------------
    def run(self) -> MetricsLog:
        import time as _time
        sc = self.scenario
        C, T = len(sc.clients), sc.total_ticks
        key = jax.random.key(sc.seed)
        rec = {f: [] for f in MetricsLog._FIELDS}
        prev_down = np.zeros(C, np.int64)
        prev_delivered = np.zeros(C, np.int32)
        prev_delayed = np.zeros(C, np.int32)
        prev_up = np.zeros(C, np.int64)
        prev_faults = np.zeros((C, 4), np.int32)
        self.wall_ms = []      # measured tick wall time — NOT in MetricsLog
        #                        (wall clock would break bit-replay)

        for i in range(T):
            wall0 = _time.perf_counter()
            # manual enter/exit keeps the 200-line tick body un-nested;
            # works identically for the no-op span when tracing is off
            tick_span = obs_span("engine.tick", cat="engine", tick=i)
            tick_span.__enter__()
            t = i * sc.tick_s
            if i == sc.n_ticks:
                # drain phase: the chaos is over — clean links so every
                # retransmitted delta can land and the run converges
                for sess in self.sessions.values():
                    sess.faults = None
            self._apply_knob_events(i)
            for ev in self._crashes.get(i, ()):
                # crash: the device loses its volatile state and drops off;
                # it rejoins (fresh epoch, full catch-up) once back up
                self._crashed_until[ev.cid] = i + max(ev.down_ticks, 1)
                if self.joined[ev.cid]:
                    self.joined[ev.cid] = False
                    self.sessions[ev.cid].crash()
                    self.server.crash(ev.cid)
                    self.server.leave(ev.cid)
            with obs_span("engine.apply_events", cat="engine"):
                spawned, moved, removed = self._apply_events(i)
            if self.mapper is not None and self.frames is not None \
                    and i < len(self.frames):
                frame = self.frames[i]
                if self.scene is not None and self._scene_dirty:
                    # dynamic scene: this frame was rendered before the
                    # event — re-splat its viewpoint against the mutated
                    # geometry so the mapper OBSERVES the change
                    from repro.data.scenes import rerender_frame
                    frame = rerender_frame(self.scene, frame)
                    self.frames[i] = frame
                with obs_span("engine.map_frame", cat="ingest"):
                    self.mapper.process_frame(frame, self.classes,
                                              jax.random.fold_in(key, i))
            gc_n = 0
            if self.world is not None and sc.tombstone_ttl is not None:
                # sync-vector-driven slot retirement: a tombstone is
                # releasable only once every subscriber's ACKED version
                # covers the deletion (lease-capped for partitioned
                # clients) — the server knows, no omniscient engine oracle
                blocked = self.server.blocked_tombstone_oids(
                    tick=i, lease_ticks=sc.lease_ticks)
                gc_n = self.world.gc(tick=i, ttl=sc.tombstone_ttl,
                                     protected=blocked)
            store = self._store()
            with obs_span("engine.refresh", cat="sync"):
                self.server.refresh(store)

            # churn + pose advance + deliverability
            deliverable = np.zeros(C, bool)
            active = np.zeros(C, bool)
            for spec in sc.clients:
                cid, sess = spec.cid, self.sessions[spec.cid]
                in_window = spec.join_tick <= i < spec.leave_tick \
                    and i >= self._crashed_until.get(cid, 0)
                if not self.joined[cid] and in_window:
                    self.joined[cid] = True
                    self.server.join(cid, spec.track.pose_at(t),
                                     self._radius[cid], tick=i)
                elif self.joined[cid] and not in_window:
                    self.joined[cid] = False
                    self.server.leave(cid)
                if self.joined[cid]:
                    pos = spec.track.pose_at(t)
                    sess.user_pos = jnp.asarray(pos)
                    self.server.set_client_pose(cid, pos, self._radius[cid])
                    # zone-crossing mid-flight fix: the device's delivery
                    # gate tracks the NEW subscriptions immediately, so an
                    # in-air packet from a just-left zone is dropped at
                    # delivery instead of applied-then-pruned a tick later
                    sess.zone_subs = self.server.subscribed[cid].copy()
                    deliverable[cid] = sess.net.is_up(t)
                    active[cid] = True

            if self._hardened:
                retx = sc.faults.retx_ticks if sc.faults is not None else 3
                self.server.maintain(tick=i, deliverable=deliverable,
                                     retx_ticks=retx)
            packets = self.server.tick(deliverable, tick=i,
                                       overlap=self.async_loop)
            sent = self.server.per_client_nbytes(packets)
            from repro.core.updates import TOMBSTONE_NBYTES
            tomb_sent = np.zeros(C, np.int64)
            for _, pkt in packets:
                tomb_sent += pkt.tomb_counts().astype(np.int64) \
                    * TOMBSTONE_NBYTES

            # client step: delivery + ingest + SQ/LQ mode
            mode = np.full(C, -1, np.int8)
            step_span = obs_span("engine.client_step", cat="client")
            step_span.__enter__()
            for spec in sc.clients:
                cid, sess = spec.cid, self.sessions[spec.cid]
                if not active[cid]:
                    continue
                m = None
                for _, pkt in packets:
                    m = sess.step(t, pkt.packet_for(cid))
                if m is None:
                    m = sess.step(t)
                mode[cid] = 1 if m == "SQ" else 0
                # prune-on-unsubscribe: entries in zones the client left
                # are dead state it will never receive tombstones for
                subs = self.server.subscribed[cid]
                if not subs.all():
                    sess.prune_zones(self.server.grid, subs)
            step_span.__exit__(None, None, None)

            # upstream control plane: cumulative acks + resync requests
            # (clean link: reliable outside outages; fault transport:
            # seeded uplink loss draws)
            ctrl_span = obs_span("engine.control_plane", cat="sync")
            ctrl_span.__enter__()
            for spec in sc.clients:
                cid, sess = spec.cid, self.sessions[spec.cid]
                if not self.joined[cid]:
                    sess.drain_acks(), sess.drain_ctrl()   # gone: discard
                    continue
                if not sess.net.is_up(t):
                    continue            # buffered until the link is back
                for k, (z, ep, seq) in enumerate(sess.drain_acks()):
                    if sess.faults is not None \
                            and sess.faults.uplink_lost(1, cid, i, k, seq):
                        continue
                    self.server.ack(cid, z, ep, seq, tick=i)
                for k, (kind, z) in enumerate(sess.drain_ctrl()):
                    if sess.faults is not None \
                            and sess.faults.uplink_lost(2, cid, i, k, z):
                        continue
                    if kind == "resync":
                        self.server.request_resync(cid)
            ctrl_span.__exit__(None, None, None)

            # seeded query plan
            queried = np.zeros(C, np.int8)
            hit = np.full(C, -1, np.int8)
            q_ms = np.full(C, np.nan)
            classes = self._live_classes()
            query_span = obs_span("engine.queries", cat="query")
            query_span.__enter__()
            for spec in sc.clients:
                cid = spec.cid
                if not active[cid] or not len(classes):
                    continue
                rng = np.random.default_rng(
                    (sc.seed, 131 * i + cid))
                if rng.random() >= sc.query.prob:
                    continue
                target = int(classes[int(rng.integers(len(classes)))])
                emb = self._query_embed(target)
                if emb is None:
                    continue
                sess = self.sessions[cid]
                queried[cid] = 1
                E = sc.embed_dim
                if mode[cid] == 1:       # SQ over the fleet store
                    spec_q = Query(
                        embed=emb,
                        near=(jnp.asarray(spec.track.pose_at(t)),
                              jnp.asarray(sc.query.radius, jnp.float32)),
                        k=sc.query.k)
                    q_ms[cid] = sess.net.transfer_ms(
                        2 * E + sc.query.k * _SQ_ROW_B)
                    if self.query_hook is not None:
                        self.query_hook(cid, t, spec_q)
                    else:
                        res = self.server.query(spec_q)
                        hit[cid] = self._score_hit(res, target)
                else:                    # LQ on the device local map
                    res = sess.dev.query_spec(Query(embed=emb,
                                                    k=sc.query.k))
                    q_ms[cid] = lq_model_ms(
                        int(np.asarray(sess.dev.local.active).sum()),
                        self._lq_curve)
                    hit[cid] = self._score_hit(res, target)
            query_span.__exit__(None, None, None)

            # MODELed device power for this tick
            sq_qps = (queried * (mode == 1)) / sc.tick_s
            lq_qps = (queried * (mode == 0)) / sc.tick_s
            power = np.array([
                self.power.average_power(streaming=bool(active[c]),
                                         local_qps=float(lq_qps[c]),
                                         server_qps=float(sq_qps[c]))
                if active[c] else 0.0 for c in range(C)])

            if self.tick_hook is not None:
                self.tick_hook(t)

            # record
            st = self._store()
            down = np.array([self.sessions[c].down_bytes for c in range(C)],
                            np.int64)
            dlv = np.array([self.sessions[c].delivered for c in range(C)],
                           np.int32)
            dly = np.array([self.sessions[c].delayed for c in range(C)],
                           np.int32)
            from repro.core.store import deleted_mask
            rec["tick"].append(i)
            rec["events"].append((spawned, moved, removed))
            rec["gc_released"].append(gc_n)
            rec["server_live"].append(int(np.asarray(st.active).sum()))
            rec["server_tombstones"].append(
                int(np.asarray(deleted_mask(st)).sum()))
            rec["sent_bytes"].append(sent.astype(np.int64))
            rec["sent_tomb_bytes"].append(tomb_sent)
            rec["recv_bytes"].append(down - prev_down)
            rec["delivered"].append(dlv - prev_delivered)
            rec["delayed"].append(dly - prev_delayed)
            prev_down, prev_delivered, prev_delayed = down, dlv, dly
            rec["client_active"].append(active.copy())
            rec["client_live"].append(np.array(
                [int(np.asarray(self.sessions[c].dev.local.active).sum())
                 for c in range(C)], np.int32))
            rec["client_nbytes"].append(np.array(
                [local_map_nbytes(self.sessions[c].dev.local)
                 for c in range(C)], np.int64))
            rec["mode_sq"].append(mode.copy())
            rec["queried"].append(queried.copy())
            rec["query_hit"].append(hit.copy())
            rec["query_ms"].append(q_ms.copy())
            rec["power_w"].append(power)
            up = np.array([self.sessions[c].up_bytes for c in range(C)],
                          np.int64)
            flt = np.array([[self.sessions[c].lost,
                             self.sessions[c].dup_drops,
                             self.sessions[c].corrupt_drops,
                             self.sessions[c].resyncs]
                            for c in range(C)], np.int32)
            rec["up_bytes"].append(up - prev_up)
            rec["faults"].append(flt - prev_faults)
            prev_up, prev_faults = up, flt
            tick_wall = (_time.perf_counter() - wall0) * 1e3
            self.wall_ms.append(tick_wall)
            tick_span.__exit__(None, None, None)
            reg = obs_metrics.get_registry()
            if reg is not None:
                reg.histogram("engine_tick_ms").observe(tick_wall)
                reg.counter("engine_queries_total").inc(int(queried.sum()))

        return MetricsLog(**{f: np.asarray(v) for f, v in rec.items()},
                          wall_ms=self.wall_ms)

    # ------------------------------------------------------------------
    def _score_hit(self, res, target_cls: int) -> int:
        """Top-1 retrieval correctness against world ground truth."""
        if self.world is None:
            return -1
        oid = int(np.asarray(res.oids).ravel()[0])
        if oid == 0:
            return 0
        return int(self.world.labels.get(oid) == target_cls)


def run_scenario(scenario: Scenario, **kw) -> MetricsLog:
    """One-call convenience: build the engine and run it."""
    return ScenarioEngine(scenario, **kw).run()
