"""Deterministic scenario engine: dynamic scenes (object churn + tombstone
deletes), user/network/fleet traces, one discrete-event session loop.

The paper's headline device claims — sub-100 ms queries under network
drops, bounded memory at tens of thousands of objects, downstream bandwidth
∝ map changes (Sec. 3.2, Fig. 6) — only mean anything when the scene
*changes*.  This package makes the dynamic regime a first-class, replayable
workload: a seeded declarative ``Scenario`` (object lifecycle events, user
trajectories, network traces, fleet churn, knob schedule) driven by one
``ScenarioEngine`` loop that subsumes the ad-hoc session drivers
(examples/network_drop_session.py, server.fleet.FleetSimulator are thin
wrappers) and emits a structured, bit-replayable ``MetricsLog``.
"""
from repro.core.runtime import FaultModel
from repro.sim.scenario import (ClientSpec, CrashEvent, GridSpec, KnobEvent,
                                NetTrace, ObjectEvent, PoseTrack, QueryPlan,
                                Scenario, churn_scenario)
from repro.sim.world import WorldState
from repro.sim.engine import MetricsLog, ScenarioEngine, run_scenario
