"""BENCH run trajectories: append-only, git-sha-stamped benchmark history.

``benchmarks/run.py --json`` keeps writing ``BENCH_<suite>.json`` at the
repo root as the "latest" snapshot (unchanged contract), but each run now
ALSO appends one line to ``BENCH_history/<suite>.jsonl`` so the perf
trajectory across PRs is a first-class artifact instead of a sequence of
silent overwrites.  ``benchmarks/regression_gate.py`` reads this history
(or the committed root files) as its baseline.

Provenance (git sha, date) is **passed in by the CLI**, never sampled
here: the module stays pure so library callers (tests, the gate) control
exactly what gets stamped, and nothing in the replay-deterministic code
paths ever touches the clock or the git tree.
"""
from __future__ import annotations

import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[3]
HISTORY_DIR = REPO_ROOT / "BENCH_history"

__all__ = ["append_run", "load_history", "latest_run", "HISTORY_DIR"]


def _history_path(suite: str, history_dir=None) -> Path:
    return Path(history_dir or HISTORY_DIR) / f"{suite}.jsonl"


def append_run(suite: str, result: dict, *, git_sha: str, date: str,
               smoke: bool = False, history_dir=None) -> Path:
    """Append one benchmark run to ``BENCH_history/<suite>.jsonl``.

    ``git_sha``/``date`` are caller-supplied provenance strings (the CLI
    samples them once at process start).  Returns the history file path.
    """
    path = _history_path(suite, history_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    entry = {"suite": suite, "smoke": bool(smoke), "git_sha": git_sha,
             "date": date, "result": result}
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return path


def load_history(suite: str, *, history_dir=None,
                 smoke: bool | None = None) -> list:
    """All recorded runs for ``suite``, oldest first (optionally filtered
    to smoke / full runs).  Missing history -> []."""
    path = _history_path(suite, history_dir)
    if not path.exists():
        return []
    runs = [json.loads(line) for line in path.read_text().splitlines()
            if line.strip()]
    if smoke is not None:
        runs = [r for r in runs if bool(r.get("smoke")) == smoke]
    return runs


def latest_run(suite: str, *, history_dir=None,
               smoke: bool | None = None) -> dict | None:
    """The most recent recorded run (None when there is no history)."""
    runs = load_history(suite, history_dir=history_dir, smoke=smoke)
    return runs[-1] if runs else None
