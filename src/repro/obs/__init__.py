"""Unified observability: span tracing, metrics, BENCH trajectories.

Three pieces, one contract — *observation never perturbs the replay*:

* ``obs.trace``     — near-zero-overhead span tracer (context manager +
                      decorator, nested spans, optional JAX fencing) with
                      Chrome/Perfetto trace-event JSON export.
* ``obs.metrics``   — process-wide registry of counters / gauges /
                      fixed-bucket histograms with deterministic
                      percentile math and Prometheus-text / JSON export.
* ``obs.trajectory``— git-sha-stamped BENCH run history
                      (``BENCH_history/<suite>.jsonl``) feeding the
                      cross-PR regression gate
                      (``benchmarks/regression_gate.py``).

Wall-clock only ever flows INTO spans/metrics, never into the
deterministic ``MetricsLog`` replay contract (asserted by
``tests/test_obs.py::test_golden_replay_unperturbed_by_obs``).
"""
from repro.obs.metrics import (Histogram, MetricsRegistry, get_registry,
                               set_registry)
from repro.obs.trace import (Tracer, get_tracer, set_tracer, span, traced)

__all__ = ["Histogram", "MetricsRegistry", "get_registry", "set_registry",
           "Tracer", "get_tracer", "set_tracer", "span", "traced"]
