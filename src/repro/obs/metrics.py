"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Unifies the repo's ad-hoc accounting (``update_nbytes`` byte totals,
``ClientSession.up_bytes``/fault counters, engine tick wall times) behind
one API with two export formats (Prometheus text, JSON snapshot).

Determinism rules:

* Values flow INTO metrics; nothing ever flows back out into computation,
  so attaching a registry to a run cannot perturb a bit-exact replay.
* Histogram percentiles come from **fixed bucket bounds + integer counts**
  — pure arithmetic over recorded samples, no wall clock, no sampling.
  The percentile estimate is the *upper edge* of the bucket holding the
  rank-``ceil(p/100 * n)``-th sample (nearest-rank rule), so two runs that
  observe the same samples report identical percentiles to the bit.
* ``exact_percentiles`` computes nearest-rank percentiles over a raw
  sample list (used for the small ``wall_ms`` vectors where keeping every
  sample is cheap) — it always returns an actual observed sample.
"""
from __future__ import annotations

import bisect
import json
import math
from dataclasses import dataclass, field

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "set_registry", "exact_percentiles",
           "default_latency_buckets"]


def default_latency_buckets() -> tuple:
    """Log-spaced ms buckets, 10 us .. ~100 s: 5 per decade, fixed across
    runs so recorded histograms are comparable between PRs."""
    return tuple(round(10.0 ** (e / 5.0), 6) for e in range(-10, 26))


def exact_percentiles(samples, ps=(50, 95, 99)) -> dict:
    """Nearest-rank percentiles over raw samples (deterministic, returns
    actual observed values).  Empty input -> all-zero, n = 0."""
    out = {"n": len(samples)}
    xs = sorted(float(x) for x in samples)
    for p in ps:
        if not xs:
            out[f"p{p}"] = 0.0
            continue
        rank = max(int(math.ceil(p / 100.0 * len(xs))), 1)
        out[f"p{p}"] = xs[rank - 1]
    if xs:
        out["mean"] = sum(xs) / len(xs)
        out["max"] = xs[-1]
    else:
        out["mean"] = out["max"] = 0.0
    return out


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


# ---------------------------------------------------------------------------
@dataclass
class Counter:
    """Monotonic counter, one value per label set."""
    name: str
    help: str = ""
    values: dict = field(default_factory=dict)    # label key -> number

    def inc(self, v=1, **labels) -> None:
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0) + v

    def value(self, **labels):
        return self.values.get(_label_key(labels), 0)

    def total(self):
        return sum(self.values.values())


@dataclass
class Gauge:
    """Last-write-wins value, one per label set."""
    name: str
    help: str = ""
    values: dict = field(default_factory=dict)

    def set(self, v, **labels) -> None:
        self.values[_label_key(labels)] = v

    def value(self, **labels):
        return self.values.get(_label_key(labels), 0)


@dataclass
class Histogram:
    """Fixed-bucket histogram with deterministic percentile math.

    ``bounds`` are the inclusive upper edges of each bucket; samples above
    the last bound land in a +inf overflow bucket.  Bounds are fixed at
    construction, so the bucket layout — and therefore every percentile —
    is a pure function of the observed samples.
    """
    name: str
    help: str = ""
    bounds: tuple = field(default_factory=default_latency_buckets)
    series: dict = field(default_factory=dict)    # label key ->
    #                                               (counts list, sum, n)

    def _series(self, labels: dict):
        k = _label_key(labels)
        s = self.series.get(k)
        if s is None:
            s = self.series[k] = [[0] * (len(self.bounds) + 1), 0.0, 0]
        return s

    def observe(self, v, **labels) -> None:
        s = self._series(labels)
        s[0][bisect.bisect_left(self.bounds, v)] += 1
        s[1] += v
        s[2] += 1

    def count(self, **labels) -> int:
        k = _label_key(labels)
        return self.series[k][2] if k in self.series else 0

    def percentile(self, p: float, **labels) -> float:
        """Nearest-rank percentile from bucket counts: the upper edge of
        the bucket containing the rank-``ceil(p/100 * n)``-th sample (0.0
        for an empty series; +inf only if that sample overflowed the last
        bound).  For a single-sample series every percentile is that
        sample's bucket edge."""
        k = _label_key(labels)
        if k not in self.series:
            return 0.0
        counts, _, n = self.series[k]
        if n == 0:
            return 0.0
        rank = max(int(math.ceil(p / 100.0 * n)), 1)
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank:
                return self.bounds[i] if i < len(self.bounds) \
                    else float("inf")
        return float("inf")      # unreachable: seen == n >= rank

    def summary(self, **labels) -> dict:
        """{n, mean, p50, p95, p99} for one label set."""
        k = _label_key(labels)
        if k not in self.series or self.series[k][2] == 0:
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        _, tot, n = self.series[k]
        return {"n": n, "mean": tot / n,
                "p50": self.percentile(50, **labels),
                "p95": self.percentile(95, **labels),
                "p99": self.percentile(99, **labels)}


# ---------------------------------------------------------------------------
@dataclass
class MetricsRegistry:
    """Named metric registry; metrics are created on first use.

    ``counter/gauge/histogram`` return the existing instance when the name
    is already registered (help/bounds from the first registration win),
    so call sites don't need to coordinate.
    """
    counters: dict = field(default_factory=dict)
    gauges: dict = field(default_factory=dict)
    histograms: dict = field(default_factory=dict)

    def counter(self, name: str, help: str = "") -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name, help)
        return g

    def histogram(self, name: str, help: str = "",
                  bounds: tuple | None = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = Histogram(name, help) if bounds is None \
                else Histogram(name, help, bounds=tuple(bounds))
            self.histograms[name] = h
        return h

    def clear(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able snapshot: counters/gauges by label string, histograms
        as {n, mean, p50, p95, p99} summaries per label set."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, c in sorted(self.counters.items()):
            out["counters"][name] = {_label_str(k) or "_": v
                                     for k, v in sorted(c.values.items())}
        for name, g in sorted(self.gauges.items()):
            out["gauges"][name] = {_label_str(k) or "_": v
                                   for k, v in sorted(g.values.items())}
        for name, h in sorted(self.histograms.items()):
            out["histograms"][name] = {
                _label_str(k) or "_": h.summary(**dict(k))
                for k in sorted(h.series)}
        return out

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (counters/gauges plus histogram
        _bucket/_sum/_count series with cumulative ``le`` labels)."""
        lines = []
        for name, c in sorted(self.counters.items()):
            if c.help:
                lines.append(f"# HELP {name} {c.help}")
            lines.append(f"# TYPE {name} counter")
            for k, v in sorted(c.values.items()):
                lines.append(f"{name}{_label_str(k)} {v}")
        for name, g in sorted(self.gauges.items()):
            if g.help:
                lines.append(f"# HELP {name} {g.help}")
            lines.append(f"# TYPE {name} gauge")
            for k, v in sorted(g.values.items()):
                lines.append(f"{name}{_label_str(k)} {v}")
        for name, h in sorted(self.histograms.items()):
            if h.help:
                lines.append(f"# HELP {name} {h.help}")
            lines.append(f"# TYPE {name} histogram")
            for k in sorted(h.series):
                counts, tot, n = h.series[k]
                cum = 0
                for b, c in zip(h.bounds, counts):
                    cum += c
                    lk = list(k) + [("le", repr(float(b)))]
                    lines.append(f"{name}_bucket{_label_str(tuple(lk))} "
                                 f"{cum}")
                lk = list(k) + [("le", "+Inf")]
                lines.append(f"{name}_bucket{_label_str(tuple(lk))} {n}")
                lines.append(f"{name}_sum{_label_str(k)} {tot}")
                lines.append(f"{name}_count{_label_str(k)} {n}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# the process-wide registry (None = metrics off, the default)
# ---------------------------------------------------------------------------
_REGISTRY: MetricsRegistry | None = None


def get_registry() -> MetricsRegistry | None:
    return _REGISTRY


def set_registry(reg: MetricsRegistry | None) -> MetricsRegistry | None:
    """Install (or clear, with None) the process-wide registry; returns
    the previous one so callers can restore it."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, reg
    return prev
