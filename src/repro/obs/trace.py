"""Span tracer: nested wall-clock spans with Chrome trace-event export.

Design constraints (the reasons this file is small and boring):

* **Near-zero overhead when off.**  Instrumented hot paths call the
  module-level ``span(...)`` helper; with no tracer installed it returns a
  shared no-op singleton — one global load and one ``is None`` test per
  call site, no allocation.
* **Deterministic replay stays deterministic.**  Spans record wall clock,
  but only into the tracer's own buffer — never into any value the
  instrumented code returns.  Enabling tracing on a ``ScenarioEngine`` run
  leaves the ``MetricsLog`` bit-identical (tested).
* **Compile-safe.**  Spans wrap *dispatch boundaries* (host-side calls
  into jitted functions), never code inside a jitted body — a tracer call
  under ``jax.jit`` would trace once and lie forever.  For async dispatch
  the span can *fence*: hand the result pytree to ``Span.fence`` and — on
  a ``Tracer(fenced=True)`` — the exit timestamp is taken after
  ``jax.block_until_ready``, so a span covering ``_collect_fleet``
  measures the real device cost, not the dispatch enqueue.  Fencing is
  opt-in because the extra syncs serialize work that would otherwise
  overlap (it trades wall-clock overhead for attribution honesty).

Export is the Chrome trace-event JSON format (chrome://tracing, Perfetto
UI): complete events (``"ph": "X"``) with microsecond timestamps; nesting
is implicit from containment per (pid, tid).
"""
from __future__ import annotations

import functools
import json
import time
from dataclasses import dataclass, field

__all__ = ["Span", "Tracer", "get_tracer", "set_tracer", "span", "traced"]


class _NullSpan:
    """Shared no-op span: the disabled-path cost is one ``is None`` test."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, value):
        return value

    def set(self, **args):
        pass


_NULL_SPAN = _NullSpan()


@dataclass
class Span:
    """One open span; append-to-buffer happens at exit."""
    tracer: "Tracer"
    name: str
    cat: str
    t0: float = 0.0
    tid: int = 0
    args: dict = None
    _fence: object = None

    def __enter__(self):
        self.tid = self.tracer._depth
        self.tracer._depth += 1
        self.t0 = time.perf_counter()
        return self

    def fence(self, value):
        """Block on ``value`` (a JAX array/pytree) before the span closes —
        async dispatch must not make the stage look free."""
        self._fence = value
        return value

    def set(self, **args):
        """Attach key/value args shown in the trace viewer."""
        if self.args is None:
            self.args = {}
        self.args.update(args)

    def __exit__(self, *exc):
        if self._fence is not None and self.tracer.fenced:
            import jax
            jax.block_until_ready(self._fence)
        t1 = time.perf_counter()
        tr = self.tracer
        tr._depth -= 1
        tr.events.append((self.name, self.cat, self.t0, t1, self.tid,
                          self.args))
        return False


@dataclass
class Tracer:
    """Collects spans; export with ``chrome_trace()`` / ``save()``."""
    events: list = field(default_factory=list)   # (name, cat, t0, t1,
    #                                               depth, args)
    # fencing is opt-in: Tracer(fenced=True) blocks on each span's fenced
    # pytree before closing, charging async device work to the span that
    # dispatched it.  Off by default — the extra syncs serialize work that
    # would otherwise overlap, so the unfenced tracer stays in the <5%
    # overhead budget while the fenced one trades overhead for honesty.
    fenced: bool = False
    _depth: int = 0
    _origin: float = field(default_factory=time.perf_counter)

    def span(self, name: str, cat: str = "", **args) -> Span:
        return Span(self, name, cat, args=args or None)

    def clear(self) -> None:
        self.events.clear()
        self._depth = 0
        self._origin = time.perf_counter()

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Spans become complete events; the recorded nesting depth maps to
        ``tid`` so sibling stacks render as lanes and containment shows
        parent/child (Perfetto infers nesting from time containment per
        track, which holds by construction here: a child's [t0, t1] lies
        inside its parent's).
        """
        evs = []
        for name, cat, t0, t1, depth, args in self.events:
            ev = {"name": name, "cat": cat or "default", "ph": "X",
                  "pid": 1, "tid": 1,
                  "ts": (t0 - self._origin) * 1e6,
                  "dur": max((t1 - t0) * 1e6, 0.0),
                  "args": dict(args) if args else {}}
            ev["args"]["depth"] = depth
            evs.append(ev)
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)

    # ------------------------------------------------------------------
    def durations_ms(self, name: str | None = None) -> list:
        """[ms] span durations (optionally filtered by name) — the bridge
        from traces to metrics histograms."""
        return [(t1 - t0) * 1e3 for n, _, t0, t1, _, _ in self.events
                if name is None or n == name]


# ---------------------------------------------------------------------------
# the process-wide tracer (None = tracing off, the default)
# ---------------------------------------------------------------------------
_TRACER: Tracer | None = None


def get_tracer() -> Tracer | None:
    return _TRACER


def set_tracer(tracer: Tracer | None) -> Tracer | None:
    """Install (or clear, with None) the process-wide tracer; returns the
    previous one so callers can restore it."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def span(name: str, cat: str = "", **args):
    """Open a span on the process-wide tracer (no-op when tracing is off).

        with obs.span("fleet.collect", cat="sync", zone=z) as sp:
            pkt = sess.collect(...)
            sp.fence(pkt.batch)
    """
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return t.span(name, cat, **args)


def traced(name: str | None = None, cat: str = ""):
    """Decorator form: trace every call of ``fn`` as one span."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            t = _TRACER
            if t is None:
                return fn(*a, **kw)
            with t.span(label, cat):
                return fn(*a, **kw)
        return wrapper
    return deco
