"""AdamW with global-norm clipping and mixed-precision master params.

Model params may live in bf16; the optimizer keeps an fp32 master copy plus
fp32 moments.  Under the production mesh the master/moment trees are
additionally ZeRO-1 sharded over the data axis (see distributed/sharding.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    master: Any       # fp32 copy of params
    m: Any
    v: Any


def opt_state_specs(param_specs, ocfg: AdamWConfig) -> OptState:
    f32 = jax.tree.map(lambda l: cm.spec(l.shape, jnp.float32), param_specs)
    return OptState(step=cm.spec((), jnp.int32), master=f32, m=f32, v=f32)


def init_opt_state(params, ocfg: AdamWConfig) -> OptState:
    f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(jnp.zeros_like, f32)
    return OptState(step=jnp.zeros((), jnp.int32), master=f32,
                    m=zeros, v=jax.tree.map(jnp.zeros_like, f32))


def lr_schedule(step, ocfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(ocfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - ocfg.warmup_steps) /
                    max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return ocfg.lr * warm * (ocfg.min_lr_frac + (1 - ocfg.min_lr_frac) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


_NO_DECAY_SUFFIXES = ("scale", "bias", "A_log", "D", "dt_bias", "mix_mu",
                      "decay_base", "bonus_u")


def _decay_mask(path) -> bool:
    name = str(getattr(path[-1], "key", path[-1]))
    return not any(name.endswith(s) for s in _NO_DECAY_SUFFIXES)


def adamw_update(grads, opt: OptState, params, ocfg: AdamWConfig):
    """Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = lr_schedule(step, ocfg)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, g, m, v, mp):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        upd_ = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + ocfg.eps)
        if _decay_mask(path):
            upd_ = upd_ + ocfg.weight_decay * mp
        mp_new = mp - lr * upd_
        return m_new, v_new, mp_new

    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree.structure(grads)
    ms = jax.tree.leaves(opt.m)
    vs = jax.tree.leaves(opt.v)
    mps = jax.tree.leaves(opt.master)
    out_m, out_v, out_p = [], [], []
    for (path, g), m, v, mp in zip(flat, ms, vs, mps):
        m_new, v_new, mp_new = upd(path, g, m, v, mp)
        out_m.append(m_new)
        out_v.append(v_new)
        out_p.append(mp_new)
    new_master = jax.tree.unflatten(treedef, out_p)
    new_opt = OptState(step=step, master=new_master,
                       m=jax.tree.unflatten(treedef, out_m),
                       v=jax.tree.unflatten(treedef, out_v))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), new_master,
                              params)
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
