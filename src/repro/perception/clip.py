"""Mini-CLIP: a trainable two-tower embedder over the synthetic world.

The paper's MobileCLIP role, rebuilt small: an object tower over rendered
depth crops (the observation the mapping server actually has per detection)
and a text tower over caption tokens, trained with a symmetric InfoNCE loss.
examples/train_perception.py trains it and reports retrieval accuracy; the
OracleEmbedder remains the controlled backend for system benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.scenes import CLASS_NAMES, N_CLASSES
from repro.data.tokens import VOCAB, VOCAB_SIZE
from repro.models import common as cm

CROP = 16  # depth-crop resolution fed to the object tower


@dataclass(frozen=True)
class ClipConfig:
    embed_dim: int = 64
    width: int = 128
    depth: int = 2
    temperature_init: float = 0.07


def clip_param_specs(ccfg: ClipConfig) -> dict:
    w, e = ccfg.width, ccfg.embed_dim
    specs: dict = {
        "obj_in": cm.spec((CROP * CROP + 4, w), jnp.float32),
        "txt_embed": cm.spec((VOCAB_SIZE, w), jnp.float32),
        "logit_scale": cm.spec((), jnp.float32),
    }
    for t in ("obj", "txt"):
        for i in range(ccfg.depth):
            specs[f"{t}_w{i}"] = cm.spec((w, w), jnp.float32)
            specs[f"{t}_b{i}_bias"] = cm.spec((w,), jnp.float32)
        specs[f"{t}_out"] = cm.spec((w, e), jnp.float32)
    return specs


def init_clip_params(ccfg: ClipConfig, key: jax.Array):
    p = cm.init_from_specs(key, clip_param_specs(ccfg))
    p["logit_scale"] = jnp.log(1.0 / ccfg.temperature_init)
    return p


def _mlp(params, prefix, x, depth):
    for i in range(depth):
        x = jax.nn.gelu(x @ params[f"{prefix}_w{i}"] +
                        params[f"{prefix}_b{i}_bias"])
    x = x @ params[f"{prefix}_out"]
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def encode_object(params, crops, stats, ccfg: ClipConfig):
    """crops: [B, CROP, CROP] normalized depth; stats: [B, 4] (bbox h/w in
    pixels /100, mean depth, valid fraction)."""
    x = jnp.concatenate([crops.reshape(crops.shape[0], -1), stats], axis=-1)
    return _mlp(params, "obj", x @ params["obj_in"], ccfg.depth)


def encode_text(params, tokens, ccfg: ClipConfig):
    """tokens: [B, L] int32 (0-padded) -> mean-pooled tower."""
    emb = jnp.take(params["txt_embed"], tokens, axis=0)
    mask = (tokens > 0)[..., None]
    x = jnp.sum(emb * mask, axis=1) / jnp.maximum(mask.sum(axis=1), 1)
    return _mlp(params, "txt", x, ccfg.depth)


def clip_loss(params, batch, ccfg: ClipConfig):
    oe = encode_object(params, batch["crops"], batch["stats"], ccfg)
    te = encode_text(params, batch["tokens"], ccfg)
    scale = jnp.exp(params["logit_scale"])
    logits = scale * oe @ te.T
    labels = jnp.arange(logits.shape[0])
    li = -jnp.mean(jax.nn.log_softmax(logits, axis=1)[labels, labels])
    lt = -jnp.mean(jax.nn.log_softmax(logits, axis=0)[labels, labels])
    return 0.5 * (li + lt), {"scale": scale}


# ---------------------------------------------------------------------------
# data: (depth crop, class caption) pairs from rendered frames
# ---------------------------------------------------------------------------

def class_tokens(cid: int, max_len: int = 4) -> np.ndarray:
    words = f"find the {CLASS_NAMES[cid]}".split()
    ids = [VOCAB.get(w, 0) for w in words][:max_len]
    return np.asarray(ids + [0] * (max_len - len(ids)), np.int32)


def crop_from_frame(depth: np.ndarray, mask: np.ndarray):
    ys, xs = np.nonzero(mask)
    y0, y1, x0, x1 = ys.min(), ys.max() + 1, xs.min(), xs.max() + 1
    d = np.where(mask, depth, 0.0)[y0:y1, x0:x1]
    # nearest-resize to CROP x CROP
    iy = np.linspace(0, d.shape[0] - 1, CROP).astype(int)
    ix = np.linspace(0, d.shape[1] - 1, CROP).astype(int)
    crop = d[np.ix_(iy, ix)]
    mu = crop[crop > 0].mean() if (crop > 0).any() else 1.0
    stats = np.asarray([(y1 - y0) / 100.0, (x1 - x0) / 100.0, mu / 5.0,
                        float((crop > 0).mean())], np.float32)
    return (crop / max(mu, 1e-3)).astype(np.float32), stats


def pair_batches(scene, classes, *, batch: int, seed: int = 0, h=120, w=160,
                 n_frames: int = 60):
    """Yield contrastive batches with one object per distinct class."""
    from repro.data.scenes import scene_stream
    rng = np.random.default_rng(seed)
    samples: dict[int, list] = {}
    for fr in scene_stream(scene, n_frames=n_frames, keyframe_interval=3,
                           h=h, w=w):
        for oid in fr.visible_ids:
            cid = classes[int(oid)]
            crop, stats = crop_from_frame(fr.depth, fr.inst == oid)
            samples.setdefault(cid, []).append((crop, stats))
    cids = [c for c, v in samples.items() if len(v) >= 2]
    while True:
        picks = rng.choice(cids, size=min(batch, len(cids)), replace=False)
        crops, stats, toks = [], [], []
        for c in picks:
            i = rng.integers(len(samples[c]))
            crops.append(samples[c][i][0])
            stats.append(samples[c][i][1])
            toks.append(class_tokens(int(c)))
        yield {"crops": jnp.asarray(np.stack(crops)),
               "stats": jnp.asarray(np.stack(stats)),
               "tokens": jnp.asarray(np.stack(toks)),
               "class_ids": np.asarray(picks)}
