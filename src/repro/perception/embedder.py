"""Per-object semantic embeddings: the MobileCLIP role in the paper's
pipeline (Sec. 4.1).

Two interchangeable backends behind one interface:

* OracleEmbedder — deterministic class-conditioned unit vectors + per-view
  noise.  Retrieval quality is controlled and measurable (class cosine
  margins), which is exactly what the paper's system evaluation needs:
  quality differences must come from SYSTEM choices (downsampling, deferral),
  not model noise.
* ClipEmbedder — a real two-tower (object-crop tower + text tower) built
  from the repro model zoo and trained contrastively in
  examples/train_perception.py.  Used by the end-to-end demo.

Both produce unit-norm [E] embeddings for observations and queries.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.scenes import N_CLASSES


@dataclass
class OracleEmbedder:
    embed_dim: int = 512
    noise: float = 0.4
    seed: int = 7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        basis = rng.normal(size=(N_CLASSES, self.embed_dim))
        basis /= np.linalg.norm(basis, axis=1, keepdims=True)
        self._basis = jnp.asarray(basis, jnp.float32)

    def embed_observation(self, class_ids: jax.Array, key: jax.Array,
                          *, quality: jax.Array | float = 1.0) -> jax.Array:
        """[D] class ids -> [D, E] noisy view embeddings.  ``quality`` in
        (0,1] scales noise up for degraded observations (small/deferred
        objects observed anyway in ablations)."""
        base = self._basis[class_ids]
        # ``noise`` is the total perturbation norm (dim-independent): the
        # per-component sigma scales by 1/sqrt(E)
        sigma = self.noise / jnp.maximum(jnp.asarray(quality), 1e-3)
        sigma = sigma / (self.embed_dim ** 0.5)
        noise = jax.random.normal(key, base.shape) * sigma
        e = base + noise
        return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True),
                               1e-9)

    def embed_text(self, class_id: int) -> jax.Array:
        """Query-side embedding for 'where is my <class>?'."""
        return self._basis[class_id]
