"""Application-configurable resource-vs-quality knobs (paper Tab. 2).

Every SemanticXR innovation is parameterized here; defaults are the paper's
defaults.  Applications tune these per object class / deployment without
touching the perception or mapping pipeline (Sec. 3.4).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Knobs:
    # Query latency vs. device power (Sec. 3.2, query-mode switching)
    net_latency_switch_threshold_ms: float = 100.0

    # Object class mapping policy (Sec. 3.4)
    skip_mapping_set: tuple = ()             # class ids never mapped
    max_object_points_server: int = 2000     # geometry downsampling (Sec. 3.1)

    # Local map geometric detail vs. memory (Sec. 3.2)
    max_object_points_client: int = 200
    # optional per-class overrides: {class_id: client_points}
    class_point_overrides: tuple = ()

    # Local map freshness vs. downstream bandwidth (Sec. 3.2)
    local_map_update_frequency: int = 2      # frames between update ticks
    min_obs_before_sync: int = 2             # transient filtering

    # Upstream bandwidth budget (Sec. 3.3)
    min_mapping_bbox_area: int = 2000        # px, full-res units
    depth_downsampling_ratio: int = 5        # per spatial dim

    # Update prioritization (Sec. 3.2)
    priority_classes: tuple = ()             # app-declared task-relevant ids
    priority_class_boost: float = 1.0
    proximity_weight: float = 0.5
    semantic_weight: float = 0.5

    # capacities (fixed shapes for the JAX substrate)
    server_capacity: int = 4096              # max objects in the server map
    client_capacity: int = 512               # local map object budget
    max_detections_per_frame: int = 32

    def client_points_for(self, class_id: int) -> int:
        for cid, pts in self.class_point_overrides:
            if cid == class_id:
                return pts
        return self.max_object_points_client


DEFAULT_KNOBS = Knobs()
