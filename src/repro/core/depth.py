"""Object-level depth-mapping co-design (paper Sec. 3.3, Tab. 5).

Upstream, the device decimates depth by ``depth_downsampling_ratio`` per
spatial dim before transmission (a 5x5 stride ~ 25x fewer pixels, ~90% BW
cut).  Downstream quality loss is mitigated per OBJECT, not per frame:
detections whose projected bbox area (full-res units) falls below
``min_mapping_bbox_area`` are deferred — they re-enter once closer/bigger
observations give reliable depth.  RGB rides the hardware H.264 encoder.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geometry as geo
from repro.core.knobs import Knobs


def downsample_depth(depth: jax.Array, ratio: int) -> jax.Array:
    """Stride-decimate a [H, W] depth frame by ``ratio`` per dim."""
    if ratio <= 1:
        return depth
    return depth[::ratio, ::ratio]


def downsample_mask(mask: jax.Array, ratio: int) -> jax.Array:
    if ratio <= 1:
        return mask
    return mask[::ratio, ::ratio]


# The min_mapping_bbox_area knob default is expressed in the paper's
# full-sensor (720p) pixel units; bbox areas measured at a simulated render
# resolution are rescaled to these units before gating.
REF_SENSOR_PIXELS = 720 * 1280


def mapping_gate(area, knobs: Knobs, *, frame_pixels: int):
    """True if this observation is incorporated now; False = deferred
    (object-level mapping decision, Sec. 3.3).

    The ONE place the gate lives: ``area`` is the detection's projected
    bbox pixel area in the frame's own full-res units (scalar or [K]
    array, np or jnp), ``frame_pixels`` the frame's H*W.  Area is rescaled
    to full-sensor (720p) units so the knob default applies at any
    simulated render resolution; the gate only bites when depth is
    actually downsampled (ratio > 1) — at full depth there is no quality
    loss to defer for.
    """
    scaled = area * (REF_SENSOR_PIXELS / frame_pixels)
    keep = scaled >= knobs.min_mapping_bbox_area
    return keep | (knobs.depth_downsampling_ratio <= 1)


def mapping_gate_mask(mask_full: jax.Array, knobs: Knobs) -> jax.Array:
    """Gate straight from an instance mask (area via geometry.bbox_pixel_area)."""
    return mapping_gate(geo.bbox_pixel_area(mask_full), knobs,
                        frame_pixels=mask_full.size)


@dataclass(frozen=True)
class UpstreamRates:
    """Per-frame upstream payload (bytes) under the co-design."""
    rgb_bytes: float
    depth_bytes: float
    pose_bytes: float = 12 * 4        # 3x4 pose matrix fp32

    @property
    def total(self) -> float:
        return self.rgb_bytes + self.depth_bytes + self.pose_bytes


# Calibration constants (documented in EXPERIMENTS.md): the client streams
# only the keyframe subset to the mapping server (paper Sec. 6 "streams a
# subset of frames"), so the RGB share is the keyframe slice of the 5 Mbps
# H.264 stream; 16-bit depth packs losslessly at ~0.3x (smooth indoor
# ranges).  With these, the model reproduces the paper's Tab. 5 endpoints
# (26.4 Mbps no-downsampling, 2.5 Mbps at 5x5).
RGB_KEYFRAME_MBPS = 1.2
DEPTH_PACK = 0.3


def upstream_bytes_per_frame(h: int, w: int, knobs: Knobs, *,
                             fps: float = 30.0) -> UpstreamRates:
    r = knobs.depth_downsampling_ratio
    depth_px = (h // r) * (w // r) if r > 1 else h * w
    return UpstreamRates(rgb_bytes=RGB_KEYFRAME_MBPS * 1e6 / 8 / fps,
                         depth_bytes=2.0 * depth_px * DEPTH_PACK)


def upstream_mbps(h: int, w: int, knobs: Knobs, *, fps: float = 30.0,
                  keyframe_interval: int = 5) -> float:
    """Average upstream rate in Mbps (RGB keyframe share + depth + pose at
    the keyframe rate)."""
    rates = upstream_bytes_per_frame(h, w, knobs, fps=fps)
    per_sec = RGB_KEYFRAME_MBPS * 1e6 / 8 + \
        (rates.depth_bytes + rates.pose_bytes) * fps / keyframe_interval
    return per_sec * 8 / 1e6
