"""Object-level geometry: depth lifting, downsampling, centroids/bboxes.

TPU adaptation of the paper's geometry path: per-object point clouds live in
fixed-capacity masked buffers (capacity == the paper's max_object_points
knob), so downsampling is a deterministic gather instead of the CPU-side
random subsample — same quality role (Sec. 3.1), but shape-stable for
jit/vmap over the object batch.

The production ingest path no longer composes ``lift_depth`` ->
``downsample`` -> ``centroid_bbox`` per frame: kernels/lift_compact fuses
all three into one streaming pass with prefix-count destination indexing
(no per-object argsort, no [D, HW, 3] intermediate).  The functions here
remain the semantic ground truth (the fused path's oracle,
``ref.lift_compact_ref``, is built from them), the B / B+P Fig. 3 ablation
arms, and the merge/update primitives used outside frame ingest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lift_depth(depth: jax.Array, mask: jax.Array, intrinsics: jax.Array,
               pose: jax.Array, *, stride: int = 1, max_points: int = 2048):
    """Back-project masked depth pixels to world points.

    depth: [H, W] metres; mask: [H, W] bool (one object's instance mask);
    intrinsics: [fx, fy, cx, cy] at FULL resolution; pose: [4,4] cam->world.
    ``stride``: depth was downsampled by this factor per dim (Sec. 3.3) —
    pixel coordinates are scaled back to full-res units before projection.
    Returns (points [max_points,3], n [], valid mask [max_points]).
    """
    H, W = depth.shape
    fx, fy, cx, cy = intrinsics
    ys, xs = jnp.meshgrid(jnp.arange(H), jnp.arange(W), indexing="ij")
    xs_full = (xs.astype(jnp.float32) + 0.5) * stride
    ys_full = (ys.astype(jnp.float32) + 0.5) * stride
    z = depth
    valid = mask & (z > 1e-4)
    x = (xs_full - cx) / fx * z
    y = (ys_full - cy) / fy * z
    pts_cam = jnp.stack([x, y, z], axis=-1).reshape(-1, 3)
    valid = valid.reshape(-1)
    # world = R @ p + t
    pts_w = pts_cam @ pose[:3, :3].T + pose[:3, 3]
    # deterministic top-max_points selection of valid pixels
    order = jnp.argsort(~valid)                     # valid first, stable
    take = order[:max_points]
    pts = pts_w[take]
    ok = valid[take]
    n = jnp.minimum(valid.sum(), max_points)
    return jnp.where(ok[:, None], pts, 0.0), n.astype(jnp.int32), ok


def downsample(points: jax.Array, n: jax.Array, budget: int):
    """Cap a masked point cloud at ``budget`` points (Sec. 3.1).

    Deterministic stride gather over the valid prefix: index i of the output
    reads floor(i * n / budget) — uniform coverage, shape-stable.
    Returns (points [budget,3], n_out []).
    """
    P = points.shape[0]
    n = jnp.maximum(n, 1)
    ar = jnp.arange(budget)
    # stride-gather only when over budget; identity below budget (a
    # compressive gather at n < budget would duplicate-and-drop points)
    idx = jnp.where(n > budget, (ar * n) // budget, ar)
    idx = jnp.minimum(idx, P - 1)
    out = points[idx]
    n_out = jnp.minimum(n, budget)
    valid = jnp.arange(budget) < n_out
    return jnp.where(valid[:, None], out, 0.0), n_out.astype(jnp.int32)


def downsample_dyn(points: jax.Array, n: jax.Array, budget: jax.Array,
                   out_cap: int):
    """``downsample`` with a *traced* per-call budget (<= static out_cap).

    The budget only shapes the valid prefix, not the output buffer, so it
    can vary per row without retracing — updates._gather_batch uses this to
    honor per-class client point budgets (Knobs.class_point_overrides) in
    one gather over a mixed-class packet.  For budget == out_cap this is
    exactly ``downsample(points, n, out_cap)``.
    Returns (points [out_cap, 3], n_out []).
    """
    P = points.shape[0]
    n = jnp.maximum(n, 1)
    b = jnp.maximum(jnp.minimum(budget, out_cap), 1)
    ar = jnp.arange(out_cap)
    idx = jnp.where(n > b, (ar * n) // b, ar)
    idx = jnp.minimum(idx, P - 1)
    out = points[idx]
    n_out = jnp.minimum(n, b)
    valid = ar < n_out
    return jnp.where(valid[:, None], out, 0.0), n_out.astype(jnp.int32)


def centroid_bbox(points: jax.Array, n: jax.Array):
    """(centroid [3], bbox_min [3], bbox_max [3]) of a masked cloud."""
    P = points.shape[0]
    valid = (jnp.arange(P) < n)[:, None]
    denom = jnp.maximum(n, 1).astype(jnp.float32)
    c = jnp.sum(jnp.where(valid, points, 0.0), axis=0) / denom
    big = 1e9
    mn = jnp.min(jnp.where(valid, points, big), axis=0)
    mx = jnp.max(jnp.where(valid, points, -big), axis=0)
    mn = jnp.where(n > 0, mn, 0.0)
    mx = jnp.where(n > 0, mx, 0.0)
    return c, mn, mx


def merge_clouds(pts_a, n_a, pts_b, n_b, budget: int):
    """Merge two masked clouds and re-cap at budget (association merge).

    Validity is positional (``arange < n``), so "compact valid-a then
    valid-b" is just the concatenation of the two prefixes — the merged
    cloud's row i is ``a[i]`` for i < n_a else ``b[i - n_a]``.  Composing
    that with the downsample stride gather gives the whole merge as TWO
    gathers and a select: no [Pa+Pb] intermediate, no argsort compaction
    (the seed hot-spot, kept as ``merge_clouds_argsort`` below as the
    benchmark baseline).  Outputs are identical to the seed path whenever
    ``n_a <= budget`` — which the mapping pipeline guarantees by passing
    ``budget == max_object_points_server`` (the store row size bounding
    n_a).  Beyond that regime the seed path counted phantom valid points
    (its n included the part of cloud a past the budget crop) and read
    rows past the valid prefix, which this version does not reproduce.
    """
    Pa = min(budget, pts_a.shape[0])
    Pb = pts_b.shape[0]
    n_a = jnp.minimum(n_a, Pa)
    n = jnp.minimum(n_a + jnp.minimum(n_b, Pb), Pa + Pb).astype(jnp.int32)
    nn = jnp.maximum(n, 1)                      # downsample's empty-cloud quirk
    ar = jnp.arange(budget)
    idx = jnp.where(nn > budget, (ar * nn) // budget, ar)
    from_a = idx < n_a
    out = jnp.where(from_a[:, None],
                    pts_a[jnp.minimum(idx, Pa - 1)],
                    pts_b[jnp.clip(idx - n_a, 0, Pb - 1)])
    n_out = jnp.minimum(nn, budget)
    valid = ar < n_out
    return jnp.where(valid[:, None], out, 0.0), n_out.astype(jnp.int32)


def merge_clouds_argsort(pts_a, n_a, pts_b, n_b, budget: int):
    """Seed implementation of merge_clouds (argsort compaction) — the
    baseline for the association microbenchmark and equivalence tests."""
    both = jnp.concatenate([pts_a[:budget], pts_b], axis=0)
    # compact: valid-a first, then valid-b
    Pa = pts_a[:budget].shape[0]
    va = jnp.arange(Pa) < n_a
    vb = jnp.arange(pts_b.shape[0]) < n_b
    valid = jnp.concatenate([va, vb])
    order = jnp.argsort(~valid)
    both = both[order]
    n = (n_a + n_b).astype(jnp.int32)
    return downsample(both, jnp.minimum(n, both.shape[0]), budget)


def bbox_pixel_area(mask: jax.Array, stride: int = 1) -> jax.Array:
    """Projected bbox area of an instance mask, in FULL-res pixel units
    (min_mapping_bbox_area gate, Sec. 3.3)."""
    H, W = mask.shape
    ys = jnp.any(mask, axis=1)
    xs = jnp.any(mask, axis=0)
    def extent(v):
        idx = jnp.arange(v.shape[0])
        mn = jnp.min(jnp.where(v, idx, v.shape[0]))
        mx = jnp.max(jnp.where(v, idx, -1))
        return jnp.maximum(mx - mn + 1, 0)
    return extent(ys) * extent(xs) * (stride * stride)
