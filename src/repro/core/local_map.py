"""Object-level sparse local map (device side, paper Sec. 3.2).

Fixed-capacity per-object entries: embedding for query matching + a point
cloud further downsampled to the client budget.  Per-object memory is fixed,
so total device memory grows with retained objects, never with scene size.
When the map is full, admitting a higher-priority update evicts the
lowest-priority retained object (object-level update prioritization).

Priority = semantic relevance to app-declared interests
         + proximity to the user
         + app-declared class boosts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs


class LocalMap(NamedTuple):
    ids: jax.Array        # [cap] int32 (0 = empty)
    active: jax.Array     # [cap] bool
    embed: jax.Array      # [cap, E] f32
    label: jax.Array      # [cap] int32
    points: jax.Array     # [cap, Pc, 3] f16 — client point budget
    n_points: jax.Array   # [cap] int32
    centroid: jax.Array   # [cap, 3] f32
    version: jax.Array    # [cap] int32 — last synced server version
    priority: jax.Array   # [cap] f32


def init_local_map(knobs: Knobs, embed_dim: int) -> LocalMap:
    cap, Pc = knobs.client_capacity, knobs.max_object_points_client
    return LocalMap(
        ids=jnp.zeros((cap,), jnp.int32),
        active=jnp.zeros((cap,), bool),
        embed=jnp.zeros((cap, embed_dim), jnp.float32),
        label=jnp.zeros((cap,), jnp.int32),
        points=jnp.zeros((cap, Pc, 3), jnp.float16),
        n_points=jnp.zeros((cap,), jnp.int32),
        centroid=jnp.zeros((cap, 3), jnp.float32),
        version=jnp.zeros((cap,), jnp.int32),
        priority=jnp.zeros((cap,), jnp.float32),
    )


def local_map_nbytes(m: LocalMap) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in m))


def compute_priority(embed, label, centroid, *, user_pos, knobs: Knobs,
                     interest_embeds=None):
    """Priority score for update admission / eviction (Sec. 3.2)."""
    prox = 1.0 / (1.0 + jnp.linalg.norm(centroid - user_pos, axis=-1))
    score = knobs.proximity_weight * prox
    if interest_embeds is not None and interest_embeds.shape[0] > 0:
        sem = jnp.max(embed @ interest_embeds.T, axis=-1)
        score = score + knobs.semantic_weight * jnp.maximum(sem, 0.0)
    if knobs.priority_classes:
        boost = jnp.isin(label, jnp.asarray(knobs.priority_classes,
                                            jnp.int32))
        score = score + knobs.priority_class_boost * boost
    return score


class ObjectUpdate(NamedTuple):
    """One object's delta, as shipped over the downlink (see updates.py)."""
    oid: jax.Array        # [] int32
    embed: jax.Array      # [E] f32
    label: jax.Array      # [] int32
    points: jax.Array     # [Pc, 3] f16
    n_points: jax.Array   # [] int32
    centroid: jax.Array   # [3] f32
    version: jax.Array    # [] int32
    deleted: jax.Array = None   # [] bool — tombstone row: the device frees
    #                             the slot and retires the id (None = live)


class UpdateBatch(NamedTuple):
    """Struct-of-arrays update packet: U object deltas as one pytree.

    The wire format equivalent of ``list[ObjectUpdate]`` — built in one
    vmapped gather on the server (updates.collect_updates) and applied in one
    jitted scan on the device (apply_updates_batch).  ``valid`` masks padding
    rows: U is bucketed to a power of two so jit retraces stay bounded.
    """
    oid: jax.Array        # [U] int32
    embed: jax.Array      # [U, E] f32
    label: jax.Array      # [U] int32
    points: jax.Array     # [U, Pc, 3] f16
    n_points: jax.Array   # [U] int32
    centroid: jax.Array   # [U, 3] f32
    version: jax.Array    # [U] int32
    valid: jax.Array      # [U] bool — padding mask
    deleted: jax.Array = None   # [U] bool — tombstone rows (None = all live)


def _admit_one_slot(m: LocalMap, u: ObjectUpdate, priority: jax.Array,
                    enabled: jax.Array):
    """Core admission/eviction step shared by the single and batched paths;
    returns ``(map, touched_slot)`` — the slot this row wrote or freed, or
    -1 when the row was a no-op (stale, padding, unadmitted, or a tombstone
    for an unretained id).  The touched slots feed cluster-index
    maintenance (repro.index.ClusterIndex.update_slots) without a diff.

    A tombstone row (``u.deleted``) frees the matching slot instead of
    admitting: id retired, entry deactivated — the slot is immediately
    reusable by later rows of the same batch (scan order).  Tombstones for
    ids the map never retained are no-ops.

    Idempotent and order-tolerant per object: a row whose version is BELOW
    the retained entry's is stale (a duplicated or reordered delivery) and
    is dropped; an equal-version row rewrites the same bytes (a no-op on
    the payload, refreshing only the priority).  The hardened transport
    leans on this — replaying any suffix of a client's update stream must
    never regress the map."""
    is_del = jnp.asarray(False) if u.deleted is None else u.deleted
    # existing entry?
    hit = (m.ids == u.oid) & m.active
    has = hit.any()
    slot_existing = jnp.argmax(hit)
    # else: first free slot, or eviction candidate
    free = ~m.active
    has_free = free.any()
    slot_free = jnp.argmax(free)
    evict_pri = jnp.where(m.active, m.priority, jnp.inf)
    slot_evict = jnp.argmin(evict_pri)
    can_evict = priority > evict_pri[slot_evict]
    slot = jnp.where(has, slot_existing,
                     jnp.where(has_free, slot_free, slot_evict))
    stale = has & (u.version < m.version[slot_existing])
    admit = (has | has_free | can_evict) & enabled & ~is_del & ~stale
    erase = is_del & has & enabled & ~stale

    def free_slot(m: LocalMap) -> LocalMap:
        return m._replace(
            ids=m.ids.at[slot_existing].set(0),
            active=m.active.at[slot_existing].set(False),
            version=m.version.at[slot_existing].set(0),
            n_points=m.n_points.at[slot_existing].set(0),
            priority=m.priority.at[slot_existing].set(0.0))

    m = jax.lax.cond(erase, free_slot, lambda x: x, m)

    def write(m: LocalMap) -> LocalMap:
        return LocalMap(
            ids=m.ids.at[slot].set(u.oid),
            active=m.active.at[slot].set(True),
            embed=m.embed.at[slot].set(u.embed),
            label=m.label.at[slot].set(u.label),
            points=m.points.at[slot].set(u.points.astype(m.points.dtype)),
            n_points=m.n_points.at[slot].set(u.n_points),
            centroid=m.centroid.at[slot].set(u.centroid),
            version=m.version.at[slot].set(u.version),
            priority=m.priority.at[slot].set(priority),
        )

    m = jax.lax.cond(admit, write, lambda x: x, m)
    touched = jnp.where(erase, slot_existing,
                        jnp.where(admit, slot, -1)).astype(jnp.int32)
    return m, touched


def _admit_one(m: LocalMap, u: ObjectUpdate, priority: jax.Array,
               enabled: jax.Array) -> LocalMap:
    return _admit_one_slot(m, u, priority, enabled)[0]


def prune_slots(m: LocalMap, drop: jax.Array) -> LocalMap:
    """Deactivate every entry where ``drop`` [cap] is True (id retired,
    version forgotten, slot reusable).  The zone-leave staleness fix rides
    this: when a client unsubscribes from a zone, the entries whose
    centroids route there are pruned so a later re-join ships a clean
    catch-up instead of leaving dead objects answering local queries."""
    keep = ~drop
    return m._replace(
        ids=jnp.where(keep, m.ids, 0),
        active=m.active & keep,
        version=jnp.where(keep, m.version, 0),
        n_points=jnp.where(keep, m.n_points, 0),
        priority=jnp.where(keep, m.priority, 0.0))


def apply_update(m: LocalMap, u: ObjectUpdate, priority: jax.Array) -> LocalMap:
    """Admit one object update; evict lowest-priority entry if full and the
    newcomer outranks it. jit-able."""
    return _admit_one(m, u, priority, jnp.asarray(True))


def apply_updates_batch(m: LocalMap, batch: UpdateBatch,
                        priorities: jax.Array) -> LocalMap:
    """Apply a whole UpdateBatch in one jitted call (scan inside the jit).

    Semantically identical to folding ``apply_update`` over the batch rows in
    order — including eviction order — but a single XLA dispatch instead of
    one per object (tests/test_batched_equivalence.py holds the two equal).
    """
    def step(m: LocalMap, x):
        row, pri = x
        u = ObjectUpdate(oid=row.oid, embed=row.embed, label=row.label,
                         points=row.points, n_points=row.n_points,
                         centroid=row.centroid, version=row.version,
                         deleted=row.deleted)
        return _admit_one(m, u, pri, row.valid), None

    m, _ = jax.lax.scan(step, m, (batch, priorities))
    return m


def apply_updates_batch_slots(m: LocalMap, batch: UpdateBatch,
                              priorities: jax.Array):
    """``apply_updates_batch`` that also returns the touched slots [U]
    (written or freed row per batch entry, -1 for no-ops) — the O(changes)
    feed for cluster-index maintenance on the device ingest path."""
    def step(m: LocalMap, x):
        row, pri = x
        u = ObjectUpdate(oid=row.oid, embed=row.embed, label=row.label,
                         points=row.points, n_points=row.n_points,
                         centroid=row.centroid, version=row.version,
                         deleted=row.deleted)
        return _admit_one_slot(m, u, pri, row.valid)

    return jax.lax.scan(step, m, (batch, priorities))
