"""Incremental object association + merge (paper Sec. 2.3.1 / 3.1).

New per-frame detections are matched to existing map objects by combined
spatial proximity (centroid distance, normalized by bbox scale) and semantic
similarity (embedding cosine).  Matches merge in place (running-mean
embedding, re-downsampled merged geometry, version bump); misses insert new
objects; transient observations are pruned by obs_count gating downstream.

TPU adaptation: the per-detection greedy loop of the reference pipelines
becomes a batched cost matrix [max_detections, capacity] (an MXU matmul for
the cosine term, the pairwise-distance kernel in kernels/pairwise for the
spatial term) + a small sequential resolve over <=32 detections.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.store import ObjectStore


class Detections(NamedTuple):
    """Fixed-capacity batch of per-frame object observations."""
    embed: jax.Array      # [D, E] f32 unit-norm
    label: jax.Array      # [D] int32
    points: jax.Array     # [D, P, 3]
    n_points: jax.Array   # [D] int32
    valid: jax.Array      # [D] bool


def association_scores(store: ObjectStore, det: Detections, *,
                       spatial_sigma: float = 0.75):
    """[D, cap] combined match score in [0,1]; inactive slots = -inf."""
    cent_d = jax.vmap(lambda p, n: geo.centroid_bbox(p, n)[0])(
        det.points, det.n_points)                          # [D,3]
    dist2 = jnp.sum(
        jnp.square(cent_d[:, None, :] - store.centroid[None, :, :]), axis=-1)
    spatial = jnp.exp(-dist2 / (2 * spatial_sigma ** 2))   # [D,cap]
    semantic = det.embed @ store.embed.T                   # cosine, unit norm
    score = 0.5 * spatial + 0.5 * semantic
    score = jnp.where(store.active[None, :], score, -jnp.inf)
    score = jnp.where(det.valid[:, None], score, -jnp.inf)
    return score, cent_d


def associate(store: ObjectStore, det: Detections, *, frame: jax.Array,
              match_threshold: float = 0.6, point_budget: int = 2000,
              ema: float = 0.25) -> ObjectStore:
    """Associate one frame's detections into the store. jit-able.

    Scores are computed once as a batched [D, cap] matrix (the object-level
    parallelism claim: one MXU matmul instead of a per-object loop), then a
    short sequential resolve merges/inserts — detections within a frame come
    from instance segmentation and are distinct objects by construction.
    """
    score, cent_d = association_scores(store, det)
    D, cap = score.shape
    frame = jnp.asarray(frame, jnp.int32)
    point_budget = min(point_budget, store.points.shape[1])

    def step(st: ObjectStore, i):
        row = score[i]
        j = jnp.argmax(row)
        best = row[j]
        is_match = (best >= match_threshold) & det.valid[i]

        # --- merge path
        def merge(st: ObjectStore) -> ObjectStore:
            new_emb = (1 - ema) * st.embed[j] + ema * det.embed[i]
            new_emb = new_emb / jnp.maximum(jnp.linalg.norm(new_emb), 1e-9)
            mpts, mn_ = geo.merge_clouds(st.points[j], st.n_points[j],
                                         det.points[i], det.n_points[i],
                                         point_budget)
            c, mn, mx = geo.centroid_bbox(mpts, mn_)
            return st._replace(
                embed=st.embed.at[j].set(new_emb),
                points=st.points.at[j].set(mpts),
                n_points=st.n_points.at[j].set(mn_),
                centroid=st.centroid.at[j].set(c),
                bbox_min=st.bbox_min.at[j].set(mn),
                bbox_max=st.bbox_max.at[j].set(mx),
                obs_count=st.obs_count.at[j].add(1),
                version=st.version.at[j].add(1),
                last_seen=st.last_seen.at[j].set(frame),
            )

        # --- insert path (first free slot)
        def insert(st: ObjectStore) -> ObjectStore:
            free = jnp.argmin(st.active)       # first False
            can = ~st.active[free] & det.valid[i]
            pts, n = geo.downsample(det.points[i], det.n_points[i],
                                    point_budget)
            c, mn, mx = geo.centroid_bbox(pts, n)

            def do(st: ObjectStore) -> ObjectStore:
                return st._replace(
                    ids=st.ids.at[free].set(st.next_id),
                    active=st.active.at[free].set(True),
                    embed=st.embed.at[free].set(det.embed[i]),
                    label=st.label.at[free].set(det.label[i]),
                    points=st.points.at[free].set(pts),
                    n_points=st.n_points.at[free].set(n),
                    centroid=st.centroid.at[free].set(c),
                    bbox_min=st.bbox_min.at[free].set(mn),
                    bbox_max=st.bbox_max.at[free].set(mx),
                    obs_count=st.obs_count.at[free].set(1),
                    version=st.version.at[free].set(1),
                    last_seen=st.last_seen.at[free].set(frame),
                    next_id=st.next_id + 1,
                )
            return jax.lax.cond(can, do, lambda s: s, st)

        st = jax.lax.cond(is_match, merge, insert, st)
        return st, None

    store, _ = jax.lax.scan(step, store, jnp.arange(D))
    return store


def prune_transients(store: ObjectStore, *, frame: jax.Array,
                     min_obs: int = 2, max_age: int = 30) -> ObjectStore:
    """Deactivate objects never confirmed by repeat observation (Sec. 2.3.1):
    an object seen fewer than ``min_obs`` times and not re-observed within
    ``max_age`` frames is dropped as a transient detection."""
    frame = jnp.asarray(frame, jnp.int32)
    stale = (frame - store.last_seen > max_age) & (store.obs_count < min_obs)
    return store._replace(active=store.active & ~stale)
