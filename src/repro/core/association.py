"""Incremental object association + merge (paper Sec. 2.3.1 / 3.1).

New per-frame detections are matched to existing map objects by combined
spatial proximity (centroid distance, normalized by bbox scale) and semantic
similarity (embedding cosine).  Matches merge in place (running-mean
embedding, re-downsampled merged geometry, version bump); misses insert new
objects; transient observations are pruned by obs_count gating downstream.

TPU adaptation: the per-detection greedy loop of the reference pipelines
becomes a batched cost matrix [max_detections, capacity] (an MXU matmul for
the cosine term, the pairwise-distance kernel in kernels/pairwise for the
spatial term) + a fully batched resolve: argmax per detection, within-frame
conflict resolution (detections are distinct objects by construction, so at
most one detection may merge into a store slot), one vmapped merge over the
detection batch, and one scatter per store field.  No per-detection scan —
the whole frame is a single XLA dispatch under jit.

``associate_reference`` keeps the original sequential-scan semantics as the
equivalence oracle (tests/test_batched_equivalence.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core import store as store_mod
from repro.core.store import ObjectStore


class Detections(NamedTuple):
    """Fixed-capacity batch of per-frame object observations."""
    embed: jax.Array      # [D, E] f32 unit-norm
    label: jax.Array      # [D] int32
    points: jax.Array     # [D, P, 3]
    n_points: jax.Array   # [D] int32
    valid: jax.Array      # [D] bool


def association_scores(store: ObjectStore, det: Detections, *,
                       spatial_sigma: float = 0.75, det_centroid=None):
    """[D, cap] combined match score in [0,1]; inactive slots = -inf.

    ``det_centroid`` ([D, 3]) skips the per-detection centroid pass when
    the caller already has it — the fused lift kernel (kernels/lift_compact)
    folds centroid accumulation into its streaming sweep, so the ingest
    path never recomputes it here."""
    if det_centroid is not None:
        cent_d = det_centroid
    else:
        cent_d = jax.vmap(lambda p, n: geo.centroid_bbox(p, n)[0])(
            det.points, det.n_points)                      # [D,3]
    dist2 = jnp.sum(
        jnp.square(cent_d[:, None, :] - store.centroid[None, :, :]), axis=-1)
    spatial = jnp.exp(-dist2 / (2 * spatial_sigma ** 2))   # [D,cap]
    semantic = det.embed @ store.embed.T                   # cosine, unit norm
    score = 0.5 * spatial + 0.5 * semantic
    score = jnp.where(store.active[None, :], score, -jnp.inf)
    score = jnp.where(det.valid[:, None], score, -jnp.inf)
    return score, cent_d


def associate(store: ObjectStore, det: Detections, *, frame: jax.Array,
              match_threshold: float = 0.6, point_budget: int = 2000,
              ema: float = 0.25, det_centroid=None) -> ObjectStore:
    """Associate one frame's detections into the store. jit-able.

    Fully batched resolve — no per-detection scan:

      1. argmax over the [D, cap] score matrix picks each detection's best
         existing object; within-frame conflicts (two detections claiming the
         same slot) are resolved to the highest-scoring claimant, losers fall
         through to the insert path (detections in one frame come from
         instance segmentation and are distinct objects by construction).
      2. merge values (embedding EMA, merged+recapped cloud, centroid/bbox)
         are computed for the whole detection batch with one vmap.
      3. inserts are assigned free slots in detection order (matching the
         sequential semantics: the r-th inserting detection takes the r-th
         free slot by ascending index and id ``next_id + r``).
      4. each store field is written with ONE scatter; rows that neither
         merge nor insert target index ``cap``, which JAX scatter drops.
    """
    score, _ = association_scores(store, det, det_centroid=det_centroid)
    D, cap = score.shape
    frame = jnp.asarray(frame, jnp.int32)
    point_budget = min(point_budget, store.points.shape[1])

    # --- 1. resolve matches + within-frame conflicts
    j_star = jnp.argmax(score, axis=1)                          # [D]
    best = jnp.take_along_axis(score, j_star[:, None], 1)[:, 0]
    wants = (best >= match_threshold) & det.valid
    claim = wants[:, None] & (j_star[:, None] == jnp.arange(cap)[None, :])
    claim_score = jnp.where(claim, best[:, None], -jnp.inf)     # [D, cap]
    winner = jnp.argmax(claim_score, axis=0)                    # [cap]
    is_match = wants & (winner[j_star] == jnp.arange(D))

    # --- 2. geometry for the whole batch with ONE vmapped merge: selecting
    # the inputs (store cloud for matches, an empty n_a=0 cloud for inserts,
    # under which merge_clouds degenerates to downsample(det.points)) is
    # cheaper than computing both the merge and insert variants per row.
    tgt_emb = store.embed[j_star]                               # [D, E]
    memb = (1 - ema) * tgt_emb + ema * det.embed
    memb = memb / jnp.maximum(
        jnp.linalg.norm(memb, axis=-1, keepdims=True), 1e-9)
    n_a = jnp.where(is_match, store.n_points[j_star], 0)
    npts, nn = jax.vmap(
        lambda pa, na, pb, nb: geo.merge_clouds(pa, na, pb, nb, point_budget)
    )(store.points[j_star], n_a, det.points, det.n_points)
    nc, nmn, nmx = jax.vmap(geo.centroid_bbox)(npts, nn)

    # --- 3. free-slot assignment for inserts in detection order.  A slot
    # is free only when neither live nor tombstoned: a pending deletion
    # still owns its slot until the protocol retires it
    # (store.release_tombstones) — reusing it would hide the new object
    # behind clients' synced versions.
    occupied = store.active | store_mod.deleted_mask(store)
    do_insert = det.valid & ~is_match
    rank = jnp.maximum(jnp.cumsum(do_insert) - 1, 0)            # [D]
    free_order = jnp.argsort(occupied)          # stable: free slots, asc idx
    n_free = (~occupied).sum()
    ins_ok = do_insert & (jnp.cumsum(do_insert) - 1 < n_free)
    ins_slot = free_order[jnp.minimum(rank, cap - 1)]

    # --- 4. one scatter per field; non-writing rows hit index cap (dropped)
    tgt = jnp.where(is_match, j_star, jnp.where(ins_ok, ins_slot, cap))
    new_emb = jnp.where(is_match[:, None], memb, det.embed)
    new_obs = jnp.where(is_match, store.obs_count[j_star] + 1, 1)
    new_ver = jnp.where(is_match, store.version[j_star] + 1, 1)
    new_ids = jnp.where(is_match, store.ids[j_star], store.next_id + rank)
    n_inserted = jnp.minimum(do_insert.sum(), n_free).astype(jnp.int32)
    return store._replace(
        ids=store.ids.at[tgt].set(new_ids),
        active=store.active.at[tgt].set(True),
        embed=store.embed.at[tgt].set(new_emb),
        label=store.label.at[tgt].set(
            jnp.where(is_match, store.label[j_star], det.label)),
        points=store.points.at[tgt].set(npts),
        n_points=store.n_points.at[tgt].set(nn),
        centroid=store.centroid.at[tgt].set(nc),
        bbox_min=store.bbox_min.at[tgt].set(nmn),
        bbox_max=store.bbox_max.at[tgt].set(nmx),
        obs_count=store.obs_count.at[tgt].set(new_obs),
        version=store.version.at[tgt].set(new_ver),
        last_seen=store.last_seen.at[tgt].set(frame),
        next_id=store.next_id + n_inserted,
    )


def associate_reference(store: ObjectStore, det: Detections, *,
                        frame: jax.Array, match_threshold: float = 0.6,
                        point_budget: int = 2000,
                        ema: float = 0.25) -> ObjectStore:
    """Seed sequential-scan associate — the equivalence oracle for the
    batched path above (identical semantics on conflict-free frames)."""
    score, cent_d = association_scores(store, det)
    D, cap = score.shape
    frame = jnp.asarray(frame, jnp.int32)
    point_budget = min(point_budget, store.points.shape[1])

    def step(st: ObjectStore, i):
        row = score[i]
        j = jnp.argmax(row)
        best = row[j]
        is_match = (best >= match_threshold) & det.valid[i]

        # --- merge path
        def merge(st: ObjectStore) -> ObjectStore:
            new_emb = (1 - ema) * st.embed[j] + ema * det.embed[i]
            new_emb = new_emb / jnp.maximum(jnp.linalg.norm(new_emb), 1e-9)
            mpts, mn_ = geo.merge_clouds_argsort(
                st.points[j], st.n_points[j], det.points[i],
                det.n_points[i], point_budget)
            c, mn, mx = geo.centroid_bbox(mpts, mn_)
            return st._replace(
                embed=st.embed.at[j].set(new_emb),
                points=st.points.at[j].set(mpts),
                n_points=st.n_points.at[j].set(mn_),
                centroid=st.centroid.at[j].set(c),
                bbox_min=st.bbox_min.at[j].set(mn),
                bbox_max=st.bbox_max.at[j].set(mx),
                obs_count=st.obs_count.at[j].add(1),
                version=st.version.at[j].add(1),
                last_seen=st.last_seen.at[j].set(frame),
            )

        # --- insert path (first free slot)
        def insert(st: ObjectStore) -> ObjectStore:
            free = jnp.argmin(st.active)       # first False
            can = ~st.active[free] & det.valid[i]
            pts, n = geo.downsample(det.points[i], det.n_points[i],
                                    point_budget)
            c, mn, mx = geo.centroid_bbox(pts, n)

            def do(st: ObjectStore) -> ObjectStore:
                return st._replace(
                    ids=st.ids.at[free].set(st.next_id),
                    active=st.active.at[free].set(True),
                    embed=st.embed.at[free].set(det.embed[i]),
                    label=st.label.at[free].set(det.label[i]),
                    points=st.points.at[free].set(pts),
                    n_points=st.n_points.at[free].set(n),
                    centroid=st.centroid.at[free].set(c),
                    bbox_min=st.bbox_min.at[free].set(mn),
                    bbox_max=st.bbox_max.at[free].set(mx),
                    obs_count=st.obs_count.at[free].set(1),
                    version=st.version.at[free].set(1),
                    last_seen=st.last_seen.at[free].set(frame),
                    next_id=st.next_id + 1,
                )
            return jax.lax.cond(can, do, lambda s: s, st)

        st = jax.lax.cond(is_match, merge, insert, st)
        return st, None

    store, _ = jax.lax.scan(step, store, jnp.arange(D))
    return store


def prune_transients(store: ObjectStore, *, frame: jax.Array,
                     min_obs: int = 2, max_age: int = 30) -> ObjectStore:
    """Deactivate objects never confirmed by repeat observation (Sec. 2.3.1):
    an object seen fewer than ``min_obs`` times and not re-observed within
    ``max_age`` frames is dropped as a transient detection."""
    frame = jnp.asarray(frame, jnp.int32)
    stale = (frame - store.last_seen > max_age) & (store.obs_count < min_obs)
    return store._replace(active=store.active & ~stale)
