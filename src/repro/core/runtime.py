"""Device-cloud runtime: network channel, power model, client, session loop.

The container has one machine, so the device-cloud boundary is simulated with
explicit models; every byte that crosses it is accounted by the real
serialized sizes from updates.py / depth.py.

NetworkModel — RTT + bandwidth + scheduled outage windows (paper Sec. 4.3:
~20 ms good, ~66 ms degraded, full outage).

PowerModel — the container cannot read watts; coefficients are calibrated to
the paper's OWN Jetson measurements (Fig. 7: idle 8.6 W, +2% streaming,
+1.2 W at 1 query/3 s, 13.23 W at 14.7 q/s continuous) and clearly labeled a
MODEL in EXPERIMENTS.md.  Energy per local query is derived from the
continuous-rate measurement: (13.23-8.6) W / 14.7 q/s = 0.315 J/query;
streaming power from the +2% figure.
"""
from __future__ import annotations

import copy
import functools
import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import query as query_mod
from repro.core.knobs import Knobs
from repro.core.local_map import (LocalMap, apply_update,
                                  apply_updates_batch_slots,
                                  compute_priority, init_local_map,
                                  local_map_nbytes, prune_slots)
from repro.core.store import ObjectStore
from repro.core.updates import (ACK_NBYTES, RESYNC_NBYTES, SyncState,
                                collect_updates, init_sync)
from repro.obs import metrics as obs_metrics
from repro.obs.trace import traced as obs_traced


# ---------------------------------------------------------------------------
@dataclass
class NetworkModel:
    rtt_ms: float = 20.0
    bandwidth_mbps: float = 200.0
    outages: tuple = ()            # ((t_start, t_end) seconds, ...)

    def is_up(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.outages)

    def transfer_ms(self, nbytes: float) -> float:
        return self.rtt_ms + nbytes * 8 / (self.bandwidth_mbps * 1e6) * 1e3

    def delivery_time(self, t: float, nbytes: float) -> float | None:
        """Completion time of a transfer started at ``t``.

        A transfer whose window straddles an outage start does NOT complete
        at pre-outage latency: progress stalls through each outage window
        and resumes after it.  Returns None when the link is down at send
        time (nothing is put in flight).
        """
        if not self.is_up(t):
            return None
        remaining = self.transfer_ms(nbytes) * 1e-3
        cur = t
        for a, b in sorted(self.outages):
            if b <= cur:
                continue
            gap = max(a - cur, 0.0)
            if gap >= remaining:
                return cur + remaining
            remaining -= gap
            cur = b
        return cur + remaining

    def measured_latency_ms(self, t: float) -> float:
        """What the client's RGB-D stream monitor observes (Sec. 3.2)."""
        return float("inf") if not self.is_up(t) else self.rtt_ms


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultModel:
    """Seeded hostile-network fault injection + hardened-protocol knobs.

    Outage windows (NetworkModel) model a *clean* link going away; this
    models the link misbehaving while nominally up: per-packet loss,
    duplication, reordering (bounded extra delay on a copy), and
    truncation/corruption (checksum mismatch at the receiver -> drop).
    Every draw is keyed on (seed, stream tag, client, zone, epoch, seq), so
    a scenario replays its faults bit-identically — chaos runs are as
    deterministic as clean ones.

    The protocol knobs ride here too: the client's gap-detection resync
    timeout (exponential backoff, capped) and the server's retransmit
    timeout in ticks (oldest unacked in-flight packet older than this ->
    roll the client's sync vectors back to its acked state and re-ship
    under a bumped epoch)."""
    seed: int = 0
    loss_prob: float = 0.0
    dup_prob: float = 0.0
    reorder_prob: float = 0.0
    reorder_jitter_s: float = 2.0
    corrupt_prob: float = 0.0
    # hardened-protocol knobs
    resync_timeout_s: float = 2.0
    resync_backoff_cap_s: float = 16.0
    retx_ticks: int = 3

    def packet_draws(self, cid: int, zone: int, epoch: int,
                     seq: int) -> np.ndarray:
        """[9] uniform draws for one downlink packet, a fixed layout so
        branch-free replay holds: [dup?, loss c0, loss c1, reorder c0,
        reorder c1, jitter c0, jitter c1, corrupt c0, corrupt c1]."""
        rng = np.random.default_rng((self.seed, 3, cid, zone,
                                     max(epoch, 0), seq))
        return rng.random(9)

    def uplink_lost(self, tag: int, cid: int, tick: int, a: int,
                    b: int) -> bool:
        """Loss draw for one upstream control frame (ack/resync)."""
        if self.loss_prob <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, 5, tag, cid, tick, a, b))
        return bool(rng.random() < self.loss_prob)


@dataclass
class PowerModel:
    idle_w: float = 8.6
    streaming_w: float = 0.17          # ~2% over idle (paper Sec. 5.6)
    joules_per_local_query: float = 0.315   # (13.23-8.6)/14.7
    sq_overhead_w: float = 0.02        # tx/rx of a text query is negligible

    def average_power(self, *, streaming: bool, local_qps: float = 0.0,
                      server_qps: float = 0.0) -> float:
        p = self.idle_w
        if streaming:
            p += self.streaming_w
        p += self.joules_per_local_query * local_qps
        p += self.sq_overhead_w * server_qps
        return p

    def on_device_mapping_power(self) -> float:
        """Full pipeline on device (paper: ~50 W in MAXN, seconds/frame)."""
        return 50.0


# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _client_fns(knobs: Knobs, use_pallas: bool, donate: bool = False):
    """Jitted device-side functions, shared by every DeviceClient with the
    same knobs — a C-client fleet compiles each step once, not C times.

    ``donate=True`` donates the LocalMap argument of the batched ingest:
    the pre-ingest map is dead once ``DeviceClient.ingest`` rebinds
    ``self.local``, so apply_updates_batch writes the new map in place
    instead of allocating a full copy per packet.  Byte-identical results
    (tests/test_serving_loop.py); opt-in because oracle tests re-apply
    packets to a saved pre-ingest map."""
    def query(m, e):           # LQ: the declarative engine's fused dispatch
        return query_mod.execute_query(
            m, query_mod.Query(embed=e, k=5), use_pallas=use_pallas)
    apply_one = jax.jit(apply_update)

    def _ingest_fn(m, batch, user_pos, interest_embeds):
        pri = compute_priority(batch.embed, batch.label, batch.centroid,
                               user_pos=user_pos, knobs=knobs,
                               interest_embeds=interest_embeds)
        # (map, touched slots [U]) — the slots feed cluster-index
        # maintenance when the client has one enabled
        return apply_updates_batch_slots(m, batch, pri)
    ingest = jax.jit(_ingest_fn, donate_argnums=(0,)) if donate \
        else jax.jit(_ingest_fn)
    return query, apply_one, ingest


@dataclass
class DeviceClient:
    knobs: Knobs
    embed_dim: int
    local: LocalMap = None
    use_pallas: bool = False
    donate: bool = False               # in-place batched ingest (the old
    #                                    map is donated; see _client_fns)
    cluster_index: object = None       # repro.index.ClusterIndex | None
    # measured stats
    lq_count: int = 0
    sq_count: int = 0

    def __post_init__(self):
        if self.local is None:
            self.local = init_local_map(self.knobs, self.embed_dim)
        self._query, self._apply, self._ingest = _client_fns(
            self.knobs, self.use_pallas, self.donate)

    def enable_index(self, **kw) -> None:
        """Attach a cluster-summary index over the local map; from then on
        every ingest maintains it from the batch's touched slots and
        ``query_spec`` plans coarse-to-fine once the map is big enough."""
        from repro.index import ClusterIndex
        self.cluster_index = ClusterIndex.for_target(self.local, **kw)

    def ingest(self, packet, *, user_pos, interest_embeds=None):
        """Apply a whole UpdatePacket in ONE jitted dispatch: batched
        compute_priority + apply_updates_batch (scan inside the jit) —
        vs the seed's per-object apply_update loop (N dispatches/tick)."""
        if packet is None or packet.count == 0:
            return
        self.local, touched = self._ingest(self.local, packet.batch,
                                           user_pos, interest_embeds)
        if self.cluster_index is not None:
            t = np.unique(np.asarray(touched))
            self.cluster_index.update_slots(self.local, t[t >= 0])

    def ingest_sequential(self, packet, *, user_pos, interest_embeds=None):
        """Seed per-object ingest path — kept as the microbenchmark baseline
        and the equivalence oracle for the batched path."""
        for u in packet.updates:
            pri = compute_priority(u.embed[None], u.label[None],
                                   u.centroid[None], user_pos=user_pos,
                                   knobs=self.knobs,
                                   interest_embeds=interest_embeds)[0]
            self.local = self._apply(self.local, u, pri)

    def memory_bytes(self) -> int:
        return local_map_nbytes(self.local)

    def query(self, embed: jax.Array):
        """Embedding-only LQ (top-5 cosine) — the paper's Fig. 4/5 path."""
        res = self._query(self.local, embed)
        jax.block_until_ready(res.scores)
        self.lq_count += 1
        return res

    def query_spec(self, spec):
        """Declarative LQ: run a full ``core.query.Query`` (spatial +
        attribute predicates, score combination) against the local map as
        one fused dispatch — coarse-to-fine through ``cluster_index`` when
        one is enabled and the map has outgrown the flat sweep."""
        res = query_mod.execute_query(self.local, spec,
                                      use_pallas=self.use_pallas,
                                      index=self.cluster_index)
        jax.block_until_ready(res.scores)
        self.lq_count += 1
        return res


# ---------------------------------------------------------------------------
@dataclass
class OutageBuffer:
    """O(1) stand-in for the packets a client missed during an outage.

    The sync vector is the real buffer: it already encodes exactly what the
    client is owed, and ``flush_buffer`` re-collects against the CURRENT
    store so intermediate versions coalesce into one packet.  Retaining the
    per-tick packets themselves (the seed behavior) grew without bound over
    a long outage for zero information gain."""
    since_tick: int                 # first tick the client missed
    ticks: int = 0                  # how many update ticks were skipped

    def __len__(self) -> int:       # truthiness/len compat with the old list
        return 1 if self.ticks else 0


@dataclass
class CloudService:
    """Server side of the split: map store + per-client sync + SQ engine."""
    knobs: Knobs
    store_ref: object                      # MappingServer (owns the store)
    sync: SyncState = None
    buffered: OutageBuffer = None          # coalesced outage state (O(1))
    tick: int = 0

    def __post_init__(self):
        if self.sync is None:
            self.sync = init_sync(self.knobs.server_capacity)
        if self.buffered is None:
            self.buffered = OutageBuffer(since_tick=0)
        self._query = lambda st, e: query_mod.execute_query(
            st, query_mod.Query(embed=e, k=5))

    def update_tick(self, *, network_up: bool, full_map: bool = False,
                    priorities=None):
        """Run one update tick; returns the packet that reached the device
        (None during outage — the tick coalesces into the O(1) OutageBuffer
        and the sync vector stays put, so reconnection ships one packet
        covering everything missed, Sec. 3.2)."""
        if not network_up:
            # the sync vector is untouched and nothing can be delivered:
            # don't even build a packet (the seed collected one per outage
            # tick and queued it, growing without bound)
            if self.buffered.ticks == 0:
                self.buffered.since_tick = self.tick
            self.buffered.ticks += 1
            self.tick += 1
            return None
        packet, new_sync = collect_updates(
            self.store_ref.store, self.sync, self.knobs, tick=self.tick,
            full_map=full_map, priorities=priorities)
        self.tick += 1
        self.sync = new_sync
        # a delivered tick IS the reconnect flush (the collect coalesced
        # everything the sync vector was owed) — close the outage window
        if self.buffered.ticks:
            self.buffered = OutageBuffer(since_tick=self.tick)
        return packet

    def flush_buffer(self):
        """Reconnection: pending updates apply at once (re-collected against
        the current store so intermediate versions coalesce)."""
        self.buffered = OutageBuffer(since_tick=self.tick)
        packet, self.sync = collect_updates(
            self.store_ref.store, self.sync, self.knobs, tick=self.tick)
        return packet

    def query(self, embed: jax.Array):
        """Embedding-only SQ (top-5 cosine) — the paper's Fig. 4 path."""
        res = self._query(self.store_ref.store, embed)
        jax.block_until_ready(res.scores)
        return res

    def query_spec(self, spec):
        """Declarative SQ: one fused predicate+score+top-k dispatch over
        the server store (see core.query.Query) — two-stage through the
        mapping server's cluster index when it maintains one."""
        res = query_mod.execute_query(
            self.store_ref.store, spec,
            index=getattr(self.store_ref, "cluster_index", None))
        jax.block_until_ready(res.scores)
        return res


# ---------------------------------------------------------------------------
def choose_mode(net: NetworkModel, t: float, knobs: Knobs) -> str:
    """SemanticXR-SQ vs -LQ switching on observed latency (Sec. 3.2)."""
    lat = net.measured_latency_ms(t)
    return "SQ" if lat <= knobs.net_latency_switch_threshold_ms else "LQ"


# ---------------------------------------------------------------------------
@dataclass
class ClientSession:
    """The per-tick client step, shared by the single-client session loop
    (examples/network_drop_session.py) and the fleet simulator
    (server/fleet.py) — one code path for packet delivery (outage-aware:
    a transfer straddling an outage start is delayed, not delivered at
    pre-outage latency), ingest, byte accounting, and SQ/LQ mode choice.

    Two transports share the receive path:

    * ``faults is None`` (clean link) — the legacy behavior, byte- and
      tick-identical to the pre-hardening protocol: FIFO delivery, ingest
      within the send tick when the link allows.  Packets that carry
      protocol framing (``seq``/``epoch`` from the fleet tier) still run
      the sequencing/ack bookkeeping — FIFO delivery trivially satisfies
      the in-order apply, and the emitted cumulative acks are what drives
      the server's sync-vector slot retirement.
    * ``faults`` set — the fault-injection transport: per-packet seeded
      loss/duplication/reordering/corruption draws, delivery strictly via
      the in-flight queue (so reordered copies really arrive out of
      order), checksum verification, a per-zone reorder buffer with
      in-order apply, and gap-detection resync requests with exponential
      backoff.
    """
    dev: DeviceClient
    net: NetworkModel
    knobs: Knobs
    user_pos: object = None            # [3] — priority/eviction anchor
    interest_embeds: object = None
    dt: float = 1.0                    # tick period (seconds)
    cid: int = 0                       # fault-draw key (fleet client id)
    faults: FaultModel | None = None   # None = clean legacy transport
    down_bytes: int = 0
    up_bytes: int = 0                  # ack/resync control frames (hardened
    #                                    accounting only)
    delivered: int = 0                 # packets actually ingested
    delayed: int = 0                   # packets not ingested within their
    #                                    send tick (outage straddle, slow
    #                                    link, or FIFO backlog)
    # fault/protocol counters (cumulative; the engine logs per-tick deltas)
    lost: int = 0                      # downlink packets the channel ate
    dup_drops: int = 0                 # duplicate deliveries discarded
    corrupt_drops: int = 0             # checksum-failed deliveries discarded
    stale_drops: int = 0               # out-of-subscription deliveries
    #                                    dropped at the device (zone-crossing
    #                                    mid-flight staleness fix)
    resyncs: int = 0                   # resync requests issued
    epoch: int = -1                    # adopted server sync epoch
    pending: list = field(default_factory=list)   # [(deliver_at, packet)]
    acks: list = field(default_factory=list)      # [(zone, epoch, seq)] out
    ctrl: list = field(default_factory=list)      # [("resync", zone)] out
    zone_subs: object = None           # [Z] bool — the device's CURRENT
    #                                    zone subscriptions.  Set on every
    #                                    pose/zone change (engine) and at
    #                                    each prune; packets from zones
    #                                    outside it are dropped AT DELIVERY
    #                                    (never ingested) instead of being
    #                                    applied and pruned a tick later.
    #                                    None = gate off (legacy callers).
    _expect: dict = field(default_factory=dict)   # zone -> next seq to apply
    _reorder: dict = field(default_factory=dict)  # zone -> {seq: packet}
    _gap_since: dict = field(default_factory=dict)   # zone -> gap open time
    _backoff: dict = field(default_factory=dict)  # zone -> current timeout

    def __post_init__(self):
        if self.user_pos is None:
            self.user_pos = jnp.zeros(3)

    def _ingest(self, packet):
        self.dev.ingest(packet, user_pos=self.user_pos,
                        interest_embeds=self.interest_embeds)
        self.down_bytes += packet.nbytes
        self.delivered += 1
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.counter("client_down_bytes_total",
                        "bytes ingested per client").inc(packet.nbytes,
                                                         client=self.cid)

    def _count_fault(self, kind: str) -> None:
        """Mirror a transport fault counter into the metrics registry."""
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.counter("client_faults_total",
                        "transport faults per client by kind").inc(
                            client=self.cid, kind=kind)

    # -- hardened receive path ---------------------------------------------
    def _adopt_epoch(self, epoch: int, fresh: bool) -> None:
        """A packet from a newer epoch: the server rolled this client back
        (resync / retransmit timeout) or restarted it (join / crash
        recovery / lease expiry).  Sequence streams restart at 0; a fresh
        epoch also resets the device map — the catch-up that follows is the
        whole subscribed store, so nothing stale can survive."""
        self.epoch = epoch
        self._expect = {}
        self._reorder = {}
        self._gap_since = {}
        self._backoff = {}
        if fresh:
            self.dev.local = init_local_map(self.dev.knobs,
                                            self.dev.embed_dim)
            self._resync_index()

    def _resync_index(self) -> None:
        """Re-diff the client's cluster index after a map replacement that
        bypassed the ingest path (epoch reset, crash, zone prune)."""
        if self.dev.cluster_index is not None:
            self.dev.cluster_index.refresh(self.dev.local)

    def _zone_ok(self, zone: int) -> bool:
        """Is the device still subscribed to ``zone``?  Gate for the
        stale-zone drop; ``zone_subs is None`` disables the gate (legacy
        single-zone callers that never track subscriptions)."""
        if self.zone_subs is None:
            return True
        subs = np.asarray(self.zone_subs, bool)
        return bool(subs[zone]) if zone < len(subs) else False

    def _ack(self, zone: int, seq: int) -> None:
        self.acks.append((zone, self.epoch, seq))
        if self.faults is not None:
            self.up_bytes += ACK_NBYTES
            reg = obs_metrics.get_registry()
            if reg is not None:
                reg.counter("client_up_bytes_total",
                            "upstream control bytes per client").inc(
                                ACK_NBYTES, client=self.cid, kind="ack")

    def _receive(self, t: float, packet) -> None:
        """Apply one arrived packet through the protocol state machine.
        Unframed packets (legacy single-client path: ``seq is None``) apply
        directly — the CloudService sync vector is their ordering."""
        if getattr(packet, "seq", None) is None:
            self._ingest(packet)
            return
        if not packet.checksum_ok():
            self.corrupt_drops += 1
            self._count_fault("corrupt_drop")
            return
        if packet.epoch < self.epoch:
            return                         # pre-resync straggler: discard
        if packet.epoch > self.epoch:
            self._adopt_epoch(packet.epoch, packet.fresh)
        z = packet.zone
        exp = self._expect.get(z, 0)
        if packet.seq < exp:
            # duplicate of an applied packet; re-ack in case the original
            # cumulative ack was lost upstream
            self.dup_drops += 1
            self._count_fault("dup_drop")
            self._ack(z, exp - 1)
            return
        if packet.seq > exp:
            buf = self._reorder.setdefault(z, {})
            if packet.seq not in buf:
                buf[packet.seq] = packet
            else:
                self.dup_drops += 1
                self._count_fault("dup_drop")
            self._gap_since.setdefault(z, t)
            return
        # in order: apply, then drain whatever the gap was holding back.
        # Zone-crossing mid-flight fix: a packet from a zone the device no
        # longer subscribes to is DROPPED here, never ingested — but its
        # seq still advances and the cumulative ack still goes out, so the
        # stream position survives a zone round-trip (the server kept the
        # seq stream via reset_client(keep_seq=True); swallowing the seq
        # would make re-entry packets look like a gap -> spurious resyncs).
        ok = self._zone_ok(z)
        buf = self._reorder.get(z, {})
        seq = packet.seq
        while True:
            if ok:
                self._ingest(packet)
            else:
                self.stale_drops += 1
                self._count_fault("stale_zone_drop")
            seq += 1
            if seq in buf:
                packet = buf.pop(seq)
            else:
                break
        self._expect[z] = seq
        self._ack(z, seq - 1)              # cumulative: covers the run
        if buf:
            self._gap_since[z] = t         # a later gap is still open
        else:
            self._gap_since.pop(z, None)
            self._backoff.pop(z, None)

    def _clean_delivery_at(self, t: float, nbytes: int) -> float:
        send = t
        while (at := self.net.delivery_time(send, nbytes)) is None:
            # sender raced an outage start: retransmit after it ends
            # (walk successive windows — outages may be back-to-back)
            send = max(b for a, b in self.net.outages if a <= send < b)
        return at

    def _send_faulty(self, t: float, packet) -> None:
        """Fault-injection downlink: seeded per-packet draws decide loss,
        duplication, reordering jitter, and corruption per copy.  Delivery
        is NOT FIFO-clamped — each copy matures at its own time, so a
        jittered copy really is overtaken (the seq layer re-orders)."""
        fm = self.faults
        seq = packet.seq if packet.seq is not None else (1 << 20) + packet.tick
        r = fm.packet_draws(self.cid, packet.zone, packet.epoch, seq)
        copies = 2 if r[0] < fm.dup_prob else 1
        for k in range(copies):
            if r[1 + k] < fm.loss_prob:
                self.lost += 1
                self._count_fault("lost")
                continue
            at = self._clean_delivery_at(t, packet.nbytes)
            if r[3 + k] < fm.reorder_prob:
                at += float(r[5 + k]) * fm.reorder_jitter_s
            p = packet
            if r[7 + k] < fm.corrupt_prob and packet.checksum is not None:
                p = copy.copy(packet)
                p.checksum = packet.checksum ^ 0x5A5A5A5A
            if at > t + self.dt:
                self.delayed += 1
            self.pending.append((at, p))

    def _check_gaps(self, t: float) -> None:
        """Gap open past the (backed-off) timeout -> queue a resync request
        for the engine to carry upstream.  The server answers by rolling
        the whole client back to its acked state under a bumped epoch."""
        fm = self.faults
        for z, since in list(self._gap_since.items()):
            wait = self._backoff.get(z, fm.resync_timeout_s)
            if t - since >= wait:
                self.ctrl.append(("resync", z))
                self.resyncs += 1
                self.up_bytes += RESYNC_NBYTES
                self._count_fault("resync")
                reg = obs_metrics.get_registry()
                if reg is not None:
                    reg.counter("client_up_bytes_total",
                                "upstream control bytes per client").inc(
                                    RESYNC_NBYTES, client=self.cid,
                                    kind="resync")
                self._gap_since[z] = t
                self._backoff[z] = min(wait * 2, fm.resync_backoff_cap_s)

    # -- engine drains (control-plane outboxes) ----------------------------
    def drain_acks(self) -> list:
        out, self.acks = self.acks, []
        return out

    def drain_ctrl(self) -> list:
        out, self.ctrl = self.ctrl, []
        return out

    def prune_zones(self, grid, subscribed: np.ndarray) -> int:
        """Prune-on-unsubscribe: drop retained objects whose centroids
        route to zones the client no longer subscribes to (zone-leave
        staleness fix — without it a returning client keeps answering
        local queries from dead state it will never receive tombstones
        for).  Returns how many entries were pruned."""
        # refresh the delivery gate too: even callers that don't wire
        # zone_subs on pose changes converge here each prune
        self.zone_subs = np.asarray(subscribed, bool).copy()
        m = self.dev.local
        act = np.asarray(m.active)
        if not act.any():
            return 0
        z = grid.zone_of(np.asarray(m.centroid))
        drop = act & ~np.asarray(subscribed, bool)[z]
        n = int(drop.sum())
        if n:
            self.dev.local = prune_slots(m, jnp.asarray(drop))
            self._resync_index()
        return n

    def crash(self) -> None:
        """Device restart: volatile state is gone — the local map, every
        in-flight packet, the protocol position.  Cumulative traffic
        counters survive (they model the *session's* accounting, and the
        engine logs deltas).  The server notices via the join path: the
        rejoin bumps the epoch with fresh=True, forcing a full catch-up
        instead of silently replaying stale sync state."""
        self.pending.clear()
        self.acks.clear()
        self.ctrl.clear()
        self.dev.local = init_local_map(self.dev.knobs, self.dev.embed_dim)
        self._resync_index()
        self.epoch = -1
        self.zone_subs = None
        self._expect = {}
        self._reorder = {}
        self._gap_since = {}
        self._backoff = {}

    # -- the per-tick step -------------------------------------------------
    @obs_traced("client.step", cat="client")
    def step(self, t: float, packet=None) -> str:
        """Advance to time ``t``: deliver matured in-flight packets, send
        ``packet`` (ingesting within the tick unless an outage delays it),
        and return the query mode ("SQ"/"LQ") for this tick.

        Clean-link delivery is FIFO per link: a packet sent while older
        packets are still in flight queues behind them, so a later
        (newer-version) packet can never overtake a delayed one and then
        be overwritten by it when the stale packet matures.  Under the
        fault-injection transport the FIFO clamp is OFF (reordering is the
        point) and the sequencing layer provides the ordering instead."""
        matured = sorted((p for p in self.pending if p[0] <= t),
                         key=lambda p: p[0])
        self.pending = [p for p in self.pending if p[0] > t]
        for _, p in matured:
            self._receive(t, p)
        if packet is not None and packet.count > 0:
            if self.faults is not None:
                self._send_faulty(t, packet)
            else:
                at = self._clean_delivery_at(t, packet.nbytes)
                if self.pending:
                    at = max(at, self.pending[-1][0])  # FIFO behind in-flight
                if not self.pending and at <= t + self.dt:
                    self._receive(t, packet)
                else:
                    self.delayed += 1
                    self.pending.append((at, packet))
        if self.faults is not None:
            self._check_gaps(t)
        return choose_mode(self.net, t, self.knobs)
