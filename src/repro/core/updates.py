"""Object-level incremental update protocol (paper Sec. 3.2, Fig. 6).

The server tracks the per-client synced version of every object and, on each
update tick (every ``local_map_update_frequency`` frames), ships exactly the
objects that are (a) new or modified since the last sync, (b) observed at
least ``min_obs_before_sync`` times (transient filtering), and (c) admitted
by the prioritizer.  Downstream bandwidth is therefore proportional to map
*changes*; the device-cloud baseline ships the full map each tick.

Byte accounting is exact over the wire format below — the downstream-BW
benchmark (Fig. 6) reads these numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.local_map import ObjectUpdate
from repro.core.store import ObjectStore

# wire format per object: id(4) + label(2) + version(4) + n_points(2)
# + centroid(3*4) + embedding(E*2, fp16) + points(n*3*2, fp16)
_HEADER_B = 4 + 2 + 4 + 2 + 12


def update_nbytes(embed_dim: int, n_points: int) -> int:
    return _HEADER_B + 2 * embed_dim + 6 * int(n_points)


@dataclass
class UpdatePacket:
    updates: list            # list[ObjectUpdate]
    nbytes: int
    tick: int


class SyncState(NamedTuple):
    """Server-side per-client sync vector: last shipped version per slot."""
    synced_version: np.ndarray   # [cap] int32 (host-side bookkeeping)


def init_sync(capacity: int) -> SyncState:
    return SyncState(synced_version=np.zeros((capacity,), np.int32))


def collect_updates(store: ObjectStore, sync: SyncState, knobs: Knobs, *,
                    tick: int, full_map: bool = False,
                    priorities: np.ndarray | None = None,
                    max_updates: int | None = None):
    """Build the update packet for one tick.

    full_map=True reproduces the device-cloud baseline (whole scene each
    tick).  Returns (packet, new_sync).
    """
    active = np.asarray(store.active)
    version = np.asarray(store.version)
    obs = np.asarray(store.obs_count)
    changed = active & (obs >= knobs.min_obs_before_sync)
    if not full_map:
        changed &= version > sync.synced_version
    idx = np.nonzero(changed)[0]
    if priorities is not None and len(idx):
        idx = idx[np.argsort(-priorities[idx], kind="stable")]
    if max_updates is not None:
        idx = idx[:max_updates]

    Pc = knobs.max_object_points_client
    updates, nbytes = [], 0
    ids = np.asarray(store.ids)
    labels = np.asarray(store.label)
    for i in idx:
        pts, n = geo.downsample(store.points[i], store.n_points[i], Pc)
        c, _, _ = geo.centroid_bbox(pts, n)
        u = ObjectUpdate(
            oid=jnp.asarray(ids[i]), embed=store.embed[i],
            label=jnp.asarray(labels[i]), points=pts.astype(jnp.float16),
            n_points=n, centroid=c, version=jnp.asarray(version[i]))
        updates.append(u)
        nbytes += update_nbytes(store.embed.shape[1], int(n))

    new_synced = sync.synced_version.copy()
    new_synced[idx] = version[idx]
    return UpdatePacket(updates=updates, nbytes=nbytes, tick=tick), \
        SyncState(synced_version=new_synced)
