"""Object-level incremental update protocol (paper Sec. 3.2, Fig. 6).

The server tracks the per-client synced version of every object and, on each
update tick (every ``local_map_update_frequency`` frames), ships exactly the
objects that are (a) new or modified since the last sync, (b) observed at
least ``min_obs_before_sync`` times (transient filtering), and (c) admitted
by the prioritizer.  Downstream bandwidth is therefore proportional to map
*changes*; the device-cloud baseline ships the full map each tick.

The packet body is a struct-of-arrays UpdateBatch (local_map.py): one jitted
gather + vmapped downsample builds the whole tick instead of a per-object
Python loop, and the device applies it with one apply_updates_batch call.
U is padded to a power-of-two bucket so the builder jit retraces O(log U)
times, not per distinct packet size.

Byte accounting is exact over the wire format below — the downstream-BW
benchmark (Fig. 6) reads these numbers.
"""
from __future__ import annotations

import functools
import zlib
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.local_map import ObjectUpdate, UpdateBatch
from repro.core.store import ObjectStore, deleted_mask

# wire format per object: id(4) + label(2) + version(4) + n_points(2)
# + centroid(3*4) + embedding(E*2, fp16) + points(n*3*2, fp16).
# The deleted flag rides the sign bit of the n_points field, so live rows
# cost no extra bytes; a tombstone row ships header-only minus the payload
# fields it has no use for: id(4) + version(4) + flagged n_points(1) = 9 B.
_HEADER_B = 4 + 2 + 4 + 2 + 12
TOMBSTONE_NBYTES = 9

# hardened-protocol framing (counted only when the fault-injection
# transport is on — the clean-link wire format above is unchanged):
# per-packet header seq(4) + epoch(4) + flags(1) + crc32(4), and the
# fixed-size upstream control frames (cumulative ack / resync request):
# zone(2) + epoch(4) + seq-or-reason(4) + crc under the radio MTU floor.
PROTO_HEADER_NBYTES = 13
ACK_NBYTES = 12
RESYNC_NBYTES = 12

_MIN_BUCKET = 8


def update_nbytes(embed_dim: int, n_points: int, *,
                  deleted: bool = False) -> int:
    if deleted:
        return TOMBSTONE_NBYTES
    return _HEADER_B + 2 * embed_dim + 6 * int(n_points)


def bucket(n: int) -> int:
    """Round ``n`` up to the next power-of-two batch bucket (min 8) — the
    shared padding policy bounding jit retraces across every delta path
    (update collect, zone scatters, tombstone release, cluster-index
    recompute)."""
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


_bucket = bucket          # original (private) name, kept for call sites


@functools.lru_cache(maxsize=None)
def class_budget_table(knobs: Knobs, n_labels: int = 256) -> np.ndarray:
    """[n_labels] per-class client point budgets: ``class_point_overrides``
    where declared (capped at the client buffer size), the default
    elsewhere.  Lookup is clamped, so out-of-range class ids get the
    default budget.  Cached per (frozen) Knobs — collect_updates reads it
    every tick."""
    table = np.full((n_labels,), knobs.max_object_points_client, np.int32)
    for cid, pts in knobs.class_point_overrides:
        if 0 <= cid < n_labels:
            table[cid] = min(int(pts), knobs.max_object_points_client)
    table.setflags(write=False)        # shared across ticks: freeze it
    return table


@functools.partial(jax.jit, static_argnames=("out_cap",))
def _gather_batch(store: ObjectStore, idx: jax.Array, valid: jax.Array,
                  budgets: jax.Array, out_cap: int) -> UpdateBatch:
    """Build the SoA packet body for slots ``idx`` in one dispatch.

    ``budgets`` [U] is the per-row point budget (per-class overrides
    resolved by the caller); rows keep at most that many points inside the
    shared [U, out_cap, 3] buffer, so a mixed-class packet is still one
    gather and the jit keys only on (out_cap, bucket size).  Tombstone rows
    ship no geometry (n_points forced to 0)."""
    del_rows = deleted_mask(store)[idx]
    pts, n = jax.vmap(lambda p, m, b: geo.downsample_dyn(p, m, b, out_cap))(
        store.points[idx], store.n_points[idx], budgets)
    n = jnp.where(del_rows, 0, n)
    pts = jnp.where(del_rows[:, None, None], 0.0, pts)
    cent = jax.vmap(lambda p, m: geo.centroid_bbox(p, m)[0])(pts, n)
    cent = jnp.where(del_rows[:, None], store.centroid[idx], cent)
    return UpdateBatch(
        oid=store.ids[idx], embed=store.embed[idx], label=store.label[idx],
        points=pts.astype(jnp.float16), n_points=n, centroid=cent,
        version=store.version[idx], valid=valid, deleted=del_rows)


@dataclass
class UpdatePacket:
    batch: UpdateBatch | None    # None for an empty tick
    count: int                   # live rows in batch (rest is padding)
    nbytes: int
    tick: int
    # hardened-protocol framing (defaults keep the legacy single-client
    # path protocol-free: seq None means "apply on arrival, no ordering")
    zone: int = 0                # zone shard this packet's seq stream is for
    seq: int | None = None       # per-(client, zone) sequence number
    epoch: int = 0               # per-client sync epoch (bumped on resync)
    fresh: bool = False          # epoch started from scratch: the client
    #                              must reset its map before applying
    checksum: int | None = None  # crc32 over header + id/version columns
    #                              (None = unframed; set only under the
    #                              fault-injection transport)

    def compute_checksum(self) -> int:
        """crc32 over the packet header and the id/version columns — enough
        to catch the simulated truncation/corruption faults (payload bit
        flips ride the same drop-on-mismatch path in a real stack)."""
        head = np.array([self.count, self.zone, self.epoch,
                         -1 if self.seq is None else self.seq],
                        np.int64).tobytes()
        if self.batch is None or self.count == 0:
            return zlib.crc32(head)
        o = np.asarray(self.batch.oid)[:self.count].astype(np.int64)
        v = np.asarray(self.batch.version)[:self.count].astype(np.int64)
        return zlib.crc32(head + o.tobytes() + v.tobytes())

    def checksum_ok(self) -> bool:
        """True when unframed, or the framed checksum verifies."""
        return self.checksum is None \
            or self.checksum == self.compute_checksum()

    @property
    def updates(self) -> list:
        """Back-compat AoS view: list[ObjectUpdate] of the live rows."""
        if self.batch is None or self.count == 0:
            return []
        b = self.batch
        return [ObjectUpdate(oid=b.oid[i], embed=b.embed[i], label=b.label[i],
                             points=b.points[i], n_points=b.n_points[i],
                             centroid=b.centroid[i], version=b.version[i],
                             deleted=None if b.deleted is None
                             else b.deleted[i])
                for i in range(self.count)]

    @property
    def deleted_oids(self) -> list:
        """Object ids tombstoned by this packet (empty for live-only)."""
        if self.batch is None or self.count == 0 \
                or self.batch.deleted is None:
            return []
        d = np.asarray(self.batch.deleted)[:self.count]
        o = np.asarray(self.batch.oid)[:self.count]
        return [int(x) for x in o[d]]


class SyncState(NamedTuple):
    """Server-side per-client sync vector: last shipped version per slot."""
    synced_version: np.ndarray   # [cap] int32 (host-side bookkeeping)


def init_sync(capacity: int) -> SyncState:
    return SyncState(synced_version=np.zeros((capacity,), np.int32))


def collect_updates(store: ObjectStore, sync: SyncState, knobs: Knobs, *,
                    tick: int, full_map: bool = False,
                    priorities: np.ndarray | None = None,
                    max_updates: int | None = None):
    """Build the update packet for one tick.

    Live rows ship when new-or-modified past the sync vector and past the
    min-obs transient filter; tombstones ship to exactly the clients whose
    sync vector ever covered the object (synced > 0 — a client that never
    received it has nothing to delete) and jump the priority queue, since a
    freed client slot is worth more than a refreshed one.  Slots that are
    fully empty (retired tombstones, pruned transients) reset their sync
    entry so a future occupant is never hidden behind a stale version.

    full_map=True reproduces the device-cloud baseline (whole scene each
    tick).  Returns (packet, new_sync).
    """
    active = np.asarray(store.active)
    version = np.asarray(store.version)
    obs = np.asarray(store.obs_count)
    dele = np.asarray(deleted_mask(store))
    live = active & (obs >= knobs.min_obs_before_sync)
    tomb = dele & (sync.synced_version > 0) \
        & (version > sync.synced_version)
    if not full_map:
        live &= version > sync.synced_version
    changed = live | tomb
    idx = np.nonzero(changed)[0]
    if priorities is not None and len(idx):
        pri = priorities[idx].astype(np.float64)
        pri[tomb[idx]] = np.inf        # deletions first: they free slots
        idx = idx[np.argsort(-pri, kind="stable")]
    elif tomb.any() and len(idx):
        idx = idx[np.argsort(~tomb[idx], kind="stable")]
    if max_updates is not None:
        idx = idx[:max_updates]

    new_synced = sync.synced_version.copy()
    new_synced[idx] = version[idx]
    # empty slots (never assigned, retired, or pruned-before-shipping) must
    # not pin a stale synced version against their next occupant
    new_synced[~active & ~dele] = 0
    new_sync = SyncState(synced_version=new_synced)
    U = len(idx)
    if U == 0:
        return UpdatePacket(batch=None, count=0, nbytes=0, tick=tick), \
            new_sync

    Ub = _bucket(U)
    idx_pad = np.zeros((Ub,), np.int64)
    idx_pad[:U] = idx
    valid = np.arange(Ub) < U
    budgets = class_budget_table(knobs)[
        np.clip(np.asarray(store.label)[idx_pad], 0, 255)]
    batch = _gather_batch(store, jnp.asarray(idx_pad), jnp.asarray(valid),
                          jnp.asarray(budgets),
                          knobs.max_object_points_client)
    # exact per-object byte accounting (padding rows excluded): live rows
    # at full wire size, tombstones at the 9-byte header
    n_host = np.asarray(batch.n_points)[:U]
    n_tomb = int(tomb[idx].sum())
    E = store.embed.shape[1]
    nbytes = (U - n_tomb) * (_HEADER_B + 2 * E) + 6 * int(n_host.sum()) \
        + n_tomb * TOMBSTONE_NBYTES
    return UpdatePacket(batch=batch, count=U, nbytes=nbytes, tick=tick), \
        new_sync
