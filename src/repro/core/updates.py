"""Object-level incremental update protocol (paper Sec. 3.2, Fig. 6).

The server tracks the per-client synced version of every object and, on each
update tick (every ``local_map_update_frequency`` frames), ships exactly the
objects that are (a) new or modified since the last sync, (b) observed at
least ``min_obs_before_sync`` times (transient filtering), and (c) admitted
by the prioritizer.  Downstream bandwidth is therefore proportional to map
*changes*; the device-cloud baseline ships the full map each tick.

The packet body is a struct-of-arrays UpdateBatch (local_map.py): one jitted
gather + vmapped downsample builds the whole tick instead of a per-object
Python loop, and the device applies it with one apply_updates_batch call.
U is padded to a power-of-two bucket so the builder jit retraces O(log U)
times, not per distinct packet size.

Byte accounting is exact over the wire format below — the downstream-BW
benchmark (Fig. 6) reads these numbers.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.local_map import ObjectUpdate, UpdateBatch
from repro.core.store import ObjectStore

# wire format per object: id(4) + label(2) + version(4) + n_points(2)
# + centroid(3*4) + embedding(E*2, fp16) + points(n*3*2, fp16)
_HEADER_B = 4 + 2 + 4 + 2 + 12

_MIN_BUCKET = 8


def update_nbytes(embed_dim: int, n_points: int) -> int:
    return _HEADER_B + 2 * embed_dim + 6 * int(n_points)


def _bucket(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("budget",))
def _gather_batch(store: ObjectStore, idx: jax.Array, valid: jax.Array,
                  budget: int) -> UpdateBatch:
    """Build the SoA packet body for slots ``idx`` in one dispatch."""
    pts, n = jax.vmap(lambda p, m: geo.downsample(p, m, budget))(
        store.points[idx], store.n_points[idx])
    cent = jax.vmap(lambda p, m: geo.centroid_bbox(p, m)[0])(pts, n)
    return UpdateBatch(
        oid=store.ids[idx], embed=store.embed[idx], label=store.label[idx],
        points=pts.astype(jnp.float16), n_points=n, centroid=cent,
        version=store.version[idx], valid=valid)


@dataclass
class UpdatePacket:
    batch: UpdateBatch | None    # None for an empty tick
    count: int                   # live rows in batch (rest is padding)
    nbytes: int
    tick: int

    @property
    def updates(self) -> list:
        """Back-compat AoS view: list[ObjectUpdate] of the live rows."""
        if self.batch is None or self.count == 0:
            return []
        b = self.batch
        return [ObjectUpdate(oid=b.oid[i], embed=b.embed[i], label=b.label[i],
                             points=b.points[i], n_points=b.n_points[i],
                             centroid=b.centroid[i], version=b.version[i])
                for i in range(self.count)]


class SyncState(NamedTuple):
    """Server-side per-client sync vector: last shipped version per slot."""
    synced_version: np.ndarray   # [cap] int32 (host-side bookkeeping)


def init_sync(capacity: int) -> SyncState:
    return SyncState(synced_version=np.zeros((capacity,), np.int32))


def collect_updates(store: ObjectStore, sync: SyncState, knobs: Knobs, *,
                    tick: int, full_map: bool = False,
                    priorities: np.ndarray | None = None,
                    max_updates: int | None = None):
    """Build the update packet for one tick.

    full_map=True reproduces the device-cloud baseline (whole scene each
    tick).  Returns (packet, new_sync).
    """
    active = np.asarray(store.active)
    version = np.asarray(store.version)
    obs = np.asarray(store.obs_count)
    changed = active & (obs >= knobs.min_obs_before_sync)
    if not full_map:
        changed &= version > sync.synced_version
    idx = np.nonzero(changed)[0]
    if priorities is not None and len(idx):
        idx = idx[np.argsort(-priorities[idx], kind="stable")]
    if max_updates is not None:
        idx = idx[:max_updates]

    new_synced = sync.synced_version.copy()
    new_synced[idx] = version[idx]
    new_sync = SyncState(synced_version=new_synced)
    U = len(idx)
    if U == 0:
        return UpdatePacket(batch=None, count=0, nbytes=0, tick=tick), \
            new_sync

    Ub = _bucket(U)
    idx_pad = np.zeros((Ub,), np.int64)
    idx_pad[:U] = idx
    valid = np.arange(Ub) < U
    batch = _gather_batch(store, jnp.asarray(idx_pad), jnp.asarray(valid),
                          knobs.max_object_points_client)
    # exact per-object byte accounting (padding rows excluded)
    n_host = np.asarray(batch.n_points)[:U]
    E = store.embed.shape[1]
    nbytes = U * (_HEADER_B + 2 * E) + 6 * int(n_host.sum())
    return UpdatePacket(batch=batch, count=U, nbytes=nbytes, tick=tick), \
        new_sync
