"""Query engines: SemanticXR-SQ (server map) and SemanticXR-LQ (local map).

A query = text -> embedding -> cosine top-k over per-object descriptors ->
object ids + geometry (Sec. 2.3.2).  Both engines share the same fused
similarity+top-k path; when cfg.use_pallas the inner product + running top-k
runs in the Pallas kernel (kernels/query_topk.py) — one HBM pass over the
object embeddings regardless of map size.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.local_map import LocalMap
from repro.core.store import ObjectStore


class QueryResult(NamedTuple):
    oids: jax.Array       # [k] int32 (0 = no match)
    scores: jax.Array     # [k] f32
    slots: jax.Array      # [k] int32 store/map row of each hit


def _topk_similarity(qe: jax.Array, embeds: jax.Array, active: jax.Array,
                     ids: jax.Array, k: int, *, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops
        scores, slots = kops.query_topk(qe, embeds, active, k)
    else:
        sim = embeds @ qe                               # [cap]
        sim = jnp.where(active, sim, -jnp.inf)
        scores, slots = jax.lax.top_k(sim, k)
    return QueryResult(oids=ids[slots], scores=scores, slots=slots)


def query_server(store: ObjectStore, query_embed: jax.Array, *, k: int = 5,
                 use_pallas: bool = False) -> QueryResult:
    return _topk_similarity(query_embed, store.embed, store.active,
                            store.ids, k, use_pallas=use_pallas)


def query_local(m: LocalMap, query_embed: jax.Array, *, k: int = 5,
                use_pallas: bool = False) -> QueryResult:
    return _topk_similarity(query_embed, m.embed, m.active, m.ids, k,
                            use_pallas=use_pallas)


def batched_query_local(m: LocalMap, query_embeds: jax.Array, *, k: int = 5,
                        use_pallas: bool = False) -> QueryResult:
    """[Q, E] query batch -> QueryResult with leading Q dim."""
    return jax.vmap(lambda q: query_local(m, q, k=k, use_pallas=use_pallas))(
        query_embeds)
