"""Query engines: SemanticXR-SQ (server map) and SemanticXR-LQ (local map).

A query = text -> embedding -> cosine top-k over per-object descriptors ->
object ids + geometry (Sec. 2.3.2).  Both engines share the same fused
similarity+top-k path; when cfg.use_pallas the inner product + running top-k
runs in the Pallas kernel (kernels/query_topk.py) — one HBM pass over the
object embeddings regardless of map size.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.local_map import LocalMap
from repro.core.store import ObjectStore


class QueryResult(NamedTuple):
    oids: jax.Array       # [k] int32 (0 = no match)
    scores: jax.Array     # [k] f32
    slots: jax.Array      # [k] int32 store/map row of each hit


def _topk_similarity(qe: jax.Array, embeds: jax.Array, active: jax.Array,
                     ids: jax.Array, k: int, *, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops
        scores, slots = kops.query_topk(qe, embeds, active, k)
    else:
        sim = embeds @ qe                               # [cap]
        sim = jnp.where(active, sim, -jnp.inf)
        scores, slots = jax.lax.top_k(sim, k)
    return QueryResult(oids=ids[slots], scores=scores, slots=slots)


def query_server(store: ObjectStore, query_embed: jax.Array, *, k: int = 5,
                 use_pallas: bool = False) -> QueryResult:
    return _topk_similarity(query_embed, store.embed, store.active,
                            store.ids, k, use_pallas=use_pallas)


def query_local(m: LocalMap, query_embed: jax.Array, *, k: int = 5,
                use_pallas: bool = False) -> QueryResult:
    return _topk_similarity(query_embed, m.embed, m.active, m.ids, k,
                            use_pallas=use_pallas)


def _batched_topk(query_embeds: jax.Array, embeds: jax.Array,
                  active: jax.Array, ids: jax.Array, k: int, *,
                  use_pallas: bool = False) -> QueryResult:
    """[Q, E] query batch against one map — a single embedding-table sweep.

    use_pallas routes to the multi-query grid kernel (queries resident in
    VMEM, table streamed once for all Q); the jnp path is one [Q, cap]
    matmul + top_k, still a single dispatch rather than Q vmapped sweeps.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        scores, slots = kops.query_topk_multi(query_embeds, embeds, active, k)
    else:
        sim = query_embeds @ embeds.T                   # [Q, cap]
        sim = jnp.where(active[None, :], sim, -jnp.inf)
        scores, slots = jax.lax.top_k(sim, k)
    oids = jnp.where(slots >= 0, ids[jnp.maximum(slots, 0)], 0)
    return QueryResult(oids=oids, scores=scores, slots=slots)


def batched_query_local(m: LocalMap, query_embeds: jax.Array, *, k: int = 5,
                        use_pallas: bool = False) -> QueryResult:
    """[Q, E] query batch -> QueryResult with leading Q dim."""
    return _batched_topk(query_embeds, m.embed, m.active, m.ids, k,
                         use_pallas=use_pallas)


def batched_query_server(store: ObjectStore, query_embeds: jax.Array, *,
                         k: int = 5, use_pallas: bool = False) -> QueryResult:
    """[Q, E] query batch against the server store (the serving batch step)."""
    return _batched_topk(query_embeds, store.embed, store.active, store.ids,
                         k, use_pallas=use_pallas)
