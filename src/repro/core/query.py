"""Declarative query engine over the SemanticXR object maps (Sec. 2.3.2).

The paper's headline capability is a *queryable* semantic map: open-vocabulary
AND spatial object search with sub-100 ms latency at 10k objects.  One
``Query`` pytree spec expresses the whole request —

  * semantic similarity        ``embed`` (text embedding, optionally scaled
                               by ``sem_weight``)
  * spatial predicates         ``near=(center, radius)``, ``aabb=(lo, hi)``,
                               ``zones``+``grid`` (zone membership)
  * attribute filters          ``labels`` (allowed class ids), ``min_points``,
                               ``min_obs`` (observation-count confidence
                               proxy), ``since`` (recency: last seen frame)
  * score combination          ``sem_weight`` * cosine + ``prox_weight`` *
                               1/(1+dist-to-center)
  * top-k                      ``k``

— and ``compile_query(spec, target)`` lowers the whole predicate + score +
top-k plan into ONE fused jitted dispatch, executable uniformly against the
device ``LocalMap``, the server ``ObjectStore``, and the fleet's
``ZoneShardedStore`` (where zone/near predicates prune shards *before*
dispatch; each selected shard then runs the same fused plan and a [k]-sized
merge combines them).

Predicates are fused as ``-inf`` score injection — never a gather/compaction
pass — so a predicate-heavy query costs about the same single table sweep as
an embedding-only top-k (measured ≤1.05x at 10k objects; the predicate mask
itself is O(N) elementwise work XLA fuses into the dispatch).  With
``use_pallas`` the sweep runs in the bias-kernel variant of
``kernels/query_topk.py``: scores = MXU matmul + per-slot bias, with the
[Q, N] bias computed outside the kernel and streamed through it alongside
the [N, E] table — small next to the table traffic, and never a
gather/compaction of the table itself.

Static plan structure (which predicates are present, ``k``, label/zone sets)
lives in pytree aux data; dynamic values (embeddings, centers, radii,
thresholds) are array leaves — re-running a compiled plan with new values
never retraces.

The seed's six embedding-only entry points (``query_local``,
``query_server``, ``batched_query_local/server``, the serving step-fn and
the fleet SQ path) survive as thin deprecated wrappers over this engine.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, replace
from typing import Any, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.local_map import LocalMap
from repro.core.store import ObjectStore
from repro.obs.trace import span as obs_span

NEG = -1e30          # kernel-side mask value (see kernels/query_topk.py)

_DYN_FIELDS = ("embed", "sem_weight", "near", "aabb", "prox_weight",
               "min_points", "min_obs", "since", "density_weight")
_STATIC_FIELDS = ("labels", "zones", "grid", "k", "batched", "level")


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Query:
    """One declarative map query.  Unset (None) fields are compiled away.

    Dynamic leaves (arrays — new values never retrace):
      embed        [E] f32 (or [Q, E] when ``batched``) text embedding
      sem_weight   scalar weight on the cosine term (default 1)
      near         (center [3], radius scalar): keep objects with
                   ||centroid - center|| <= radius
      aabb         (lo [3], hi [3]): keep objects whose centroid lies inside
      prox_weight  scalar: add prox_weight / (1 + dist-to-near-center) to the
                   score (requires ``near``)
      min_points   scalar: keep objects with n_points >= min_points
      min_obs      scalar: keep objects with obs_count >= min_obs
                   (vacuous on targets without obs_count, e.g. LocalMap)
      since        scalar frame index: keep objects with last_seen >= since
                   (vacuous on targets without last_seen)
      density_weight  scalar (cluster-level queries only): add
                   density_weight * log1p(member count) to a cluster's
                   score — "the densest region matching this text"

    Static plan structure (participates in the jit cache key):
      labels       tuple of allowed class ids
      zones        tuple of zone ids (requires ``grid``); on a
                   ZoneShardedStore also prunes shards before dispatch
      grid         (x0, z0, zone_size, nx, nz) — XZ zone grid parameters
                   (see ``Query.grid_of``)
      k            top-k size
      batched      leaves carry a leading query dim Q (see stack_queries)
      level        "object" (default) returns top-k objects;
                   "cluster" returns top-k *cluster summaries* (a
                   ``repro.index.ClusterResult``) — requires a
                   ClusterIndex on the target / compile call
    """
    embed: Any = None
    sem_weight: Any = None
    near: Any = None
    aabb: Any = None
    prox_weight: Any = None
    min_points: Any = None
    min_obs: Any = None
    since: Any = None
    density_weight: Any = None
    labels: tuple | None = None
    zones: tuple | None = None
    grid: tuple | None = None
    k: int = 5
    batched: bool = False
    level: str = "object"

    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in _DYN_FIELDS),
                tuple(getattr(self, f) for f in _STATIC_FIELDS))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(**dict(zip(_DYN_FIELDS, children)),
                   **dict(zip(_STATIC_FIELDS, aux)))

    @staticmethod
    def grid_of(grid) -> tuple:
        """ZoneGrid (duck-typed: .origin/.zone_size/.nx/.nz) -> grid tuple."""
        return (float(grid.origin[0]), float(grid.origin[1]),
                float(grid.zone_size), int(grid.nx), int(grid.nz))


class QueryResult(NamedTuple):
    """Top-k hits.  Padded ranks (k exceeds the matching object count) are
    masked: score -inf, oid 0, slot -1 — stale slot ids never surface."""
    oids: jax.Array       # [k] / [Q, k] int32 (0 = no match)
    scores: jax.Array     # [k] / [Q, k] f32 (-inf = no match)
    slots: jax.Array      # [k] / [Q, k] int32 target row (-1 = no match)


def stack_queries(specs: list, pad_to: int | None = None) -> Query:
    """Stack Q same-structure specs into one batched spec (SoA leading dim).

    All specs must share plan structure (same fields set, same static
    labels/zones/grid/k).  ``pad_to`` repeats the first spec to a fixed Q so
    the downstream jit sees one shape per scheduler batch size.
    """
    if not specs:
        raise ValueError("stack_queries needs at least one spec")
    first = specs[0]
    if first.batched:
        raise ValueError("stack_queries takes unbatched specs")
    if not jax.tree.leaves(first):
        raise ValueError("stack_queries needs at least one dynamic field "
                         "(all-static specs have no per-query dimension)")
    aux0 = specs[0].tree_flatten()[1]
    for s in specs[1:]:
        if s.tree_flatten()[1] != aux0:
            raise ValueError("stack_queries: mismatched static plan "
                             "(labels/zones/grid/k must agree)")
    if pad_to is not None and pad_to > len(specs):
        specs = specs + [first] * (pad_to - len(specs))
    stacked = jax.tree.map(lambda *xs: jnp.stack(
        [jnp.asarray(x) for x in xs]), *specs)
    return replace(stacked, batched=True)


# ---------------------------------------------------------------------------
# the fused execution path
# ---------------------------------------------------------------------------
class _Cols(NamedTuple):
    """Uniform columnar view of any query target (geometry stays behind)."""
    ids: jax.Array
    active: jax.Array
    embed: jax.Array
    label: jax.Array
    n_points: jax.Array
    centroid: jax.Array
    obs_count: Any        # None on targets without it (LocalMap)
    last_seen: Any        # None on targets without it (LocalMap)


def _columns(target) -> _Cols:
    return _Cols(ids=target.ids, active=target.active, embed=target.embed,
                 label=target.label, n_points=target.n_points,
                 centroid=target.centroid,
                 obs_count=getattr(target, "obs_count", None),
                 last_seen=getattr(target, "last_seen", None))


def _promote(spec: Query) -> Query:
    """Give every dynamic leaf a leading Q=1 dim (single -> batched form)."""
    if spec.batched:
        return spec
    dyn, aux = spec.tree_flatten()
    dyn = tuple(jax.tree.map(lambda x: jnp.asarray(x)[None], d)
                for d in dyn)
    out = Query.tree_unflatten(aux, dyn)
    return replace(out, batched=True)


def _zone_ids(centroid: jax.Array, grid: tuple) -> jax.Array:
    """jnp mirror of server.zones.ZoneGrid.zone_of (clamped XZ grid)."""
    x0, z0, zs, nx, nz = grid
    ix = jnp.clip(jnp.floor((centroid[:, 0] - x0) / zs), 0, nx - 1)
    iz = jnp.clip(jnp.floor((centroid[:, 2] - z0) / zs), 0, nz - 1)
    return (ix * nz + iz).astype(jnp.int32)


def _mask_and_bonus(spec: Query, cols: _Cols):
    """All predicates as one [Q, cap] bool mask + the proximity bonus term.

    Pure elementwise math over the columns — XLA fuses it with the
    similarity matmul and the top-k into a single dispatch; there is no
    per-predicate pass and never a gather/compaction.
    """
    cap = cols.active.shape[0]
    ok = jnp.broadcast_to(cols.active[None, :], (1, cap))
    if spec.labels is not None:
        ok = ok & jnp.isin(cols.label,
                           jnp.asarray(spec.labels, jnp.int32))[None, :]
    if spec.zones is not None:
        if spec.grid is None:
            raise ValueError("Query.zones requires Query.grid")
        zid = _zone_ids(cols.centroid, spec.grid)
        ok = ok & jnp.isin(zid, jnp.asarray(spec.zones, jnp.int32))[None, :]
    if spec.min_points is not None:
        ok = ok & (cols.n_points[None, :] >= spec.min_points[:, None])
    if spec.min_obs is not None and cols.obs_count is not None:
        ok = ok & (cols.obs_count[None, :] >= spec.min_obs[:, None])
    if spec.since is not None and cols.last_seen is not None:
        ok = ok & (cols.last_seen[None, :] >= spec.since[:, None])
    if spec.aabb is not None:
        lo, hi = spec.aabb
        inside = ((cols.centroid[None] >= lo[:, None, :])
                  & (cols.centroid[None] <= hi[:, None, :])).all(-1)
        ok = ok & inside
    bonus = None
    if spec.near is not None:
        center, radius = spec.near
        d = jnp.linalg.norm(cols.centroid[None] - center[:, None, :],
                            axis=-1)                       # [Q, cap]
        ok = ok & (d <= radius[:, None])
        if spec.prox_weight is not None:
            bonus = spec.prox_weight[:, None] / (1.0 + d)
    elif spec.prox_weight is not None:
        raise ValueError("Query.prox_weight requires Query.near")
    return ok, bonus


def _finalize(ids: jax.Array, scores: jax.Array,
              slots: jax.Array) -> QueryResult:
    """Mask padded ranks: -inf score, sentinel slot -1, oid 0."""
    invalid = (scores <= NEG) | (slots < 0)
    slots = jnp.where(invalid, -1, slots)
    oids = jnp.where(invalid, 0, ids[jnp.maximum(slots, 0)])
    scores = jnp.where(invalid, -jnp.inf, scores)
    return QueryResult(oids=oids, scores=scores, slots=slots)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _execute(spec: Query, cols: _Cols, *, use_pallas: bool = False):
    """The one compiled execution path: predicates + score + top-k fused.

    Plan structure (spec aux + presence of optional leaves/columns) keys the
    jit cache; new dynamic values re-run the same executable.
    """
    squeeze = not spec.batched
    spec = _promote(spec)
    cap = cols.active.shape[0]
    k = min(spec.k, cap)
    leaves = jax.tree.leaves(spec)
    Q = int(leaves[0].shape[0]) if leaves else 1
    ok, bonus = _mask_and_bonus(spec, cols)
    ok = jnp.broadcast_to(ok, (Q, cap))

    if use_pallas and spec.embed is not None:
        from repro.kernels import ops as kops
        qs = spec.embed
        if spec.sem_weight is not None:
            qs = qs * spec.sem_weight[:, None]
        bias = jnp.zeros((Q, cap), jnp.float32) if bonus is None \
            else jnp.broadcast_to(bonus, (Q, cap))
        bias = jnp.where(ok, bias, NEG)
        scores, slots = kops.query_topk_bias(qs, cols.embed, bias, k)
    else:
        if spec.embed is not None:
            sim = spec.embed @ cols.embed.T                # [Q, cap]
            if spec.sem_weight is not None:
                sim = sim * spec.sem_weight[:, None]
        else:
            sim = jnp.zeros(ok.shape, jnp.float32)
        if bonus is not None:
            sim = sim + bonus
        sim = jnp.where(ok, sim, -jnp.inf)
        scores, slots = jax.lax.top_k(sim, k)

    res = _finalize(cols.ids, scores, slots)
    if k < spec.k:                 # honor k > capacity with padded ranks
        pad = spec.k - k
        res = QueryResult(
            oids=jnp.pad(res.oids, ((0, 0), (0, pad))),
            scores=jnp.pad(res.scores, ((0, 0), (0, pad)),
                           constant_values=-jnp.inf),
            slots=jnp.pad(res.slots, ((0, 0), (0, pad)),
                          constant_values=-1))
    if squeeze:
        res = QueryResult(*(x[0] for x in res))
    return res


@functools.partial(jax.jit, static_argnames=("capz",))
def _merge_shards(oids, scores, slots, zone_ids, capz: int):
    """Fold S per-shard top-k results ([S, Q, k] each) into one [Q, k].

    Shard-local slots globalize to ``zone * zone_capacity + slot`` so a
    sharded result is addressable like a flat one."""
    gslot = jnp.where(slots >= 0,
                      zone_ids[:, None, None] * capz + slots, -1)
    cat = lambda x: jnp.moveaxis(x, 0, 1).reshape(x.shape[1], -1)
    sc, oid, sl = cat(scores), cat(oids), cat(gslot)       # [Q, S*k]
    k = scores.shape[-1]
    top, sel = jax.lax.top_k(sc, k)
    take = lambda x: jnp.take_along_axis(x, sel, axis=1)
    return QueryResult(oids=take(oid), scores=top, slots=take(sl))


# ---------------------------------------------------------------------------
# compile + execute API
# ---------------------------------------------------------------------------
def _is_sharded(target) -> bool:
    return hasattr(target, "zones") and hasattr(target, "grid")


def _select_shards(spec: Query, target) -> list:
    """Zone predicates prune shards BEFORE dispatch (host-side, using the
    spec's concrete values at compile time)."""
    Z = target.grid.n_zones
    if spec.zones is not None:
        return [z for z in sorted(set(spec.zones)) if 0 <= z < Z]
    if spec.near is not None:
        center, radius = spec.near
        c = np.atleast_2d(np.asarray(center))
        r = np.atleast_1d(np.asarray(radius))
        sel = np.zeros((Z,), bool)
        for i in range(c.shape[0]):
            sel |= target.grid.overlaps(c[i], float(r[min(i, len(r) - 1)]))
        return [z for z in range(Z) if sel[z]]
    return list(range(Z))


def _count_flat_fallback():
    """Mark an index-carrying target served by the flat sweep (below the
    engagement threshold) — the coverage counterpart of
    ``query_index_two_stage_total``."""
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.get_registry()
    if reg is not None:
        reg.counter("query_index_flat_total",
                    "index present but below min_flat_size: flat sweep").inc()


@dataclass
class CompiledQuery:
    """A (spec, target)-shaped executable plan.

    Calling it re-runs the fused dispatch; pass a new same-structure ``spec``
    (and/or an updated target) to re-execute without retracing.  For sharded
    targets the shard selection is fixed at compile time from the spec's
    concrete zone/near values.

    ``index`` (a ``repro.index.ClusterIndex``, or a ``{zone: ClusterIndex}``
    dict for sharded targets) switches the plan to the coarse-to-fine
    two-stage path when the target is large enough (``index.engaged()``);
    below that threshold the flat sweep runs unchanged.  When no index is
    passed the plan discovers one on the target itself
    (``target.cluster_index`` / ``target.indexes``).  ``level="cluster"``
    specs require an index and return a ``repro.index.ClusterResult``.
    """
    spec: Query
    use_pallas: bool = False
    shards: tuple | None = None        # zone ids (sharded targets only)
    index: Any = None                  # ClusterIndex | {zone: ClusterIndex}

    def __call__(self, target, spec: Query | None = None) -> QueryResult:
        with obs_span("query.dispatch", cat="query",
                      sharded=_is_sharded(target)) as sp:
            res = self._run(target, spec)
            sp.fence(res.scores)
        return res

    def _run(self, target, spec: Query | None = None) -> QueryResult:
        spec = self.spec if spec is None else spec
        if not _is_sharded(target):
            idx = self.index if self.index is not None \
                else getattr(target, "cluster_index", None)
            if spec.level == "cluster":
                if idx is None:
                    raise ValueError(
                        "Query(level='cluster') needs a ClusterIndex: pass "
                        "index= to compile_query or attach one as "
                        "target.cluster_index")
                from repro.index.search import cluster_query
                return cluster_query(spec, [(None, idx, target)])
            if idx is not None:
                if idx.engaged():
                    from repro.index.search import two_stage_query
                    return two_stage_query(spec, target, idx,
                                           use_pallas=self.use_pallas)
                _count_flat_fallback()
            return _execute(spec, _columns(target),
                            use_pallas=self.use_pallas)
        return self._run_sharded(target, spec)

    def _run_sharded(self, target, spec: Query) -> QueryResult:
        shards = self.shards if self.shards is not None \
            else tuple(_select_shards(spec, target))
        idxs = self.index if self.index is not None \
            else getattr(target, "indexes", None)
        if not idxs:                   # {} (index never enabled) == None
            idxs = None
        k = spec.k
        Q = None
        if spec.batched:
            lead = jax.tree.leaves(spec)
            Q = int(lead[0].shape[0]) if lead else 1
        if spec.level == "cluster":
            from repro.index.search import ClusterResult, cluster_query
            items = [] if idxs is None else \
                [(z, idxs[z], target.zones[z]) for z in shards
                 if idxs.get(z) is not None]
            if not items:
                if idxs is None:
                    raise ValueError(
                        "Query(level='cluster') on a sharded target needs "
                        "zone indexes: pass index= to compile_query or call "
                        "enable_index() on the store")
                shape = (k,) if Q is None else (Q, k)
                return ClusterResult(
                    zones=jnp.full(shape, -1, jnp.int32),
                    cells=jnp.full(shape, -1, jnp.int32),
                    scores=jnp.full(shape, -jnp.inf),
                    counts=jnp.zeros(shape, jnp.int32),
                    centroids=jnp.zeros(shape + (3,), jnp.float32))
            return cluster_query(spec, items)
        if not shards:
            shape = (k,) if Q is None else (Q, k)
            return QueryResult(oids=jnp.zeros(shape, jnp.int32),
                               scores=jnp.full(shape, -jnp.inf),
                               slots=jnp.full(shape, -1, jnp.int32))
        # the same fused plan per selected shard (shards share shapes, so
        # this compiles once), then a [k]-sized merge; shards with an
        # engaged index take the two-stage path, the rest stay flat
        bspec = spec if spec.batched else _promote(spec)
        parts = []
        for z in shards:
            zt = target.zones[z]
            zidx = None if idxs is None else idxs.get(z)
            if zidx is not None and zidx.engaged():
                from repro.index.search import two_stage_query
                parts.append(two_stage_query(bspec, zt, zidx,
                                             use_pallas=self.use_pallas))
            else:
                if zidx is not None:
                    _count_flat_fallback()
                parts.append(_execute(bspec, _columns(zt),
                                      use_pallas=self.use_pallas))
        res = _merge_shards(jnp.stack([p.oids for p in parts]),
                            jnp.stack([p.scores for p in parts]),
                            jnp.stack([p.slots for p in parts]),
                            jnp.asarray(shards, jnp.int32),
                            capz=int(target.zones[0].ids.shape[0]))
        if not spec.batched:
            res = QueryResult(*(x[0] for x in res))
        return res


def compile_query(spec: Query, target, *, use_pallas: bool = False,
                  index: Any = None) -> CompiledQuery:
    """Lower ``spec`` against ``target``'s kind into one executable plan.

    ``target`` is a LocalMap, ObjectStore, or ZoneShardedStore (duck-typed).
    The returned plan is reusable: call it with updated targets/specs of the
    same structure without recompiling.  ``index`` (or an index discovered
    on the target) makes the plan coarse-to-fine — see ``CompiledQuery``.
    """
    shards = tuple(_select_shards(spec, target)) if _is_sharded(target) \
        else None
    return CompiledQuery(spec=spec, use_pallas=use_pallas, shards=shards,
                         index=index)


def execute_query(target, spec: Query, *, use_pallas: bool = False,
                  index: Any = None) -> QueryResult:
    """One-shot convenience: compile (cached by structure) + run."""
    return CompiledQuery(spec=spec, use_pallas=use_pallas,
                         index=index)(target)


# ---------------------------------------------------------------------------
# deprecated embedding-only wrappers (the seed API)
# ---------------------------------------------------------------------------
def _warn_deprecated(name: str):
    warnings.warn(
        f"repro.core.query.{name} is deprecated: build a repro.core.query."
        "Query spec and run it through compile_query/execute_query (which "
        "adds spatial/attribute predicates and score combination on the "
        "same fused dispatch).", DeprecationWarning, stacklevel=3)


def query_server(store: ObjectStore, query_embed: jax.Array, *, k: int = 5,
                 use_pallas: bool = False) -> QueryResult:
    """Deprecated: ``execute_query(store, Query(embed=..., k=k))``."""
    _warn_deprecated("query_server")
    return execute_query(store, Query(embed=query_embed, k=k),
                         use_pallas=use_pallas)


def query_local(m: LocalMap, query_embed: jax.Array, *, k: int = 5,
                use_pallas: bool = False) -> QueryResult:
    """Deprecated: ``execute_query(m, Query(embed=..., k=k))``."""
    _warn_deprecated("query_local")
    return execute_query(m, Query(embed=query_embed, k=k),
                         use_pallas=use_pallas)


def batched_query_local(m: LocalMap, query_embeds: jax.Array, *, k: int = 5,
                        use_pallas: bool = False) -> QueryResult:
    """Deprecated: ``execute_query`` with a batched Query spec."""
    _warn_deprecated("batched_query_local")
    return execute_query(m, Query(embed=query_embeds, k=k, batched=True),
                         use_pallas=use_pallas)


def batched_query_server(store: ObjectStore, query_embeds: jax.Array, *,
                         k: int = 5, use_pallas: bool = False) -> QueryResult:
    """Deprecated: ``execute_query`` with a batched Query spec."""
    _warn_deprecated("batched_query_server")
    return execute_query(store, Query(embed=query_embeds, k=k, batched=True),
                         use_pallas=use_pallas)
