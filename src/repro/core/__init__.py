"""SemanticXR core: objects as first-class units of communication, execution
and memory footprint across the device-cloud boundary (the paper's primary
contribution, implemented as a composable JAX library)."""
from repro.core.knobs import Knobs, DEFAULT_KNOBS
from repro.core.store import ObjectStore, init_store, store_from_knobs
from repro.core.local_map import LocalMap, init_local_map, ObjectUpdate
from repro.core.pipeline import MappingServer, StageTimes
from repro.core.query import (Query, QueryResult, CompiledQuery,
                              compile_query, execute_query, stack_queries)
from repro.core.runtime import (NetworkModel, PowerModel, DeviceClient,
                                CloudService, ClientSession, choose_mode)
