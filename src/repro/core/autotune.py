"""Knob autotuning (paper Sec. 7.4 future work, implemented).

The paper exposes resource-vs-quality knobs (Tab. 2) but tunes them by hand.
This controller closes the loop: given budgets, it picks the
quality-maximal knob settings that satisfy them, and adapts the update
frequency online from measured downstream bytes.

* upstream: choose the SMALLEST depth-downsampling ratio whose modeled rate
  fits the budget (smallest ratio = most geometry = best quality).
* downstream: multiplicative-increase/decrease on the update interval,
  driven by the measured bytes of recent update packets.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from repro.core.depth import upstream_mbps
from repro.core.knobs import Knobs


def tune_upstream(knobs: Knobs, *, budget_mbps: float, h: int = 720,
                  w: int = 1280, max_ratio: int = 8) -> Knobs:
    """Quality-first: smallest ratio meeting the budget (monotone search)."""
    for r in range(1, max_ratio + 1):
        cand = dataclasses.replace(knobs, depth_downsampling_ratio=r)
        if upstream_mbps(h, w, cand) <= budget_mbps:
            return cand
    return dataclasses.replace(knobs, depth_downsampling_ratio=max_ratio)


@dataclass
class DownstreamTuner:
    """Adapt local_map_update_frequency to a bytes/second budget."""
    budget_bytes_per_s: float
    tick_rate_hz: float = 6.0          # keyframe rate
    min_interval: int = 1
    max_interval: int = 32
    _ema: float = field(default=0.0)

    def observe(self, knobs: Knobs, packet_bytes: int) -> Knobs:
        interval = knobs.local_map_update_frequency
        rate = packet_bytes * self.tick_rate_hz / max(interval, 1)
        self._ema = 0.5 * self._ema + 0.5 * rate
        if self._ema > self.budget_bytes_per_s and interval < self.max_interval:
            interval *= 2                       # back off: halve frequency
        elif self._ema < 0.4 * self.budget_bytes_per_s and \
                interval > self.min_interval:
            interval = max(interval // 2, self.min_interval)  # recover
        return dataclasses.replace(knobs,
                                   local_map_update_frequency=interval)
