"""Server-side per-frame semantic mapping pipeline (paper Fig. 2 + Sec. 3.1).

Three execution modes, matching the paper's Fig. 3 ablation bars:
  B        device-cloud baseline: frame-level sequential execution — each
           detected object runs the (compiled) per-object stages one after
           another, geometry uncapped into association.
  B+P      + object-level parallelism: the frame's detections are padded to
           a fixed object batch and every stage runs batched (one MXU
           dispatch instead of D sequential ones).
  B+P+SD   + object-level geometry downsampling: per-object clouds capped at
           max_object_points_server before association (= SemanticXR).

The production SemanticXR path is ONE jitted ``ingest_frame`` dispatch from
the padded instance masks all the way through embed -> fused
lift/compact/downsample/stats (kernels/lift_compact — no per-object argsort,
no [D, HW, 3] intermediate) -> associate -> prune: a single device round
trip per keyframe instead of the seed's four stage syncs.  Setting
``instrument=True`` opts into the staged execution with per-stage
``block_until_ready`` walls so Fig. 3's bar decomposition stays measurable;
B and B+P keep the seed stage implementations as ablation arms.

Perception models (detector stand-in = GT instance masks from the renderer;
embedder = perception/embedder.py) are identical across modes — observed
differences are system organization only (paper Sec. 4.2).  All stage
functions are jitted with shape-stable (padded) signatures so steady-state
latency is measured, not retracing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import association as assoc
from repro.core import depth as depth_mod
from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.store import ObjectStore, store_from_knobs
from repro.data.scenes import Frame
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs.trace import span as obs_span
from repro.perception.embedder import OracleEmbedder

LIFT_BUFFER = 4096   # uncapped per-object buffer (baseline mode)


@dataclass
class StageTimes:
    detect_ms: float = 0.0
    embed_ms: float = 0.0
    lift_ms: float = 0.0
    associate_ms: float = 0.0
    ingest_ms: float = 0.0     # fused single-dispatch path (embed+lift+assoc)

    @property
    def total_ms(self):
        return (self.detect_ms + self.embed_ms + self.lift_ms +
                self.associate_ms + self.ingest_ms)

    def record(self, mode: str) -> None:
        """Feed the per-stage wall times into the process-wide metrics
        registry (no-op when none is installed)."""
        reg = obs_metrics.get_registry()
        if reg is None:
            return
        h = reg.histogram("mapping_stage_ms",
                          "per-keyframe mapping stage wall time (ms)")
        for stage in ("detect", "embed", "lift", "associate", "ingest"):
            v = getattr(self, f"{stage}_ms")
            if v > 0.0:
                h.observe(v, stage=stage, mode=mode)


@dataclass
class MappingServer:
    knobs: Knobs
    embedder: OracleEmbedder
    mode: str = "semanticxr"        # "baseline" | "parallel" | "semanticxr"
    instrument: bool = False        # semanticxr: staged timings vs one dispatch
    donate: bool = False            # donate the store to the fused ingest
    #                                 dispatch: the pre-frame store is dead
    #                                 once process_frame rebinds self.store,
    #                                 so XLA updates the [cap, ...] arrays in
    #                                 place instead of copying them per
    #                                 keyframe.  Opt-in: callers that hold a
    #                                 pre-frame store reference (snapshot
    #                                 readers, ablation oracles) must stay
    #                                 on the copying path.
    store: ObjectStore = None
    frame_count: int = 0
    deferred: int = 0
    cluster_index: object = None    # repro.index.ClusterIndex | None

    def __post_init__(self):
        kn = self.knobs
        if self.store is None:
            self.store = store_from_knobs(kn, self.embedder.embed_dim)
        r = kn.depth_downsampling_ratio
        budget = kn.max_object_points_server

        lift = partial(geo.lift_depth, stride=r, max_points=LIFT_BUFFER)
        # seed batched stages (B+P ablation arm): [D, ...] padded object batch
        self._lift_batch = jax.jit(jax.vmap(lift, in_axes=(None, 0, None,
                                                           None)))
        self._embed_batch = jax.jit(self.embedder.embed_observation)
        # sequential stages (baseline): one object at a time
        self._lift_one = jax.jit(lift)
        self._embed_one = jax.jit(
            lambda c, k: self.embedder.embed_observation(c[None], k)[0])

        # fused lift->compact->downsample->stats (SD instrumented arm): one
        # dispatch replaces lift_batch + down_batch + the per-detection
        # centroid pass inside association
        self._lift_fused = partial(ops.lift_compact, stride=r, budget=budget,
                                   lift_cap=LIFT_BUFFER)

        self._associate = jax.jit(lambda st, det, fr: assoc.associate(
            st, det, frame=fr, point_budget=budget))
        self._associate_cent = jax.jit(
            lambda st, det, cent, fr: assoc.associate(
                st, det, frame=fr, point_budget=budget, det_centroid=cent))
        self._prune = jax.jit(lambda st, fr: assoc.prune_transients(
            st, frame=fr, min_obs=kn.min_obs_before_sync))

        # the production path: ONE jitted dispatch per keyframe
        def ingest_frame(st, depth_lo, masks, intr, pose, cids, valid, key,
                         frame):
            embs = self.embedder.embed_observation(cids, key)
            pts, ns, cent, _, _ = ops.lift_compact(
                depth_lo, masks, intr, pose, stride=r, budget=budget,
                lift_cap=LIFT_BUFFER)
            det = assoc.Detections(embed=embs, label=cids, points=pts,
                                   n_points=ns, valid=valid)
            st = assoc.associate(st, det, frame=frame, point_budget=budget,
                                 det_centroid=cent)
            return assoc.prune_transients(st, frame=frame,
                                          min_obs=kn.min_obs_before_sync)

        self._ingest = jax.jit(ingest_frame, donate_argnums=(0,)) \
            if self.donate else jax.jit(ingest_frame)

    # ------------------------------------------------------------------
    def _detect(self, frame: Frame, classes: dict):
        """Detector stand-in: GT instance masks + mapping-policy filters.

        One vectorized bbox/area pass over the instance map — no per-object
        ``np.nonzero`` loop — with the deferral decision delegated to
        ``depth.mapping_gate``, the single home of the
        ``min_mapping_bbox_area`` logic (Sec. 3.3).
        Returns (class_ids [nd], masks_lo [nd, H/r, W/r] bool)."""
        kn = self.knobs
        r = kn.depth_downsampling_ratio
        inst_lo = frame.inst[::r, ::r] if r > 1 else frame.inst
        oids = np.asarray(frame.visible_ids, np.int32)
        cids = np.asarray([classes[int(o)] for o in oids], np.int32)
        if oids.size and kn.skip_mapping_set:
            m = ~np.isin(cids, np.asarray(kn.skip_mapping_set))
            oids, cids = oids[m], cids[m]
        if oids.size == 0:
            return cids[:0], np.zeros((0,) + inst_lo.shape, bool)

        # full-res bbox areas in one pass: row/col presence -> extents
        pres = frame.inst[None, :, :] == oids[:, None, None]   # [K, H, W]

        def extent(present):                                   # [K, L] bool
            first = present.argmax(axis=1)
            last = present.shape[1] - 1 - present[:, ::-1].argmax(axis=1)
            return last - first + 1

        area = extent(pres.any(axis=2)) * extent(pres.any(axis=1))
        keep = np.asarray(depth_mod.mapping_gate(
            area, kn, frame_pixels=frame.inst.size))
        self.deferred += int((~keep).sum())
        oids = oids[keep][: kn.max_detections_per_frame]
        cids = cids[keep][: kn.max_detections_per_frame]
        masks_lo = inst_lo[None, :, :] == oids[:, None, None]
        return cids, masks_lo

    # ------------------------------------------------------------------
    def process_frame(self, frame: Frame, classes: dict,
                      key: jax.Array) -> StageTimes:
        """Map one keyframe; returns per-stage wall times (Fig. 3)."""
        kn = self.knobs
        r = kn.depth_downsampling_ratio
        D = kn.max_detections_per_frame
        times = StageTimes()

        t0 = time.perf_counter()
        cids_np, masks_lo = self._detect(frame, classes)
        times.detect_ms = (time.perf_counter() - t0) * 1e3
        nd = len(cids_np)
        if nd == 0:
            self.frame_count += 1
            times.record(self.mode)
            return times

        depth_lo = jnp.asarray(depth_mod.downsample_depth(frame.depth, r))
        intr = jnp.asarray(frame.intrinsics)
        pose = jnp.asarray(frame.pose, jnp.float32)
        pad_c = jnp.asarray(np.pad(cids_np, (0, D - nd)))
        pad_m = np.zeros((D,) + masks_lo.shape[1:], bool)
        pad_m[:nd] = masks_lo
        valid = jnp.asarray(np.arange(D) < nd)

        # --- production path: ONE dispatch from masks to pruned store
        if self.mode == "semanticxr" and not self.instrument:
            t0 = time.perf_counter()
            with obs_span("pipeline.ingest_frame", cat="ingest",
                          nd=nd) as sp:
                self.store = self._ingest(self.store, depth_lo,
                                          jnp.asarray(pad_m), intr, pose,
                                          pad_c, valid, key,
                                          jnp.asarray(self.frame_count))
                sp.fence(self.store.active)
            jax.block_until_ready(self.store.active)
            times.ingest_ms = (time.perf_counter() - t0) * 1e3
            self._maintain_index()
            self.frame_count += 1
            times.record(self.mode)
            return times

        # --- staged execution (B / B+P arms, and instrumented SD)
        # embedding (object-level parallelism: batch vs sequential)
        t0 = time.perf_counter()
        if self.mode == "baseline":
            embs = jnp.stack([self._embed_one(jnp.asarray(cids_np[i]),
                                              jax.random.fold_in(key, i))
                              for i in range(nd)])
        else:
            embs = self._embed_batch(pad_c, key)
        embs.block_until_ready()
        times.embed_ms = (time.perf_counter() - t0) * 1e3

        # lift to 3D
        cent = None
        t0 = time.perf_counter()
        if self.mode == "baseline":
            lifted = [self._lift_one(depth_lo, jnp.asarray(masks_lo[i]),
                                     intr, pose) for i in range(nd)]
            pts = jnp.stack([l[0] for l in lifted])
            ns = jnp.stack([l[1] for l in lifted])
        elif self.mode == "parallel":
            pts, ns, _ = self._lift_batch(depth_lo, jnp.asarray(pad_m), intr,
                                          pose)
        else:
            # fused kernel: lift + downsample + centroid/bbox in one sweep
            pts, ns, cent, _, _ = self._lift_fused(depth_lo,
                                                   jnp.asarray(pad_m), intr,
                                                   pose)
        pts.block_until_ready()
        times.lift_ms = (time.perf_counter() - t0) * 1e3

        # association + merge (store buffers hold the cap; baseline and
        # P modes carry the uncapped buffer into the merge path)
        t0 = time.perf_counter()
        if self.mode == "baseline":
            pad = D - nd
            pts = jnp.pad(pts, ((0, pad), (0, 0), (0, 0)))
            ns = jnp.pad(ns, (0, pad))
            embs = jnp.pad(embs, ((0, pad), (0, 0)))
        det = assoc.Detections(
            embed=embs,
            label=pad_c,
            points=pts,
            n_points=ns,
            valid=valid,
        )
        fr = jnp.asarray(self.frame_count)
        if cent is not None:
            self.store = self._associate_cent(self.store, det, cent, fr)
        else:
            self.store = self._associate(self.store, det, fr)
        self.store = self._prune(self.store, fr)
        jax.block_until_ready(self.store.active)
        times.associate_ms = (time.perf_counter() - t0) * 1e3

        self._maintain_index()
        self.frame_count += 1
        times.record(self.mode)
        return times

    # ------------------------------------------------------------------
    def enable_index(self, **kw) -> None:
        """Attach a cluster-summary index (repro.index) over the mapping
        store; every mapped keyframe then maintains it incrementally and
        ``CloudService.query_spec`` plans coarse-to-fine through it."""
        from repro.index import ClusterIndex
        self.cluster_index = ClusterIndex.for_target(self.store, **kw)

    def _maintain_index(self):
        if self.cluster_index is not None:
            self.cluster_index.refresh(self.store)
