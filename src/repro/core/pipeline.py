"""Server-side per-frame semantic mapping pipeline (paper Fig. 2 + Sec. 3.1).

Three execution modes, matching the paper's Fig. 3 ablation bars:
  B        device-cloud baseline: frame-level sequential execution — each
           detected object runs the (compiled) per-object stages one after
           another, geometry uncapped into association.
  B+P      + object-level parallelism: the frame's detections are padded to
           a fixed object batch and every stage runs batched (one MXU
           dispatch instead of D sequential ones).
  B+P+SD   + object-level geometry downsampling: per-object clouds capped at
           max_object_points_server before association (= SemanticXR).

Perception models (detector stand-in = GT instance masks from the renderer;
embedder = perception/embedder.py) are identical across modes — observed
differences are system organization only (paper Sec. 4.2).  All stage
functions are jitted with shape-stable (padded) signatures so steady-state
latency is measured, not retracing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import association as assoc
from repro.core import depth as depth_mod
from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.store import ObjectStore, store_from_knobs
from repro.data.scenes import Frame
from repro.perception.embedder import OracleEmbedder

LIFT_BUFFER = 4096   # uncapped per-object buffer (baseline mode)


@dataclass
class StageTimes:
    detect_ms: float = 0.0
    embed_ms: float = 0.0
    lift_ms: float = 0.0
    associate_ms: float = 0.0

    @property
    def total_ms(self):
        return (self.detect_ms + self.embed_ms + self.lift_ms +
                self.associate_ms)


@dataclass
class MappingServer:
    knobs: Knobs
    embedder: OracleEmbedder
    mode: str = "semanticxr"        # "baseline" | "parallel" | "semanticxr"
    store: ObjectStore = None
    frame_count: int = 0
    deferred: int = 0

    def __post_init__(self):
        kn = self.knobs
        if self.store is None:
            self.store = store_from_knobs(kn, self.embedder.embed_dim)

        lift = partial(geo.lift_depth, stride=kn.depth_downsampling_ratio,
                       max_points=LIFT_BUFFER)
        # batched stages (P / SD modes): [D, ...] padded object batch
        self._lift_batch = jax.jit(jax.vmap(lift, in_axes=(None, 0, None,
                                                           None)))
        self._embed_batch = jax.jit(self.embedder.embed_observation)
        self._down_batch = jax.jit(jax.vmap(
            lambda p, n: geo.downsample(p, n, kn.max_object_points_server)))
        # sequential stages (baseline): one object at a time
        self._lift_one = jax.jit(lift)
        self._embed_one = jax.jit(
            lambda c, k: self.embedder.embed_observation(c[None], k)[0])

        self._associate = jax.jit(lambda st, det, fr: assoc.associate(
            st, det, frame=fr, point_budget=kn.max_object_points_server))
        self._prune = jax.jit(lambda st, fr: assoc.prune_transients(
            st, frame=fr, min_obs=kn.min_obs_before_sync))

    # ------------------------------------------------------------------
    def _detect(self, frame: Frame, classes: dict):
        """Detector stand-in: GT instance masks + mapping-policy filters."""
        kn = self.knobs
        r = kn.depth_downsampling_ratio
        dets = []
        for oid in frame.visible_ids:
            cid = classes[int(oid)]
            if cid in kn.skip_mapping_set:
                continue
            mask_full = frame.inst == oid
            ys, xs = np.nonzero(mask_full)
            area = (ys.max() - ys.min() + 1) * (xs.max() - xs.min() + 1)
            # depth co-design gate: defer small objects (Sec. 3.3).  Area is
            # scaled to full-sensor units so the knob default applies at any
            # simulated render resolution.
            full_scale = (720 * 1280) / mask_full.size
            if r > 1 and area * full_scale < kn.min_mapping_bbox_area:
                self.deferred += 1
                continue
            dets.append((int(oid), cid, mask_full))
        return dets[: kn.max_detections_per_frame]

    # ------------------------------------------------------------------
    def process_frame(self, frame: Frame, classes: dict,
                      key: jax.Array) -> StageTimes:
        """Map one keyframe; returns per-stage wall times (Fig. 3)."""
        kn = self.knobs
        r = kn.depth_downsampling_ratio
        D = kn.max_detections_per_frame
        times = StageTimes()

        t0 = time.perf_counter()
        dets = self._detect(frame, classes)
        times.detect_ms = (time.perf_counter() - t0) * 1e3
        if not dets:
            self.frame_count += 1
            return times
        nd = len(dets)

        depth_lo = jnp.asarray(depth_mod.downsample_depth(frame.depth, r))
        intr = jnp.asarray(frame.intrinsics)
        pose = jnp.asarray(frame.pose, jnp.float32)
        masks_lo = np.stack([depth_mod.downsample_mask(m, r)
                             for _, _, m in dets])
        cids_np = np.array([c for _, c, _ in dets], np.int32)

        # --- embedding (object-level parallelism: batch vs sequential)
        t0 = time.perf_counter()
        if self.mode == "baseline":
            embs = jnp.stack([self._embed_one(jnp.asarray(cids_np[i]),
                                              jax.random.fold_in(key, i))
                              for i in range(nd)])
        else:
            pad_c = jnp.asarray(np.pad(cids_np, (0, D - nd)))
            embs = self._embed_batch(pad_c, key)
        embs.block_until_ready()
        times.embed_ms = (time.perf_counter() - t0) * 1e3

        # --- lift to 3D
        t0 = time.perf_counter()
        if self.mode == "baseline":
            lifted = [self._lift_one(depth_lo, jnp.asarray(masks_lo[i]),
                                     intr, pose) for i in range(nd)]
            pts = jnp.stack([l[0] for l in lifted])
            ns = jnp.stack([l[1] for l in lifted])
        else:
            pad_m = np.zeros((D,) + masks_lo.shape[1:], bool)
            pad_m[:nd] = masks_lo
            pts, ns, _ = self._lift_batch(depth_lo, jnp.asarray(pad_m), intr,
                                          pose)
        # geometry downsampling (SD): cap before association
        if self.mode == "semanticxr":
            pts, ns = self._down_batch(pts, ns)
        pts.block_until_ready()
        times.lift_ms = (time.perf_counter() - t0) * 1e3

        # --- association + merge (store buffers hold the cap; baseline and
        # P modes carry the uncapped buffer into the merge path)
        t0 = time.perf_counter()
        if self.mode == "baseline":
            pad = D - nd
            pts = jnp.pad(pts, ((0, pad), (0, 0), (0, 0)))
            ns = jnp.pad(ns, (0, pad))
            embs = jnp.pad(embs, ((0, pad), (0, 0)))
        det = assoc.Detections(
            embed=embs,
            label=jnp.asarray(np.pad(cids_np, (0, D - nd))),
            points=pts,
            n_points=ns,
            valid=jnp.arange(D) < nd,
        )
        self.store = self._associate(self.store, det,
                                     jnp.asarray(self.frame_count))
        self.store = self._prune(self.store, jnp.asarray(self.frame_count))
        jax.block_until_ready(self.store.active)
        times.associate_ms = (time.perf_counter() - t0) * 1e3

        self.frame_count += 1
        return times
