"""Server-side semantic map: objects as first-class, fixed-capacity SoA state.

A map object = (stable id, semantic embedding, class label, 3D point cloud)
— the paper's core abstraction (Sec. 3).  The store is a pytree of arrays so
every operation (association, merge, query) is jit-able and shardable; slot
count is the capacity knob, `active` masks live slots.

``version`` increments on any semantically meaningful change (new geometry
angle, embedding update) — the incremental-update protocol (updates.py) ships
exactly the objects whose version advanced past the client's synced vector.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs


class ObjectStore(NamedTuple):
    ids: jax.Array          # [cap] int32, 0 = never assigned
    active: jax.Array       # [cap] bool
    embed: jax.Array        # [cap, E] f32, unit norm
    label: jax.Array        # [cap] int32
    points: jax.Array       # [cap, P, 3] f32 (masked by n_points)
    n_points: jax.Array     # [cap] int32
    centroid: jax.Array     # [cap, 3] f32
    bbox_min: jax.Array     # [cap, 3] f32
    bbox_max: jax.Array     # [cap, 3] f32
    obs_count: jax.Array    # [cap] int32
    version: jax.Array      # [cap] int32
    last_seen: jax.Array    # [cap] int32 frame index of last observation
    next_id: jax.Array      # [] int32


def init_store(capacity: int, embed_dim: int, max_points: int) -> ObjectStore:
    cap, P = capacity, max_points
    return ObjectStore(
        ids=jnp.zeros((cap,), jnp.int32),
        active=jnp.zeros((cap,), bool),
        embed=jnp.zeros((cap, embed_dim), jnp.float32),
        label=jnp.zeros((cap,), jnp.int32),
        points=jnp.zeros((cap, P, 3), jnp.float32),
        n_points=jnp.zeros((cap,), jnp.int32),
        centroid=jnp.zeros((cap, 3), jnp.float32),
        bbox_min=jnp.zeros((cap, 3), jnp.float32),
        bbox_max=jnp.zeros((cap, 3), jnp.float32),
        obs_count=jnp.zeros((cap,), jnp.int32),
        version=jnp.zeros((cap,), jnp.int32),
        last_seen=jnp.zeros((cap,), jnp.int32),
        next_id=jnp.ones((), jnp.int32),
    )


def store_from_knobs(knobs: Knobs, embed_dim: int) -> ObjectStore:
    return init_store(knobs.server_capacity, embed_dim,
                      knobs.max_object_points_server)


def synthetic_store(n: int, capacity: int, embed_dim: int, max_points: int,
                    *, seed: int = 0, centroid_low=(-4.0, 0.0, -4.0),
                    centroid_high=(4.0, 2.0, 4.0), n_labels: int = 20,
                    obs_count: int = 3) -> ObjectStore:
    """Directly-filled store with ``n`` active objects — the shared builder
    for benchmarks and tests that need a fixed-size map without running the
    mapping pipeline (unit-norm embeddings, random clouds/centroids,
    version 1, ids 1..n)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n, embed_dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    cents = rng.uniform(centroid_low, centroid_high,
                        size=(n, 3)).astype(np.float32)
    st = init_store(capacity, embed_dim, max_points)
    return st._replace(
        ids=st.ids.at[:n].set(jnp.arange(1, n + 1, dtype=jnp.int32)),
        active=st.active.at[:n].set(True),
        embed=st.embed.at[:n].set(emb),
        label=st.label.at[:n].set(jnp.asarray(
            rng.integers(0, n_labels, size=n), jnp.int32)),
        points=st.points.at[:n].set(
            rng.normal(size=(n, max_points, 3)).astype(np.float32)),
        n_points=st.n_points.at[:n].set(jnp.asarray(
            rng.integers(4, max_points, size=n), jnp.int32)),
        centroid=st.centroid.at[:n].set(cents),
        obs_count=st.obs_count.at[:n].set(obs_count),
        version=st.version.at[:n].set(1),
        next_id=jnp.asarray(n + 1, jnp.int32))


def n_active(store: ObjectStore) -> jax.Array:
    return store.active.sum()


def store_nbytes(store: ObjectStore) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in store))
