"""Server-side semantic map: objects as first-class, fixed-capacity SoA state.

A map object = (stable id, semantic embedding, class label, 3D point cloud)
— the paper's core abstraction (Sec. 3).  The store is a pytree of arrays so
every operation (association, merge, query) is jit-able and shardable; slot
count is the capacity knob, `active` masks live slots.

``version`` increments on any semantically meaningful change (new geometry
angle, embedding update) — the incremental-update protocol (updates.py) ships
exactly the objects whose version advanced past the client's synced vector.

Map *shrinkage* is first-class: ``remove_objects`` turns a live slot into a
version-bumped **tombstone** (``active=False, deleted=True``, id and centroid
retained so the update protocol and zone routing can still address it).  A
tombstone occupies its slot — association must not hand it to a new insert,
or a version-1 occupant would hide behind clients' higher synced versions —
until ``release_tombstones`` retires it once every sync vector has shipped
the deletion.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs


class ObjectStore(NamedTuple):
    ids: jax.Array          # [cap] int32, 0 = never assigned
    active: jax.Array       # [cap] bool
    embed: jax.Array        # [cap, E] f32, unit norm
    label: jax.Array        # [cap] int32
    points: jax.Array       # [cap, P, 3] f32 (masked by n_points)
    n_points: jax.Array     # [cap] int32
    centroid: jax.Array     # [cap, 3] f32
    bbox_min: jax.Array     # [cap, 3] f32
    bbox_max: jax.Array     # [cap, 3] f32
    obs_count: jax.Array    # [cap] int32
    version: jax.Array      # [cap] int32
    last_seen: jax.Array    # [cap] int32 frame index of last observation
    next_id: jax.Array      # [] int32
    deleted: jax.Array = None   # [cap] bool — tombstoned slots (removal
    #                             pending propagation; see remove_objects)


def init_store(capacity: int, embed_dim: int, max_points: int) -> ObjectStore:
    cap, P = capacity, max_points
    return ObjectStore(
        ids=jnp.zeros((cap,), jnp.int32),
        active=jnp.zeros((cap,), bool),
        embed=jnp.zeros((cap, embed_dim), jnp.float32),
        label=jnp.zeros((cap,), jnp.int32),
        points=jnp.zeros((cap, P, 3), jnp.float32),
        n_points=jnp.zeros((cap,), jnp.int32),
        centroid=jnp.zeros((cap, 3), jnp.float32),
        bbox_min=jnp.zeros((cap, 3), jnp.float32),
        bbox_max=jnp.zeros((cap, 3), jnp.float32),
        obs_count=jnp.zeros((cap,), jnp.int32),
        version=jnp.zeros((cap,), jnp.int32),
        last_seen=jnp.zeros((cap,), jnp.int32),
        next_id=jnp.ones((), jnp.int32),
        deleted=jnp.zeros((cap,), bool),
    )


def deleted_mask(store: ObjectStore) -> jax.Array:
    """[cap] bool tombstone mask; stores built before the field existed
    (deleted=None) read as all-False."""
    if store.deleted is None:
        return jnp.zeros_like(store.active)
    return store.deleted


def store_from_knobs(knobs: Knobs, embed_dim: int) -> ObjectStore:
    return init_store(knobs.server_capacity, embed_dim,
                      knobs.max_object_points_server)


def synthetic_store(n: int, capacity: int, embed_dim: int, max_points: int,
                    *, seed: int = 0, centroid_low=(-4.0, 0.0, -4.0),
                    centroid_high=(4.0, 2.0, 4.0), n_labels: int = 20,
                    obs_count: int = 3) -> ObjectStore:
    """Directly-filled store with ``n`` active objects — the shared builder
    for benchmarks and tests that need a fixed-size map without running the
    mapping pipeline (unit-norm embeddings, random clouds/centroids,
    version 1, ids 1..n)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n, embed_dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    cents = rng.uniform(centroid_low, centroid_high,
                        size=(n, 3)).astype(np.float32)
    st = init_store(capacity, embed_dim, max_points)
    return st._replace(
        ids=st.ids.at[:n].set(jnp.arange(1, n + 1, dtype=jnp.int32)),
        active=st.active.at[:n].set(True),
        embed=st.embed.at[:n].set(emb),
        label=st.label.at[:n].set(jnp.asarray(
            rng.integers(0, n_labels, size=n), jnp.int32)),
        points=st.points.at[:n].set(
            rng.normal(size=(n, max_points, 3)).astype(np.float32)),
        n_points=st.n_points.at[:n].set(jnp.asarray(
            rng.integers(4, max_points, size=n), jnp.int32)),
        centroid=st.centroid.at[:n].set(cents),
        obs_count=st.obs_count.at[:n].set(obs_count),
        version=st.version.at[:n].set(1),
        next_id=jnp.asarray(n + 1, jnp.int32))


def clustered_synthetic_store(n: int, capacity: int, embed_dim: int,
                              max_points: int, *, seed: int = 0,
                              n_proto: int = 64, proto_spread: float = 0.5,
                              n_hotspots: int = 128, room: float = 80.0,
                              hotspot_sigma: float = 1.2,
                              n_labels: int = 20,
                              obs_count: int = 3) -> ObjectStore:
    """Like ``synthetic_store`` but with *structured* content: centroids
    clustered around ``n_hotspots`` spatial hotspots in a ``room``-sized
    floor, and each hotspot populated from ONE of ``n_proto`` embedding
    prototypes (members = prototype + ``proto_spread``-norm noise,
    renormalized).  Real scenes look like this — many instances of few
    object kinds, spatially grouped (a desk cluster of monitors, a shelf
    of books) — and it is the regime where a cluster index earns its keep:
    i.i.d.-random embeddings give every cell the same mean and a residual
    near 1, so the coarse semantic bound can never certify a pruned sweep.
    Point clouds are zero-filled: at index/query scale the geometry column
    is dead weight (n_points is drawn, so predicates still bite)."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(n_proto, embed_dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    hid = rng.integers(0, n_hotspots, size=n)
    pid = hid % n_proto                  # spatially-correlated object kinds
    # noise scaled to unit-vector norm: ||noise|| ~ proto_spread, so
    # within-hotspot cosine similarity stays ~1/sqrt(1 + spread^2)
    emb = protos[pid] + proto_spread / np.sqrt(embed_dim) * rng.normal(
        size=(n, embed_dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)

    hot = rng.uniform(-room / 2, room / 2, size=(n_hotspots, 3)) \
        .astype(np.float32)
    hot[:, 1] = rng.uniform(0.0, 2.0, size=n_hotspots)
    cents = hot[hid] + hotspot_sigma * rng.normal(size=(n, 3)) \
        .astype(np.float32)

    st = init_store(capacity, embed_dim, 1)   # P=1: geometry is dead weight
    return st._replace(
        ids=st.ids.at[:n].set(jnp.arange(1, n + 1, dtype=jnp.int32)),
        active=st.active.at[:n].set(True),
        embed=st.embed.at[:n].set(emb),
        label=st.label.at[:n].set(jnp.asarray(pid % n_labels, jnp.int32)),
        n_points=st.n_points.at[:n].set(jnp.asarray(
            rng.integers(4, max(max_points, 5), size=n), jnp.int32)),
        centroid=st.centroid.at[:n].set(cents),
        obs_count=st.obs_count.at[:n].set(obs_count),
        version=st.version.at[:n].set(1),
        next_id=jnp.asarray(n + 1, jnp.int32))


def n_active(store: ObjectStore) -> jax.Array:
    return store.active.sum()


# ---------------------------------------------------------------------------
# Double-buffered store for the overlapped serving loop (serving/loop.py).
#
# JAX buffer donation makes functional updates in-place: the donated
# input's buffers are overwritten by the outputs.  That is exactly what a
# concurrent reader must never observe — so the serving loop keeps TWO
# generations.  ``front`` is the published snapshot every query / zone
# refresh reads; ``back`` is the previous generation, dead to all new
# dispatches, and therefore safe to donate to the next ingest scatter.
# Publishing is a host-side pointer swap (atomic under the GIL), so a
# reader sees exactly the pre-tick or the post-tick store, never a torn
# mix; dispatches already in flight against the old front are protected by
# the runtime's buffer usage tracking (a donated buffer's writes are
# sequenced after its outstanding reads).
# ---------------------------------------------------------------------------
_copy_store = jax.jit(lambda s: jax.tree.map(jnp.copy, s))


def copy_store(store: ObjectStore) -> ObjectStore:
    """Deep device copy (fresh buffers) — the second generation seed."""
    return _copy_store(store)


@dataclass
class SnapshotStore:
    """Two-generation ObjectStore with snapshot versioning.

    Protocol (one serving tick)::

        scratch = snap.take_back()                  # dead gen t-1 buffers
        new = ingest(scratch_donated, snap.pending, delta_t)   # catch up
        ... issue sync + query dispatches against snap.front ...
        snap.publish(new, pending=delta_t)          # swap; version += 1

    The donated ingest applies ``pending`` (the delta that produced the
    current front) and then this tick's delta, so the two-tick-old back
    buffer catches up in O(changed rows) without ever copying the full
    store — the donation saving the serving benchmark measures.
    ``version`` is the publish counter: a reader pairs it with the
    snapshot it grabbed to tell pre-tick from post-tick results.
    """
    front: ObjectStore
    back: ObjectStore | None = None
    version: int = 0
    pending: object = None       # delta that produced front from back

    @classmethod
    def of(cls, store: ObjectStore) -> "SnapshotStore":
        return cls(front=store, back=copy_store(store))

    def snapshot(self) -> tuple:
        """(published store, publish version) — consistent by construction."""
        return self.front, self.version

    def take_back(self) -> ObjectStore:
        """Hand out the dead generation for donation (once per tick)."""
        assert self.back is not None, \
            "take_back called twice without an intervening publish"
        b = self.back
        self.back = None
        return b

    def publish(self, new_front: ObjectStore, *, pending=None) -> None:
        """Swap: the current front becomes the next donation target."""
        assert self.back is None, "publish without take_back"
        self.back = self.front
        self.front = new_front
        self.pending = pending
        self.version += 1


def store_nbytes(store: ObjectStore) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in store
                   if x is not None))


# ---------------------------------------------------------------------------
# Map shrinkage: tombstone removal + slot retirement (paper Sec. 3.2 —
# downstream bandwidth must scale with map CHANGES, and a removal is a
# change like any other).
# ---------------------------------------------------------------------------
@jax.jit
def _tombstone_slots(store: ObjectStore, slots: jax.Array,
                     valid: jax.Array) -> ObjectStore:
    """Tombstone store rows ``slots`` (padding rows dropped via OOB index):
    active -> False, deleted -> True, version bump so the removal ships."""
    cap = store.ids.shape[0]
    tgt = jnp.where(valid & store.active[jnp.minimum(slots, cap - 1)],
                    slots, cap)
    return store._replace(
        active=store.active.at[tgt].set(False, mode="drop"),
        deleted=deleted_mask(store).at[tgt].set(True, mode="drop"),
        version=store.version.at[tgt].add(1, mode="drop"),
        n_points=store.n_points.at[tgt].set(0, mode="drop"))


def remove_objects(store: ObjectStore, oids) -> ObjectStore:
    """Remove live objects by id: each matching slot becomes a tombstone
    (id, centroid and version retained; geometry zeroed).  The slot stays
    occupied until release_tombstones — reusing it immediately would hide
    the next occupant behind clients' synced versions.  No-op for unknown
    or already-dead ids."""
    oids = np.atleast_1d(np.asarray(oids, np.int64))
    ids = np.asarray(store.ids)
    act = np.asarray(store.active)
    hit = np.isin(ids, oids) & act
    slots = np.nonzero(hit)[0]
    if not len(slots):
        return store
    from repro.core.updates import _bucket   # local import: cycle-free
    B = _bucket(len(slots))
    pad = np.zeros((B,), np.int32)
    pad[:len(slots)] = slots
    return _tombstone_slots(store, jnp.asarray(pad),
                            jnp.asarray(np.arange(B) < len(slots)))


def tombstone_slots(store: ObjectStore) -> np.ndarray:
    """Host-side indices of tombstoned slots (propagation pending)."""
    return np.nonzero(np.asarray(deleted_mask(store)))[0]


def release_tombstones(store: ObjectStore, slots=None) -> ObjectStore:
    """Retire tombstones: clear id/version/deleted so the slot is reusable.

    Call only once every client's sync vector covers the tombstone's
    version (the deletion has shipped) — the caller must then also reset
    those slots' synced versions (updates.SyncState rows /
    SessionManager.reset_slots) before an insert reuses them.  ``slots``
    defaults to every tombstone."""
    if slots is None:
        slots = tombstone_slots(store)
    slots = np.atleast_1d(np.asarray(slots, np.int64))
    if not len(slots):
        return store
    s = jnp.asarray(slots)
    return store._replace(
        ids=store.ids.at[s].set(0),
        deleted=deleted_mask(store).at[s].set(False),
        version=store.version.at[s].set(0),
        obs_count=store.obs_count.at[s].set(0))
