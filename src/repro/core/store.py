"""Server-side semantic map: objects as first-class, fixed-capacity SoA state.

A map object = (stable id, semantic embedding, class label, 3D point cloud)
— the paper's core abstraction (Sec. 3).  The store is a pytree of arrays so
every operation (association, merge, query) is jit-able and shardable; slot
count is the capacity knob, `active` masks live slots.

``version`` increments on any semantically meaningful change (new geometry
angle, embedding update) — the incremental-update protocol (updates.py) ships
exactly the objects whose version advanced past the client's synced vector.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs


class ObjectStore(NamedTuple):
    ids: jax.Array          # [cap] int32, 0 = never assigned
    active: jax.Array       # [cap] bool
    embed: jax.Array        # [cap, E] f32, unit norm
    label: jax.Array        # [cap] int32
    points: jax.Array       # [cap, P, 3] f32 (masked by n_points)
    n_points: jax.Array     # [cap] int32
    centroid: jax.Array     # [cap, 3] f32
    bbox_min: jax.Array     # [cap, 3] f32
    bbox_max: jax.Array     # [cap, 3] f32
    obs_count: jax.Array    # [cap] int32
    version: jax.Array      # [cap] int32
    last_seen: jax.Array    # [cap] int32 frame index of last observation
    next_id: jax.Array      # [] int32


def init_store(capacity: int, embed_dim: int, max_points: int) -> ObjectStore:
    cap, P = capacity, max_points
    return ObjectStore(
        ids=jnp.zeros((cap,), jnp.int32),
        active=jnp.zeros((cap,), bool),
        embed=jnp.zeros((cap, embed_dim), jnp.float32),
        label=jnp.zeros((cap,), jnp.int32),
        points=jnp.zeros((cap, P, 3), jnp.float32),
        n_points=jnp.zeros((cap,), jnp.int32),
        centroid=jnp.zeros((cap, 3), jnp.float32),
        bbox_min=jnp.zeros((cap, 3), jnp.float32),
        bbox_max=jnp.zeros((cap, 3), jnp.float32),
        obs_count=jnp.zeros((cap,), jnp.int32),
        version=jnp.zeros((cap,), jnp.int32),
        last_seen=jnp.zeros((cap,), jnp.int32),
        next_id=jnp.ones((), jnp.int32),
    )


def store_from_knobs(knobs: Knobs, embed_dim: int) -> ObjectStore:
    return init_store(knobs.server_capacity, embed_dim,
                      knobs.max_object_points_server)


def n_active(store: ObjectStore) -> jax.Array:
    return store.active.sum()


def store_nbytes(store: ObjectStore) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in store))
