"""Multi-tenant fleet server: the paper's server "multiplexes
perception/caption/query work from many XR clients" (Sec. 3.2) — this
subsystem turns the single-tenant pieces into that server.

Three layers:

``session``  SessionManager — C clients' sync state as stacked arrays
             (``synced_version: [C, N]``, per-client pose / min-obs knobs),
             so one update tick for the whole fleet is ONE jitted vmapped
             collect dispatch (`_collect_fleet`) producing C packets, not a
             Python loop over `core.updates.collect_updates`.

``zones``    ZoneShardedStore — objects partitioned into spatial zones
             (grid over the room plane), each zone an independent
             `core.store.ObjectStore` shard, placeable on mesh devices via
             `distributed.sharding.zone_shard_devices`.  Clients subscribe
             to the zones their pose overlaps; downstream work scales with
             per-client zone *changes*, not fleet size.

``mesh``     ClientRoster / MeshSessionTier / MeshFleetPacket — the client
             axis of a zone's session tier partitioned across S session
             shards (one per mesh device) by subscribed-zone affinity;
             control-plane messages route to the owning shard, the k-way
             merge happens only at the wire boundary, packets stay
             byte-identical to the single-device path
             (`FleetServer(n_session_shards=S)`).

``fleet``    FleetServer (zones x sessions composition) and FleetSimulator —
             tens-to-hundreds of simulated clients with heterogeneous
             `core.runtime.NetworkModel`s (mixed RTTs, staggered outages,
             join/leave churn), sharing the single-client per-tick step
             (`core.runtime.ClientSession`) and routing cross-client queries
             through `serving.batching.BatchScheduler` +
             `core.query` multi-query top-k.

Queries against the fleet store go through the declarative engine
(`core.query`, re-exported here): `FleetServer.query(Query(...))` compiles
the spec against the zone-sharded store — zone/near predicates prune shards
before dispatch, every selected shard runs the same fused plan.

Benchmarks: `benchmarks/fleet_scale.py` (tick latency and per-client
downstream bytes vs fleet size C) -> BENCH_fleet_scale.json; see
EXPERIMENTS.md § Fleet scale.  Tests: tests/test_fleet.py.
"""
from repro.core.query import (Query, QueryResult, CompiledQuery,
                              compile_query, execute_query, stack_queries)
from repro.server.session import (FleetBatch, FleetPacket, FleetSync,
                                  SessionManager)
from repro.server.zones import ZoneGrid, ZoneShardedStore
from repro.server.mesh import (ClientRoster, MeshFleetPacket,
                               MeshSessionTier)
from repro.server.fleet import FleetServer, FleetSimulator, SimClient
