"""Zone-sharded object store: spatial partition of the server map.

Objects are routed to zones by centroid over a fixed XZ grid; each zone is
an independent, fixed-capacity `ObjectStore` shard, so per-zone work
(per-client sync, queries) touches only that zone's slots.  Clients
subscribe to the zones their pose-radius overlaps — a client whose pose
stays inside one zone receives ZERO downstream bytes for objects mutated
only in other zones (tests/test_fleet.py asserts this with exact
`update_nbytes` accounting).

The mapping frontend stays monolithic (association needs the global view);
``refresh_from`` mirrors its store into the shards incrementally: only rows
whose version advanced since the last copy are re-scattered (one bucketed
jitted scatter per dirty zone, not per object).  Slot bookkeeping is
host-side; freed shard slots are reported so the per-zone SessionManager
can forget stale sync versions before the slot is reused.

When a device mesh is available the shards are placed round-robin on its
devices via `distributed.sharding.zone_shard_devices`; on the single-device
container placement is a no-op.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs
from repro.core.store import ObjectStore, deleted_mask, init_store
from repro.core.updates import _bucket


@dataclass(frozen=True)
class ZoneGrid:
    """Fixed XZ-plane partition of the mapped space into nx*nz zones."""
    origin: tuple            # (x0, z0) — min corner of the grid
    zone_size: float         # zone edge length (metres)
    nx: int
    nz: int

    @property
    def n_zones(self) -> int:
        return self.nx * self.nz

    @classmethod
    def for_room(cls, room_size: float, nx: int = 2, nz: int = 2):
        half = room_size / 2
        return cls(origin=(-half, -half), zone_size=room_size / max(nx, nz),
                   nx=nx, nz=nz)

    def zone_of(self, centroids: np.ndarray) -> np.ndarray:
        """[M, 3] centroids -> [M] zone ids (out-of-grid clamps to edge)."""
        c = np.atleast_2d(np.asarray(centroids))
        ix = np.clip(((c[:, 0] - self.origin[0]) // self.zone_size)
                     .astype(np.int64), 0, self.nx - 1)
        iz = np.clip(((c[:, 2] - self.origin[1]) // self.zone_size)
                     .astype(np.int64), 0, self.nz - 1)
        return ix * self.nz + iz

    def overlaps(self, pos, radius: float) -> np.ndarray:
        """[Z] bool — zones whose XZ rectangle intersects the pose circle.

        Border zones extend to infinity on their grid-exterior sides,
        mirroring the clamp in ``zone_of``: an object outside the grid and
        the client standing next to it land in the same zone."""
        pos = np.asarray(pos)
        px, pz = float(pos[0]), float(pos[2])
        inf = float("inf")
        out = np.zeros((self.n_zones,), bool)
        for ix in range(self.nx):
            for iz in range(self.nz):
                x0 = self.origin[0] + ix * self.zone_size
                z0 = self.origin[1] + iz * self.zone_size
                x1, z1 = x0 + self.zone_size, z0 + self.zone_size
                if ix == 0:
                    x0 = -inf
                if ix == self.nx - 1:
                    x1 = inf
                if iz == 0:
                    z0 = -inf
                if iz == self.nz - 1:
                    z1 = inf
                cx = np.clip(px, x0, x1)
                cz = np.clip(pz, z0, z1)
                if (cx - px) ** 2 + (cz - pz) ** 2 <= radius ** 2:
                    out[ix * self.nz + iz] = True
        return out

    def _zone_rects(self):
        """[Z] rectangle bounds (x0, x1, z0, z1) in zone-id order, border
        zones extended to infinity — cached: the grid is frozen."""
        r = getattr(self, "_rects", None)
        if r is None:
            inf = float("inf")
            ix, iz = np.divmod(np.arange(self.n_zones), self.nz)
            x0 = self.origin[0] + ix * self.zone_size
            z0 = self.origin[1] + iz * self.zone_size
            x1, z1 = x0 + self.zone_size, z0 + self.zone_size
            x0 = np.where(ix == 0, -inf, x0)
            x1 = np.where(ix == self.nx - 1, inf, x1)
            z0 = np.where(iz == 0, -inf, z0)
            z1 = np.where(iz == self.nz - 1, inf, z1)
            r = (x0, x1, z0, z1)
            object.__setattr__(self, "_rects", r)
        return r

    def overlaps_batch(self, poses: np.ndarray, radius) -> np.ndarray:
        """[C, 3] poses -> [C, Z] bool, identical to per-client ``overlaps``
        but one broadcast circle-rectangle test instead of a C * Z Python
        loop (the fleet pose-update hot path at C=256+)."""
        p = np.atleast_2d(np.asarray(poses, np.float64))
        x0, x1, z0, z1 = self._zone_rects()
        cx = np.clip(p[:, 0:1], x0[None], x1[None])        # [C, Z]
        cz = np.clip(p[:, 2:3], z0[None], z1[None])
        d2 = (cx - p[:, 0:1]) ** 2 + (cz - p[:, 2:3]) ** 2
        r = np.asarray(radius, np.float64).reshape(-1, 1)
        return d2 <= r ** 2


@jax.jit
def _zone_scatter(zone: ObjectStore, src: ObjectStore, g_idx: jax.Array,
                  z_idx: jax.Array, valid: jax.Array, deact_idx: jax.Array,
                  deact_valid: jax.Array) -> ObjectStore:
    """Copy src rows g_idx into zone rows z_idx and deactivate deact_idx —
    one scatter per field, padding rows dropped via OOB indices."""
    capz = zone.ids.shape[0]
    tgt = jnp.where(valid, z_idx, capz)
    dt = jnp.where(deact_valid, deact_idx, capz)

    def put(zf, sf):
        return zf.at[tgt].set(sf[g_idx], mode="drop")

    # copied rows take the SOURCE row's live/tombstone state (a global
    # tombstone mirrors as a shard tombstone so the deletion propagates
    # through the per-zone sync sessions); freed slots clear both
    active = zone.active.at[dt].set(False, mode="drop") \
                        .at[tgt].set(src.active[g_idx], mode="drop")
    deleted = deleted_mask(zone).at[dt].set(False, mode="drop") \
        .at[tgt].set(deleted_mask(src)[g_idx], mode="drop")
    return ObjectStore(
        ids=put(zone.ids, src.ids), active=active,
        embed=put(zone.embed, src.embed), label=put(zone.label, src.label),
        points=put(zone.points, src.points),
        n_points=put(zone.n_points, src.n_points),
        centroid=put(zone.centroid, src.centroid),
        bbox_min=put(zone.bbox_min, src.bbox_min),
        bbox_max=put(zone.bbox_max, src.bbox_max),
        obs_count=put(zone.obs_count, src.obs_count),
        version=put(zone.version, src.version),
        last_seen=put(zone.last_seen, src.last_seen),
        next_id=zone.next_id, deleted=deleted)


def _pad_idx(vals: list, bucket: int):
    arr = np.zeros((bucket,), np.int32)
    arr[:len(vals)] = vals
    return jnp.asarray(arr), jnp.asarray(np.arange(bucket) < len(vals))


@dataclass
class ZoneShardedStore:
    """The server map as Z independent ObjectStore shards + host routing."""
    knobs: Knobs
    embed_dim: int
    grid: ZoneGrid
    zone_capacity: int = 0
    max_points: int = 0
    zones: list = field(default_factory=list)
    indexes: dict = field(default_factory=dict)  # zone -> ClusterIndex
    #                                  (enable_index; core.query discovers
    #                                   this attr for the two-stage plan)
    _dropped_oids: set = field(default_factory=set)  # refused by full shard
    _slot: list = field(default_factory=list)   # per zone: {oid -> slot}
    _ver: list = field(default_factory=list)    # per zone: copied version
    _free: list = field(default_factory=list)   # per zone: free slot stack

    def __post_init__(self):
        Z = self.grid.n_zones
        if not self.zone_capacity:
            # headroom over an even split so skewed scenes don't overflow
            self.zone_capacity = max(16, 2 * self.knobs.server_capacity // Z)
        if not self.max_points:
            self.max_points = self.knobs.max_object_points_server
        if not self.zones:
            self.zones = [init_store(self.zone_capacity, self.embed_dim,
                                     self.max_points) for _ in range(Z)]
        else:
            self.zone_capacity = int(self.zones[0].ids.shape[0])
        # bookkeeping is rebuilt from the shards' own arrays, so passing
        # pre-populated zones keeps their occupied slots occupied
        self._slot, self._ver, self._free = [], [], []
        for zone in self.zones:
            act = np.asarray(zone.active) | np.asarray(deleted_mask(zone))
            ids = np.asarray(zone.ids)
            ver = np.asarray(zone.version)
            occ = np.nonzero(act)[0]
            self._slot.append({int(ids[s]): int(s) for s in occ})
            vv = np.full((self.zone_capacity,), -1, np.int64)
            vv[occ] = ver[occ]
            self._ver.append(vv)
            self._free.append([s for s in
                               range(self.zone_capacity - 1, -1, -1)
                               if not act[s]])

    # ------------------------------------------------------------------
    def refresh_from(self, store: ObjectStore):
        """Mirror the global store into the shards (only version-advanced
        rows are copied).  Returns (freed_per_zone, changed_per_zone):
        per-zone lists of freed shard slots — feed these to
        SessionManager.reset_slots before the slot is reused — and per-zone
        dirtiness flags so clean zones can skip their next collect.
        """
        active = np.asarray(store.active)
        version = np.asarray(store.version)
        ids = np.asarray(store.ids)
        cent = np.asarray(store.centroid)
        # tombstones mirror like live rows (routed by their retained
        # centroid): the shard must hold the version-bumped deletion until
        # every subscriber has shipped it; once the global store retires
        # the slot the row vanishes from `now` and the shard slot is freed
        gidx = np.nonzero(active | np.asarray(deleted_mask(store)))[0]
        Z = self.grid.n_zones
        now = [dict() for _ in range(Z)]
        if len(gidx):
            zids = self.grid.zone_of(cent[gidx])
            for g, z in zip(gidx, zids):
                now[int(z)][int(ids[g])] = int(g)

        freed_per_zone, changed_per_zone = [], []
        for z in range(Z):
            slot = self._slot[z]
            freed, g_list, s_list = [], [], []
            for oid in [o for o in slot if o not in now[z]]:
                s = slot.pop(oid)
                self._ver[z][s] = -1
                self._free[z].append(s)
                freed.append(s)
            for oid, g in now[z].items():
                s = slot.get(oid)
                if s is None:
                    if not self._free[z]:
                        self._dropped_oids.add(oid)
                        continue
                    s = self._free[z].pop()
                    slot[oid] = s
                if self._ver[z][s] != version[g]:
                    self._ver[z][s] = version[g]
                    g_list.append(g)
                    s_list.append(s)
            freed_per_zone.append(freed)
            changed_per_zone.append(bool(freed or g_list))
            if freed or g_list:
                B = _bucket(max(len(g_list), 1))
                gb, gv = _pad_idx(g_list, B)
                sb, _ = _pad_idx(s_list, B)
                db, dv = _pad_idx(freed, _bucket(max(len(freed), 1)))
                self.zones[z] = _zone_scatter(self.zones[z], store, gb, sb,
                                              gv, db, dv)
                # cluster-index maintenance rides the same delta: exactly
                # the scattered + freed shard slots are re-indexed
                zidx = self.indexes.get(z)
                if zidx is not None:
                    zidx.update_slots(self.zones[z], s_list + freed)
        return freed_per_zone, changed_per_zone

    # ------------------------------------------------------------------
    def enable_index(self, *, n_cells_target: int | None = None,
                     cell_cap: int | None = None,
                     min_flat_size: int | None = None) -> dict:
        """Attach one incrementally-maintained ClusterIndex per zone shard
        (repro.index) over the zone's own rectangle; from then on
        ``refresh_from`` keeps them current and ``core.query`` plans the
        coarse-to-fine two-stage sweep on any shard past
        ``min_flat_size`` live objects."""
        from repro.core.updates import bucket
        from repro.index import ClusterIndex, DEFAULT_MIN_FLAT
        from repro.index.cluster import CellGrid
        if min_flat_size is None:
            min_flat_size = DEFAULT_MIN_FLAT
        capz = self.zone_capacity
        if n_cells_target is None:
            n_cells_target = min(max(capz // 256, 16), 16_384)
        for z in range(self.grid.n_zones):
            ix, iz = divmod(z, self.grid.nz)
            x0 = self.grid.origin[0] + ix * self.grid.zone_size
            z0 = self.grid.origin[1] + iz * self.grid.zone_size
            cgrid = CellGrid.for_rect(x0, z0, self.grid.zone_size,
                                      self.grid.zone_size, n_cells_target)
            cc = cell_cap if cell_cap is not None else \
                bucket(max(4 * capz // cgrid.n_cells, 16))
            idx = ClusterIndex(grid=cgrid, embed_dim=self.embed_dim,
                               capacity=capz, cell_cap=int(cc),
                               min_flat_size=min_flat_size)
            idx.refresh(self.zones[z])
            self.indexes[z] = idx
        return self.indexes

    # ------------------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Distinct objects ever refused by a full shard (not retries)."""
        return len(self._dropped_oids)

    def subscriptions(self, pos, radius: float) -> np.ndarray:
        return self.grid.overlaps(pos, radius)

    def n_active(self) -> int:
        return int(sum(int(np.asarray(z.active).sum()) for z in self.zones))

    def place_on(self, mesh) -> None:
        """Place shard z on mesh device z % ndev (no-op on 1 device)."""
        from repro.distributed.sharding import zone_shard_devices
        devs = zone_shard_devices(mesh, len(self.zones))
        self.zones = [jax.device_put(zone, d)
                      for zone, d in zip(self.zones, devs)]
