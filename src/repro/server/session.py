"""Multi-tenant per-client sync: stacked sync vectors, one vmapped collect.

The single-client protocol (core/updates.py) keeps one ``synced_version[N]``
vector per client and builds each client's packet with a host-side pass over
the store.  Serving C clients that way costs C Python-loop iterations and C
dispatches per tick.  Here the fleet's sync state is ONE ``[C, N]`` array
and the whole tick is one jitted dispatch (`_collect_fleet`):

  changed[C, N]  = active & (obs >= min_obs[c]) & (version > synced[c])
                   & subscribed-and-deliverable[c]
  priority[C, N] = vmapped compute_priority over per-client user_pos
  top-k          = per-client budgeted selection (lax.top_k over the
                   priority-masked scores; invalid rows sort last, so live
                   rows form a prefix exactly like the single-client packet)
  gather         = fused gather+stride-downsample straight from store rows
                   to the [C, U, Pc, 3] wire tensor (no [C, U, Pserver, 3]
                   intermediate)
  sync advance   = vmapped scatter of the shipped versions

Byte accounting matches core/updates.py exactly (same wire format), so the
fleet packets and single-client packets are interchangeable — asserted in
tests/test_fleet.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.local_map import UpdateBatch, compute_priority
from repro.core.store import ObjectStore, deleted_mask
from repro.core.updates import _HEADER_B, TOMBSTONE_NBYTES, UpdatePacket


class FleetSync(NamedTuple):
    """Stacked per-client sync vectors: last shipped version per store slot."""
    synced_version: jax.Array    # [C, N] int32


class FleetBatch(NamedTuple):
    """C clients' update packets as one SoA pytree (leading [C, U] dims)."""
    oid: jax.Array        # [C, U] int32
    embed: jax.Array      # [C, U, E] f32
    label: jax.Array      # [C, U] int32
    points: jax.Array     # [C, U, Pc, 3] f16
    n_points: jax.Array   # [C, U] int32
    centroid: jax.Array   # [C, U, 3] f32
    version: jax.Array    # [C, U] int32
    valid: jax.Array      # [C, U] bool — live-row prefix mask per client
    deleted: jax.Array = None   # [C, U] bool — tombstone rows


def _downsample_gather(points: jax.Array, n_points: jax.Array,
                       idx: jax.Array, budget: int):
    """Gather store rows ``idx`` [C, U] and stride-downsample to ``budget``
    in one fused indexing op — identical semantics to geo.downsample
    composed with the row gather, without materializing [C, U, Pserver, 3].
    """
    P = points.shape[1]
    n = jnp.maximum(n_points[idx], 1)                       # [C, U]
    ar = jnp.arange(budget)
    sub = jnp.where(n[..., None] > budget, (ar * n[..., None]) // budget, ar)
    sub = jnp.minimum(sub, P - 1)                           # [C, U, B]
    out = points[idx[..., None], sub]                       # [C, U, B, 3]
    n_out = jnp.minimum(n, budget).astype(jnp.int32)
    valid = ar < n_out[..., None]
    return jnp.where(valid[..., None], out, 0.0), n_out


@functools.partial(jax.jit,
                   static_argnames=("budget", "points_budget", "knobs"))
def _collect_fleet(store: ObjectStore, synced: jax.Array, mask_c: jax.Array,
                   min_obs: jax.Array, user_pos: jax.Array,
                   interest_embeds, *, budget: int, points_budget: int,
                   knobs: Knobs):
    """One update tick for the whole fleet in a single dispatch.

    Returns (FleetBatch, new_synced [C, N], nbytes [C], counts [C]).
    """
    dele = deleted_mask(store)
    live = (store.active[None]
            & (store.obs_count[None] >= min_obs[:, None])
            & (store.version[None] > synced))
    # a tombstone ships to exactly the clients whose sync vector ever
    # covered the object; clients that never held it delete nothing
    tomb = (dele[None] & (synced > 0)
            & (store.version[None] > synced))
    changed = (live | tomb) & mask_c[:, None]
    pri = jax.vmap(lambda up: compute_priority(
        store.embed, store.label, store.centroid, user_pos=up, knobs=knobs,
        interest_embeds=interest_embeds))(user_pos)          # [C, N]
    # deletions jump the queue: a freed client slot outranks a refresh
    pri = jnp.where(tomb, jnp.float32(1e30), pri)
    score = jnp.where(changed, pri, -jnp.inf)
    top, idx = jax.lax.top_k(score, budget)                  # [C, U]
    valid = jnp.isfinite(top)
    row_del = jnp.take_along_axis(tomb, idx, axis=1) & valid  # [C, U]

    pts, n = _downsample_gather(store.points, store.n_points, idx,
                                points_budget)
    n = jnp.where(row_del, 0, n)
    pts = jnp.where(row_del[..., None, None], 0.0, pts)
    cent = jax.vmap(jax.vmap(lambda p, m: geo.centroid_bbox(p, m)[0]))(pts, n)
    cent = jnp.where(row_del[..., None], store.centroid[idx], cent)
    batch = FleetBatch(
        oid=store.ids[idx], embed=store.embed[idx], label=store.label[idx],
        points=pts.astype(jnp.float16), n_points=n, centroid=cent,
        version=store.version[idx], valid=valid, deleted=row_del)

    N = synced.shape[1]
    shipped = jnp.where(valid, idx, N)                       # OOB -> dropped
    new_synced = jax.vmap(
        lambda s, i, w: s.at[i].set(w, mode="drop"))(
            synced, shipped, store.version[idx])
    # fully-empty slots must not pin a stale synced version on any client
    new_synced = jnp.where((store.active | dele)[None], new_synced, 0)

    E = store.embed.shape[1]
    n_live = jnp.where(valid, n, 0)
    counts = valid.sum(axis=-1).astype(jnp.int32)
    n_tomb = row_del.sum(axis=-1).astype(jnp.int32)
    nbytes = ((counts - n_tomb) * (_HEADER_B + 2 * E)
              + 6 * n_live.sum(axis=-1) + n_tomb * TOMBSTONE_NBYTES)
    return batch, new_synced, nbytes, counts


@dataclass
class FleetPacket:
    """One tick's C packets: the FleetBatch plus host-side accounting."""
    batch: FleetBatch
    counts: np.ndarray       # [C] live rows per client
    nbytes: np.ndarray       # [C] exact wire bytes per client
    tick: int

    @property
    def total_nbytes(self) -> int:
        return int(self.nbytes.sum())

    def tomb_counts(self) -> np.ndarray:
        """[C] tombstone rows actually shipped per client this tick."""
        if self.batch is None or self.batch.deleted is None:
            return np.zeros_like(self.counts)
        return (np.asarray(self.batch.deleted)
                & np.asarray(self.batch.valid)).sum(axis=1)

    def packet_for(self, c: int) -> UpdatePacket:
        """Single-client UpdatePacket view (leading-dim slice, no copy on
        the live path — `DeviceClient.ingest` consumes the batch as-is)."""
        cnt = int(self.counts[c])
        if cnt == 0:
            return UpdatePacket(batch=None, count=0, nbytes=0, tick=self.tick)
        b = self.batch
        ub = UpdateBatch(oid=b.oid[c], embed=b.embed[c], label=b.label[c],
                         points=b.points[c], n_points=b.n_points[c],
                         centroid=b.centroid[c], version=b.version[c],
                         valid=b.valid[c],
                         deleted=None if b.deleted is None else b.deleted[c])
        return UpdatePacket(batch=ub, count=cnt, nbytes=int(self.nbytes[c]),
                            tick=self.tick)


@dataclass
class SessionManager:
    """C clients' sync state against one store (or one zone shard).

    Per-client knobs live as stacked host arrays (pose, min-obs,
    subscription); the sync vectors live on device as one [C, N] array.
    ``collect`` is the fleet hot path: one `_collect_fleet` dispatch for all
    C clients.  Unsubscribed / undeliverable clients simply don't advance
    their sync rows, so their next deliverable tick coalesces everything
    they missed (same semantics as CloudService.flush_buffer).
    """
    knobs: Knobs
    n_clients: int
    capacity: int                      # N = slot count of the served store
    budget: int = 64                   # max objects shipped per client/tick
    sync: FleetSync = None
    subscribed: np.ndarray = None      # [C] bool
    user_pos: np.ndarray = None        # [C, 3] f32
    min_obs: np.ndarray = None         # [C] int32
    interest_embeds: object = None     # optional [I, E] shared interests
    tick: int = 0
    dirty: bool = True                 # False only when the last collect
    #                                    covered every subscriber and
    #                                    shipped nothing (fleet quiesced)

    def __post_init__(self):
        C, N = self.n_clients, self.capacity
        self.budget = min(self.budget, N)
        if self.sync is None:
            self.sync = FleetSync(jnp.zeros((C, N), jnp.int32))
        if self.subscribed is None:
            self.subscribed = np.ones((C,), bool)
        if self.user_pos is None:
            self.user_pos = np.zeros((C, 3), np.float32)
        if self.min_obs is None:
            self.min_obs = np.full((C,), self.knobs.min_obs_before_sync,
                                   np.int32)

    # -- per-client knob management (control plane, off the hot path) ------
    def set_client(self, c: int, *, user_pos=None, min_obs=None,
                   subscribed=None):
        if user_pos is not None:
            self.user_pos[c] = np.asarray(user_pos, np.float32)
        if min_obs is not None:
            if int(min_obs) != int(self.min_obs[c]):
                self.dirty = True      # eligibility changed: re-collect
            self.min_obs[c] = int(min_obs)
        if subscribed is not None:
            if bool(subscribed) != bool(self.subscribed[c]):
                self.dirty = True      # membership changed: re-collect
            self.subscribed[c] = bool(subscribed)

    def reset_client(self, c: int):
        """Fresh join: zero the sync row so the next tick ships a full
        catch-up of the subscribed store."""
        self.dirty = True
        self.sync = FleetSync(self.sync.synced_version.at[c].set(0))

    def reset_slots(self, slots):
        """Store slots were freed/reassigned (zone shard slot reuse): forget
        every client's synced version there so a future occupant ships."""
        if len(slots):
            self.dirty = True
            self.sync = FleetSync(
                self.sync.synced_version.at[:, np.asarray(slots)].set(0))

    # -- hot path ----------------------------------------------------------
    def collect(self, store: ObjectStore, *,
                deliverable: np.ndarray | None = None) -> FleetPacket:
        """One fleet update tick: ONE jitted dispatch for all C clients."""
        mask = self.subscribed if deliverable is None \
            else self.subscribed & np.asarray(deliverable, bool)
        batch, new_synced, nbytes, counts = _collect_fleet(
            store, self.sync.synced_version, jnp.asarray(mask),
            jnp.asarray(self.min_obs), jnp.asarray(self.user_pos),
            self.interest_embeds, budget=self.budget,
            points_budget=self.knobs.max_object_points_client,
            knobs=self.knobs)
        self.sync = FleetSync(new_synced)
        pkt = FleetPacket(batch=batch, counts=np.asarray(counts),
                          nbytes=np.asarray(nbytes), tick=self.tick)
        self.tick += 1
        # quiesced iff every subscriber was covered and nothing shipped
        # (a partial-coverage tick may still owe undeliverable clients)
        self.dirty = bool(pkt.counts.any()) or not (mask ==
                                                    self.subscribed).all()
        return pkt
