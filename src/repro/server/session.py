"""Multi-tenant per-client sync: stacked sync vectors, one vmapped collect.

The single-client protocol (core/updates.py) keeps one ``synced_version[N]``
vector per client and builds each client's packet with a host-side pass over
the store.  Serving C clients that way costs C Python-loop iterations and C
dispatches per tick.  Here the fleet's sync state is ONE ``[C, N]`` array
and the whole tick is one jitted dispatch (`_collect_fleet`):

  changed[C, N]  = active & (obs >= min_obs[c]) & (version > synced[c])
                   & subscribed-and-deliverable[c]
  priority[C, N] = vmapped compute_priority over per-client user_pos
  top-k          = per-client budgeted selection (lax.top_k over the
                   priority-masked scores; invalid rows sort last, so live
                   rows form a prefix exactly like the single-client packet)
  gather         = fused gather+stride-downsample straight from store rows
                   to the [C, U, Pc, 3] wire tensor (no [C, U, Pserver, 3]
                   intermediate)
  sync advance   = vmapped scatter of the shipped versions

Byte accounting matches core/updates.py exactly (same wire format), so the
fleet packets and single-client packets are interchangeable — asserted in
tests/test_fleet.py.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.local_map import UpdateBatch, compute_priority
from repro.core.store import ObjectStore, deleted_mask
from repro.obs.trace import span as obs_span
from repro.core.updates import (_HEADER_B, PROTO_HEADER_NBYTES,
                                TOMBSTONE_NBYTES, UpdatePacket,
                                class_budget_table)


class FleetSync(NamedTuple):
    """Stacked per-client sync state, all device-resident so consecutive
    collects chain through dispatch order alone — no host round-trip
    between a tick's collect and the next tick's (the overlapped serving
    loop defers packet framing a full tick on the strength of this)."""
    synced_version: jax.Array    # [C, N] int32 — last shipped version
    ever_sent: jax.Array = None  # [C, N] bool — row was EVER shipped


class FleetBatch(NamedTuple):
    """C clients' update packets as one SoA pytree (leading [C, U] dims)."""
    oid: jax.Array        # [C, U] int32
    embed: jax.Array      # [C, U, E] f32
    label: jax.Array      # [C, U] int32
    points: jax.Array     # [C, U, Pc, 3] f16
    n_points: jax.Array   # [C, U] int32
    centroid: jax.Array   # [C, U, 3] f32
    version: jax.Array    # [C, U] int32
    valid: jax.Array      # [C, U] bool — live-row prefix mask per client
    deleted: jax.Array = None   # [C, U] bool — tombstone rows


def _downsample_gather(points: jax.Array, n_points: jax.Array,
                       idx: jax.Array, row_budget: jax.Array, budget: int):
    """Gather store rows ``idx`` [C, U] and stride-downsample each row to
    its own ``row_budget`` (per-class overrides; ``budget`` is the shared
    buffer width and hard cap) in one fused indexing op — identical
    semantics to geo.downsample_dyn composed with the row gather, without
    materializing [C, U, Pserver, 3].
    """
    P = points.shape[1]
    n = jnp.maximum(n_points[idx], 1)                       # [C, U]
    b = jnp.clip(row_budget, 1, budget)[..., None]          # [C, U, 1]
    ar = jnp.arange(budget)
    sub = jnp.where(n[..., None] > b, (ar * n[..., None]) // b, ar)
    sub = jnp.minimum(sub, P - 1)                           # [C, U, B]
    out = points[idx[..., None], sub]                       # [C, U, B, 3]
    n_out = jnp.minimum(n[..., None], b)[..., 0].astype(jnp.int32)
    valid = ar < n_out[..., None]
    return jnp.where(valid[..., None], out, 0.0), n_out


def _collect_fleet_impl(store: ObjectStore, synced: jax.Array,
                        ever_sent: jax.Array, clear_mask: jax.Array,
                        mask_c: jax.Array,
                        min_obs: jax.Array, user_pos: jax.Array,
                        interest_embeds, class_budgets: jax.Array, *,
                        budget: int, points_budget: int, knobs: Knobs):
    """One update tick for the whole fleet in a single dispatch.

    ``class_budgets`` [256] is the per-class client point budget table
    (updates.class_budget_table) — the fleet path honors
    ``Knobs.class_point_overrides`` row-by-row exactly like the
    single-client gather.

    Returns (FleetBatch, new_synced [C, N], new_ever [C, N], nbytes [C],
    counts [C], idx [C, U] — the store slots behind each packet row, for
    the sender's in-flight/ack bookkeeping).
    """
    # slots freed since the last collect (reset_slots) clear INSIDE the
    # dispatch: the [N] mask rides in as 1 KB of host data instead of two
    # eager [C, N] where-ops materializing fresh sync arrays every free —
    # the kernel already streams synced/ever_sent, so the fold is free
    synced = jnp.where(clear_mask[None], 0, synced)
    ever_sent = jnp.where(clear_mask[None], False, ever_sent)
    dele = deleted_mask(store)
    live = (store.active[None]
            & (store.obs_count[None] >= min_obs[:, None])
            & (store.version[None] > synced))
    # a tombstone ships to exactly the clients the object was EVER shipped
    # to; clients that never held it delete nothing.  ever_sent (not
    # synced > 0) is the gate: a resync rollback drops sync to the acked
    # vector, but the deletion must still reach a client whose ack was
    # lost upstream.
    tomb = (dele[None] & ever_sent
            & (store.version[None] > synced))
    changed = (live | tomb) & mask_c[:, None]
    pri = jax.vmap(lambda up: compute_priority(
        store.embed, store.label, store.centroid, user_pos=up, knobs=knobs,
        interest_embeds=interest_embeds))(user_pos)          # [C, N]
    # deletions jump the queue: a freed client slot outranks a refresh
    pri = jnp.where(tomb, jnp.float32(1e30), pri)
    score = jnp.where(changed, pri, -jnp.inf)
    top, idx = jax.lax.top_k(score, budget)                  # [C, U]
    valid = jnp.isfinite(top)
    row_del = jnp.take_along_axis(tomb, idx, axis=1) & valid  # [C, U]

    row_b = class_budgets[jnp.clip(store.label[idx], 0, 255)]
    pts, n = _downsample_gather(store.points, store.n_points, idx, row_b,
                                points_budget)
    n = jnp.where(row_del, 0, n)
    pts = jnp.where(row_del[..., None, None], 0.0, pts)
    cent = jax.vmap(jax.vmap(lambda p, m: geo.centroid_bbox(p, m)[0]))(pts, n)
    cent = jnp.where(row_del[..., None], store.centroid[idx], cent)
    batch = FleetBatch(
        oid=store.ids[idx], embed=store.embed[idx], label=store.label[idx],
        points=pts.astype(jnp.float16), n_points=n, centroid=cent,
        version=store.version[idx], valid=valid, deleted=row_del)

    N = synced.shape[1]
    shipped = jnp.where(valid, idx, N)                       # OOB -> dropped
    new_synced = jax.vmap(
        lambda s, i, w: s.at[i].set(w, mode="drop"))(
            synced, shipped, store.version[idx])
    # fully-empty slots must not pin a stale synced version on any client
    new_synced = jnp.where((store.active | dele)[None], new_synced, 0)
    # the sent-gate updates INSIDE the dispatch so consecutive collects
    # chain on-device (no empty-slot clearing here: only reset_slots /
    # reset_client may forget a shipped row, exactly like the host mirror)
    new_ever = jax.vmap(lambda e, i: e.at[i].set(True, mode="drop"))(
        ever_sent, shipped)

    E = store.embed.shape[1]
    n_live = jnp.where(valid, n, 0)
    counts = valid.sum(axis=-1).astype(jnp.int32)
    n_tomb = row_del.sum(axis=-1).astype(jnp.int32)
    nbytes = ((counts - n_tomb) * (_HEADER_B + 2 * E)
              + 6 * n_live.sum(axis=-1) + n_tomb * TOMBSTONE_NBYTES)
    return batch, new_synced, new_ever, nbytes, counts, idx


_COLLECT_STATICS = ("budget", "points_budget", "knobs")
_collect_fleet = functools.partial(
    jax.jit, static_argnames=_COLLECT_STATICS)(_collect_fleet_impl)
# Donating variant: the [C, N] sync-state array is dead the moment the
# dispatch is issued (the session rebinds to new_synced), so XLA may write
# new_synced in place instead of allocating + copying a fresh [C, N] every
# tick.  Byte-identical to the non-donating path (tests/test_serving_loop);
# opt-in via SessionManager(donate=True) because callers that keep their
# own reference to synced_version (oracle tests, benchmarks that reset the
# sync state from a saved array) would read a deleted buffer.
_collect_fleet_donated = jax.jit(_collect_fleet_impl, donate_argnums=(1, 2),
                                 static_argnames=_COLLECT_STATICS)


class _PendingCollect(NamedTuple):
    """An issued-but-unresolved collect dispatch: device handles plus the
    host-side context ``collect_finish`` needs.  Between issue and finish
    the caller is free to dispatch other work (the overlapped loop issues
    every zone's collect, then ingest and queries, before materializing
    any counts) — nothing here forces a device sync."""
    batch: FleetBatch
    nbytes: jax.Array     # [C] device
    counts: jax.Array     # [C] device
    idx: jax.Array        # [C, U] device
    mask: np.ndarray      # [C] bool — subscribed & deliverable at issue
    zone: int
    epoch: np.ndarray
    fresh: np.ndarray
    now: int | None
    scrub: np.ndarray = None   # [N] bool — slots freed AFTER issue; their
    #                            rows must not enter in-flight/ever_sent
    #                            bookkeeping at finish (deferred pipeline)


@dataclass
class FleetPacket:
    """One tick's C packets: the FleetBatch plus host-side accounting.

    When the session assigns sequence numbers (``seqs[c] >= 0``) the
    single-client views carry the hardened-protocol framing: per-(client,
    zone) seq, the client's sync epoch, and — under the fault-injection
    transport (``proto``) — a crc32 checksum.  Framing bytes are counted
    in ``nbytes`` only when ``proto`` is on, so the clean-link byte
    accounting is unchanged."""
    batch: FleetBatch
    counts: np.ndarray       # [C] live rows per client
    nbytes: np.ndarray       # [C] exact wire bytes per client
    tick: int
    zone: int = 0            # zone shard this packet's seq streams belong to
    seqs: np.ndarray = None  # [C] int64 — per-client seq (-1 = unframed)
    epoch: np.ndarray = None  # [C] int64 — per-client sync epoch
    fresh: np.ndarray = None  # [C] bool — epoch restarted from scratch
    proto: bool = False      # fault-injection transport: checksum + header

    @property
    def total_nbytes(self) -> int:
        return int(self.nbytes.sum())

    def block_until_ready(self) -> None:
        """Fence the packet's device tensors (serving-loop sync path)."""
        if self.batch is not None:
            jax.block_until_ready(self.batch.valid)

    def tomb_counts(self) -> np.ndarray:
        """[C] tombstone rows actually shipped per client this tick."""
        if self.batch is None or self.batch.deleted is None:
            return np.zeros_like(self.counts)
        return (np.asarray(self.batch.deleted)
                & np.asarray(self.batch.valid)).sum(axis=1)

    def packet_for(self, c: int) -> UpdatePacket:
        """Single-client UpdatePacket view (leading-dim slice, no copy on
        the live path — `DeviceClient.ingest` consumes the batch as-is)."""
        cnt = int(self.counts[c])
        if cnt == 0:
            return UpdatePacket(batch=None, count=0, nbytes=0, tick=self.tick)
        b = self.batch
        ub = UpdateBatch(oid=b.oid[c], embed=b.embed[c], label=b.label[c],
                         points=b.points[c], n_points=b.n_points[c],
                         centroid=b.centroid[c], version=b.version[c],
                         valid=b.valid[c],
                         deleted=None if b.deleted is None else b.deleted[c])
        pkt = UpdatePacket(batch=ub, count=cnt, nbytes=int(self.nbytes[c]),
                           tick=self.tick)
        if self.seqs is not None and int(self.seqs[c]) >= 0:
            pkt.zone = self.zone
            pkt.seq = int(self.seqs[c])
            pkt.epoch = int(self.epoch[c])
            pkt.fresh = bool(self.fresh[c])
            if self.proto:
                pkt.checksum = pkt.compute_checksum()
        return pkt


@dataclass
class SessionManager:
    """C clients' sync state against one store (or one zone shard).

    Per-client knobs live as stacked host arrays (pose, min-obs,
    subscription); the sync vectors live on device as one [C, N] array.
    ``collect`` is the fleet hot path: one `_collect_fleet` dispatch for all
    C clients.  Unsubscribed / undeliverable clients simply don't advance
    their sync rows, so their next deliverable tick coalesces everything
    they missed (same semantics as CloudService.flush_buffer).
    """
    knobs: Knobs
    n_clients: int
    capacity: int                      # N = slot count of the served store
    budget: int = 64                   # max objects shipped per client/tick
    sync: FleetSync = None
    subscribed: np.ndarray = None      # [C] bool
    user_pos: np.ndarray = None        # [C, 3] f32
    min_obs: np.ndarray = None         # [C] int32
    interest_embeds: object = None     # optional [I, E] shared interests
    tick: int = 0
    dirty: bool = True                 # False only when the last collect
    #                                    covered every subscriber and
    #                                    shipped nothing (fleet quiesced)
    proto: bool = False                # fault-injection transport on: count
    #                                    framing bytes + checksum packets
    donate: bool | None = False        # donate the [C, N] sync state to the
    #                                    collect dispatch (in-place advance;
    #                                    see _collect_fleet_donated).  None =
    #                                    backend-aware auto policy
    #                                    (kernels.ops.donate_default): on for
    #                                    TPU/GPU, OFF on CPU, where a donated
    #                                    dispatch blocks on the donated
    #                                    buffer's producer
    acked: np.ndarray = None           # [C, N] int32 — versions each client
    #                                    has CONFIRMED applying (cumulative
    #                                    acks); trails sync, drives slot
    #                                    retirement
    next_seq: np.ndarray = None        # [C] int64 — next seq per client
    inflight: list = None              # per-client deque of
    #                                    (seq, tick, slots, versions)
    ever_sent: np.ndarray = None       # [C, N] bool — row was EVER shipped
    #                                    to the client; gates tombstones and
    #                                    deletion debt.  Survives rollback
    #                                    (unlike sync, which falls back to
    #                                    acked): a lost upstream ack must
    #                                    not suppress a later deletion.

    def __post_init__(self):
        C, N = self.n_clients, self.capacity
        self.budget = min(self.budget, N)
        if self.donate is None:
            from repro.kernels.ops import donate_default
            self.donate = donate_default()
        if self.sync is None:
            self.sync = FleetSync(jnp.zeros((C, N), jnp.int32),
                                  jnp.zeros((C, N), bool))
        elif self.sync.ever_sent is None:
            self.sync = self.sync._replace(
                ever_sent=jnp.asarray(self.ever_sent)
                if self.ever_sent is not None
                else jnp.zeros((C, N), bool))
        if self.subscribed is None:
            self.subscribed = np.ones((C,), bool)
        if self.user_pos is None:
            self.user_pos = np.zeros((C, 3), np.float32)
        if self.min_obs is None:
            self.min_obs = np.full((C,), self.knobs.min_obs_before_sync,
                                   np.int32)
        if self.acked is None:
            self.acked = np.zeros((C, N), np.int32)
        if self.next_seq is None:
            self.next_seq = np.zeros((C,), np.int64)
        if self.inflight is None:
            self.inflight = [deque() for _ in range(C)]
        if self.ever_sent is None:
            self.ever_sent = np.zeros((C, N), bool)
        self._open_scrubs = []      # scrub masks of issued, unfinished collects
        # [N] bool — slots freed since the last collect; the next collect
        # dispatch zeroes their synced/ever_sent columns in-kernel
        self._pending_clear = np.zeros((N,), bool)
        self._class_budgets = jnp.asarray(class_budget_table(self.knobs))

    # -- per-client knob management (control plane, off the hot path) ------
    def set_client(self, c: int, *, user_pos=None, min_obs=None,
                   subscribed=None):
        if user_pos is not None:
            self.user_pos[c] = np.asarray(user_pos, np.float32)
        if min_obs is not None:
            if int(min_obs) != int(self.min_obs[c]):
                self.dirty = True      # eligibility changed: re-collect
            self.min_obs[c] = int(min_obs)
        if subscribed is not None:
            if bool(subscribed) != bool(self.subscribed[c]):
                self.dirty = True      # membership changed: re-collect
            self.subscribed[c] = bool(subscribed)

    def set_all(self, *, subscribed=None, user_pos=None):
        """Whole-fleet writes of the stacked per-client knob arrays (the
        pose-stream hot path).  Dirty marking stays with the caller —
        FleetServer.set_poses computes membership changes once for every
        zone from the [C, Z] broadcast test."""
        if subscribed is not None:
            self.subscribed[:] = np.asarray(subscribed, bool)
        if user_pos is not None:
            self.user_pos[:] = np.asarray(user_pos, np.float32)

    def reset_client(self, c: int, *, keep_seq: bool = False):
        """Fresh join (or zone re-entry): zero the sync + acked rows so the
        next tick ships a full catch-up of the subscribed store.

        ``keep_seq=True`` preserves the client's sequence stream — used by
        the zone-leave prune, where the client's protocol position must
        survive the subscription gap (only epoch bumps may restart seqs,
        because only they reset the client's expected-seq counters)."""
        self.dirty = True
        self.sync = FleetSync(self.sync.synced_version.at[c].set(0),
                              self.sync.ever_sent.at[c].set(False))
        self.acked[c] = 0
        self.ever_sent[c] = False
        self.inflight[c].clear()
        if not keep_seq:
            self.next_seq[c] = 0

    def reset_slots(self, slots):
        """Store slots were freed/reassigned (zone shard slot reuse): forget
        every client's synced AND acked version there so a future occupant
        ships — and is never falsely 'already acked' by its predecessor's
        confirmations.  In-flight entries scrub the slots too: an ack that
        lands after the reuse must not re-mark them."""
        if len(slots):
            self.dirty = True
            sl = np.asarray(slots)
            # O(1) slot-membership lookup instead of np.isin (a sort) per
            # in-flight entry — this runs per freed zone per tick, over
            # every un-acked packet of every client, and dominated the
            # serving tick at C=256 before the rewrite
            hit = np.zeros((self.capacity,), bool)
            hit[sl] = True
            # the DEVICE clear is deferred: the [N] mask accumulates on the
            # host and the next collect dispatch applies it first thing
            # (see _collect_fleet_impl) — nothing reads the device sync
            # state between here and that collect, and eagerly clearing
            # costs two [C, N] materializations per freed zone per tick
            self._pending_clear |= hit
            self.acked[:, sl] = 0
            self.ever_sent[:, sl] = False
            # collects issued but not yet framed (deferred pipeline) must
            # not resurrect these slots in their finish-time bookkeeping
            for m in self._open_scrubs:
                m[sl] = True
            for q in self.inflight:
                for k, (seq, tk, islots, ivers) in enumerate(q):
                    drop = hit[islots]
                    if drop.any():
                        keep = ~drop
                        q[k] = (seq, tk, islots[keep], ivers[keep])

    # -- ack / resync bookkeeping (hardened protocol control plane) --------
    def ack(self, c: int, seq: int):
        """Cumulative ack: the client has applied every packet up to and
        including ``seq`` — fold those in-flight versions into its acked
        vector (monotonic: a stale duplicate ack can never regress it)."""
        q = self.inflight[c]
        while q and q[0][0] <= seq:
            _, _, islots, ivers = q.popleft()
            if len(islots):
                self.acked[c, islots] = np.maximum(self.acked[c, islots],
                                                   ivers)

    def rollback(self, c: int):
        """Resync: everything sent past the client's last cumulative ack is
        presumed lost.  The sync row falls back to the acked vector, the
        sequence stream restarts, and the next collect re-ships exactly the
        un-acked delta (idempotent on the device: version-guarded).

        ``ever_sent`` deliberately survives the rollback: an UPSTREAM ack
        loss must not erase the fact that a row was ever shipped, or a
        later tombstone would be suppressed (sent-gated) and the client
        kept a ghost object with no deletion debt blocking its slot."""
        self.dirty = True
        self.sync = self.sync._replace(
            synced_version=self.sync.synced_version.at[c].set(
                jnp.asarray(self.acked[c])))
        self.inflight[c].clear()
        self.next_seq[c] = 0

    def oldest_unacked_tick(self, c: int):
        """Collect tick of the client's oldest un-acked packet (None if
        nothing is outstanding) — the server's retransmit-timeout signal."""
        q = self.inflight[c]
        return q[0][1] if q else None

    def deletion_debt(self, store: ObjectStore) -> np.ndarray:
        """[C, N] bool: client c still owes an ack that covers slot n's
        tombstone.  A slot is retirable only when NO subscriber owes it:
        the object was ever shipped to the client (ever_sent) but its
        acked version does not yet cover the deletion (acked < tombstone
        version)."""
        dele = np.asarray(deleted_mask(store))
        ver = np.asarray(store.version)
        return dele[None] & self.ever_sent & (self.acked < ver[None])

    # -- hot path ----------------------------------------------------------
    def collect_start(self, store: ObjectStore, *,
                      deliverable: np.ndarray | None = None, zone: int = 0,
                      epoch: np.ndarray | None = None,
                      fresh: np.ndarray | None = None,
                      now: int | None = None) -> _PendingCollect:
        """Issue the fleet collect dispatch; return device handles.

        This is the async half of ``collect``: the `_collect_fleet` jit is
        dispatched (donating the old sync state when ``donate``), the sync
        vector is rebound to the new device array, and NO host transfer
        happens — the caller overlaps other dispatch families before
        ``collect_finish`` materializes counts and does seq bookkeeping."""
        mask = self.subscribed if deliverable is None \
            else self.subscribed & np.asarray(deliverable, bool)
        fn = _collect_fleet_donated if self.donate else _collect_fleet
        clear = jnp.asarray(self._pending_clear)
        self._pending_clear = np.zeros((self.capacity,), bool)
        with obs_span("session.collect_fleet", cat="sync", zone=zone) as sp:
            batch, new_synced, new_ever, nbytes, counts, idx = fn(
                store, self.sync.synced_version, self.sync.ever_sent,
                clear, jnp.asarray(mask),
                jnp.asarray(self.min_obs), jnp.asarray(self.user_pos),
                self.interest_embeds, self._class_budgets, budget=self.budget,
                points_budget=self.knobs.max_object_points_client,
                knobs=self.knobs)
            sp.fence(batch.valid)
        self.sync = FleetSync(new_synced, new_ever)
        # the collect consumes the dirty flag; finish (or any event in
        # between — refresh marks, subscription changes) re-raises it
        self.dirty = False
        scrub = np.zeros((self.capacity,), bool)
        self._open_scrubs.append(scrub)
        return _PendingCollect(batch=batch, nbytes=nbytes, counts=counts,
                               idx=idx, mask=mask, zone=zone, epoch=epoch,
                               fresh=fresh, now=now, scrub=scrub)

    def collect_finish(self, p: _PendingCollect) -> FleetPacket:
        """Materialize an issued collect: host transfer + seq/in-flight
        bookkeeping.  Finishing in issue order keeps the packets
        byte-identical to the sequential ``collect`` path."""
        batch = p.batch
        counts = np.asarray(p.counts)
        nbytes = np.asarray(p.nbytes).astype(np.int64)
        seqs = np.full((self.n_clients,), -1, np.int64)
        if counts.any():
            idx_h = np.asarray(p.idx)
            valid_h = np.asarray(batch.valid)
            vers_h = np.asarray(batch.version)
            stamp = self.tick if p.now is None else p.now
            scrubbed = p.scrub is not None and p.scrub.any()
            for c in np.nonzero(counts)[0]:
                seqs[c] = self.next_seq[c]
                self.next_seq[c] += 1
                v = valid_h[c]
                sl, vv = idx_h[c][v], vers_h[c][v]
                if scrubbed:
                    # slots freed after issue (deferred finish): the packet
                    # still ships as computed, but its rows must not enter
                    # retirement bookkeeping — a later occupant of the slot
                    # would inherit the predecessor's send/ack state
                    keep = ~p.scrub[sl]
                    sl, vv = sl[keep], vv[keep]
                self.inflight[c].append((int(seqs[c]), stamp, sl, vv))
                self.ever_sent[c, sl] = True
            if self.proto:
                nbytes[counts > 0] += PROTO_HEADER_NBYTES
        pkt = FleetPacket(batch=batch, counts=counts, nbytes=nbytes,
                          tick=self.tick, zone=p.zone, seqs=seqs,
                          epoch=np.zeros((self.n_clients,), np.int64)
                          if p.epoch is None
                          else np.asarray(p.epoch, np.int64),
                          fresh=np.zeros((self.n_clients,), bool)
                          if p.fresh is None else np.asarray(p.fresh, bool),
                          proto=self.proto)
        self.tick += 1
        if p.scrub is not None:
            self._open_scrubs = [m for m in self._open_scrubs
                                 if m is not p.scrub]
        # quiesced iff every subscriber was covered and nothing shipped (a
        # partial-coverage tick may still owe undeliverable clients); OR —
        # not assign — so marks raised between a deferred issue and this
        # finish (refresh, slot churn, subscription moves) survive
        self.dirty = (self.dirty or bool(pkt.counts.any())
                      or not (p.mask == self.subscribed).all())
        return pkt

    def collect(self, store: ObjectStore, *,
                deliverable: np.ndarray | None = None, zone: int = 0,
                epoch: np.ndarray | None = None,
                fresh: np.ndarray | None = None,
                now: int | None = None) -> FleetPacket:
        """One fleet update tick: ONE jitted dispatch for all C clients.

        Every non-empty per-client packet takes the next number on that
        client's sequence stream, and the shipped (slot, version) pairs are
        queued in-flight until the client's cumulative ack lands — the
        sync vector records what was SENT, ``acked`` what was CONFIRMED,
        and slot retirement trusts only the latter."""
        return self.collect_finish(self.collect_start(
            store, deliverable=deliverable, zone=zone, epoch=epoch,
            fresh=fresh, now=now))
