"""Fleet server + simulated client fleet.

FleetServer composes the zone-sharded store (zones.py) with one
SessionManager per zone: a server tick is one vmapped collect dispatch per
*dirty* zone — never a Python loop over clients — and a client subscribed
to quiet zones costs (and receives) nothing.

FleetSimulator drives tens-to-hundreds of clients against one mapped scene:
heterogeneous NetworkModels (mixed RTTs/bandwidths, staggered outages),
join/leave churn mid-session, per-client poses wandering the room (zone
subscriptions follow), and cross-client queries — declarative
`core.query.Query` specs (open-vocab similarity + radius-around-pose) —
multiplexed through `serving.batching.BatchScheduler` over the fused
query engine.  Each
client's delivery/ingest/mode step is `core.runtime.ClientSession` — the
same code path as the single-client example.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs
from repro.core.query import Query, QueryResult, compile_query
from repro.core.runtime import ClientSession, DeviceClient, NetworkModel
from repro.core.store import ObjectStore
from repro.server.session import FleetPacket, SessionManager
from repro.server.zones import ZoneGrid, ZoneShardedStore


# ---------------------------------------------------------------------------
@dataclass
class FleetServer:
    """Zone-sharded store + per-zone multi-client sync sessions."""
    knobs: Knobs
    embed_dim: int
    n_clients: int
    grid: ZoneGrid
    budget: int = 64                   # per-client objects per tick per zone
    zoned: ZoneShardedStore = None
    sessions: list = field(default_factory=list)   # one SessionManager/zone
    subscribed: np.ndarray = None      # [C, Z] bool (host mirror)

    def __post_init__(self):
        if self.zoned is None:
            self.zoned = ZoneShardedStore(knobs=self.knobs,
                                          embed_dim=self.embed_dim,
                                          grid=self.grid)
        if not self.sessions:
            self.sessions = [
                SessionManager(knobs=self.knobs, n_clients=self.n_clients,
                               capacity=self.zoned.zone_capacity,
                               budget=self.budget,
                               subscribed=np.zeros((self.n_clients,), bool))
                for _ in range(self.grid.n_zones)]
        if self.subscribed is None:
            self.subscribed = np.zeros((self.n_clients, self.grid.n_zones),
                                       bool)

    # -- control plane -----------------------------------------------------
    def refresh(self, store: ObjectStore):
        """Mirror the mapping frontend's store into the zone shards; freed
        shard slots reset every client's sync version there (slot reuse
        must not hide the next occupant behind a stale synced_version),
        and zones with any copied/freed rows are marked dirty."""
        freed, changed = self.zoned.refresh_from(store)
        for z in range(self.grid.n_zones):
            if freed[z]:
                self.sessions[z].reset_slots(freed[z])
            elif changed[z]:
                self.sessions[z].dirty = True

    def set_client_pose(self, c: int, pos, radius: float):
        subs = self.zoned.subscriptions(pos, radius)
        self.subscribed[c] = subs
        for z in range(self.grid.n_zones):
            self.sessions[z].set_client(c, user_pos=pos, subscribed=subs[z])

    def join(self, c: int, pos, radius: float):
        for s in self.sessions:
            s.reset_client(c)
        self.set_client_pose(c, pos, radius)

    def leave(self, c: int):
        self.subscribed[c] = False
        for s in self.sessions:
            s.set_client(c, subscribed=False)

    # -- hot path ------------------------------------------------------------
    def tick(self, deliverable: np.ndarray) -> list:
        """One fleet update tick: one vmapped collect per DIRTY zone that
        has a deliverable subscriber.  A zone is clean (skipped outright)
        when its last collect covered every subscriber and shipped nothing,
        and no refresh/join/subscription change has touched it since —
        idle-tick cost scales with changed zones, not zone count.  Returns
        [(zone, FleetPacket)] — per-client packets are leading-dim views.
        """
        out = []
        for z, sess in enumerate(self.sessions):
            if not sess.dirty or not (sess.subscribed & deliverable).any():
                continue
            out.append((z, sess.collect(self.zoned.zones[z],
                                        deliverable=deliverable)))
        return out

    def per_client_nbytes(self, packets: list) -> np.ndarray:
        total = np.zeros((self.n_clients,), np.int64)
        for _, pkt in packets:
            total += pkt.nbytes
        return total

    # -- query plane ---------------------------------------------------------
    def query(self, spec: Query, *, use_pallas: bool = False) -> QueryResult:
        """Run a declarative query against the zone-sharded fleet store.

        ``compile_query`` prunes shards from the spec's zone / near
        predicates before dispatch; each selected shard runs the same fused
        predicate+score+top-k plan.  Result slots are global
        ``zone * zone_capacity + shard_slot`` rows."""
        return compile_query(spec, self.zoned,
                             use_pallas=use_pallas)(self.zoned)


# ---------------------------------------------------------------------------
@dataclass
class SimClient:
    cid: int
    session: ClientSession
    anchor: np.ndarray                 # wander center
    radius: float                      # zone-subscription radius
    join_tick: int = 0
    leave_tick: int = 10**9
    active: bool = False
    queries: int = 0
    lq_ticks: int = 0

    def pose_at(self, t: float) -> np.ndarray:
        ang = 0.15 * t + 0.7 * self.cid
        return self.anchor + np.array([0.8 * np.cos(ang), 0.0,
                                       0.8 * np.sin(ang)], np.float32)


def _heterogeneous_net(rng, tick_s: float, n_ticks: int) -> NetworkModel:
    """Mixed-quality links (paper Sec. 4.3 regimes) + staggered outages."""
    rtt = float(rng.choice([20.0, 40.0, 66.0]))
    bw = float(rng.choice([50.0, 100.0, 200.0]))
    outages = ()
    if rng.random() < 0.5:
        start = float(rng.uniform(0, n_ticks * tick_s * 0.8))
        outages = ((start, start + float(rng.uniform(1, 4) * tick_s)),)
    return NetworkModel(rtt_ms=rtt, bandwidth_mbps=bw, outages=outages)


@dataclass
class FleetSimulator:
    """Drive C simulated clients against one mapped scene for N ticks."""
    knobs: Knobs
    embed_dim: int
    n_clients: int = 16
    grid: ZoneGrid = None
    budget: int = 32
    seed: int = 0
    tick_s: float = 1.0
    churn: float = 0.25                # fraction of clients that join late
    query_prob: float = 0.5
    query_radius: float = 6.0          # SQ spatial predicate around the pose
    server: FleetServer = None
    clients: list = field(default_factory=list)
    scheduler: object = None
    stats: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.grid is None:
            self.grid = ZoneGrid.for_room(8.0, nx=2, nz=2)
        if self.server is None:
            self.server = FleetServer(knobs=self.knobs,
                                      embed_dim=self.embed_dim,
                                      n_clients=self.n_clients,
                                      grid=self.grid, budget=self.budget)

    def _build_clients(self, n_ticks: int):
        rng = np.random.default_rng(self.seed)
        half = self.grid.zone_size * max(self.grid.nx, self.grid.nz) / 2
        self.clients = []
        for c in range(self.n_clients):
            dev = DeviceClient(knobs=self.knobs, embed_dim=self.embed_dim)
            net = _heterogeneous_net(rng, self.tick_s, n_ticks)
            anchor = np.array([rng.uniform(-half * 0.8, half * 0.8), 1.5,
                               rng.uniform(-half * 0.8, half * 0.8)],
                              np.float32)
            join = 0
            leave = 10**9
            if rng.random() < self.churn:
                join = int(rng.integers(1, max(n_ticks // 2, 2)))
            if rng.random() < self.churn / 2:
                leave = int(rng.integers(n_ticks // 2, n_ticks))
            self.clients.append(SimClient(
                cid=c, session=ClientSession(dev=dev, net=net,
                                             knobs=self.knobs,
                                             dt=self.tick_s),
                anchor=anchor, radius=1.5, join_tick=join, leave_tick=leave))

    def _build_scheduler(self, get_map):
        from repro.serving.batching import BatchScheduler, make_query_step_fn
        bs = max(4, min(self.n_clients, 16))
        return BatchScheduler(batch_size=bs,
                              step_fn=make_query_step_fn(get_map, pad_to=bs))

    def run(self, *, n_ticks: int = 30, mapper=None, frames=None,
            embedder=None, classes=None, key=None) -> dict:
        """Run the fleet.  ``mapper`` + ``frames`` drive the mapping
        frontend; pass mapper=None with a pre-filled store via
        ``self.server.refresh(store)`` inside a custom loop instead."""
        self._build_clients(n_ticks)
        self.scheduler = self._build_scheduler(
            lambda: mapper.store if mapper else None)
        frames = list(frames) if frames is not None else []
        key = key if key is not None else jax.random.key(self.seed)

        tick_lat, down_total, hedges0 = [], 0, self.scheduler.hedge_count
        for i in range(n_ticks):
            t = i * self.tick_s
            active_labels = np.zeros((0,), np.int32)
            if mapper is not None:
                if i < len(frames):
                    mapper.process_frame(frames[i], classes,
                                         jax.random.fold_in(key, i))
                    self.server.refresh(mapper.store)
                active_labels = np.asarray(mapper.store.label)[
                    np.asarray(mapper.store.active)]

            # churn + pose advance
            deliverable = np.zeros((self.n_clients,), bool)
            for cl in self.clients:
                if not cl.active and cl.join_tick <= i < cl.leave_tick:
                    cl.active = True
                    self.server.join(cl.cid, cl.pose_at(t), cl.radius)
                elif cl.active and i >= cl.leave_tick:
                    cl.active = False
                    self.server.leave(cl.cid)
                if cl.active:
                    pos = cl.pose_at(t)
                    cl.session.user_pos = jnp.asarray(pos)
                    self.server.set_client_pose(cl.cid, pos, cl.radius)
                    deliverable[cl.cid] = cl.session.net.is_up(t)

            t0 = time.perf_counter()
            packets = self.server.tick(deliverable)
            tick_lat.append((time.perf_counter() - t0) * 1e3)

            # client side: shared per-tick step (delivery + ingest + mode)
            per_client = self.server.per_client_nbytes(packets)
            down_total += int(per_client.sum())
            for cl in self.clients:
                if not cl.active:
                    continue
                mode = None
                for _, pkt in packets:
                    mode = cl.session.step(t, pkt.packet_for(cl.cid))
                if mode is None:
                    mode = cl.session.step(t)
                # cross-client queries: SQ rides the shared batch scheduler
                # as a declarative spec — open-vocab similarity AND a
                # radius-around-the-client spatial predicate, one dispatch
                if embedder is not None and len(active_labels) \
                        and np.random.default_rng(self.seed + i * 131
                                                  + cl.cid).random() \
                        < self.query_prob:
                    cid_q = int(active_labels[(cl.cid + i)
                                              % len(active_labels)])
                    if mode == "SQ":
                        self.scheduler.submit(Query(
                            embed=embedder.embed_text(cid_q),
                            near=(jnp.asarray(cl.pose_at(t)),
                                  jnp.asarray(self.query_radius,
                                              jnp.float32)),
                            k=3))
                        cl.queries += 1
                    else:
                        cl.lq_ticks += 1
            if mapper is not None:
                self.scheduler.step()

        if mapper is not None:
            self.scheduler.drain()      # serve every remaining submission
        act = [cl for cl in self.clients if cl.active]
        self.stats = {
            "n_ticks": n_ticks,
            "n_clients": self.n_clients,
            "active_at_end": len(act),
            "tick_ms_mean": float(np.mean(tick_lat)) if tick_lat else 0.0,
            "down_bytes_total": down_total,
            "down_bytes_per_client": down_total / max(self.n_clients, 1),
            "delivered_packets": sum(c.session.delivered
                                     for c in self.clients),
            "delayed_packets": sum(c.session.delayed for c in self.clients),
            "sq_queries": sum(c.queries for c in self.clients),
            "lq_fallbacks": sum(c.lq_ticks for c in self.clients),
            "hedges": self.scheduler.hedge_count - hedges0,
            "served": len(self.scheduler.done),
            "unserved": len(self.scheduler.waiting),
            "dropped_by_full_zone": self.server.zoned.dropped,
        }
        return self.stats
