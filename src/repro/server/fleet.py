"""Fleet server + simulated client fleet.

FleetServer composes the zone-sharded store (zones.py) with one
SessionManager per zone: a server tick is one vmapped collect dispatch per
*dirty* zone — never a Python loop over clients — and a client subscribed
to quiet zones costs (and receives) nothing.

FleetSimulator drives tens-to-hundreds of clients against one mapped scene:
heterogeneous NetworkModels (mixed RTTs/bandwidths, staggered outages),
join/leave churn mid-session, per-client poses wandering the room (zone
subscriptions follow), and cross-client queries — declarative
`core.query.Query` specs (open-vocab similarity + radius-around-pose) —
multiplexed through `serving.batching.BatchScheduler` over the fused
query engine.  Since PR 5 the simulator is a THIN WRAPPER: it translates
its seeded fleet parameters into a declarative `sim.Scenario` and replays
it through `sim.ScenarioEngine` (the shared discrete-event session loop),
keeping only the legacy stats-dict surface and the BatchScheduler query
hook.  Each client's delivery/ingest/mode step is
`core.runtime.ClientSession` — the same code path as the single-client
example.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.knobs import Knobs
from repro.core.query import Query, QueryResult, compile_query
from repro.core.runtime import ClientSession, NetworkModel
from repro.core.store import ObjectStore
from repro.obs import metrics as obs_metrics
from repro.obs.trace import span as obs_span
from repro.server.session import FleetPacket, SessionManager
from repro.server.zones import ZoneGrid, ZoneShardedStore


# ---------------------------------------------------------------------------
@dataclass
class FleetServer:
    """Zone-sharded store + per-zone multi-client sync sessions.

    The hardened control plane lives here: per-client sync epochs (bumped
    on resync / rejoin / retransmit timeout), cumulative-ack routing into
    the per-zone sessions, and sync-vector-driven tombstone retirement —
    a deleted slot is releasable only once every subscriber's ACKED
    version covers the deletion, with a lease timeout evicting
    permanently-partitioned clients so they can't leak slots forever."""
    knobs: Knobs
    embed_dim: int
    n_clients: int
    grid: ZoneGrid
    budget: int = 64                   # per-client objects per tick per zone
    proto: bool = False                # fault-injection transport framing
    donate: bool | None = False        # sessions donate their [C, N] sync
    #                                    state to the collect dispatch
    #                                    (in-place advance; byte-identical).
    #                                    None = backend-aware auto
    #                                    (kernels.ops.donate_default)
    n_session_shards: int = 1          # >1: each zone's session tier is a
    #                                    MeshSessionTier — the client axis
    #                                    partitioned across S session shards
    #                                    (one per mesh device), control
    #                                    plane routed to the owning shard,
    #                                    packets byte-identical (server/
    #                                    mesh.py)
    roster: object = None              # shared ClientRoster when sharded
    #                                    (None = round-robin over clients)
    index: bool = True                 # maintain per-zone cluster indexes
    #                                    (repro.index; queries go two-stage
    #                                     only past min_flat_size, so small
    #                                     fleets keep flat-sweep results)
    zoned: ZoneShardedStore = None
    sessions: list = field(default_factory=list)   # one SessionManager/zone
    subscribed: np.ndarray = None      # [C, Z] bool (host mirror)
    epoch: np.ndarray = None           # [C] int64 per-client sync epoch
    epoch_fresh: np.ndarray = None     # [C] bool — epoch restarted from
    #                                    scratch (client resets its map on
    #                                    adoption); cleared on first ack
    last_ack_tick: np.ndarray = None   # [C] int64 — lease bookkeeping
    needs_fresh: np.ndarray = None     # [C] bool — lease expired: next
    #                                    deliverable tick forces a fresh
    #                                    epoch instead of trusting state

    def __post_init__(self):
        if self.zoned is None:
            self.zoned = ZoneShardedStore(knobs=self.knobs,
                                          embed_dim=self.embed_dim,
                                          grid=self.grid)
        if self.index and not self.zoned.indexes:
            self.zoned.enable_index()
        if not self.sessions:
            if self.n_session_shards > 1:
                from repro.server.mesh import ClientRoster, MeshSessionTier
                if self.roster is None:
                    self.roster = ClientRoster.round_robin(
                        self.n_clients, self.n_session_shards)
                self.sessions = [
                    MeshSessionTier(knobs=self.knobs, roster=self.roster,
                                    capacity=self.zoned.zone_capacity,
                                    budget=self.budget, proto=self.proto,
                                    donate=self.donate)
                    for _ in range(self.grid.n_zones)]
            else:
                self.sessions = [
                    SessionManager(
                        knobs=self.knobs, n_clients=self.n_clients,
                        capacity=self.zoned.zone_capacity,
                        budget=self.budget, proto=self.proto,
                        donate=self.donate,
                        subscribed=np.zeros((self.n_clients,), bool))
                    for _ in range(self.grid.n_zones)]
        if self.subscribed is None:
            self.subscribed = np.zeros((self.n_clients, self.grid.n_zones),
                                       bool)
        C = self.n_clients
        if self.epoch is None:
            self.epoch = np.zeros((C,), np.int64)
        if self.epoch_fresh is None:
            self.epoch_fresh = np.zeros((C,), bool)
        if self.last_ack_tick is None:
            self.last_ack_tick = np.zeros((C,), np.int64)
        if self.needs_fresh is None:
            self.needs_fresh = np.zeros((C,), bool)

    # -- control plane -----------------------------------------------------
    def refresh(self, store: ObjectStore):
        """Mirror the mapping frontend's store into the zone shards; freed
        shard slots reset every client's sync version there (slot reuse
        must not hide the next occupant behind a stale synced_version),
        and zones with any copied/freed rows are marked dirty."""
        freed, changed = self.zoned.refresh_from(store)
        for z in range(self.grid.n_zones):
            if freed[z]:
                self.sessions[z].reset_slots(freed[z])
            elif changed[z]:
                self.sessions[z].dirty = True

    def set_client_pose(self, c: int, pos, radius: float):
        subs = self.zoned.subscriptions(pos, radius)
        left = self.subscribed[c] & ~subs
        self.subscribed[c] = subs
        for z in range(self.grid.n_zones):
            if left[z]:
                # zone exit: forget what the client held there (it prunes
                # its side too — prune-on-unsubscribe), so re-entry ships a
                # clean catch-up instead of trusting stale state.  The seq
                # stream survives: no epoch bump for a mere zone crossing.
                self.sessions[z].reset_client(c, keep_seq=True)
            self.sessions[z].set_client(c, user_pos=pos, subscribed=subs[z])

    def set_poses(self, poses: np.ndarray, radius: float) -> None:
        """Whole-fleet pose update: one [C, Z] broadcast subscription test
        + per-zone array writes, semantically identical to C
        ``set_client_pose`` calls (the 60 FPS pose-stream hot path — the
        per-client loop is ~C*Z Python iterations per tick)."""
        poses = np.asarray(poses, np.float32)
        subs = self.zoned.grid.overlaps_batch(poses, radius)   # [C, Z]
        left = self.subscribed & ~subs
        changed = self.subscribed != subs
        self.subscribed = subs
        for z, sess in enumerate(self.sessions):
            for c in np.nonzero(left[:, z])[0]:
                sess.reset_client(int(c), keep_seq=True)   # zone exit
            if changed[:, z].any():
                sess.dirty = True                          # membership
            # routed whole-fleet write: in-place on a plain session, split
            # by the roster on a sharded tier (direct [:] writes would
            # silently no-op against the tier's assembled-copy property)
            sess.set_all(subscribed=subs[:, z], user_pos=poses)

    def _bump_epoch(self, c: int, *, fresh: bool):
        """Advance the client's sync epoch.  fresh=True restarts the whole
        session (join / crash recovery / lease expiry: client resets its
        map, server forgets sync + acked state); fresh=False is a resync
        rollback (sync falls back to acked, un-acked delta re-ships).

        A pending fresh flag is sticky: if the client never acked the
        fresh epoch (its packets may all have been lost), a follow-up
        resync bump must stay fresh — downgrading to a rollback would let
        the client keep a map the server has already written off."""
        fresh = fresh or bool(self.epoch_fresh[c])
        self.epoch[c] += 1
        self.epoch_fresh[c] = fresh
        for s in self.sessions:
            if fresh:
                s.reset_client(c)
            else:
                s.rollback(c)

    def join(self, c: int, pos, radius: float, *, tick: int = 0):
        self._bump_epoch(c, fresh=True)
        self.last_ack_tick[c] = tick
        self.needs_fresh[c] = False
        self.set_client_pose(c, pos, radius)

    def leave(self, c: int):
        self.subscribed[c] = False
        for s in self.sessions:
            s.reset_client(c)          # a gone client must not pin slots
            s.set_client(c, subscribed=False)

    def crash(self, c: int):
        """The device restarted: its volatile protocol/map state is gone.
        Drop the server-side session rows so nothing stale blocks
        retirement while it is down; the rejoin (`join`) hands it a fresh
        epoch and a full catch-up."""
        for s in self.sessions:
            s.reset_client(c)

    def crash_shard(self, shard: int, *, tick: int = 0):
        """A session shard's host died: its slice of the sync/ack/in-flight
        state is gone.  Recovery is per-CLIENT fresh epochs for exactly the
        clients homed on that shard (their next deliverable tick ships a
        full catch-up); clients on surviving shards keep their epochs,
        streams, and in-flight windows untouched — asserted in
        tests/test_fault_tolerance.py."""
        assert self.roster is not None, "crash_shard needs a sharded tier"
        for c in np.nonzero(self.roster.assign == shard)[0]:
            self._bump_epoch(int(c), fresh=True)
            self.last_ack_tick[c] = tick
            self.needs_fresh[c] = False

    # -- hardened-protocol control plane -----------------------------------
    def ack(self, c: int, zone: int, epoch: int, seq: int, *, tick: int = 0):
        """Route a client's cumulative ack ``(zone, epoch, seq)`` into the
        zone session.  Acks from a superseded epoch are dropped — their seq
        numbering no longer matches the stream."""
        if epoch != int(self.epoch[c]):
            reg = obs_metrics.get_registry()
            if reg is not None:
                reg.counter("fleet_stale_acks_total",
                            "acks dropped for a superseded epoch").inc(
                                client=int(c))
            return
        self.epoch_fresh[c] = False    # client adopted: later packets cont
        self.last_ack_tick[c] = tick
        self.sessions[zone].ack(c, seq)
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.counter("fleet_acks_total",
                        "cumulative acks applied").inc(client=int(c),
                                                       zone=int(zone))

    def ack_tick(self, packets: list, *, tick: int) -> int:
        """Batched ack of one tick's own packets — the always-connected
        fleet fast path (the serving loop's clients apply every delivered
        packet immediately).  Equivalent to ``ack(c, z, epoch[c], seq)``
        per framed client but without the per-call epoch lookup: these
        seqs were just issued under the CURRENT epochs, so none can be
        stale.  Returns the number of (client, zone) acks applied."""
        n = 0
        acked = np.zeros((self.n_clients,), bool)
        for z, pkt in packets:
            sess = self.sessions[z]
            for c in np.nonzero(pkt.seqs >= 0)[0]:
                sess.ack(int(c), int(pkt.seqs[c]))
            acked[pkt.seqs >= 0] = True
            n += int((pkt.seqs >= 0).sum())
        if acked.any():
            self.epoch_fresh[acked] = False
            self.last_ack_tick[acked] = tick
        reg = obs_metrics.get_registry()
        if reg is not None and n:
            reg.counter("fleet_acks_total",
                        "cumulative acks applied").inc(n, batched=1)
        return n

    def request_resync(self, c: int):
        """Client detected an unrecoverable gap: roll it back to its acked
        state under a bumped epoch (its reorder buffers restart too)."""
        with obs_span("fleet.resync", cat="sync", client=int(c)):
            self._bump_epoch(c, fresh=False)
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.counter("fleet_resyncs_total",
                        "server-side resync rollbacks").inc(client=int(c))

    def maintain(self, *, tick: int, deliverable: np.ndarray,
                 retx_ticks: int):
        """Server-side retransmit timeout: a reachable client whose oldest
        un-acked packet has aged past ``retx_ticks`` is rolled back (cont
        epoch) so the un-acked delta re-ships — covers tail loss the
        client-side gap detector can't see (nothing after the hole)."""
        for c in range(self.n_clients):
            if not deliverable[c] or not self.subscribed[c].any():
                continue
            oldest = [t for s in self.sessions
                      if (t := s.oldest_unacked_tick(c)) is not None]
            if oldest and tick - min(oldest) >= retx_ticks:
                self._bump_epoch(c, fresh=False)

    def blocked_tombstone_oids(self, *, tick: int,
                               lease_ticks: int | None = None) -> set:
        """Object ids whose tombstoned slots must NOT be released yet:
        some subscriber's acked version does not cover the deletion.

        The lease is the partition escape hatch: a client that owes
        deletions and hasn't acked anything for ``lease_ticks`` forfeits
        its hold — its next deliverable tick starts a fresh epoch (full
        catch-up), so correctness survives the forfeit.  Clients owing
        nothing keep their lease trivially current (an idle caught-up
        client is never expired into a spurious resync)."""
        owes = np.zeros((self.n_clients,), bool)
        debt = []
        for z, sess in enumerate(self.sessions):
            d = sess.deletion_debt(self.zoned.zones[z])    # [C, N]
            d &= sess.subscribed[:, None]
            debt.append(d)
            owes |= d.any(axis=1)
        self.last_ack_tick[~owes] = tick
        if lease_ticks is not None:
            expired = owes & (tick - self.last_ack_tick >= lease_ticks)
            if expired.any():
                self.needs_fresh |= expired
                for z in range(len(debt)):
                    debt[z][expired] = False
        blocked = set()
        for z, d in enumerate(debt):
            slots = np.nonzero(d.any(axis=0))[0]
            if len(slots):
                ids = np.asarray(self.zoned.zones[z].ids)[slots]
                blocked.update(int(i) for i in ids)
        return blocked

    # -- hot path ------------------------------------------------------------
    def tick(self, deliverable: np.ndarray, *, tick: int | None = None,
             overlap: bool = False) -> list:
        """One fleet update tick: one vmapped collect per DIRTY zone that
        has a deliverable subscriber.  A zone is clean (skipped outright)
        when its last collect covered every subscriber and shipped nothing,
        and no refresh/join/subscription change has touched it since —
        idle-tick cost scales with changed zones, not zone count.  Returns
        [(zone, FleetPacket)] — per-client packets are leading-dim views.

        ``overlap=True`` issues every dirty zone's collect dispatch first
        and only then materializes the packets (collect_start/finish):
        zone k's host bookkeeping overlaps zone k+1's device compute
        instead of fencing per zone.  Zones are independent (per-zone
        sessions, server state only read), so the packets are byte-
        identical to the sequential path — asserted in tests.
        """
        if overlap:
            return self.tick_finish(self.tick_start(deliverable, tick=tick))
        self._epoch_catchup(deliverable, tick)
        out = []
        with obs_span("fleet.tick", cat="sync") as sp:
            zs = [z for z, sess in enumerate(self.sessions)
                  if sess.dirty and (sess.subscribed & deliverable).any()]
            out = [(z, self.sessions[z].collect(
                self.zoned.zones[z], deliverable=deliverable, zone=z,
                epoch=self.epoch, fresh=self.epoch_fresh, now=tick))
                for z in zs]
            sp.set(zones_collected=len(out))
        self._tick_metrics(out)
        return out

    def _epoch_catchup(self, deliverable: np.ndarray,
                       tick: int | None) -> None:
        pend = self.needs_fresh & np.asarray(deliverable, bool) \
            & self.subscribed.any(axis=1)
        for c in np.nonzero(pend)[0]:
            # lease expired while partitioned: now that the client is
            # reachable again, restart its session under a fresh epoch
            self._bump_epoch(int(c), fresh=True)
            self.last_ack_tick[c] = self.sessions[0].tick if tick is None \
                else tick
            self.needs_fresh[c] = False

    def tick_start(self, deliverable: np.ndarray, *,
                   tick: int | None = None) -> list:
        """Issue every dirty zone's collect dispatch; return [(zone,
        _PendingCollect)] for ``tick_finish``.  The fully-pipelined serving
        loop finishes these a TICK later: the sync state (synced_version +
        ever_sent) lives on-device, so the next tick's collects chain off
        these dispatches with no host dependency on the framing."""
        deliverable = np.asarray(deliverable, bool)
        self._epoch_catchup(deliverable, tick)
        with obs_span("fleet.tick_start", cat="sync") as sp:
            started = [(z, self.sessions[z].collect_start(
                self.zoned.zones[z], deliverable=deliverable, zone=z,
                epoch=self.epoch, fresh=self.epoch_fresh, now=tick))
                for z, sess in enumerate(self.sessions)
                if sess.dirty and (sess.subscribed & deliverable).any()]
            sp.set(zones_collected=len(started))
        return started

    def tick_finish(self, started: list) -> list:
        """Frame issued collects into packets (host transfers + seq/
        in-flight bookkeeping), in issue order — byte-identical to the
        sequential path."""
        with obs_span("fleet.tick_finish", cat="sync"):
            out = [(z, self.sessions[z].collect_finish(p))
                   for z, p in started]
        self._tick_metrics(out)
        return out

    def _tick_metrics(self, out: list) -> None:
        reg = obs_metrics.get_registry()
        if reg is not None and out:
            cnt = reg.counter("fleet_sent_bytes_total",
                              "downstream wire bytes by client/zone")
            for z, pkt in out:
                for c in np.nonzero(pkt.nbytes)[0]:
                    cnt.inc(int(pkt.nbytes[c]), client=int(c), zone=int(z))

    def per_client_nbytes(self, packets: list) -> np.ndarray:
        total = np.zeros((self.n_clients,), np.int64)
        for _, pkt in packets:
            total += pkt.nbytes
        return total

    # -- query plane ---------------------------------------------------------
    def query(self, spec: Query, *, use_pallas: bool = False) -> QueryResult:
        """Run a declarative query against the zone-sharded fleet store.

        ``compile_query`` prunes shards from the spec's zone / near
        predicates before dispatch; each selected shard runs the same fused
        predicate+score+top-k plan — coarse-to-fine through its cluster
        index once the shard passes the engagement threshold.  Result slots
        are global ``zone * zone_capacity + shard_slot`` rows."""
        return compile_query(spec, self.zoned,
                             use_pallas=use_pallas)(self.zoned)


# ---------------------------------------------------------------------------
@dataclass
class SimClient:
    cid: int
    session: ClientSession             # the engine-owned per-tick step
    anchor: np.ndarray                 # wander center
    radius: float                      # zone-subscription radius
    join_tick: int = 0
    leave_tick: int = 10**9
    active: bool = False
    queries: int = 0
    lq_ticks: int = 0
    net: NetworkModel = None

    def pose_at(self, t: float) -> np.ndarray:
        ang = 0.15 * t + 0.7 * self.cid
        return self.anchor + np.array([0.8 * np.cos(ang), 0.0,
                                       0.8 * np.sin(ang)], np.float32)


def _heterogeneous_net(rng, tick_s: float, n_ticks: int) -> NetworkModel:
    """Mixed-quality links (paper Sec. 4.3 regimes) + staggered outages."""
    rtt = float(rng.choice([20.0, 40.0, 66.0]))
    bw = float(rng.choice([50.0, 100.0, 200.0]))
    outages = ()
    if rng.random() < 0.5:
        start = float(rng.uniform(0, n_ticks * tick_s * 0.8))
        outages = ((start, start + float(rng.uniform(1, 4) * tick_s)),)
    return NetworkModel(rtt_ms=rtt, bandwidth_mbps=bw, outages=outages)


@dataclass
class FleetSimulator:
    """Drive C simulated clients against one mapped scene for N ticks."""
    knobs: Knobs
    embed_dim: int
    n_clients: int = 16
    grid: ZoneGrid = None
    budget: int = 32
    seed: int = 0
    tick_s: float = 1.0
    churn: float = 0.25                # fraction of clients that join late
    query_prob: float = 0.5
    query_radius: float = 6.0          # SQ spatial predicate around the pose
    server: FleetServer = None
    clients: list = field(default_factory=list)
    scheduler: object = None
    stats: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.grid is None:
            self.grid = ZoneGrid.for_room(8.0, nx=2, nz=2)
        if self.server is None:
            self.server = FleetServer(knobs=self.knobs,
                                      embed_dim=self.embed_dim,
                                      n_clients=self.n_clients,
                                      grid=self.grid, budget=self.budget)

    def _build_clients(self, n_ticks: int):
        rng = np.random.default_rng(self.seed)
        half = self.grid.zone_size * max(self.grid.nx, self.grid.nz) / 2
        self.clients = []
        for c in range(self.n_clients):
            net = _heterogeneous_net(rng, self.tick_s, n_ticks)
            anchor = np.array([rng.uniform(-half * 0.8, half * 0.8), 1.5,
                               rng.uniform(-half * 0.8, half * 0.8)],
                              np.float32)
            join = 0
            leave = 10**9
            if rng.random() < self.churn:
                join = int(rng.integers(1, max(n_ticks // 2, 2)))
            if rng.random() < self.churn / 2:
                leave = int(rng.integers(n_ticks // 2, n_ticks))
            # session is attached after the engine builds it (the engine
            # owns DeviceClient/ClientSession; SimClient is the public view)
            self.clients.append(SimClient(
                cid=c, session=None, anchor=anchor, radius=1.5,
                join_tick=join, leave_tick=leave, net=net))

    def _build_scheduler(self, get_map):
        from repro.serving.batching import BatchScheduler, make_query_step_fn
        bs = max(4, min(self.n_clients, 16))
        return BatchScheduler(batch_size=bs,
                              step_fn=make_query_step_fn(get_map, pad_to=bs))

    def _scenario(self, n_ticks: int):
        """Declarative Scenario mirroring this simulator's seeded fleet —
        the engine replays it; the simulator itself only maps results back
        to the legacy stats dict."""
        from repro.sim.scenario import (ClientSpec, GridSpec, NetTrace,
                                        PoseTrack, QueryPlan, Scenario)
        specs = tuple(ClientSpec(
            cid=cl.cid,
            net=NetTrace(rtt_ms=cl.net.rtt_ms,
                         bandwidth_mbps=cl.net.bandwidth_mbps,
                         outages=cl.net.outages),
            track=PoseTrack(anchor=tuple(float(x) for x in cl.anchor),
                            orbit_radius=0.8, angular_rate=0.15,
                            phase=0.7 * cl.cid),
            join_tick=cl.join_tick, leave_tick=cl.leave_tick,
            subscribe_radius=cl.radius) for cl in self.clients)
        room = self.grid.zone_size * max(self.grid.nx, self.grid.nz)
        return Scenario(
            seed=self.seed, n_ticks=n_ticks, tick_s=self.tick_s,
            embed_dim=self.embed_dim, knobs=self.knobs,
            grid=GridSpec(room=room, nx=self.grid.nx, nz=self.grid.nz),
            budget=self.budget, clients=specs,
            query=QueryPlan(prob=self.query_prob, radius=self.query_radius,
                            k=3))

    def run(self, *, n_ticks: int = 30, mapper=None, frames=None,
            embedder=None, classes=None, key=None) -> dict:
        """Run the fleet: a thin wrapper over sim.ScenarioEngine.

        ``mapper`` + ``frames`` drive the mapping frontend; SQ queries ride
        ``serving.BatchScheduler`` via the engine's query hook (the
        continuous-batching path the paper's server uses), so the scheduler
        stats (hedges/served) stay observable.  Pass mapper=None with a
        pre-filled store via ``self.server.refresh(store)`` inside a custom
        loop instead."""
        from repro.sim.engine import ScenarioEngine
        self._build_clients(n_ticks)
        self.scheduler = self._build_scheduler(
            lambda: mapper.store if mapper else None)
        hedges0 = self.scheduler.hedge_count

        def submit_sq(cid, t, spec):
            self.scheduler.submit(spec)

        engine = ScenarioEngine(
            self._scenario(n_ticks), mapper=mapper,
            frames=list(frames) if frames is not None else None,
            classes=classes, embedder=embedder, server=self.server,
            query_hook=submit_sq if mapper is not None else None,
            tick_hook=(lambda t: self.scheduler.step())
            if mapper is not None else None)
        for cl in self.clients:            # expose engine-owned sessions
            cl.session = engine.sessions[cl.cid]
        log = engine.run()

        if mapper is not None:
            self.scheduler.drain()      # serve every remaining submission
        sq = log.queried * (log.mode_sq == 1)
        lq = log.queried * (log.mode_sq == 0)
        for cl in self.clients:
            cl.active = bool(log.client_active[-1, cl.cid])
            cl.queries = int(sq[:, cl.cid].sum())
            cl.lq_ticks = int(lq[:, cl.cid].sum())
        self.stats = {
            "n_ticks": n_ticks,
            "n_clients": self.n_clients,
            "active_at_end": int(log.client_active[-1].sum()),
            "tick_ms_mean": float(np.mean(engine.wall_ms))
            if engine.wall_ms else 0.0,
            "down_bytes_total": int(log.sent_bytes.sum()),
            "down_bytes_per_client": int(log.sent_bytes.sum())
            / max(self.n_clients, 1),
            "delivered_packets": int(log.delivered.sum()),
            "delayed_packets": int(log.delayed.sum()),
            "sq_queries": int(sq.sum()),
            "lq_fallbacks": int(lq.sum()),
            "hedges": self.scheduler.hedge_count - hedges0,
            "served": len(self.scheduler.done),
            "unserved": len(self.scheduler.waiting),
            "dropped_by_full_zone": self.server.zoned.dropped,
        }
        return self.stats
