"""Mesh-sharded session tier: the [C, N] fleet sync state partitioned
across S session shards, one per mesh device.

``SessionManager`` keeps the whole fleet's sync state as one [C, N] device
array and the per-client host state (acked / inflight / ever_sent /
next_seq) as C-row host arrays — one host, one device.  Past C≈1k the
single [C, N] dispatch still scales, but the arrays live on one device and
the host bookkeeping on one process; the ROADMAP's C≥4096 tier wants both
partitioned.  ``MeshSessionTier`` shards the CLIENT axis: S plain
SessionManager parts, part s owning rows for the clients a ``ClientRoster``
homes there (subscribed-zone affinity via
``distributed.sharding.client_shard_affinity``, round-robin before poses
exist).  Each part is placed on its own mesh device (``place_on``), so a
part's vmapped ``_collect_fleet`` gathers run where its clients' zone
stores live.

Correctness rests on a property of ``_collect_fleet_impl``: every
per-client row of the collect is computed independently (vmapped change
detection, per-row priority, per-row ``lax.top_k``, per-row gather), so a
[C_s, N] collect over a subset of clients produces BIT-IDENTICAL rows to
the same clients' rows in the unsharded [C, N] collect.  The tier
therefore never merges tensors: the k-way merge happens only at the wire
boundary — ``MeshFleetPacket`` assembles the per-client byte/seq/count
accounting into [C] arrays and delegates ``packet_for(c)`` to the owning
part's row view, so wire packets are byte-identical to the single-device
path (asserted per client at every C in benchmarks/fleet_scale.py and in
tests/test_fleet.py).

Control-plane routing: acks, resyncs, rollbacks, and per-client resets are
routed to the owning shard through the roster (``parts[assign[c]]``, row
``row[c]``); store-slot events (``reset_slots``) broadcast to every part,
exactly like the unsharded [C, N] column clear.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax

from repro.core.knobs import Knobs
from repro.core.store import ObjectStore
from repro.core.updates import UpdatePacket
from repro.server.session import SessionManager


# ---------------------------------------------------------------------------
@dataclass
class ClientRoster:
    """Static client -> session-shard partition.

    ``assign[c]`` is the shard homing client c; ``row[c]`` its row inside
    that shard's [C_s, N] state (ascending-cid order, so a shard's rows
    are a stable sorted view of its members).  The roster is fixed for the
    tier's lifetime — re-homing a client would have to move its sync/ack/
    in-flight state across hosts mid-protocol (ROADMAP: live migration).
    """
    assign: np.ndarray                 # [C] int32
    n_shards: int
    row: np.ndarray = None             # [C] int32, derived
    members: tuple = None              # per-shard int64[C_s] global cids

    def __post_init__(self):
        self.assign = np.asarray(self.assign, np.int32)
        assert self.assign.ndim == 1
        assert (0 <= self.assign).all() and (self.assign < self.n_shards).all()
        C = len(self.assign)
        self.row = np.zeros((C,), np.int32)
        members = []
        for s in range(self.n_shards):
            cids = np.nonzero(self.assign == s)[0].astype(np.int64)
            members.append(cids)
            self.row[cids] = np.arange(len(cids), dtype=np.int32)
        self.members = tuple(members)

    @property
    def n_clients(self) -> int:
        return len(self.assign)

    def counts(self) -> np.ndarray:
        return np.array([len(m) for m in self.members], np.int64)

    @classmethod
    def round_robin(cls, n_clients: int, n_shards: int) -> "ClientRoster":
        return cls(assign=np.arange(n_clients, dtype=np.int32) % n_shards,
                   n_shards=n_shards)

    @classmethod
    def from_affinity(cls, subscribed: np.ndarray, n_shards: int,
                      zone_shards=None) -> "ClientRoster":
        """Partition by subscribed-zone affinity (majority vote over the
        zones' shard placement; see distributed.sharding)."""
        from repro.distributed.sharding import client_shard_affinity
        return cls(assign=client_shard_affinity(subscribed, n_shards,
                                                zone_shards),
                   n_shards=n_shards)


# ---------------------------------------------------------------------------
@dataclass
class MeshFleetPacket:
    """One tick's C packets from S shard collects, merged ONLY at the wire
    boundary: the per-client accounting ([C] nbytes/counts/seqs/epoch/
    fresh) is assembled from the part packets, while the payload tensors
    stay in their per-part [C_s, U] batches — ``packet_for(c)`` is the
    owning part's row view, so the framed bytes are exactly the
    single-device packet's."""
    parts: list                        # per-shard FleetPacket (None = empty
    #                                    shard: no clients homed there)
    roster: ClientRoster
    counts: np.ndarray                 # [C] assembled
    nbytes: np.ndarray                 # [C] assembled
    seqs: np.ndarray                   # [C] assembled (-1 = unframed)
    epoch: np.ndarray                  # [C] assembled
    fresh: np.ndarray                  # [C] assembled
    tick: int
    zone: int = 0
    proto: bool = False

    @property
    def total_nbytes(self) -> int:
        return int(self.nbytes.sum())

    def block_until_ready(self) -> None:
        """Fence every shard's device tensors (serving-loop sync path)."""
        for pkt in self.parts:
            if pkt is not None:
                pkt.block_until_ready()

    def tomb_counts(self) -> np.ndarray:
        out = np.zeros_like(self.counts)
        for s, pkt in enumerate(self.parts):
            if pkt is not None:
                out[self.roster.members[s]] = pkt.tomb_counts()
        return out

    def packet_for(self, c: int) -> UpdatePacket:
        pkt = self.parts[int(self.roster.assign[c])]
        if pkt is None:
            return UpdatePacket(batch=None, count=0, nbytes=0, tick=self.tick)
        return pkt.packet_for(int(self.roster.row[c]))


class _MeshPending:
    """Issued-but-unfinished collects of every part, in shard order."""
    __slots__ = ("pending",)

    def __init__(self, pending):
        self.pending = pending         # per-shard _PendingCollect | None


# ---------------------------------------------------------------------------
@dataclass
class MeshSessionTier:
    """S SessionManager parts behind the SessionManager facade FleetServer
    drives: same control-plane methods (global client ids, routed to the
    owning shard) and the same collect_start/collect_finish hot path (every
    part dispatched per tier collect, so part ticks stay in lockstep with
    the tier tick and quiescence semantics match the unsharded session:
    tier dirty == OR over part dirty == unsharded dirty)."""
    knobs: Knobs
    capacity: int                      # N = slot count of the served store
    roster: ClientRoster = None
    n_clients: int = 0                 # used only when roster is None
    n_shards: int = 2                  # used only when roster is None
    budget: int = 64
    proto: bool = False
    donate: bool | None = False        # None = backend-aware auto policy
    parts: list = field(default_factory=list)
    devices: list = None               # per-shard jax device (None entries =
    #                                    default device; 1-device container:
    #                                    every part on the same device)
    tick: int = 0

    def __post_init__(self):
        if self.roster is None:
            self.roster = ClientRoster.round_robin(self.n_clients,
                                                   self.n_shards)
        self.n_clients = self.roster.n_clients
        self.n_shards = self.roster.n_shards
        if self.devices is None:
            self.devices = [None] * self.n_shards
        if not self.parts:
            self.parts = [
                SessionManager(knobs=self.knobs, n_clients=len(m),
                               capacity=self.capacity, budget=self.budget,
                               proto=self.proto, donate=self.donate,
                               subscribed=np.zeros((len(m),), bool))
                if len(m) else None
                for m in self.roster.members]

    # -- partition helpers -------------------------------------------------
    def _route(self, c: int):
        part = self.parts[int(self.roster.assign[c])]
        assert part is not None
        return part, int(self.roster.row[c])

    def _live(self):
        return ((s, p) for s, p in enumerate(self.parts) if p is not None)

    def _assemble1(self, get, dtype, fill=0):
        out = np.full((self.n_clients,), fill, dtype)
        for s, p in self._live():
            out[self.roster.members[s]] = get(p)
        return out

    def place_on(self, mesh) -> None:
        """Move each part's device-resident sync state onto its mesh
        device (round-robin, same placement rule as zone_shard_devices).
        Host-side per-client state stays with the part object — on a real
        multi-host mesh that state lives in the shard's server process."""
        from repro.distributed.sharding import zone_shard_devices
        self.devices = zone_shard_devices(mesh, self.n_shards)
        for s, p in self._live():
            p.sync = jax.device_put(p.sync, self.devices[s])

    # -- SessionManager facade: state reads --------------------------------
    @property
    def dirty(self) -> bool:
        return any(p.dirty for _, p in self._live())

    @dirty.setter
    def dirty(self, v: bool) -> None:
        for _, p in self._live():
            p.dirty = v

    @property
    def subscribed(self) -> np.ndarray:
        return self._assemble1(lambda p: p.subscribed, bool, False)

    @property
    def user_pos(self) -> np.ndarray:
        out = np.zeros((self.n_clients, 3), np.float32)
        for s, p in self._live():
            out[self.roster.members[s]] = p.user_pos
        return out

    # -- control plane (routed to the owning shard) ------------------------
    def set_all(self, *, subscribed=None, user_pos=None):
        for s, p in self._live():
            m = self.roster.members[s]
            p.set_all(
                subscribed=None if subscribed is None
                else np.asarray(subscribed, bool)[m],
                user_pos=None if user_pos is None
                else np.asarray(user_pos, np.float32)[m])

    def set_client(self, c: int, **kw):
        part, r = self._route(c)
        part.set_client(r, **kw)

    def reset_client(self, c: int, *, keep_seq: bool = False):
        part, r = self._route(c)
        part.reset_client(r, keep_seq=keep_seq)

    def reset_slots(self, slots):
        # store-slot lifecycle is global: every shard's columns clear,
        # exactly like the unsharded [C, N] column clear
        for _, p in self._live():
            p.reset_slots(slots)

    def ack(self, c: int, seq: int):
        part, r = self._route(c)
        part.ack(r, seq)

    def rollback(self, c: int):
        part, r = self._route(c)
        part.rollback(r)

    def oldest_unacked_tick(self, c: int):
        part, r = self._route(c)
        return part.oldest_unacked_tick(r)

    def deletion_debt(self, store: ObjectStore) -> np.ndarray:
        out = np.zeros((self.n_clients, self.capacity), bool)
        for s, p in self._live():
            out[self.roster.members[s]] = p.deletion_debt(store)
        return out

    # -- hot path ----------------------------------------------------------
    def collect_start(self, store: ObjectStore, *,
                      deliverable: np.ndarray | None = None, zone: int = 0,
                      epoch: np.ndarray | None = None,
                      fresh: np.ndarray | None = None,
                      now: int | None = None) -> _MeshPending:
        """Issue every shard's collect dispatch (the shard devices run
        concurrently under jax async dispatch; on the 1-device container
        the dispatches queue).  Every live part is dispatched whenever the
        tier collects, so part ticks/quiescence advance in lockstep with
        the unsharded session."""
        pend = [None] * self.n_shards
        for s, p in self._live():
            m = self.roster.members[s]
            st = store
            if self.devices[s] is not None:
                # placed tier: the shard reads a device-local view of the
                # store (no-op when the placement already matches, as on
                # the 1-device container)
                st = jax.device_put(store, self.devices[s])
            pend[s] = p.collect_start(
                st,
                deliverable=None if deliverable is None
                else np.asarray(deliverable, bool)[m],
                zone=zone,
                epoch=None if epoch is None else np.asarray(epoch)[m],
                fresh=None if fresh is None else np.asarray(fresh)[m],
                now=now)
        return _MeshPending(pend)

    def collect_finish(self, pending: _MeshPending) -> MeshFleetPacket:
        parts = [None] * self.n_shards
        for s, p in self._live():
            if pending.pending[s] is not None:
                parts[s] = p.collect_finish(pending.pending[s])
        roster = self.roster
        pkt = MeshFleetPacket(
            parts=parts, roster=roster,
            counts=self._assemble_pkt(parts, "counts", np.int64, 0),
            nbytes=self._assemble_pkt(parts, "nbytes", np.int64, 0),
            seqs=self._assemble_pkt(parts, "seqs", np.int64, -1),
            epoch=self._assemble_pkt(parts, "epoch", np.int64, 0),
            fresh=self._assemble_pkt(parts, "fresh", bool, False),
            tick=self.tick,
            zone=parts[self._first_live()].zone
            if self._first_live() is not None else 0,
            proto=self.proto)
        self.tick += 1
        return pkt

    def _first_live(self):
        for s, p in enumerate(self.parts):
            if p is not None:
                return s
        return None

    def _assemble_pkt(self, parts, name, dtype, fill):
        out = np.full((self.n_clients,), fill, dtype)
        for s, pkt in enumerate(parts):
            if pkt is not None:
                out[self.roster.members[s]] = getattr(pkt, name)
        return out

    def collect(self, store: ObjectStore, *,
                deliverable: np.ndarray | None = None, zone: int = 0,
                epoch: np.ndarray | None = None,
                fresh: np.ndarray | None = None,
                now: int | None = None) -> MeshFleetPacket:
        return self.collect_finish(self.collect_start(
            store, deliverable=deliverable, zone=zone, epoch=epoch,
            fresh=fresh, now=now))
