"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.  Single pod = (data=16, model=16) = 256 chips (TPU v5e pod);
multi-pod adds a leading "pod" axis (2 pods = 512 chips).  Batch shards over
("pod","data") so cross-pod traffic is gradient all-reduce only.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax")
    devs = np.asarray(devices[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / examples on the CPU container."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
