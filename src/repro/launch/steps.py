"""jit-able step functions with shardings attached — used by the dry-run,
the trainer, and the server."""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeCell, input_specs
from repro.distributed import sharding as sh
from repro.models import common as cm
from repro.models.api import model_api
from repro.optim import adamw


def _ns(mesh, pspecs):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _logits_pspec(mesh, global_batch: int, vocab: int) -> P:
    import numpy as np
    dpa = sh.dp_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in dpa])) or 1
    batch = dpa if (global_batch % dp == 0 and global_batch >= dp) else None
    v = "model" if vocab % mesh.shape["model"] == 0 else None
    return P(batch, v)


def build_train_step(cfg: cm.ArchConfig, mesh: Mesh, cell: ShapeCell,
                     ocfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """Returns (jitted step, arg ShapeDtypeStructs w/ shardings)."""
    api = model_api(cfg)
    pspecs = api.param_specs()
    ospecs = adamw.opt_state_specs(pspecs, ocfg)
    ispecs = input_specs(cfg, cell)

    p_sh = _ns(mesh, sh.param_pspecs(cfg, pspecs, mesh))
    o_sh = _ns(mesh, sh.zero_pspecs(cfg, ospecs, mesh))
    i_sh = _ns(mesh, sh.input_pspecs(cfg, ispecs, mesh,
                                     global_batch=cell.global_batch))

    A = max(cfg.grad_accum, 1)

    def train_step(params, opt, batch):
        if A == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: api.loss(p, batch), has_aux=True)(params)
        else:
            # gradient accumulation: scan over microbatches; activation
            # memory scales with batch/A while grads accumulate in fp32
            micro = jax.tree.map(
                lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)

            def step_fn(carry, mb):
                gsum, lsum = carry
                (l, m), g = jax.value_and_grad(
                    lambda p: api.loss(p, mb), has_aux=True)(params)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), ms = jax.lax.scan(
                step_fn, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / A, gsum)
            loss = lsum / A
            metrics = jax.tree.map(lambda x: x[-1], ms)
        new_params, new_opt, om = adamw.adamw_update(grads, opt, params, ocfg)
        return new_params, new_opt, {**metrics, **om, "loss": loss}

    rep = NamedSharding(mesh, P())
    step = jax.jit(train_step,
                   in_shardings=(p_sh, o_sh, i_sh),
                   out_shardings=(p_sh, o_sh, rep),
                   donate_argnums=(0, 1))
    args = (
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                       sharding=s),
                     pspecs, p_sh),
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                       sharding=s),
                     ospecs, o_sh),
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                       sharding=s),
                     ispecs, i_sh),
    )
    return step, args


def build_prefill_step(cfg: cm.ArchConfig, mesh: Mesh, cell: ShapeCell):
    api = model_api(cfg)
    pspecs = api.param_specs()
    ispecs = input_specs(cfg, cell)
    cspecs = api.cache_specs(cell.global_batch, cell.seq_len)

    p_sh = _ns(mesh, sh.param_pspecs(cfg, pspecs, mesh))
    i_sh = _ns(mesh, sh.input_pspecs(cfg, ispecs, mesh,
                                     global_batch=cell.global_batch))
    c_sh = _ns(mesh, sh.cache_pspecs(cfg, cspecs, mesh,
                                     global_batch=cell.global_batch))
    logit_sh = NamedSharding(mesh, _logits_pspec(mesh, cell.global_batch, cfg.vocab_size))

    def prefill_step(params, batch, caches):
        return api.prefill(params, batch, caches)

    step = jax.jit(prefill_step,
                   in_shardings=(p_sh, i_sh, c_sh),
                   out_shardings=(logit_sh, c_sh),
                   donate_argnums=(2,))
    mk = lambda specs, shs: jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        specs, shs)
    return step, (mk(pspecs, p_sh), mk(ispecs, i_sh), mk(cspecs, c_sh))


def build_decode_step(cfg: cm.ArchConfig, mesh: Mesh, cell: ShapeCell):
    api = model_api(cfg)
    pspecs = api.param_specs()
    ispecs = input_specs(cfg, cell)
    cspecs = api.cache_specs(cell.global_batch, cell.seq_len)

    p_sh = _ns(mesh, sh.param_pspecs(cfg, pspecs, mesh))
    i_sh = _ns(mesh, sh.input_pspecs(cfg, ispecs, mesh,
                                     global_batch=cell.global_batch))
    c_sh = _ns(mesh, sh.cache_pspecs(cfg, cspecs, mesh,
                                     global_batch=cell.global_batch))
    logit_sh = NamedSharding(mesh, _logits_pspec(mesh, cell.global_batch, cfg.vocab_size))

    def decode_step(params, tokens, caches, pos):
        return api.decode(params, tokens, caches, pos)

    step = jax.jit(decode_step,
                   in_shardings=(p_sh, i_sh["tokens"], c_sh,
                                 NamedSharding(mesh, P())),
                   out_shardings=(logit_sh, c_sh),
                   donate_argnums=(2,))
    mk = lambda specs, shs: jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        specs, shs)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
    return step, (mk(pspecs, p_sh), mk(ispecs, i_sh)["tokens"],
                  mk(cspecs, c_sh), pos_spec)


def build_step(cfg, mesh, cell):
    if cell.kind == "train":
        return build_train_step(cfg, mesh, cell)
    if cell.kind == "prefill":
        return build_prefill_step(cfg, mesh, cell)
    return build_decode_step(cfg, mesh, cell)
