import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, record memory analysis, cost analysis, and the collective
schedule.  Writes one JSON per cell under experiments/dryrun/.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The first two lines above MUST precede any jax import: jax locks the device
count at first init, and only the dry-run wants 512 host devices.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED, SHAPES, cell_is_runnable, get_config
from repro.launch import costs as costs_mod
from repro.launch import hlo_costs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             absorb_mla: bool = False, prune_tiles: bool = False,
             seq_parallel: bool = False, grad_accum: int = 1,
             int8_kv: bool = False, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg = get_config(arch)
    cell = SHAPES[shape]
    # MoE dispatch groups track the data-parallel world so token groups stay
    # shard-local.
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                      if a != "model"]))
    if cfg.moe is not None:
        cfg = cfg.replace(moe_groups=min(dp, cell.global_batch),
                          moe_weight_shard="2d" if cell.kind == "train"
                          else "ep")
    if cfg.rwkv is not None and cell.kind != "train":
        cfg = cfg.replace(rwkv_tm_shard="replicated")
    if int8_kv and cell.kind == "decode":
        cfg = cfg.replace(kv_cache_dtype="int8")
    if absorb_mla and cfg.mla is not None:
        cfg = cfg.replace(mla=cfg.mla, name=cfg.name + "+absorb")
        import dataclasses
        cfg = cfg.replace(mla=dataclasses.replace(cfg.mla, absorb=True))
    if prune_tiles:
        cfg = cfg.replace(prune_tiles=True)
    if seq_parallel and cell.kind == "train" and cell.seq_len % 16 == 0:
        dpa = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        cfg = cfg.replace(act_shard=(dpa, "model"))
    if grad_accum > 1 and cell.kind == "train":
        cfg = cfg.replace(grad_accum=grad_accum)
    if cell.kind != "train":
        cfg = cfg.replace(remat=False)

    rec = {"arch": arch, "shape": shape, "kind": cell.kind,
           "mesh": dict(mesh.shape), "chips": chips,
           "multi_pod": multi_pod, "mla_absorb": bool(absorb_mla and cfg.mla),
           "prune_tiles": prune_tiles, "seq_parallel": seq_parallel,
           "grad_accum": grad_accum, "int8_kv": int8_kv}
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        step, args = build_step(cfg, mesh, cell)
        if cell.kind == "decode":
            lowered = step.lower(*args)
        else:
            lowered = step.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes_per_dev": ma.argument_size_in_bytes,
        "output_bytes_per_dev": ma.output_size_in_bytes,
        "temp_bytes_per_dev": ma.temp_size_in_bytes,
        "alias_bytes_per_dev": ma.alias_size_in_bytes,
        "peak_bytes_per_dev": (ma.argument_size_in_bytes +
                               ma.output_size_in_bytes +
                               ma.temp_size_in_bytes -
                               ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    rec["hlo_cost_raw"] = {k: ca[k] for k in ("flops", "bytes accessed")
                           if k in ca}

    hlo = compiled.as_text()
    coll = hlo_costs.collective_costs(hlo, chips)
    rec["collectives"] = {
        "wire_bytes_per_dev": coll.wire_bytes,
        "by_kind": dict(coll.by_kind),
        "n_sites": len(coll.ops),
    }

    cc = costs_mod.step_costs(cfg, cell)
    rl = costs_mod.roofline_terms(cc, coll.wire_bytes, chips=chips)
    rec["analytic"] = {
        "flops": cc.flops, "hbm_bytes": cc.hbm_bytes,
        "model_flops": cc.model_flops, "n_params": cc.n_params,
        "n_active": cc.n_active,
    }
    rec["roofline"] = rl
    rec["timings"] = {"lower_s": t1 - t0, "compile_s": t2 - t1}

    if verbose:
        mem = rec["memory"]
        print(f"[{arch} x {shape}] mesh={tuple(mesh.shape.values())} "
              f"compile={t2 - t1:.1f}s "
              f"peak/dev={mem['peak_bytes_per_dev']/2**30:.2f}GiB "
              f"coll/dev={coll.wire_bytes/2**20:.1f}MiB "
              f"dominant={rl['dominant']} bound={rl['bound_s']*1e3:.2f}ms "
              f"mfu={rl['roofline_mfu']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--absorb-mla", action="store_true")
    ap.add_argument("--prune-tiles", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    outdir = Path(args.out) / ("pod2" if args.multi_pod else "pod1")
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    failures = []
    for a, s in cells:
        name = f"{a}__{s}" + ("__absorb" if args.absorb_mla else "") + \
            ("__prune" if args.prune_tiles else "") + \
            ("__sp" if args.seq_parallel else "") + \
            (f"__ga{args.grad_accum}" if args.grad_accum > 1 else "") + \
            ("__int8kv" if args.int8_kv else "")
        path = outdir / f"{name}.json"
        if not cell_is_runnable(a, s):
            rec = {"arch": a, "shape": s, "skipped": True,
                   "reason": "long_500k needs sub-quadratic attention; "
                             "this arch is pure full-attention (DESIGN.md)"}
            path.write_text(json.dumps(rec, indent=1))
            print(f"[{a} x {s}] SKIP (full-attention @ 500k)")
            continue
        try:
            rec = run_cell(a, s, multi_pod=args.multi_pod,
                           absorb_mla=args.absorb_mla,
                           prune_tiles=args.prune_tiles,
                           seq_parallel=args.seq_parallel,
                           grad_accum=args.grad_accum,
                           int8_kv=args.int8_kv)
            path.write_text(json.dumps(rec, indent=1))
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((a, s, repr(e)))
            traceback.print_exc()
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("dry-run complete:", len(cells), "cells")


if __name__ == "__main__":
    main()
