"""Analytic FLOP / HBM-traffic model for every (arch x shape) cell.

Why analytic: XLA's HloCostAnalysis costs while-loop bodies exactly ONCE, and
every layer stack / attention tile walk / recurrence chunk here is a loop —
the raw ``compiled.cost_analysis()`` number under-counts by the product of
trip counts.  This model mirrors the *implementation* (not an idealized
paper formula): blocked attention visits every KV tile even when the
sliding-window mask kills it; MoE pays the capacity-factor padding; naive MLA
decode re-expands K/V per step.  That makes waste visible in the
MODEL_FLOPS/HLO_FLOPS ratio instead of hiding it.

Validation: tests/test_costs.py compiles small UNROLLED variants (python
loops, no lax.scan/map) and asserts this model matches cost_analysis()
within tolerance.

HBM model: params are streamed once per step; optimizer traffic is
master/m/v fp32 read+write; attention score tiles are counted as
VMEM-resident (the Pallas kernel keeps them on-chip; see kernels/); KV-cache
reads dominate decode.  Documented per-term in the breakdown dict.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ShapeCell
from repro.models import common as cm
from repro.models.api import model_api
from repro.models.moe import expert_capacity


def _mm(m, n, k):
    return 2.0 * m * n * k


@dataclass
class CellCosts:
    flops: float            # total executed FLOPs (all devices)
    hbm_bytes: float        # total HBM traffic (all devices)
    model_flops: float      # 6*N*D train / 2*N_active*D inference
    n_params: int
    n_active: int
    breakdown: dict

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.flops, 1.0)


def param_counts(cfg: cm.ArchConfig) -> tuple[int, int]:
    """(total params, active-per-token params)."""
    api = model_api(cfg)
    n = cm.count_params(api.param_specs())
    n_active = n
    if cfg.moe is not None:
        mo = cfg.moe
        n_moe_layers = sum(1 for i in range(cfg.n_body_layers)
                           if cfg.block_kinds(i % cfg.period)[1] == cm.MLP_MOE)
        expert_p = 3 * cfg.d_model * mo.d_ff_expert
        inactive = n_moe_layers * (mo.n_experts - mo.top_k) * expert_p
        n_active = n - inactive
    return n, n_active


# ---------------------------------------------------------------------------
# per-layer forward FLOPs (mirrors models/*.py exactly)
# ---------------------------------------------------------------------------

def _visited_tiles_frac(cfg, S, T, window) -> float:
    """Fraction of the S*T tile grid the blocked attention touches."""
    if not cfg.prune_tiles or S == 1:
        return 1.0
    Cq = min(cfg.attn_chunk, S)
    Ck = min(1024, T)
    nq, nk = -(-S // Cq), -(-T // Ck)
    total = visited = 0
    for i in range(nq):
        hi = min((((i + 1) * Cq) + Ck - 1) // Ck, nk)
        lo = 0 if not window else max((i * Cq - window + 1) // Ck, 0)
        visited += hi - lo
        total += nk
    return visited / max(total, 1)


def _attn_flops(cfg, B, S, T, *, decode=False, window=0):
    H, K, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    proj = _mm(B * S, H * dh, d) + 2 * _mm(B * S, K * dh, d) \
        + _mm(B * S, d, H * dh)
    # blocked attention: full S*T tile sweep in the baseline; the prune_tiles
    # optimization visits only the causal/window band (mirrors attention.py)
    core = 2 * (2.0 * B * H * S * T * dh) * _visited_tiles_frac(cfg, S, T,
                                                                window)
    return proj + core


def _mla_flops(cfg, B, S, T, *, decode=False):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk, qr, dv, rkv, rq = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                           m.v_head_dim, m.kv_lora_rank, m.q_lora_rank)
    f = 0.0
    if rq:
        f += _mm(B * S, rq, d) + _mm(B * S, H * (qk + qr), rq)
    else:
        f += _mm(B * S, H * (qk + qr), d)
    f += _mm(B * S, rkv + qr, d)                      # kv down
    f += _mm(B * S, d, H * dv)                        # out proj
    if not decode:
        f += _mm(B * S, H * qk, rkv) + _mm(B * S, H * dv, rkv)  # expand K/V
        # attention core counted via _mla_prefill_core
    else:
        if m.absorb:
            f += _mm(B * H, rkv, qk)                  # fold W_UK into q
            f += 2.0 * B * H * T * (rkv + qr) * 2     # scores vs latent+rope
            f += 2.0 * B * H * T * rkv                # o_lat
            f += _mm(B * H, dv, rkv)                  # unfold W_UV
        else:
            f += _mm(B * T, H * qk, rkv) + _mm(B * T, H * dv, rkv)  # re-expand
            f += 2.0 * B * H * T * (qk + qr) * 2      # scores (nope+rope)
            f += 2.0 * B * H * T * dv                 # pv
    return f


def _mla_prefill_core(cfg, B, S):
    m = cfg.mla
    H = cfg.n_heads
    return 2.0 * B * H * S * S * (m.qk_nope_head_dim + m.qk_rope_head_dim) \
        + 2.0 * B * H * S * S * m.v_head_dim


def _mamba_flops(cfg, B, S):
    d = cfg.d_model
    di = cfg.mamba.expand * d
    N = cfg.mamba.d_state
    dtr = cfg.mamba.dt_rank or math.ceil(d / 16)
    f = _mm(B * S, 2 * di, d)                         # in_proj
    f += 2.0 * B * S * di * cfg.mamba.d_conv          # causal conv
    f += _mm(B * S, dtr + 2 * N, di)                  # x_proj
    f += _mm(B * S, di, dtr)                          # dt_proj
    f += 10.0 * B * S * di * N                        # scan elementwise (assoc)
    f += 2.0 * B * S * di * N                         # y = C.h
    f += _mm(B * S, d, di)                            # out_proj
    return f


def _rwkv_tm_flops(cfg, B, S):
    d = cfg.d_model
    rw = cfg.rwkv
    h, dh = d // rw.head_dim, rw.head_dim
    C = min(rw.chunk, S)
    nch = math.ceil(S / C)
    f = _mm(B * S, 5 * rw.mix_lora, d) + 2.0 * B * S * 5 * rw.mix_lora * d
    f += 5 * _mm(B * S, d, d)                         # r,k,v,g,o projections
    f += _mm(B * S, rw.decay_lora, d) + _mm(B * S, d, rw.decay_lora)
    intra = B * nch * (5.0 * C * C * h * dh)          # masked pairwise + pv
    inter = B * nch * (4.0 * C * h * dh * dh)         # state read + update
    return f + intra + inter


def _rwkv_cm_flops(cfg, B, S):
    d, ff = cfg.d_model, cfg.d_ff
    return _mm(B * S, ff, d) + _mm(B * S, d, ff) + _mm(B * S, d, d)


def _mlp_flops(cfg, B, S, d_ff):
    return 3 * _mm(B * S, d_ff, cfg.d_model)


def _moe_flops(cfg, B, S, n_groups):
    mo = cfg.moe
    T = B * S
    g = max(1, n_groups)
    while T % g:
        g -= 1
    Tg = T // g
    Cap = expert_capacity(Tg, cfg)
    f = _mm(T, mo.n_experts, cfg.d_model)             # router
    f += 3 * _mm(g * mo.n_experts * Cap, mo.d_ff_expert, cfg.d_model)
    if mo.n_shared:
        f += 3 * _mm(T, mo.n_shared * mo.d_ff_expert, cfg.d_model)
    return f


def _layer_fwd_flops(cfg, mixer, mlp, B, S, T, *, decode, n_groups):
    if mixer in (cm.MIXER_FULL, cm.MIXER_SWA, cm.MIXER_GLOBAL):
        win = cfg.sliding_window if mixer == cm.MIXER_SWA else 0
        f = _attn_flops(cfg, B, S, T, decode=decode, window=win)
        if mixer == cm.MIXER_SWA and decode:
            Tw = min(T, cfg.sliding_window)
            f = _attn_flops(cfg, B, S, Tw, decode=True)
    elif mixer == cm.MIXER_MLA:
        f = _mla_flops(cfg, B, S, T, decode=decode)
        if not decode:
            f += _mla_prefill_core(cfg, B, S)
    elif mixer == cm.MIXER_MAMBA:
        f = _mamba_flops(cfg, B, S)
    elif mixer == cm.MIXER_RWKV6:
        f = _rwkv_tm_flops(cfg, B, S) if not decode else \
            _rwkv_tm_flops(cfg, B, 1)
    else:
        raise ValueError(mixer)

    if mixer == cm.MIXER_RWKV6:
        f += _rwkv_cm_flops(cfg, B, S)
    elif mlp == cm.MLP_MOE:
        f += _moe_flops(cfg, B, S, n_groups)
    else:
        f += _mlp_flops(cfg, B, S, cfg.d_ff)
    return f


# ---------------------------------------------------------------------------
# cell-level costs
# ---------------------------------------------------------------------------

def step_costs(cfg: cm.ArchConfig, cell: ShapeCell, *, n_groups: int = 32,
               dp: int = 32) -> CellCosts:
    B, S = cell.global_batch, cell.seq_len
    n, n_active = param_counts(cfg)
    d = cfg.d_model
    bk = {}

    if cfg.encdec:
        return _encdec_costs(cfg, cell, n, n_active)

    decode = cell.kind == "decode"
    Bs, Ss = (B, 1) if decode else (B, S)
    T = S if decode else S
    fwd = 0.0
    layers = ([(cfg.mixers[0], cm.MLP_DENSE)] * cfg.n_dense_prefix +
              [cfg.block_kinds(i % cfg.period)
               for i in range(cfg.n_body_layers)])
    for i, (mixer, mlp) in enumerate(layers):
        d_ff = cfg.d_ff_dense_prefix if (i < cfg.n_dense_prefix and
                                         cfg.d_ff_dense_prefix) else cfg.d_ff
        if i < cfg.n_dense_prefix:
            fwd += _layer_fwd_flops(cfg, mixer, cm.MLP_DENSE, Bs, Ss, T,
                                    decode=decode, n_groups=n_groups) \
                - _mlp_flops(cfg, Bs, Ss, cfg.d_ff) + _mlp_flops(cfg, Bs, Ss, d_ff)
        else:
            fwd += _layer_fwd_flops(cfg, mixer, mlp, Bs, Ss, T,
                                    decode=decode, n_groups=n_groups)
    bk["layers_fwd"] = fwd
    # train computes the full-sequence chunked loss; prefill/decode only the
    # final-position logits
    head = _mm(Bs * Ss if cell.kind == "train" else B, cfg.vocab_size, d)
    bk["head_fwd"] = head

    p_bytes = 2.0 * n                                  # bf16 streamed once
    if cell.kind == "train":
        # fwd + remat recompute + 2x bwd for every matmul-dominated term
        mult = 4.0 if cfg.remat else 3.0
        flops = mult * fwd + 3.0 * head               # loss scan not rematted
        model_flops = 6.0 * n_active * (B * S)
        act = 2.0 * (B * S * d) * len(layers) * 6     # resid + block io, bf16
        opt = 24.0 * n                                # m,v,master fp32 r+w
        hbm = p_bytes + 4.0 * n + opt + act           # + grads fp32
        bk.update(hbm_params=p_bytes, hbm_opt=opt, hbm_act=act,
                  hbm_grads=4.0 * n)
    elif cell.kind == "prefill":
        flops = fwd + head
        model_flops = 2.0 * n_active * (B * S)
        act = 2.0 * (B * S * d) * len(layers) * 6
        hbm = p_bytes + act
        bk.update(hbm_params=p_bytes, hbm_act=act)
    else:  # decode
        flops = fwd + head
        model_flops = 2.0 * n_active * B
        cache_bytes = _cache_bytes(cfg, B, S)
        hbm = p_bytes + cache_bytes + 2.0 * B * d * len(layers) * 6
        bk.update(hbm_params=p_bytes, hbm_cache=cache_bytes)

    return CellCosts(flops=flops, hbm_bytes=hbm, model_flops=model_flops,
                     n_params=n, n_active=n_active, breakdown=bk)


def _cache_bytes(cfg: cm.ArchConfig, B, T) -> float:
    """Bytes read from per-layer caches during one decode step."""
    total = 0.0
    layers = ([(cfg.mixers[0], cm.MLP_DENSE)] * cfg.n_dense_prefix +
              [cfg.block_kinds(i % cfg.period)
               for i in range(cfg.n_body_layers)])
    kv_b = 1 + 4.0 / cfg.d_head if cfg.kv_cache_dtype == "int8" else 2
    for mixer, _ in layers:
        if mixer in (cm.MIXER_FULL, cm.MIXER_GLOBAL):
            total += 2.0 * B * T * cfg.n_kv_heads * cfg.d_head * kv_b
        elif mixer == cm.MIXER_SWA:
            Tw = min(T, cfg.sliding_window)
            total += 2.0 * B * Tw * cfg.n_kv_heads * cfg.d_head * kv_b
        elif mixer == cm.MIXER_MLA:
            m = cfg.mla
            total += 2.0 * B * T * (m.kv_lora_rank + m.qk_rope_head_dim)
            if not m.absorb:   # naive path re-reads expanded K/V it just wrote
                total += 2.0 * B * T * cfg.n_heads * \
                    (m.qk_nope_head_dim + m.v_head_dim) * 2
        elif mixer == cm.MIXER_MAMBA:
            di = cfg.mamba.expand * cfg.d_model
            total += 2.0 * B * di * cfg.mamba.d_state * 4
        elif mixer == cm.MIXER_RWKV6:
            h, dh = cfg.d_model // cfg.rwkv.head_dim, cfg.rwkv.head_dim
            total += 2.0 * B * h * dh * dh * 4
    return total


def _encdec_costs(cfg, cell, n, n_active) -> CellCosts:
    B, S = cell.global_batch, cell.seq_len
    d, H, dh, ff = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff
    bk = {}

    def enc_layer(S_):
        return _attn_flops(cfg, B, S_, S_) + _mlp_flops(cfg, B, S_, ff)

    def dec_layer(S_, T_enc):
        self_ = _attn_flops(cfg, B, S_, S_)
        cross = _mm(B * S_, H * dh, d) + _mm(B * T_enc, 2 * H * dh, d) + \
            2 * (2.0 * B * H * S_ * T_enc * dh) + _mm(B * S_, d, H * dh)
        return self_ + cross + _mlp_flops(cfg, B, S_, ff)

    if cell.kind == "train":
        Sd = 448
        fwd = cfg.n_enc_layers * enc_layer(S) + cfg.n_layers * dec_layer(Sd, S)
        head = _mm(B * Sd, cfg.vocab_size, d)
        mult = 4.0 if cfg.remat else 3.0
        flops = mult * fwd + 3.0 * head
        model_flops = 6.0 * n * (B * (S + Sd))
        hbm = 2.0 * n + 4.0 * n + 24.0 * n + \
            2.0 * B * (S + Sd) * d * (cfg.n_enc_layers + cfg.n_layers) * 6
    elif cell.kind == "prefill":
        fwd = cfg.n_enc_layers * enc_layer(S)
        flops = fwd
        model_flops = 2.0 * n * (B * S)
        hbm = 2.0 * n + 2.0 * B * S * d * cfg.n_enc_layers * 6
    else:
        T_enc = cfg.enc_seq
        self_ = _attn_flops(cfg, B, 1, S)
        cross = _mm(B, H * dh, d) + 2.0 * B * H * T_enc * dh * 2 + \
            _mm(B, d, H * dh)
        fwd = cfg.n_layers * (self_ + cross + _mlp_flops(cfg, B, 1, ff))
        head = _mm(B, cfg.vocab_size, d)
        flops = fwd + head
        model_flops = 2.0 * n * B
        kv = cfg.n_layers * (2.0 * B * S * H * dh * 2 +
                             2.0 * B * T_enc * H * dh * 2)
        hbm = 2.0 * n + kv
        bk["hbm_cache"] = kv
    bk["layers_fwd"] = fwd
    return CellCosts(flops=flops, hbm_bytes=hbm, model_flops=model_flops,
                     n_params=n, n_active=n_active, breakdown=bk)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

V5E = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


def roofline_terms(costs: CellCosts, collective_bytes_per_dev: float, *,
                   chips: int, hw=V5E) -> dict:
    t_compute = costs.flops / (chips * hw["peak_flops"])
    t_memory = costs.hbm_bytes / (chips * hw["hbm_bw"])
    t_coll = collective_bytes_per_dev / hw["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    mfu = (costs.model_flops / (chips * hw["peak_flops"])) / max(bound, 1e-30)
    return {**terms, "dominant": dom, "bound_s": bound,
            "roofline_mfu": mfu, "useful_ratio": costs.useful_ratio}
