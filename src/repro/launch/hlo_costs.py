"""Collective-cost extraction from compiled HLO text.

``compiled.cost_analysis()`` has no collective accounting, and XLA costs
while-loop bodies exactly once.  This parser:

  1. splits the HLO module into named computations,
  2. finds every while op and reads its trip count from the loop-condition
     computation's `constant(N)` bound,
  3. walks the call graph (entry -> while bodies/conds -> nested) assigning a
     multiplier = product of enclosing trip counts,
  4. sums wire bytes for every all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute, weighted by the multiplier.

Wire-byte model per op (ring algorithms, per-participating-device):
  all-gather:       (g-1)/g * output_bytes
  all-reduce:       2*(g-1)/g * input_bytes
  reduce-scatter:   (g-1)/g * input_bytes
  all-to-all:       (g-1)/g * input_bytes
  collective-permute: input_bytes
where g = replica-group size parsed from the op.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)"
    r"|while\(.*?\)[^\n]*?body=%?([\w\.\-]+)[^\n]*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"\scall\([^\n]*?to_apply=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(text: str) -> int:
    """Total bytes over every shape literal in `text` (tuple shapes ok)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text."""
    comps: dict[str, str] = {}
    lines = hlo.splitlines()
    name, buf, depth = None, [], 0
    for ln in lines:
        if name is None:
            m = _COMP_RE.match(ln.strip())
            if m and ln.rstrip().endswith("{"):
                name, buf, depth = m.group(1), [], 1
            continue
        depth += ln.count("{") - ln.count("}")
        if depth <= 0:
            comps[name] = "\n".join(buf)
            name = None
        else:
            buf.append(ln)
    return comps


def _trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


@dataclass
class CollectiveReport:
    wire_bytes: float = 0.0
    by_kind: dict = field(default_factory=lambda: defaultdict(float))
    ops: list = field(default_factory=list)   # (kind, bytes, multiplier)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m and m.group(1).strip():
        first = m.group(1).split("}")[0].strip("{} ")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return total_devices


def collective_costs(hlo: str, total_devices: int) -> CollectiveReport:
    comps = _split_computations(hlo)
    # entry computation: the one marked ENTRY, else largest
    entry_m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    entry = entry_m.group(1) if entry_m else max(comps, key=lambda k: len(comps[k]))

    mult: dict[str, float] = defaultdict(float)

    def visit(comp: str, m: float, seen: tuple):
        if comp not in comps or comp in seen:
            return
        mult[comp] += m
        body = comps[comp]
        for wm in _WHILE_RE.finditer(body):
            cond = wm.group(1) or wm.group(4)
            wbody = wm.group(2) or wm.group(3)
            n = _trip_count(comps.get(cond, ""))
            visit(wbody, m * n, seen + (comp,))
            visit(cond, m * (n + 1), seen + (comp,))
        for cm_ in _CALL_RE.finditer(body):
            visit(cm_.group(1), m, seen + (comp,))

    visit(entry, 1.0, ())

    rep = CollectiveReport()
    for comp, m in mult.items():
        for ln in comps.get(comp, "").splitlines():
            for kind in COLLECTIVES:
                if re.search(rf"\b{kind}\(", ln) or f" {kind}(" in ln:
                    # operand bytes: shapes inside the op's argument list;
                    # output bytes: shape before the '=' op name
                    lhs, _, rhs = ln.partition("=")
                    out_b = shape_bytes(lhs) or shape_bytes(rhs.split(kind)[0])
                    arg_text = rhs.split(kind, 1)[1] if kind in rhs else ""
                    in_b = shape_bytes(arg_text.split("),")[0]) or out_b
                    g = _group_size(ln, total_devices)
                    f = (g - 1) / max(g, 1)
                    if kind == "all-gather":
                        b = f * out_b
                    elif kind == "all-reduce":
                        b = 2 * f * in_b
                    elif kind == "reduce-scatter":
                        b = f * in_b
                    elif kind == "all-to-all":
                        b = f * in_b
                    else:  # collective-permute
                        b = in_b
                    rep.wire_bytes += m * b
                    rep.by_kind[kind] += m * b
                    rep.ops.append((kind, b, m, g))
                    break
    return rep


def while_trip_counts(hlo: str) -> dict[str, int]:
    comps = _split_computations(hlo)
    out = {}
    for comp, body in comps.items():
        for wm in _WHILE_RE.finditer(body):
            cond = wm.group(1) or wm.group(4)
            wbody = wm.group(2) or wm.group(3)
            out[wbody] = _trip_count(comps.get(cond, ""))
    return out
