"""Training launcher: any registered arch, single host or production mesh.

Fault tolerance: checkpoints every --ckpt-every steps (atomic, manifest'd);
on start, auto-resumes from the latest complete checkpoint.  --kill-at N
simulates a node failure mid-run (process aborts after step N) — rerunning
the same command continues from the last checkpoint, which is exactly the
restart story at pod scale.  Optional int8 gradient compression with error
feedback (--compress-grads) for the cross-pod axis.

Example (the ~100M end-to-end run):
    PYTHONPATH=src python -m repro.launch.train \
        --arch semanticxr-captioner-110m --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs import get_config
from repro.data import tokens as tok
from repro.distributed import collectives as coll
from repro.models.api import model_api
from repro.optim import adamw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="semanticxr-captioner-110m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="simulate node failure after this step")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    api = model_api(cfg)
    ocfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                             warmup_steps=min(50, args.steps // 4))

    params = api.init(jax.random.key(0))
    opt = adamw.init_opt_state(params, ocfg)
    ef = coll.init_ef(params) if args.compress_grads else None

    ckpt_dir = Path(args.ckpt_dir) / cfg.name
    start = 0
    last = ckpt_mod.latest_step(ckpt_dir)
    if last is not None:
        print(f"[restore] resuming from step {last}")
        params = ckpt_mod.restore(ckpt_dir, last, params)
        opt = ckpt_mod.restore(Path(ckpt_dir) / "opt", last, opt)
        start = last

    @jax.jit
    def train_step(params, opt, ef, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss(p, batch), has_aux=True)(params)
        if ef is not None:
            grads, ef = coll.compress_grads_ef(grads, ef)
        params, opt, om = adamw.adamw_update(grads, opt, params, ocfg)
        return params, opt, ef, {"loss": loss, **metrics, **om}

    it = tok.batch_iterator(args.batch, args.seq, seed=start,
                            vocab_size=cfg.vocab_size)
    t0 = time.perf_counter()
    for step in range(start + 1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, ef, m = train_step(params, opt, ef, batch)
        if step % args.log_every == 0 or step == args.steps:
            tok_s = args.batch * args.seq * args.log_every / \
                max(time.perf_counter() - t0, 1e-9)
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"ce {float(m['ce']):.4f} gnorm {float(m['grad_norm']):.2f} "
                  f"lr {float(m['lr']):.2e} tok/s {tok_s:.0f}")
            t0 = time.perf_counter()
        if args.ckpt_every and step % args.ckpt_every == 0:
            ckpt_mod.save(ckpt_dir, step, params)
            ckpt_mod.save(Path(ckpt_dir) / "opt", step, opt)
        if args.kill_at and step == args.kill_at:
            print(f"[fault-injection] simulated node failure at step {step}")
            raise SystemExit(42)
    print("training complete")
    return params


if __name__ == "__main__":
    main()
