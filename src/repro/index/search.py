"""Two-stage (coarse-to-fine) execution of ``Query`` specs over a
ClusterIndex, plus the first-class cluster-level query mode.

Object-level plan (``two_stage_query``), provably equal to the flat sweep:

1. **Stage 1** scores every cluster summary with a *conservative upper
   bound* on the best score any member could achieve, and with predicate
   masks that can only over-include (a cell passes if ANY member could
   pass).  With ``use_pallas`` the ranking runs through the same
   ``query_topk_bias`` kernel as the flat sweep — queries x
   ``summaries.embed_mean`` with the slack/mask bias streamed alongside —
   so the coarse stage is literally the fine stage at 1/cell_cap the rows.
2. **Stage 2** gathers the surviving cells' member slots (ascending slot
   order, so tie-breaking matches the flat sweep) into a fixed candidate
   slab and reuses ``core.query._execute`` — the identical fused
   predicate+score+top-k dispatch, over ~1-10% of the table.
3. **Certificate**: the k-th result score is compared against the max
   upper bound over every *unselected* cluster.  If any unselected cluster
   could still beat rank k, the selection width doubles (escalation) until
   the certificate passes or every cluster is selected — at which point
   the result is the flat sweep's by construction.  Equal-score ties
   *across* the certificate boundary may resolve to a different member
   than the flat sweep (same score, documented); ties among candidates
   resolve identically (ascending slot order).

Upper-bound derivations (all exact-math bounds; the certificate adds a
small epsilon for f32 evaluation-order noise):

* semantic: ``s = w q . e_j = w q . mean + w q . (e_j - mean)
  <= w q . mean + ||w q|| * res_max``                (Cauchy-Schwarz —
  holds for either sign of ``sem_weight``).
* proximity: ``pw / (1 + d)`` with ``d`` in [dmin, dmax] to the member
  AABB — ``pw >= 0`` maximizes at dmin, ``pw < 0`` at dmax.
* predicates: labels via per-cell class presence; near/aabb via member-
  AABB geometry; min_points/min_obs/since via per-cell maxima; zones via
  member-AABB x allowed-zone-rectangle intersection (border zones extend
  to infinity, mirroring ``ZoneGrid.zone_of``'s clamp).

Cluster-level mode (``Query(level="cluster")``): the summaries ARE the
results — score = semantic (query x mean embedding) + proximity (to the
cluster centroid) + ``density_weight * log1p(count)``, top-k cells
returned as a ``ClusterResult`` ("where is the densest region matching
this text").
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.query import (NEG, QueryResult, _Cols, _columns, _execute,
                              _promote)
from repro.core.updates import bucket as _bucket
from repro.obs import metrics as obs_metrics
from repro.obs.trace import span as obs_span

_C0 = 64              # initial stage-1 selection width (cells per query) —
                      # must exceed the typical gate-surviving cell count
                      # (~30-50 on hotspot scenes) or the selection is
                      # clipped, the certificate can't pass, and every
                      # query pays one escalation round
_CERT_EPS = 1e-5      # f32 slack on the exactness certificate
_KERNEL_MAX_K = 1024  # query_topk_bias top-k must fit one block


def candidate_fraction_buckets() -> tuple:
    """Fixed log-spaced fraction buckets (1e-4 .. 1.0) for the
    candidate-fraction histogram — stable across runs like
    ``default_latency_buckets``."""
    return tuple(round(10.0 ** (e / 4.0), 8) for e in range(-16, 1))


# ---------------------------------------------------------------------------
# conservative cluster gating (shared by stage 1 and the cluster-level mode)
# ---------------------------------------------------------------------------
def _zone_rects(zones: tuple, grid: tuple):
    """Static allowed-zone rectangles [Z, 2] lo/hi per axis, border zones
    extended to infinity (mirrors ``ZoneGrid.overlaps``)."""
    x0, z0, zs, nx, nz = grid
    inf = float("inf")
    xlo, xhi, zlo, zhi = [], [], [], []
    for z in zones:
        ix, iz = divmod(int(z), int(nz))
        xlo.append(-inf if ix == 0 else x0 + ix * zs)
        xhi.append(inf if ix == nx - 1 else x0 + (ix + 1) * zs)
        zlo.append(-inf if iz == 0 else z0 + iz * zs)
        zhi.append(inf if iz == nz - 1 else z0 + (iz + 1) * zs)
    mk = lambda v: jnp.asarray(np.asarray(v, np.float32))
    return mk(xlo), mk(xhi), mk(zlo), mk(zhi)


def _cluster_gate(spec, summ, *, has_obs: bool, has_seen: bool):
    """Conservative per-cell predicate mask [Q, M] + the finite upper-bound
    slack [Q, M] (res_max semantic slack + proximity bound) for stage 1.

    Over-inclusion is safe (stage 2 re-checks members exactly); exclusion
    is only allowed when NO member can pass — each test uses the cell's
    member AABB / class presence / attribute maxima."""
    M = summ.count.shape[0]
    ok = jnp.broadcast_to((summ.count > 0)[None, :], (1, M))
    if spec.labels is not None:
        lab = jnp.asarray(spec.labels, jnp.int32)
        ok = ok & summ.label_any[:, lab].any(axis=1)[None, :]
    if spec.min_points is not None:
        ok = ok & (summ.n_points_max[None, :] >= spec.min_points[:, None])
    if spec.min_obs is not None and has_obs:
        ok = ok & (summ.obs_max[None, :] >= spec.min_obs[:, None])
    if spec.since is not None and has_seen:
        ok = ok & (summ.last_seen_max[None, :] >= spec.since[:, None])
    if spec.aabb is not None:
        lo, hi = spec.aabb
        inter = ((summ.aabb_min[None] <= hi[:, None, :])
                 & (summ.aabb_max[None] >= lo[:, None, :])).all(-1)
        ok = ok & inter
    if spec.zones is not None:
        xlo, xhi, zlo, zhi = _zone_rects(spec.zones, spec.grid)
        hit = ((summ.aabb_min[:, None, 0] <= xhi[None])
               & (summ.aabb_max[:, None, 0] >= xlo[None])
               & (summ.aabb_min[:, None, 2] <= zhi[None])
               & (summ.aabb_max[:, None, 2] >= zlo[None])).any(axis=1)
        ok = ok & hit[None, :]

    leaves = jax.tree.leaves(spec)
    Q = int(leaves[0].shape[0]) if leaves else 1
    slack = jnp.zeros((Q, M), jnp.float32)
    if spec.embed is not None:
        qs = spec.embed
        if spec.sem_weight is not None:
            qs = qs * spec.sem_weight[:, None]
        qn = jnp.linalg.norm(qs, axis=-1)                  # [Q]
        slack = slack + qn[:, None] * summ.res_max[None, :]
    if spec.near is not None:
        center, radius = spec.near
        c = center[:, None, :]                             # [Q, 1, 3]
        # min / max distance from the query center to the member AABB
        dmin = jnp.linalg.norm(
            jnp.maximum(jnp.maximum(summ.aabb_min[None] - c,
                                    c - summ.aabb_max[None]), 0.0), axis=-1)
        ok = ok & (dmin <= radius[:, None])
        if spec.prox_weight is not None:
            dmax = jnp.linalg.norm(
                jnp.maximum(jnp.abs(c - summ.aabb_min[None]),
                            jnp.abs(c - summ.aabb_max[None])), axis=-1)
            pw = spec.prox_weight[:, None]
            slack = slack + jnp.where(pw >= 0, pw / (1.0 + dmin),
                                      pw / (1.0 + dmax))
    ok = jnp.broadcast_to(ok, (Q, M))
    # empty cells carry inf/-inf AABBs: their dmin/dmax are inf (0*inf-safe
    # here since slack multiplies finite terms), and count>0 masks them —
    # scrub any NaN the inf arithmetic produced so NEG masking wins
    slack = jnp.nan_to_num(slack, nan=0.0, posinf=0.0, neginf=0.0)
    return ok, slack


# ---------------------------------------------------------------------------
# stage 1: rank clusters by upper bound, select a width-m union
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("m", "use_pallas", "has_obs",
                                             "has_seen"))
def _stage1(spec, summ, *, m: int, use_pallas: bool, has_obs: bool,
            has_seen: bool):
    """Returns (cells [Q*m] int32 — the deduped union of each query's top-m
    cells by upper bound, ascending, -1 padded — and excl_max [Q]: each
    query's max upper bound over every UNSELECTED cluster, the certificate
    threshold)."""
    spec = _promote(spec)
    M = summ.count.shape[0]
    ok, slack = _cluster_gate(spec, summ, has_obs=has_obs, has_seen=has_seen)
    bias = jnp.where(ok, slack, NEG)
    if spec.embed is not None:
        qs = spec.embed
        if spec.sem_weight is not None:
            qs = qs * spec.sem_weight[:, None]
        sim = qs @ summ.embed_mean.T                       # [Q, M]
        ub = jnp.where(bias > NEG * 0.5, sim + bias, NEG)
        if use_pallas and m <= _KERNEL_MAX_K:
            from repro.kernels import ops as kops
            vals, picks = kops.query_topk_bias(qs, summ.embed_mean, bias, m)
        else:
            vals, picks = jax.lax.top_k(ub, m)
    else:
        ub = jnp.where(bias > NEG * 0.5, bias, NEG)
        vals, picks = jax.lax.top_k(ub, m)

    # union the per-query selections: sort, mark duplicates/invalid as -1
    flat = jnp.where(vals > NEG * 0.5, picks, M).reshape(-1)   # [Q*m]
    srt = jnp.sort(flat)
    dup = jnp.concatenate([jnp.zeros((1,), bool), srt[1:] == srt[:-1]])
    cells = jnp.where(dup | (srt >= M), -1, srt).astype(jnp.int32)

    sel = jnp.zeros((M + 1,), bool) \
        .at[jnp.where(cells >= 0, cells, M)].set(True)[:M]
    ub_f = jnp.where(ub > NEG * 0.5, ub, -jnp.inf)
    excl_max = jnp.where(sel[None, :], -jnp.inf, ub_f).max(axis=1)   # [Q]
    return cells, excl_max


# ---------------------------------------------------------------------------
# stage 2: the existing fused sweep over the surviving members only
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _stage2(spec, cols: _Cols, slot_map, *, use_pallas: bool):
    """Sweep an ascending, ``cap``-padded candidate slot slab through the
    SAME ``_execute`` dispatch the flat path uses, then map result slots
    back to target rows.  The slab is assembled host-side from the exact
    per-cell member lists, so its (bucketed) length tracks the TRUE
    candidate count — a fixed cells x cell_cap gather would pad 4-8x past
    reality on occupancy-skewed scenes and the slab sweep is the dominant
    cost of a two-stage query."""
    cap = cols.active.shape[0]
    valid = slot_map < cap
    idx = jnp.where(valid, slot_map, 0)
    cand = _Cols(
        ids=jnp.where(valid, cols.ids[idx], 0),
        active=jnp.where(valid, cols.active[idx], False),
        embed=cols.embed[idx],
        label=cols.label[idx],
        n_points=cols.n_points[idx],
        centroid=cols.centroid[idx],
        obs_count=None if cols.obs_count is None else cols.obs_count[idx],
        last_seen=None if cols.last_seen is None else cols.last_seen[idx])
    res = _execute(spec, cand, use_pallas=use_pallas)
    slots = jnp.where(res.slots >= 0,
                      slot_map[jnp.maximum(res.slots, 0)].astype(jnp.int32),
                      -1)
    return QueryResult(oids=res.oids, scores=res.scores, slots=slots)


# ---------------------------------------------------------------------------
def two_stage_query(spec, target, index, *,
                    use_pallas: bool = False) -> QueryResult:
    """Execute an object-level ``Query`` through the cluster index with the
    exactness certificate + escalation loop (module docstring)."""
    cols = _columns(target)
    has_obs = cols.obs_count is not None
    has_seen = cols.last_seen is not None
    M = index.grid.n_cells
    k = max(int(spec.k), 1)
    m = min(_C0, M)
    escalations = 0
    while True:
        with obs_span("query.index.stage1", cat="query", m=m):
            cells, excl = _stage1(spec, index.summaries, m=m,
                                  use_pallas=use_pallas, has_obs=has_obs,
                                  has_seen=has_seen)
        # assemble the candidate slab host-side from the surviving cells'
        # exact member lists (the index's host bookkeeping): the slab
        # length is the bucketed TRUE candidate count, ascending so the
        # flat sweep's slot-order tie-break is preserved bit-for-bit
        cells_np = np.asarray(cells)
        live = cells_np[cells_np >= 0]
        n_cand = int(index._size[live].sum()) if live.size else 0
        cap_t = int(cols.active.shape[0])
        P = min(_bucket(max(n_cand, 1)), _bucket(cap_t))
        slab = np.full((P,), cap_t, np.int64)
        if n_cand:
            slab[:n_cand] = np.sort(np.concatenate(
                [index._members[c][:int(index._size[c])] for c in live]))
        with obs_span("query.index.stage2", cat="query", cells=live.size,
                      slab=P) as sp:
            res = _stage2(spec, cols, jnp.asarray(slab),
                          use_pallas=use_pallas)
            sp.fence(res.scores)
        sk = np.atleast_1d(
            np.asarray(res.scores)[..., min(k, res.scores.shape[-1]) - 1])
        ex = np.asarray(excl)
        exf = np.where(np.isneginf(ex), 0.0, ex)   # keep -inf out of the
        certified = np.isneginf(ex) \
            | (sk >= exf + _CERT_EPS * np.maximum(1.0, np.abs(exf)))
        if certified.all() or m >= M:
            break
        m = min(2 * m, M)
        escalations += 1

    reg = obs_metrics.get_registry()
    if reg is not None:
        reg.counter("query_index_two_stage_total",
                    "queries served by the cluster index").inc()
        if escalations:
            reg.counter("query_index_escalations_total",
                        "certificate-failure selection doublings").inc(
                            escalations)
        frac = n_cand / max(int(cols.active.shape[0]), 1)
        reg.histogram("query_index_candidate_fraction",
                      "stage-2 candidates / table size",
                      bounds=candidate_fraction_buckets()).observe(frac)
    return res


# ---------------------------------------------------------------------------
# cluster-level queries: the summaries ARE the results
# ---------------------------------------------------------------------------
class ClusterResult(NamedTuple):
    """Top-k *clusters* (``Query(level="cluster")``).  Padded ranks: score
    -inf, cell/zone -1, count 0."""
    zones: jax.Array      # [k] / [Q, k] int32 zone id (-1 on flat targets)
    cells: jax.Array      # [k] / [Q, k] int32 grid cell id (-1 = no match)
    scores: jax.Array     # [k] / [Q, k] f32
    counts: jax.Array     # [k] / [Q, k] int32 member count
    centroids: jax.Array  # [k, 3] / [Q, k, 3] f32 cluster centroid


@functools.partial(jax.jit, static_argnames=("has_obs", "has_seen"))
def _cluster_execute(spec, summ, *, has_obs: bool, has_seen: bool):
    """Score cells directly: semantic (query x mean embedding) + proximity
    (to the cluster centroid) + density_weight * log1p(count), under the
    same conservative predicate gate, one top-k over [Q, M]."""
    squeeze = not spec.batched
    spec = _promote(spec)
    M = summ.count.shape[0]
    k = min(spec.k, M)
    ok, _ = _cluster_gate(spec, summ, has_obs=has_obs, has_seen=has_seen)
    leaves = jax.tree.leaves(spec)
    Q = int(leaves[0].shape[0]) if leaves else 1
    score = jnp.zeros((Q, M), jnp.float32)
    if spec.embed is not None:
        qs = spec.embed
        if spec.sem_weight is not None:
            qs = qs * spec.sem_weight[:, None]
        score = score + qs @ summ.embed_mean.T
    if spec.near is not None and spec.prox_weight is not None:
        center, _ = spec.near
        d = jnp.linalg.norm(summ.centroid[None] - center[:, None, :],
                            axis=-1)
        score = score + spec.prox_weight[:, None] / (1.0 + d)
    if spec.density_weight is not None:
        score = score + spec.density_weight[:, None] \
            * jnp.log1p(summ.count.astype(jnp.float32))[None, :]
    score = jnp.where(ok, score, -jnp.inf)
    vals, cells = jax.lax.top_k(score, k)
    bad = jnp.isneginf(vals)
    cells = jnp.where(bad, -1, cells)
    take = jnp.maximum(cells, 0)
    counts = jnp.where(bad, 0, summ.count[take])
    cents = jnp.where(bad[..., None], 0.0, summ.centroid[take])
    if k < spec.k:
        pad = spec.k - k
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        cells = jnp.pad(cells, ((0, 0), (0, pad)), constant_values=-1)
        counts = jnp.pad(counts, ((0, 0), (0, pad)))
        cents = jnp.pad(cents, ((0, 0), (0, pad), (0, 0)))
    out = ClusterResult(zones=jnp.full_like(cells, -1), cells=cells,
                        scores=vals, counts=counts, centroids=cents)
    if squeeze:
        out = ClusterResult(*(x[0] for x in out))
    return out


def cluster_query(spec, items) -> ClusterResult:
    """Run a cluster-level query over ``items = [(zone_or_None, index,
    target)]`` and merge to one top-k (stable: zone order breaks ties)."""
    parts = []
    for zone, index, target in items:
        cols = _columns(target)
        r = _cluster_execute(spec, index.summaries,
                             has_obs=cols.obs_count is not None,
                             has_seen=cols.last_seen is not None)
        z = -1 if zone is None else int(zone)
        parts.append(ClusterResult(
            zones=jnp.where(r.cells >= 0, z, -1), cells=r.cells,
            scores=r.scores, counts=r.counts, centroids=r.centroids))
    if len(parts) == 1:
        return parts[0]
    cat = ClusterResult(*(jnp.concatenate([getattr(p, f) for p in parts],
                                          axis=-1 if f != "centroids"
                                          else -2)
                          for f in ClusterResult._fields))
    vals, sel = jax.lax.top_k(cat.scores, min(spec.k, cat.scores.shape[-1]))
    take = lambda x: jnp.take_along_axis(x, sel, axis=-1)
    return ClusterResult(zones=take(cat.zones), cells=take(cat.cells),
                         scores=vals, counts=take(cat.counts),
                         centroids=jnp.take_along_axis(
                             cat.centroids, sel[..., None], axis=-2))
