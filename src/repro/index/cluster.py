"""Incrementally-maintained cluster-summary level above the object maps.

The flat fused query sweep (core/query.py) is O(N) per dispatch: great to
~10k objects, cracking at 30k, ~100x off the ROADMAP's million-object
target.  This module maintains one summary row per spatial grid cell —
member count, centroid mean, member AABB, mean embedding plus the max
embedding residual, per-class presence, and max n_points/obs/last_seen —
so a query can first rank ~thousands of cells and then sweep only the
members of the surviving cells (index/search.py), with a provable-exact
certificate against the flat sweep.

Maintenance contract (tested by tests/test_cluster_index.py):

* **Incremental, never rebuilt.**  ``refresh(target)`` diffs the target's
  (presence, version, cell) columns against the last view — the same
  host-side bookkeeping idiom as ``server.zones.refresh_from`` — and
  recomputes ONLY the dirty cells, as one bucketed jitted gather+reduce+
  scatter per chunk.  ``update_slots`` is the O(changes) fast path for
  callers that already know which slots they touched (zone-shard scatters,
  the device ingest scan).
* **Bit-identical to a from-scratch rebuild.**  Per-cell reductions always
  run over the cell's member slots in ascending slot order at the fixed
  ``cell_cap`` width, so the incremental value of an unchanged cell is the
  byte-for-byte value a full rebuild would produce (the churn property
  test drives random spawn/move/remove/tombstone streams and asserts it).
* **Tombstones evict.**  Presence is ``active & ~deleted``: a tombstoned
  slot leaves its cell the tick it is tombstoned and can never skew a
  centroid or mean embedding.

Cell-capacity overflow auto-grows: the member table doubles and rebuilds
(the only from-scratch path, amortized O(log N) times over a map's life).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.updates import bucket

N_LABELS = 256                 # matches updates.class_budget_table
_SENTINEL = np.iinfo(np.int32).max      # sorts after every real slot id
_CHUNK = 256                   # max dirty cells per recompute dispatch

# below this many live objects the flat sweep wins — the two-stage plan
# (and its extra dispatches) only engages past it (core/query.py)
DEFAULT_MIN_FLAT = 16_384


@dataclass(frozen=True)
class CellGrid:
    """Fixed XZ partition of the indexed space into nx*nz summary cells.

    Like ``server.zones.ZoneGrid`` but with independent x/z cell edges so
    ``fit`` can wrap arbitrary scene bounds; out-of-bounds centroids clamp
    to the border cells (mirroring ``ZoneGrid.zone_of``)."""
    origin: tuple            # (x0, z0)
    size: tuple              # (sx, sz) cell edge lengths
    nx: int
    nz: int

    @property
    def n_cells(self) -> int:
        return self.nx * self.nz

    @classmethod
    def fit(cls, centroids: np.ndarray, n_cells_target: int) -> "CellGrid":
        """Grid wrapping the given centroids with ~n_cells_target cells."""
        n_side = max(1, int(math.isqrt(max(n_cells_target, 1))))
        c = np.asarray(centroids, np.float64)
        if c.size == 0:
            lo, hi = np.array([-8.0, -8.0]), np.array([8.0, 8.0])
        else:
            lo = np.array([c[:, 0].min(), c[:, 2].min()])
            hi = np.array([c[:, 0].max(), c[:, 2].max()])
        span = np.maximum(hi - lo, 1e-3) * 1.001     # border objects inside
        return cls(origin=(float(lo[0]), float(lo[1])),
                   size=(float(span[0] / n_side), float(span[1] / n_side)),
                   nx=n_side, nz=n_side)

    @classmethod
    def for_rect(cls, x0: float, z0: float, sx: float, sz: float,
                 n_cells_target: int) -> "CellGrid":
        """Grid subdividing a known rectangle (a zone shard's footprint) —
        out-of-rect members clamp into the border cells, matching the
        shard's own clamped routing."""
        n_side = max(1, int(math.isqrt(max(n_cells_target, 1))))
        return cls(origin=(float(x0), float(z0)),
                   size=(float(sx) / n_side, float(sz) / n_side),
                   nx=n_side, nz=n_side)

    def cell_of(self, centroids: np.ndarray) -> np.ndarray:
        """[M, 3] centroids -> [M] cell ids (host side, clamped)."""
        c = np.atleast_2d(np.asarray(centroids))
        ix = np.clip(((c[:, 0] - self.origin[0]) // self.size[0])
                     .astype(np.int64), 0, self.nx - 1)
        iz = np.clip(((c[:, 2] - self.origin[1]) // self.size[1])
                     .astype(np.int64), 0, self.nz - 1)
        return (ix * self.nz + iz).astype(np.int32)


class ClusterSummaries(NamedTuple):
    """One row per grid cell — everything the two-stage planner reads.

    ``aabb_*`` is the tight AABB of member *centroids* (not cell bounds:
    tighter, and exactly what the conservative spatial predicates need).
    ``res_max`` is ``max_j ||embed_j - embed_mean||`` — with unit-norm
    member embeddings it caps any member's cosine at
    ``q . embed_mean + ||q|| * res_max`` (the stage-1 score bound).
    Empty cells: count 0, aabb +inf/-inf, everything else zeros."""
    count: jax.Array          # [M] int32
    centroid: jax.Array       # [M, 3] f32 — mean of member centroids
    aabb_min: jax.Array       # [M, 3] f32
    aabb_max: jax.Array       # [M, 3] f32
    embed_mean: jax.Array     # [M, E] f32
    res_max: jax.Array        # [M] f32
    label_any: jax.Array      # [M, N_LABELS] bool — classes present
    n_points_max: jax.Array   # [M] int32
    obs_max: jax.Array        # [M] int32 (0 when target has no obs_count)
    last_seen_max: jax.Array  # [M] int32 (0 when target lacks last_seen)


def _init_summaries(n_cells: int, embed_dim: int) -> ClusterSummaries:
    M = n_cells
    return ClusterSummaries(
        count=jnp.zeros((M,), jnp.int32),
        centroid=jnp.zeros((M, 3), jnp.float32),
        aabb_min=jnp.full((M, 3), jnp.inf, jnp.float32),
        aabb_max=jnp.full((M, 3), -jnp.inf, jnp.float32),
        embed_mean=jnp.zeros((M, embed_dim), jnp.float32),
        res_max=jnp.zeros((M,), jnp.float32),
        label_any=jnp.zeros((M, N_LABELS), bool),
        n_points_max=jnp.zeros((M,), jnp.int32),
        obs_max=jnp.zeros((M,), jnp.int32),
        last_seen_max=jnp.zeros((M,), jnp.int32))


def _target_cols(target):
    """(embed, label, n_points, centroid, obs_count|None, last_seen|None)
    — the structural key mirrors core.query._columns."""
    return (target.embed, target.label, target.n_points, target.centroid,
            getattr(target, "obs_count", None),
            getattr(target, "last_seen", None))


@functools.partial(jax.jit, static_argnames=("cell_cap",))
def _apply_cells(summ: ClusterSummaries, cols, cells: jax.Array,
                 rows: jax.Array, *, cell_cap: int) -> ClusterSummaries:
    """Recompute summaries for cells ``cells`` [D] from their sorted member
    rows ``rows`` [D, cell_cap] (-1 padded) and scatter the fresh values in.

    The per-cell reduction reads members in ascending-slot order at the
    static cell_cap width, so its value is a pure function of (cell member
    set, member columns) — independent of how many other cells ride the
    same dispatch, which is what makes incremental == rebuild bit-exact.
    Padding cells use index M (OOB: dropped by the scatter)."""
    embed, label, n_points, centroid, obs, last_seen = cols
    M = summ.count.shape[0]
    valid = rows >= 0                                   # [D, cap_c]
    idx = jnp.clip(rows, 0)
    cnt = valid.sum(axis=1).astype(jnp.int32)           # [D]
    den = jnp.maximum(cnt, 1).astype(jnp.float32)

    cent = centroid[idx]                                # [D, cap_c, 3]
    vm = valid[:, :, None]
    c_mean = jnp.where(vm, cent, 0.0).sum(axis=1) / den[:, None]
    a_min = jnp.where(vm, cent, jnp.inf).min(axis=1)
    a_max = jnp.where(vm, cent, -jnp.inf).max(axis=1)

    emb = embed[idx]                                    # [D, cap_c, E]
    e_mean = jnp.where(vm, emb, 0.0).sum(axis=1) / den[:, None]
    res = jnp.linalg.norm(emb - e_mean[:, None, :], axis=-1)
    r_max = jnp.where(valid, res, 0.0).max(axis=1)

    lab = jnp.clip(label[idx], 0, N_LABELS - 1)         # [D, cap_c]
    D = rows.shape[0]
    dd = jnp.broadcast_to(jnp.arange(D)[:, None], lab.shape)
    l_any = jnp.zeros((D, N_LABELS), jnp.int32) \
        .at[dd, lab].max(valid.astype(jnp.int32)) > 0

    npts = jnp.where(valid, n_points[idx], 0).max(axis=1)
    obs_m = jnp.zeros((D,), jnp.int32) if obs is None \
        else jnp.where(valid, obs[idx], 0).max(axis=1)
    seen_m = jnp.zeros((D,), jnp.int32) if last_seen is None \
        else jnp.where(valid, last_seen[idx], 0).max(axis=1)

    tgt = jnp.where(cells >= 0, cells, M)
    put = lambda arr, v: arr.at[tgt].set(v.astype(arr.dtype), mode="drop")
    return ClusterSummaries(
        count=put(summ.count, cnt),
        centroid=put(summ.centroid, c_mean),
        aabb_min=put(summ.aabb_min,
                     jnp.where(cnt[:, None] > 0, a_min, jnp.inf)),
        aabb_max=put(summ.aabb_max,
                     jnp.where(cnt[:, None] > 0, a_max, -jnp.inf)),
        embed_mean=put(summ.embed_mean, e_mean),
        res_max=put(summ.res_max, r_max),
        label_any=put(summ.label_any, l_any),
        n_points_max=put(summ.n_points_max, npts),
        obs_max=put(summ.obs_max, obs_m),
        last_seen_max=put(summ.last_seen_max, seen_m))


# ---------------------------------------------------------------------------
@dataclass
class ClusterIndex:
    """The cluster-summary index over ONE flat target (ObjectStore shard,
    the monolithic server store, or a device LocalMap).

    Host bookkeeping mirrors the target (per-slot cell assignment, per-cell
    member lists); device state is the [n_cells, cell_cap] sorted member
    table plus the ClusterSummaries pytree.  ``refresh`` diffs; callers
    that know their deltas call ``update_slots`` directly."""
    grid: CellGrid
    embed_dim: int
    capacity: int                       # target slot count
    cell_cap: int
    min_flat_size: int = DEFAULT_MIN_FLAT
    summaries: ClusterSummaries = None
    members: jax.Array = None           # [n_cells, cell_cap] int32, -1 pad,
    #                                     each row ascending (stage-2 order)
    # host mirrors
    _members: np.ndarray = None         # unsorted insertion-order lists
    _size: np.ndarray = None            # [n_cells] int32
    _cell: np.ndarray = None            # [cap] int32 cell id, -1 = absent
    _pos: np.ndarray = None             # [cap] int32 position in _members
    _present: np.ndarray = None         # [cap] bool
    _ver: np.ndarray = None             # [cap] int64 indexed version
    _oid: np.ndarray = None             # [cap] int64 indexed object id —
    #                                     catches slot reuse that keeps the
    #                                     version (LocalMap eviction resets
    #                                     version bookkeeping to 0)
    updates: int = 0                    # maintenance dispatches issued
    rebuilds: int = 0                   # cell_cap auto-grow events

    def __post_init__(self):
        M = self.grid.n_cells
        if self.summaries is None:
            self.summaries = _init_summaries(M, self.embed_dim)
        if self._members is None:
            self._members = np.full((M, self.cell_cap), -1, np.int32)
            self.members = jnp.asarray(self._members)
            self._size = np.zeros((M,), np.int32)
            self._cell = np.full((self.capacity,), -1, np.int32)
            self._pos = np.zeros((self.capacity,), np.int32)
            self._present = np.zeros((self.capacity,), bool)
            self._ver = np.full((self.capacity,), -1, np.int64)
            self._oid = np.zeros((self.capacity,), np.int64)

    # -- construction ------------------------------------------------------
    @classmethod
    def for_target(cls, target, *, n_cells_target: int | None = None,
                   cell_cap: int | None = None,
                   min_flat_size: int = DEFAULT_MIN_FLAT) -> "ClusterIndex":
        """Build (and fill) an index over a LocalMap/ObjectStore-shaped
        target.  Cell count targets ~256 members per cell; cell capacity
        is sized from the MEASURED peak occupancy (plus slack, auto-grown
        on later overflow) — a global-average cap would pad the stage-2
        candidate slab 4-8x past reality on hotspot-skewed scenes, and the
        slab gather is the dominant cost of a two-stage query."""
        act = np.asarray(target.active)
        dele = getattr(target, "deleted", None)
        present = act & ~np.asarray(dele) if dele is not None else act
        n = max(int(present.sum()), 1)
        cap = int(act.shape[0])
        if n_cells_target is None:
            n_cells_target = min(max(n // 256, 16), 16_384)
        cents = np.asarray(target.centroid)[present]
        grid = CellGrid.fit(cents, n_cells_target)
        if cell_cap is None:
            counts = np.bincount(grid.cell_of(cents),
                                 minlength=grid.n_cells)
            peak = int(counts.max()) if counts.size else 0
            cell_cap = bucket(max(peak + (peak >> 2) + 8, 16))
        idx = cls(grid=grid, embed_dim=int(target.embed.shape[1]),
                  capacity=cap, cell_cap=int(cell_cap),
                  min_flat_size=min_flat_size)
        idx.refresh(target)
        return idx

    # -- introspection -----------------------------------------------------
    @property
    def n_objects(self) -> int:
        return int(self._size.sum())

    def engaged(self) -> bool:
        """Would the two-stage plan use this index right now?"""
        return self.n_objects >= self.min_flat_size

    def member_slots(self, cell: int) -> np.ndarray:
        return np.sort(self._members[cell][:int(self._size[cell])])

    # -- maintenance -------------------------------------------------------
    def refresh(self, target) -> int:
        """Diff the target against the last indexed view and update the
        dirty cells.  Returns the number of changed slots."""
        act = np.asarray(target.active)
        dele = getattr(target, "deleted", None)
        present = act & ~np.asarray(dele) if dele is not None else act
        ver = np.asarray(target.version).astype(np.int64)
        ids = np.asarray(target.ids).astype(np.int64)
        changed = (present != self._present) \
            | (present & ((ver != self._ver) | (ids != self._oid)))
        if changed.any():
            self.update_slots(target, np.nonzero(changed)[0])
        return int(changed.sum())

    def update_slots(self, target, slots) -> None:
        """O(changes) delta path: re-index exactly ``slots`` (values are
        re-read from the target, so add/move/remove/tombstone all route
        through here)."""
        slots = np.unique(np.asarray(slots, np.int64))
        if not len(slots):
            return
        act = np.asarray(target.active)
        dele = getattr(target, "deleted", None)
        present = act & ~np.asarray(dele) if dele is not None else act
        ver = np.asarray(target.version).astype(np.int64)
        ids = np.asarray(target.ids).astype(np.int64)
        cent = np.asarray(target.centroid)
        new_cell = self.grid.cell_of(cent[slots])
        dirty: set = set()
        grown = False
        for s, c_new in zip(slots, new_cell):
            s = int(s)
            p = bool(present[s])
            c_old = int(self._cell[s])
            c_tgt = int(c_new) if p else -1
            if c_old >= 0 and c_old != c_tgt:
                self._drop_member(s, c_old)
                dirty.add(c_old)
            if c_tgt >= 0 and int(self._cell[s]) < 0:
                if self._size[c_tgt] >= self.cell_cap:
                    grown = True
                    break
                self._add_member(s, c_tgt)
                dirty.add(c_tgt)
            elif c_tgt >= 0:
                dirty.add(c_tgt)          # in-place value change
            self._present[s] = p
            self._ver[s] = ver[s] if p else -1
            self._oid[s] = ids[s] if p else 0
        if grown:
            self._grow_and_rebuild(target)
            return
        self._recompute(target, sorted(dirty))

    def _add_member(self, s: int, c: int) -> None:
        self._members[c, self._size[c]] = s
        self._pos[s] = self._size[c]
        self._size[c] += 1
        self._cell[s] = c

    def _drop_member(self, s: int, c: int) -> None:
        last = self._size[c] - 1
        p = int(self._pos[s])
        moved = int(self._members[c, last])
        self._members[c, p] = moved
        self._pos[moved] = p
        self._members[c, last] = -1
        self._size[c] = last
        self._cell[s] = -1

    def _sorted_rows(self, cells) -> np.ndarray:
        rows = self._members[cells].copy()
        rows[rows < 0] = _SENTINEL
        rows.sort(axis=1)
        rows[rows == _SENTINEL] = -1
        return rows

    def _recompute(self, target, dirty: list) -> None:
        """Dispatch the bucketed gather+reduce+scatter for dirty cells and
        mirror their (sorted) member rows into the device table."""
        if not dirty:
            return
        cols = _target_cols(target)
        dirty = np.asarray(dirty, np.int64)
        for lo in range(0, len(dirty), _CHUNK):
            chunk = dirty[lo:lo + _CHUNK]
            D = bucket(len(chunk))
            cells = np.full((D,), -1, np.int32)
            cells[:len(chunk)] = chunk
            rows = np.full((D, self.cell_cap), -1, np.int32)
            rows[:len(chunk)] = self._sorted_rows(chunk)
            self.summaries = _apply_cells(self.summaries, cols,
                                          jnp.asarray(cells),
                                          jnp.asarray(rows),
                                          cell_cap=self.cell_cap)
            self.members = self.members.at[jnp.asarray(chunk)].set(
                jnp.asarray(rows[:len(chunk)]))
            self.updates += 1

    def _grow_and_rebuild(self, target) -> None:
        """Cell overflow: double cell_cap and re-index from the target —
        the one from-scratch path, amortized over the map's lifetime."""
        self.cell_cap *= 2
        self.rebuilds += 1
        M = self.grid.n_cells
        self.summaries = _init_summaries(M, self.embed_dim)
        self._members = np.full((M, self.cell_cap), -1, np.int32)
        self.members = jnp.asarray(self._members)
        self._size = np.zeros((M,), np.int32)
        self._cell = np.full((self.capacity,), -1, np.int32)
        self._pos = np.zeros((self.capacity,), np.int32)
        self._present = np.zeros((self.capacity,), bool)
        self._ver = np.full((self.capacity,), -1, np.int64)
        self._oid = np.zeros((self.capacity,), np.int64)
        self.refresh(target)


def rebuilt(index: ClusterIndex, target) -> ClusterIndex:
    """A fresh index over ``target`` with ``index``'s exact geometry — the
    from-scratch oracle the churn property test compares against."""
    out = ClusterIndex(grid=index.grid, embed_dim=index.embed_dim,
                       capacity=index.capacity, cell_cap=index.cell_cap,
                       min_flat_size=index.min_flat_size)
    out.refresh(target)
    return out


def summaries_equal(a: ClusterSummaries, b: ClusterSummaries) -> bool:
    """Bit-exact comparison (inf-aware via array_equal)."""
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))
