"""Hierarchical coarse-to-fine query index (the cluster-summary level).

``cluster``   — the incrementally-maintained per-cell summaries + member
                tables (never rebuilt from scratch on the hot path).
``search``    — the two-stage certified-exact query execution and the
                first-class cluster-level result mode.

``core.query.compile_query(spec, target, index=...)`` is the front door;
this package is the machinery behind it.
"""
from repro.index.cluster import (CellGrid, ClusterIndex, ClusterSummaries,
                                 DEFAULT_MIN_FLAT, rebuilt, summaries_equal)
from repro.index.search import (ClusterResult, cluster_query,
                                two_stage_query)

__all__ = ["CellGrid", "ClusterIndex", "ClusterSummaries",
           "DEFAULT_MIN_FLAT", "rebuilt", "summaries_equal",
           "ClusterResult", "cluster_query", "two_stage_query"]
