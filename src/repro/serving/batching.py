"""Serving substrate: continuous batching + straggler mitigation.

The SemanticXR server multiplexes perception/caption/query work from many
XR clients.  Requests join a waiting queue; each engine step assembles a
fixed-size batch from running + waiting requests (continuous batching — a
finished request's slot is refilled next step, no batch drain).  Straggler
mitigation: a request whose assigned worker misses its deadline is hedged —
re-enqueued at the front for the next step; first completion wins, the
duplicate is cancelled (idempotent by request id).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass(order=True)
class Request:
    priority: float
    rid: int = field(compare=False)
    payload: Any = field(compare=False)
    enqueued_at: float = field(compare=False, default=0.0)
    deadline_ms: float = field(compare=False, default=100.0)
    started_at: float = field(compare=False, default=0.0)
    hedged: bool = field(compare=False, default=False)


@dataclass
class BatchScheduler:
    batch_size: int
    step_fn: Callable[[list], list]       # batch of payloads -> results
    hedge_after_ms: float = 50.0
    waiting: list = field(default_factory=list)   # heap by priority
    running: dict = field(default_factory=dict)   # rid -> Request
    done: dict = field(default_factory=dict)      # rid -> result
    hedge_count: int = 0
    _next_rid: int = 0

    def submit(self, payload, *, priority: float = 1.0,
               deadline_ms: float = 100.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        heapq.heappush(self.waiting, Request(
            priority=-priority, rid=rid, payload=payload,
            enqueued_at=time.perf_counter(), deadline_ms=deadline_ms))
        return rid

    def _hedge_stragglers(self, now):
        for rid, req in list(self.running.items()):
            if (now - req.started_at) * 1e3 > self.hedge_after_ms \
                    and not req.hedged:
                req.hedged = True
                self.hedge_count += 1
                heapq.heappush(self.waiting, Request(
                    priority=-1e9, rid=rid, payload=req.payload,
                    enqueued_at=now, deadline_ms=req.deadline_ms))

    def step(self) -> dict:
        """One engine iteration: fill the batch, run, retire completions."""
        now = time.perf_counter()
        self._hedge_stragglers(now)
        batch = []
        while self.waiting and len(batch) < self.batch_size:
            req = heapq.heappop(self.waiting)
            if req.rid in self.done:      # hedged duplicate already served
                continue
            req.started_at = now
            self.running[req.rid] = req
            batch.append(req)
        if not batch:
            return {}
        results = self.step_fn([r.payload for r in batch])
        out = {}
        for req, res in zip(batch, results):
            if req.rid not in self.done:  # first completion wins
                self.done[req.rid] = res
                out[req.rid] = res
            self.running.pop(req.rid, None)
        return out

    def drain(self, max_steps: int = 10_000) -> dict:
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                break
            self.step()
        return self.done
