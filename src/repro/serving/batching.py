"""Serving substrate: continuous batching + straggler mitigation.

The SemanticXR server multiplexes perception/caption/query work from many
XR clients.  Requests join a waiting queue; each engine step assembles a
fixed-size batch from running + waiting requests (continuous batching — a
finished request's slot is refilled next step, no batch drain).  Straggler
mitigation: a request whose assigned worker misses its deadline is hedged —
re-enqueued at the front for the next step; first completion wins, the
duplicate is cancelled (idempotent by request id).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


@dataclass(order=True)
class Request:
    priority: float
    rid: int = field(compare=False)
    payload: Any = field(compare=False)
    enqueued_at: float = field(compare=False, default=0.0)
    deadline_ms: float = field(compare=False, default=100.0)
    started_at: float = field(compare=False, default=0.0)
    hedged: bool = field(compare=False, default=False)


@dataclass
class BatchScheduler:
    batch_size: int
    step_fn: Callable[[list], list]       # batch of payloads -> results
    hedge_after_ms: float = 50.0
    waiting: list = field(default_factory=list)   # heap by priority
    running: dict = field(default_factory=dict)   # rid -> Request
    done: dict = field(default_factory=dict)      # rid -> result
    hedge_count: int = 0
    _next_rid: int = 0

    def submit(self, payload, *, priority: float = 1.0,
               deadline_ms: float = 100.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        heapq.heappush(self.waiting, Request(
            priority=-priority, rid=rid, payload=payload,
            enqueued_at=time.perf_counter(), deadline_ms=deadline_ms))
        return rid

    def _hedge_stragglers(self, now):
        for rid, req in list(self.running.items()):
            if (now - req.started_at) * 1e3 > self.hedge_after_ms \
                    and not req.hedged:
                req.hedged = True
                self.hedge_count += 1
                heapq.heappush(self.waiting, Request(
                    priority=-1e9, rid=rid, payload=req.payload,
                    enqueued_at=now, deadline_ms=req.deadline_ms))

    def step(self) -> dict:
        """One engine iteration: fill the batch, run, retire completions."""
        now = time.perf_counter()
        self._hedge_stragglers(now)
        batch = []
        while self.waiting and len(batch) < self.batch_size:
            req = heapq.heappop(self.waiting)
            if req.rid in self.done:      # hedged duplicate already served
                continue
            req.started_at = now
            self.running[req.rid] = req
            batch.append(req)
        if not batch:
            return {}
        results = self.step_fn([r.payload for r in batch])
        out = {}
        for req, res in zip(batch, results):
            if req.rid not in self.done:  # first completion wins
                self.done[req.rid] = res
                out[req.rid] = res
            self.running.pop(req.rid, None)
        return out

    def drain(self, max_steps: int = 10_000) -> dict:
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                break
            self.step()
        return self.done


def make_query_step_fn(get_map, *, k: int = 5, use_pallas: bool = False,
                       pad_to: int | None = None):
    """Build a BatchScheduler ``step_fn`` over the SemanticXR query engine.

    Payloads are query embeddings [E].  Each engine step stacks them into one
    [Q, E] batch and runs a SINGLE fused similarity+top-k sweep over the map
    (the multi-query Pallas kernel when use_pallas — the embedding table
    streams through once for the whole batch, instead of Q full sweeps).

    ``get_map`` returns the current map-like object (ObjectStore or LocalMap
    — anything with .embed/.active/.ids), re-read every step so a live
    mapping server can keep mutating it between steps.  ``pad_to`` pads the
    ragged tail batch to a fixed Q (defaults to the scheduler batch size at
    the call site) so the jitted step sees one shape, not one per tail size.

    Returns (oid, score) of the top hit per request, in payload order.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.query import _batched_topk

    fn = jax.jit(lambda emb, act, ids, qs: _batched_topk(
        qs, emb, act, ids, k, use_pallas=use_pallas))

    def step_fn(payloads: list) -> list:
        m = get_map()
        qs = jnp.stack(payloads)
        q = qs.shape[0]
        width = max(pad_to or 0, q)
        if width > q:
            qs = jnp.pad(qs, ((0, width - q), (0, 0)))
        res = fn(m.embed, m.active, m.ids, qs)
        oids = np.asarray(res.oids[:q, 0])
        scores = np.asarray(res.scores[:q, 0])
        return [(int(oids[i]), float(scores[i])) for i in range(q)]

    return step_fn
