"""Serving substrate: continuous batching + straggler mitigation.

The SemanticXR server multiplexes perception/caption/query work from many
XR clients.  Requests join a waiting queue; each engine step assembles a
fixed-size batch from running + waiting requests (continuous batching — a
finished request's slot is refilled next step, no batch drain).  Straggler
mitigation: a request whose assigned worker misses its deadline is hedged —
re-enqueued at the front for the next step; first completion wins, the
duplicate is cancelled (idempotent by request id).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


@dataclass(order=True)
class Request:
    priority: float
    rid: int = field(compare=False)
    payload: Any = field(compare=False)
    enqueued_at: float = field(compare=False, default=0.0)
    deadline_ms: float = field(compare=False, default=100.0)
    started_at: float = field(compare=False, default=0.0)
    hedged: bool = field(compare=False, default=False)


@dataclass
class BatchScheduler:
    batch_size: int
    step_fn: Callable[[list], list]       # batch of payloads -> results
    hedge_after_ms: float = 50.0
    waiting: list = field(default_factory=list)   # heap by priority
    running: dict = field(default_factory=dict)   # rid -> Request
    done: dict = field(default_factory=dict)      # rid -> result
    hedge_count: int = 0
    _next_rid: int = 0

    def submit(self, payload, *, priority: float = 1.0,
               deadline_ms: float = 100.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        heapq.heappush(self.waiting, Request(
            priority=-priority, rid=rid, payload=payload,
            enqueued_at=time.perf_counter(), deadline_ms=deadline_ms))
        return rid

    def _hedge_stragglers(self, now):
        for rid, req in list(self.running.items()):
            if (now - req.started_at) * 1e3 > self.hedge_after_ms \
                    and not req.hedged:
                req.hedged = True
                self.hedge_count += 1
                heapq.heappush(self.waiting, Request(
                    priority=-1e9, rid=rid, payload=req.payload,
                    enqueued_at=now, deadline_ms=req.deadline_ms))

    def step(self) -> dict:
        """One engine iteration: fill the batch, run, retire completions."""
        now = time.perf_counter()
        self._hedge_stragglers(now)
        batch = []
        while self.waiting and len(batch) < self.batch_size:
            req = heapq.heappop(self.waiting)
            if req.rid in self.done:      # hedged duplicate already served
                continue
            req.started_at = now
            self.running[req.rid] = req
            batch.append(req)
        if not batch:
            return {}
        results = self.step_fn([r.payload for r in batch])
        out = {}
        for req, res in zip(batch, results):
            if req.rid not in self.done:  # first completion wins
                self.done[req.rid] = res
                out[req.rid] = res
            self.running.pop(req.rid, None)
        return out

    def drain(self, max_steps: int = 10_000) -> dict:
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                break
            self.step()
        return self.done


class PendingResult:
    """A query result whose dispatch has been issued but not materialized.

    ``make_query_step_fn(block=False)`` stores one of these per request in
    ``BatchScheduler.done``: the whole group's batched QueryResult stays a
    device array, and the serving loop resolves rows after its per-tick
    fence instead of forcing a host sync inside the scheduler step (which
    would serialize query dispatch with ingest/sync compute).  ``resolve``
    is idempotent and returns exactly what the blocking path would have."""

    __slots__ = ("_res", "_i", "_legacy", "_out")

    def __init__(self, res, i: int, legacy: bool):
        self._res, self._i, self._legacy = res, i, legacy
        self._out = None

    def resolve(self):
        if self._out is None:
            from repro.core.query import QueryResult
            i = self._i
            oids = np.asarray(self._res.oids[i])
            scores = np.asarray(self._res.scores[i])
            if self._legacy:
                self._out = (int(oids[0]), float(scores[0]))
            else:
                self._out = QueryResult(oids=oids, scores=scores,
                                        slots=np.asarray(self._res.slots[i]))
            self._res = None           # release the batched device arrays
        return self._out


def resolve_results(done: dict) -> dict:
    """Materialize every PendingResult in a scheduler's ``done`` dict (in
    place) — the drain step of the overlapped serving loop."""
    for rid, r in done.items():
        if isinstance(r, PendingResult):
            done[rid] = r.resolve()
    return done


def make_query_step_fn(get_map, *, k: int = 5, use_pallas: bool = False,
                       pad_to: int | None = None, block: bool = True,
                       get_index=None):
    """Build a BatchScheduler ``step_fn`` over the declarative query engine.

    Payloads are ``core.query.Query`` specs — semantic, spatial, and
    attribute predicates all ride the same dispatch.  Raw embedding arrays
    [E] are accepted as legacy payloads and normalized to
    ``Query(embed=..., k=k)``.

    Each engine step groups same-plan specs, stacks each group into ONE
    batched spec (struct-of-arrays leading Q dim), and runs a SINGLE fused
    predicate+score+top-k sweep per group over the map (the bias-kernel
    Pallas sweep when use_pallas — the embedding table streams through once
    for the whole batch, instead of Q full sweeps).  A uniform scheduler
    batch (the common case: every client sends the same plan shape) is
    exactly one dispatch.

    ``get_map`` returns the current query target (ObjectStore, LocalMap, or
    ZoneShardedStore), re-read every step so a live mapping server can keep
    mutating it between steps.  ``pad_to`` pads a ragged group to a fixed Q
    (defaults to the scheduler batch size at the call site) so the jitted
    step sees one shape, not one per tail size.

    Returns, in payload order: ``(oid, score)`` of the top hit for legacy
    embedding payloads, or the request's full ``QueryResult`` row (numpy)
    for Query payloads.

    ``block=False`` returns ``PendingResult`` handles instead: the fused
    dispatch is issued but no host transfer happens inside the step — the
    overlapped serving loop fences once per tick and ``resolve``s then.

    ``get_index`` (optional) returns the current cluster index over the
    map, re-read every step like ``get_map`` — the serving loop keeps its
    index maintained against the PUBLISH buffer, so a two-stage plan is
    exact against the same snapshot the flat sweep would scan.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.query import Query, QueryResult, execute_query, \
        stack_queries

    def step_fn(payloads: list) -> list:
        m = get_map()
        index = get_index() if get_index is not None else None
        legacy = [not isinstance(p, Query) for p in payloads]
        specs = [Query(embed=jnp.asarray(p), k=k) if leg else p
                 for p, leg in zip(payloads, legacy)]
        # group by plan structure: each group is one fused dispatch
        groups: dict = {}
        for pos, s in enumerate(specs):
            key = (jax.tree.structure(s), s.tree_flatten()[1])
            groups.setdefault(key, []).append(pos)
        results: list = [None] * len(specs)
        for positions in groups.values():
            q = len(positions)
            width = max(pad_to or 0, q)
            batched = stack_queries([specs[p] for p in positions],
                                    pad_to=width)
            res = execute_query(m, batched, use_pallas=use_pallas,
                                index=index)
            if not block:
                for i, pos in enumerate(positions):
                    results[pos] = PendingResult(res, i, legacy[pos])
                continue
            oids = np.asarray(res.oids)
            scores = np.asarray(res.scores)
            slots = np.asarray(res.slots)
            for i, pos in enumerate(positions):
                if legacy[pos]:
                    results[pos] = (int(oids[i, 0]), float(scores[i, 0]))
                else:
                    results[pos] = QueryResult(oids=oids[i], scores=scores[i],
                                               slots=slots[i])
        return results

    return step_fn
