"""Seeded open-loop load generator for the serving loop (VOXAR-style).

The production XR workload is not one request at a time: every client
streams poses at ~60 FPS while queries arrive in bursts (a user looks
around, then asks three things in a second).  This module pre-draws that
workload from a seed so a benchmark can replay the IDENTICAL stream
against two serving-loop variants and compare results byte-for-byte:

- **Pose streams** — every client orbits its anchor (same parametric
  track as ``sim.scenario.PoseTrack``) and re-reports its pose every
  ``pose_every`` ticks (60 FPS when tick_s = 1/60).
- **Query arrivals** — per-client Markov-modulated Poisson process: a
  client sits in a ``base_hz`` state and flips (seeded) into a
  ``burst_hz`` state for ``burst_ticks`` at a time.  Arrival counts are
  drawn per tick, so the schedule is OPEN LOOP: arrivals do not wait for
  service, and when a burst exceeds the loop's per-tick service capacity
  the backlog — and therefore the p99 wait — is visible instead of being
  absorbed by a closed feedback loop.
- **Query content** — unit-norm embeddings plus a near-(pose, radius)
  spatial predicate; every spec shares one plan structure so the
  BatchScheduler fuses each scheduler batch into a single dispatch.

Latency accounting rides ``repro.obs``: the loop calls ``note_submit`` /
``note_served`` / ``note_resolved`` with wall timestamps and the
generator folds them into registry histograms (``serving_query_wait_ms``,
``serving_query_e2e_ms``) plus raw sample lists for exact p50/p95/p99.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core.query import Query
from repro.obs import metrics as obs_metrics


@dataclass(frozen=True)
class LoadSpec:
    """Seeded workload shape (everything the schedule derives from)."""
    n_clients: int = 256
    n_ticks: int = 240
    tick_s: float = 1.0 / 60.0     # serving tick = one frame at 60 FPS
    pose_hz: float = 60.0          # per-client pose report rate
    base_hz: float = 0.5           # per-client steady query rate
    burst_hz: float = 8.0          # in-burst query rate
    burst_prob: float = 0.01       # per-tick P(enter burst | steady)
    burst_ticks: int = 12          # burst dwell time
    k: int = 5
    radius: float = 8.0            # near-predicate radius around the pose
    room: float = 16.0             # pose anchors span [-room/2, room/2]
    seed: int = 0


@dataclass
class LoadGenerator:
    """Pre-drawn open-loop arrival schedule + latency bookkeeping."""
    spec: LoadSpec
    embed_dim: int
    # derived (built in __post_init__)
    arrivals: list = field(default_factory=list)   # [T] -> [(cid, Query)]
    n_arrivals: int = 0
    _anchor: np.ndarray = None                     # [C, 3]
    _t_submit: dict = field(default_factory=dict)  # rid -> wall
    _t_served: dict = field(default_factory=dict)  # rid -> wall
    wait_ms: list = field(default_factory=list)    # submit -> batch claim
    e2e_ms: list = field(default_factory=list)     # submit -> resolved

    def __post_init__(self):
        sp = self.spec
        rng = np.random.default_rng(sp.seed)
        C, T = sp.n_clients, sp.n_ticks
        half = sp.room / 2
        self._anchor = np.stack([
            rng.uniform(-half * 0.8, half * 0.8, size=C),
            np.full(C, 1.5), rng.uniform(-half * 0.8, half * 0.8, size=C),
        ], axis=1).astype(np.float32)
        self._phase = rng.uniform(0, 2 * np.pi, size=C)
        # MMPP state walk, vectorized over clients: burst_left[c] > 0 means
        # client c draws at burst_hz this tick
        burst_left = np.zeros(C, np.int32)
        self.arrivals = []
        for t in range(T):
            enter = (burst_left == 0) & (rng.random(C) < sp.burst_prob)
            burst_left = np.where(enter, sp.burst_ticks, burst_left)
            rate = np.where(burst_left > 0, sp.burst_hz, sp.base_hz)
            burst_left = np.maximum(burst_left - 1, 0)
            counts = rng.poisson(rate * sp.tick_s)
            tick_arrivals = []
            for c in np.nonzero(counts)[0]:
                for _ in range(int(counts[c])):
                    e = rng.normal(size=self.embed_dim).astype(np.float32)
                    e /= np.linalg.norm(e)
                    tick_arrivals.append((int(c), Query(
                        embed=jnp.asarray(e),
                        near=(jnp.asarray(self.pose_at(int(c), t)),
                              jnp.asarray(sp.radius, jnp.float32)),
                        k=sp.k)))
            self.arrivals.append(tick_arrivals)
        self.n_arrivals = sum(len(a) for a in self.arrivals)
        self.pose_every = max(1, round(1.0 / (sp.pose_hz * sp.tick_s)))

    # -- workload queries ---------------------------------------------------
    def pose_at(self, c: int, tick: int) -> np.ndarray:
        ang = 0.15 * tick * self.spec.tick_s * 60.0 + self._phase[c]
        return (self._anchor[c] + np.array(
            [0.8 * np.cos(ang), 0.0, 0.8 * np.sin(ang)],
            np.float32)).astype(np.float32)

    def poses(self, tick: int) -> np.ndarray | None:
        """[C, 3] pose reports for this tick, or None off the pose cadence."""
        if tick % self.pose_every:
            return None
        t = 0.15 * tick * self.spec.tick_s * 60.0
        ang = t + self._phase
        off = np.stack([0.8 * np.cos(ang), np.zeros_like(ang),
                        0.8 * np.sin(ang)], axis=1).astype(np.float32)
        return self._anchor + off

    # -- latency accounting (wall clock; called by the serving loop) --------
    def note_submit(self, rid: int, wall: float) -> None:
        self._t_submit[rid] = wall

    def note_served(self, rid: int, wall: float) -> None:
        """Request claimed into a scheduler batch (service start)."""
        if rid in self._t_submit and rid not in self._t_served:
            self._t_served[rid] = wall
            self.wait_ms.append((wall - self._t_submit[rid]) * 1e3)

    def note_resolved(self, rid: int, wall: float) -> None:
        """Result materialized (post-fence) — end-to-end latency."""
        if rid in self._t_submit:
            self.e2e_ms.append((wall - self._t_submit.pop(rid)) * 1e3)
            self._t_served.pop(rid, None)

    def record(self, label: str) -> dict:
        """Fold samples into obs histograms + return exact percentiles."""
        reg = obs_metrics.get_registry()
        if reg is not None:
            hw = reg.histogram("serving_query_wait_ms",
                               "submit -> batch-claim wait under load")
            he = reg.histogram("serving_query_e2e_ms",
                               "submit -> resolved query latency under load")
            for v in self.wait_ms:
                hw.observe(v, mode=label)
            for v in self.e2e_ms:
                he.observe(v, mode=label)
        return {
            "wait_ms": obs_metrics.exact_percentiles(self.wait_ms),
            "e2e_ms": obs_metrics.exact_percentiles(self.e2e_ms),
            "n_arrivals": self.n_arrivals,
        }
