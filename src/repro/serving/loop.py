"""Async pipelined serving loop: overlapped ingest / fleet-sync / query.

Every tick of the repo's drivers used to be strictly synchronous —
ingest scatter, ``block_until_ready``, fleet collect, ``np.asarray`` the
counts, query dispatch, materialize — so the device idled while Python
did bookkeeping and Python idled while the device computed.  This loop
issues all three dispatch families against one consistent snapshot and
lets JAX's async dispatch overlap them:

- **Ingest** writes the NEXT store generation.  Overlapped mode donates
  the dead back buffer of the ``SnapshotStore`` double buffer
  (``core.store``): the scatter catches the two-tick-old buffer up
  (pending + current delta) IN PLACE — O(changed rows) per tick instead
  of the O(capacity) full-store copy the synchronous functional update
  pays.  Queries keep reading the published front buffer, so a request
  served mid-ingest sees exactly the pre-tick store, never a torn mix.
- **Fleet sync** issues every dirty zone's ``_collect_fleet`` dispatch
  before materializing any packet (``SessionManager.collect_start`` /
  ``collect_finish``), with the [C, N] sync state donated.
- **Queries** drain from the ``BatchScheduler`` with a non-blocking step
  fn (``PendingResult`` handles); the loop fences ONCE per tick when it
  resolves results for latency accounting, instead of once per batch.
- **Publish** swaps the double buffer; the loop's cluster index (when
  enabled) is maintained against the publish buffer from the delta's
  touched slots, so a two-stage plan stays exact against the snapshot.

The synchronous mode runs the identical workload — same deltas, same
collect inputs, same query stream — with a fence after every dispatch
and the copying (non-donated) ingest, which is precisely the loop the
drivers run today.  Both modes serve queries against the post-previous-
tick snapshot, so their per-query results are byte-identical; the
benchmark (benchmarks/serving_loop.py) asserts that and measures the
throughput gap.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.store import ObjectStore, SnapshotStore, deleted_mask
from repro.obs import metrics as obs_metrics
from repro.obs.trace import span as obs_span
from repro.serving.batching import (BatchScheduler, PendingResult,
                                    make_query_step_fn)


# ---------------------------------------------------------------------------
# The ingest scatter: a seeded stream of per-tick mapping deltas (the
# mapping frontend's output, pre-drawn so two loop variants replay the
# identical workload).
# ---------------------------------------------------------------------------
class IngestDelta(NamedTuple):
    """One tick's store mutations, SoA with a fixed row budget U."""
    slots: jax.Array      # [U] int32 target store slots (unique per tick)
    embed: jax.Array      # [U, E] f32 unit-norm
    centroid: jax.Array   # [U, 3] f32
    points: jax.Array     # [U, P, 3] f32
    n_points: jax.Array   # [U] int32
    label: jax.Array      # [U] int32
    tomb: jax.Array       # [U] bool — row is a removal (tombstone)
    valid: jax.Array      # [U] bool


def _apply_delta_impl(store: ObjectStore, d: IngestDelta) -> ObjectStore:
    """Scatter one delta into the store (padding rows dropped via OOB).

    Upserts refresh geometry/embedding and clear any tombstone (respawn);
    tombstone rows mirror ``_tombstone_slots`` semantics (active off,
    deleted on, geometry zeroed).  Every touched row's version bumps so
    the sync protocol ships it."""
    cap = store.ids.shape[0]
    up = d.valid & ~d.tomb
    tb = d.valid & d.tomb
    tg_all = jnp.where(d.valid, d.slots, cap)
    tg_up = jnp.where(up, d.slots, cap)
    tg_tb = jnp.where(tb, d.slots, cap)
    return store._replace(
        active=store.active.at[tg_up].set(True, mode="drop")
                           .at[tg_tb].set(False, mode="drop"),
        deleted=deleted_mask(store).at[tg_up].set(False, mode="drop")
                                   .at[tg_tb].set(True, mode="drop"),
        embed=store.embed.at[tg_up].set(d.embed, mode="drop"),
        label=store.label.at[tg_up].set(d.label, mode="drop"),
        points=store.points.at[tg_up].set(d.points, mode="drop"),
        n_points=store.n_points.at[tg_up].set(d.n_points, mode="drop")
                               .at[tg_tb].set(0, mode="drop"),
        centroid=store.centroid.at[tg_up].set(d.centroid, mode="drop"),
        obs_count=store.obs_count.at[tg_all].add(1, mode="drop"),
        version=store.version.at[tg_all].add(1, mode="drop"))


# today's path: functional update — XLA must preserve the input store, so
# every [cap, ...] column is copied per tick
apply_delta = jax.jit(_apply_delta_impl)


@functools.partial(jax.jit, donate_argnums=(0,))
def _apply_delta2_donated(back: ObjectStore, pending: IngestDelta,
                          cur: IngestDelta) -> ObjectStore:
    """Catch the donated two-tick-old back buffer up: apply the delta that
    produced the current front, then this tick's — in place."""
    return _apply_delta_impl(_apply_delta_impl(back, pending), cur)


@functools.partial(jax.jit, donate_argnums=(0,))
def _apply_delta_donated(back: ObjectStore, cur: IngestDelta) -> ObjectStore:
    """First overlapped tick: back is still a clone of front (no pending)."""
    return _apply_delta_impl(back, cur)


@dataclass
class IngestStream:
    """Seeded per-tick delta schedule over a store's live region.

    Each tick touches ``churn`` distinct slots drawn from ``[0, n_live)``:
    mostly upserts (drifted centroid, re-embedded, fresh cloud), a
    ``tomb_prob`` fraction tombstones.  A slot tombstoned at tick t may be
    re-upserted later (respawn) — versions only ever advance, so the sync
    protocol stays monotonic.  All tensors are pre-staged on device as
    [T, U, ...] stacks; ``delta_at`` is a cheap device slice."""
    n_ticks: int
    n_live: int
    embed_dim: int
    max_points: int
    churn: int = 64
    tomb_prob: float = 0.05
    drift: float = 0.15            # per-touch centroid drift (m)
    room: float = 16.0
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        T, U, E, P = self.n_ticks, self.churn, self.embed_dim, \
            self.max_points
        slots = np.stack([rng.choice(self.n_live, size=U, replace=False)
                          for _ in range(T)]).astype(np.int32)
        emb = rng.normal(size=(T, U, E)).astype(np.float32)
        emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
        # touched rows drift instead of teleporting: anchor to a per-slot
        # home so zone routing changes occasionally, not constantly
        half = self.room / 2
        home = rng.uniform(-half, half,
                           size=(self.n_live, 3)).astype(np.float32)
        home[:, 1] = rng.uniform(0.0, 2.0, size=self.n_live)
        cent = home[slots] + self.drift * rng.normal(
            size=(T, U, 3)).astype(np.float32)
        pts = rng.normal(size=(T, U, P, 3)).astype(np.float32)
        npts = rng.integers(4, P, size=(T, U)).astype(np.int32)
        lab = rng.integers(0, 20, size=(T, U)).astype(np.int32)
        tomb = rng.random(size=(T, U)) < self.tomb_prob
        self._stack = IngestDelta(
            slots=jnp.asarray(slots), embed=jnp.asarray(emb),
            centroid=jnp.asarray(cent), points=jnp.asarray(pts),
            n_points=jnp.asarray(npts), label=jnp.asarray(lab),
            tomb=jnp.asarray(tomb),
            valid=jnp.ones((T, U), bool))

    def delta_at(self, t: int) -> IngestDelta:
        return IngestDelta(*(x[t] for x in self._stack))


# ---------------------------------------------------------------------------
@dataclass
class ServingLoop:
    """Event-driven serving tick over (SnapshotStore, FleetServer, queries).

    One tick, in both modes, does the same logical work against the same
    snapshot (the store published at the END of the previous tick):

      1. issue the ingest scatter producing the next generation
      2. mirror the snapshot into the fleet zones + collect dirty zones
      3. submit this tick's query arrivals; run scheduler steps
      4. publish the new generation; resolve query results

    ``overlap=False`` fences after every dispatch (today's loop);
    ``overlap=True`` fences only at result resolution.
    """
    server: object                    # FleetServer
    store: SnapshotStore
    ingest: IngestStream
    loadgen: object = None            # LoadGenerator | None
    overlap: bool = True
    batch_size: int = 16
    max_batches_per_tick: int = 2     # service capacity: backlog above this
    subscribe_radius: float = 6.0
    index: object = None              # ClusterIndex over the publish buffer
    # measured state
    tick_idx: int = 0
    results: dict = field(default_factory=dict)    # rid -> QueryResult (np)
    tick_ms: list = field(default_factory=list)
    sent_bytes: int = 0
    n_served: int = 0
    scheduler: BatchScheduler = None

    def __post_init__(self):
        self.scheduler = BatchScheduler(
            batch_size=self.batch_size,
            step_fn=make_query_step_fn(
                lambda: self.store.front, pad_to=self.batch_size,
                block=not self.overlap,
                get_index=(lambda: self.index)
                if self.index is not None else None))
        self._mode = "overlapped" if self.overlap else "sync"
        self._deliverable = np.ones((self.server.n_clients,), bool)
        self._carry = {}          # overlap: last tick's unresolved results
        self._sync_started = []   # overlap: issued, unframed fleet collects

    def enable_index(self, **kw) -> None:
        """Attach a cluster index maintained against the PUBLISH buffer:
        refreshed from each published delta's touched slots, so two-stage
        plans read the same snapshot flat sweeps do."""
        from repro.index import ClusterIndex
        self.index = ClusterIndex.for_target(self.store.front, **kw)
        self.__post_init__()       # rebuild the step fn with get_index

    # ------------------------------------------------------------------
    def _issue_ingest(self, d: IngestDelta) -> ObjectStore:
        with obs_span("serving.ingest", cat="ingest", mode=self._mode) as sp:
            if self.overlap:
                back = self.store.take_back()
                if self.store.pending is None:
                    new = _apply_delta_donated(back, d)
                else:
                    new = _apply_delta2_donated(back, self.store.pending, d)
            else:
                new = apply_delta(self.store.front, d)
                jax.block_until_ready(new.active)
            sp.fence(new.active)
        return new

    def _sync_tick(self, t: int) -> None:
        front = self.store.front
        with obs_span("serving.sync", cat="sync", mode=self._mode):
            if self.loadgen is not None:
                poses = self.loadgen.poses(t)
                if poses is not None:
                    self.server.set_poses(poses, self.subscribe_radius)
            self.server.refresh(front)
            if self.overlap:
                # issue only — framing is deferred a full tick
                # (_finish_sync), giving the collect dispatches the whole
                # tick to complete before any host transfer waits on them.
                # Legal because the sync state chains on-device (FleetSync
                # carries synced_version AND ever_sent).
                self._sync_started.append(
                    (t, self.server.tick_start(self._deliverable, tick=t)))
            else:
                packets = self.server.tick(self._deliverable, tick=t,
                                           overlap=False)
                for _, pkt in packets:
                    # fence via the packet (a mesh-sharded tier fences
                    # every shard's tensors, not one [C, U] batch)
                    pkt.block_until_ready()
                self._account_packets(packets, t)

    def _account_packets(self, packets: list, t: int) -> None:
        self.sent_bytes += sum(p.total_nbytes for _, p in packets)
        # The serving fleet is always-connected: every delivered packet
        # is applied immediately, so ack it the same tick.  This keeps
        # inflight queues O(1) instead of growing over the run (which
        # would make slot-retirement scrubs quadratic in run length).
        self.server.ack_tick(packets, tick=t)

    def _finish_sync(self, upto: int) -> None:
        """Frame every deferred collect issued at tick <= ``upto`` into
        packets (byte-identical to the sequential path: finish runs in
        issue order, and slots freed since issue are scrub-filtered from
        the retirement bookkeeping)."""
        while self._sync_started and self._sync_started[0][0] <= upto:
            t0, started = self._sync_started.pop(0)
            self._account_packets(self.server.tick_finish(started), t0)

    def _query_tick(self, t: int) -> dict:
        out = {}
        with obs_span("serving.query", cat="query", mode=self._mode):
            now = time.perf_counter()
            if self.loadgen is not None:
                for cid, spec in self.loadgen.arrivals[t]:
                    rid = self.scheduler.submit(spec)
                    self.loadgen.note_submit(rid, now)
            for _ in range(self.max_batches_per_tick):
                if not self.scheduler.waiting:
                    break
                served = self.scheduler.step()
                claim = time.perf_counter()
                if self.loadgen is not None:
                    for rid in served:
                        self.loadgen.note_served(rid, claim)
                out.update(served)
        return out

    def _resolve(self, out: dict) -> None:
        """Materialize this tick's query results — the ONE per-tick fence
        in overlapped mode (waits only on the query dispatches: they read
        the published front, never the in-flight ingest)."""
        for rid, res in out.items():
            if isinstance(res, PendingResult):
                res = res.resolve()
                self.scheduler.done[rid] = res
            self.results[rid] = res
            self.n_served += 1
        if self.loadgen is not None and out:
            done = time.perf_counter()
            for rid in out:
                self.loadgen.note_resolved(rid, done)

    # ------------------------------------------------------------------
    def tick(self) -> None:
        t = self.tick_idx
        wall0 = time.perf_counter()
        d = self.ingest.delta_at(t)
        new = self._issue_ingest(d)
        self._sync_tick(t)
        out = self._query_tick(t)
        if self.overlap:
            self.store.publish(new, pending=d)
        else:
            # synchronous mode never touched the back buffer: swap the
            # front pointer only (the stale clone is never donated)
            self.store.front = new
            self.store.pending = None
            self.store.version += 1
        if self.index is not None:
            # index maintenance rides the publish: update from the delta's
            # touched slots against the NEW publish buffer
            self.index.update_slots(self.store.front,
                                    np.asarray(d.slots))
        if self.overlap:
            # software pipelining: frame LAST tick's packets and resolve
            # LAST tick's queries now, carry this tick's — their device
            # work overlaps the whole next tick's ingest/sync/query
            # dispatch instead of fencing here.
            # Safe vs next tick's donation of the buffer they read: PJRT
            # usage events sequence the donated in-place write after every
            # outstanding read (worst case the runtime copies instead of
            # donating for that tick).  Results are unchanged — the
            # computation captured its inputs at dispatch.
            self._finish_sync(t - 1)
            self._resolve(self._carry)
            self._carry = out
        else:
            self._resolve(out)
        self.tick_idx += 1
        ms = (time.perf_counter() - wall0) * 1e3
        self.tick_ms.append(ms)
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.histogram("serving_tick_ms",
                          "serving loop tick wall time").observe(
                              ms, mode=self._mode)

    def run(self, n_ticks: int) -> dict:
        for _ in range(n_ticks):
            self.tick()
        # drain: the carried tick, then whatever arrivals are still queued
        self._finish_sync(self.tick_idx)
        self._resolve(self._carry)
        self._carry = {}
        while self.scheduler.waiting:
            out = self.scheduler.step()
            claim = time.perf_counter()
            if self.loadgen is not None:
                for rid in out:
                    self.loadgen.note_served(rid, claim)
            self._resolve(out)
        jax.block_until_ready(self.store.front.active)
        wall_s = sum(self.tick_ms) / 1e3
        stats = {
            "mode": self._mode,
            "n_ticks": n_ticks,
            "ticks_per_s": n_ticks / max(wall_s, 1e-9),
            "tick_ms": obs_metrics.exact_percentiles(self.tick_ms),
            "n_queries_served": self.n_served,
            "sent_bytes_total": int(self.sent_bytes),
            "store_version": self.store.version,
        }
        if self.loadgen is not None:
            stats.update(self.loadgen.record(self._mode))
        return stats
