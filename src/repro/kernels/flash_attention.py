"""Blocked (flash) attention Pallas kernel for the backbone prefill path.

Standard two-level online-softmax tiling reworked for the TPU memory
hierarchy: q/k/v tiles live in VMEM with MXU-aligned block shapes (q 128+,
k 128+, dh a lane multiple), the running (m, l, acc) state sits in VMEM
scratch, and the [Bq, Bk] score tile never leaves the chip — this is the
kernel the jnp path in models/attention.py models, and what the §Roofline
memory term assumes when it counts score traffic as on-chip.

Grid: (H, Sq // Bq, Sk // Bk), k innermost.  Causal + sliding-window masks
are applied via 2D iota against absolute positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                      # [Bq, dh]
    k = k_ref[0]                                      # [Bk, dh]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool | None = None):
    """q,k,v: [H, S, dh] -> [H, S, dh].  (vmap over batch outside.)

    ``interpret=None`` keys off the backend via the shared
    ``ops._interpret()`` helper (Mosaic on TPU, interpret elsewhere) —
    a direct caller gets the same deploy-ready default as ops entry points.
    """
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    H, S, dh = q.shape
    scale = dh ** -0.5
    pq = (-S) % block_q
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pq), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pq), (0, 0)))
    Sp = S + pq
    n_k = Sp // block_k
    grid = (H, Sp // block_q, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
