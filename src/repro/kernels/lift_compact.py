"""Fused frame-ingest geometry kernel: lift -> compact -> downsample -> stats.

The seed server hot path ran, per frame, a vmapped ``geometry.lift_depth``
(an O(HW log HW) ``argsort`` per object to compact valid pixels, plus a
materialized [D, HW, 3] world-point intermediate), then a SEPARATE
``downsample`` dispatch and per-object ``centroid_bbox`` work inside
association.  After PR 1-3 batched everything else, that lift stage was
~54% of B+P+SD mapping latency (BENCH_tab4_fig3_mapping.json).

This module replaces the whole composition with ONE streaming pass over the
depth frame that serves all D detections at once:

  * back-projection is computed per pixel tile ONCE and shared across
    objects (the seed recomputed nothing per object either, but paid the
    [D, HW, 3] gather instead);
  * per-object compaction uses cumsum/prefix-count destination indexing —
    the r-th valid pixel of object d has rank r by construction, O(HW),
    no sort of any kind;
  * the stride-downsample to the point budget is folded into the same
    indexing (rank r is kept iff some output slot i maps to it under
    ``floor(i * n / budget)`` — at most one i per rank since n >= budget
    makes the map strictly increasing), so ``downsample`` disappears as a
    separate dispatch;
  * centroid / bbox accumulate over the selected points in the same sweep,
    so association no longer needs a per-detection ``centroid_bbox`` pass.

Output semantics are bit-for-bit those of the seed composition
``downsample(lift_depth(...), budget)`` + ``centroid_bbox`` (oracle:
``ref.lift_compact_ref``; property tests in tests/test_lift_compact.py),
with ONE deliberate divergence: a detection with zero valid pixels gets the
true ``n = 0`` here, where the seed's ``downsample`` floor (``max(n, 1)``)
reported a phantom single point at the origin.  Same spirit as the
documented ``merge_clouds`` fix — the quirky path counted points that do
not exist; all real clouds are identical.

Two implementations of the same algorithm:

  * ``lift_compact_pallas`` — the TPU deploy kernel.  Grid over pixel
    tiles; the [D, P, 3] output refs act as cross-step carries (grids are
    sequential on TPU); the per-tile scatter is a one-hot MXU matmul
    ([P, T] @ [T, 3] per object), which Mosaic handles natively where a
    per-element scatter would not.
  * ``lift_compact_xla`` — the algorithmically identical XLA formulation
    used off-TPU (ops.lift_compact keys off the backend): the one-hot
    matmul trick only pays for itself on the MXU; on CPU/GPU the rank
    composition inverts to a searchsorted gather, back-projecting ONLY the
    <= D*budget selected pixels.  Neither path ever materializes a
    [D, HW, 3] intermediate (asserted by jaxpr inspection in the tests and
    the mapping benchmark).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e9
Z_EPS = 1e-4          # matches geometry.lift_depth's valid-depth floor


def _select_slots(rho, nl, budget: int, lift_cap: int):
    """Map valid-pixel ranks to output slots under the fused downsample.

    rho: [..., ] exclusive ranks (int32); nl: broadcastable capped counts.
    Returns (slot, keep): rank rho is emitted to ``slot`` iff ``keep``.
    Inverts downsample's ``idx(i) = floor(i * n / budget)``: the unique
    candidate slot for rank r is ceil(r * budget / n), which wins iff it
    maps back to r.  Below budget the map is the identity.
    """
    nl_safe = jnp.maximum(nl, 1)
    in_range = rho < nl
    # clip before the multiply: ranks >= nl are never kept, and the clip
    # keeps rho * budget well inside int32 for any frame size
    rho_c = jnp.minimum(rho, lift_cap)
    over = nl > budget
    slot = jnp.where(over, (rho_c * budget + nl_safe - 1) // nl_safe, rho_c)
    hit = jnp.where(over, (slot * nl) // budget == rho_c, rho_c < budget)
    keep = in_range & hit & (slot < budget)
    return slot, keep


# ----------------------------------------------------------------------
# XLA formulation (CPU/GPU path + the jit'd production path off-TPU)
# ----------------------------------------------------------------------

def lift_compact_xla(depth: jax.Array, masks: jax.Array,
                     intrinsics: jax.Array, pose: jax.Array, *,
                     stride: int = 1, budget: int, lift_cap: int = 4096):
    """depth: [H, W]; masks: [D, H, W] bool; intrinsics: [fx, fy, cx, cy]
    at FULL resolution; pose: [4, 4] cam->world.

    Returns (points [D, budget, 3], n [D], centroid [D, 3],
    bbox_min [D, 3], bbox_max [D, 3]).

    Gather formulation: one cumsum over [D, HW] gives every pixel's rank,
    a searchsorted inverts rank -> pixel for the <= budget selected ranks,
    and back-projection runs only on those pixels.
    """
    D = masks.shape[0]
    H, W = depth.shape
    HW = H * W
    fx, fy, cx, cy = intrinsics
    z_flat = depth.reshape(HW)
    v = masks.reshape(D, HW) & (z_flat > Z_EPS)[None, :]
    c = jnp.cumsum(v.astype(jnp.int32), axis=1)            # inclusive ranks
    n = jnp.minimum(c[:, -1], lift_cap)                    # [D]
    n_out = jnp.minimum(n, budget).astype(jnp.int32)

    i = jnp.arange(budget)
    r = jnp.where((n > budget)[:, None], (i[None, :] * n[:, None]) // budget,
                  jnp.broadcast_to(i[None, :], (D, budget)))
    # pixel of rank r = first j with c[j] == r + 1 (c is nondecreasing)
    pix = jax.vmap(lambda cd, rd: jnp.searchsorted(cd, rd + 1))(c, r)
    pix = jnp.minimum(pix, HW - 1)                         # padded ranks only

    zb = z_flat[pix]                                       # [D, budget]
    xs_full = ((pix % W).astype(jnp.float32) + 0.5) * stride
    ys_full = ((pix // W).astype(jnp.float32) + 0.5) * stride
    x = (xs_full - cx) / fx * zb
    y = (ys_full - cy) / fy * zb
    pts_cam = jnp.stack([x, y, zb], axis=-1)               # [D, budget, 3]
    pts_w = pts_cam @ pose[:3, :3].T + pose[:3, 3]

    valid = (i[None, :] < n_out[:, None])[..., None]
    pts = jnp.where(valid, pts_w, 0.0)
    denom = jnp.maximum(n_out, 1).astype(jnp.float32)[:, None]
    cent = jnp.sum(pts, axis=1) / denom
    mn = jnp.min(jnp.where(valid, pts_w, BIG), axis=1)
    mx = jnp.max(jnp.where(valid, pts_w, -BIG), axis=1)
    nz = (n_out > 0)[:, None]
    return (pts, n_out, cent,
            jnp.where(nz, mn, 0.0), jnp.where(nz, mx, 0.0))


# ----------------------------------------------------------------------
# Pallas streaming kernel (TPU deploy path)
# ----------------------------------------------------------------------

def _kernel(depth_ref, masks_ref, nl_ref, params_ref, pts_ref, csum_ref,
            bmin_ref, bmax_ref, base_scr, *, W: int, stride: int,
            block_t: int, budget: int, lift_cap: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        pts_ref[...] = jnp.zeros_like(pts_ref)
        csum_ref[...] = jnp.zeros_like(csum_ref)
        bmin_ref[...] = jnp.full_like(bmin_ref, BIG)
        bmax_ref[...] = jnp.full_like(bmax_ref, -BIG)
        base_scr[...] = jnp.zeros_like(base_scr)

    # --- shared back-projection: once per tile, for ALL objects
    z = depth_ref[...]                                     # [1, T]
    fx, fy, cx, cy = (params_ref[0], params_ref[1], params_ref[2],
                      params_ref[3])
    j = step * block_t + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)
    row = j // W
    xs_full = ((j - row * W).astype(jnp.float32) + 0.5) * stride
    ys_full = (row.astype(jnp.float32) + 0.5) * stride
    x = (xs_full - cx) / fx * z
    y = (ys_full - cy) / fy * z
    wx = params_ref[4] * x + params_ref[5] * y + params_ref[6] * z + \
        params_ref[13]
    wy = params_ref[7] * x + params_ref[8] * y + params_ref[9] * z + \
        params_ref[14]
    wz = params_ref[10] * x + params_ref[11] * y + params_ref[12] * z + \
        params_ref[15]
    w = jnp.concatenate([wx, wy, wz], axis=0).T            # [T, 3]

    # --- per-object prefix-count destination indexing
    vi = jnp.where(masks_ref[...] > 0, (z > Z_EPS).astype(jnp.int32), 0)
    rho = base_scr[...] + jnp.cumsum(vi, axis=1) - vi      # exclusive [D, T]
    base_scr[...] = base_scr[...] + jnp.sum(vi, axis=1, keepdims=True)
    slot, keep = _select_slots(rho, nl_ref[...], budget, lift_cap)
    sel = keep & (vi > 0)

    # --- one-hot MXU scatter: each kept pixel owns exactly one slot, so
    # the accumulated value is the exact point (0 everywhere else)
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, 1, budget), 2)
    oh = (jnp.where(sel, slot, -1)[:, :, None] == slots)   # [D, T, P]
    pts_ref[...] += jnp.einsum("dtp,tc->dpc", oh.astype(jnp.float32), w,
                               preferred_element_type=jnp.float32)

    # --- centroid / bbox folded into the same sweep
    sel3 = sel[:, :, None]
    wb = w[None, :, :]                                     # [1, T, 3]
    csum_ref[...] += jnp.sum(jnp.where(sel3, wb, 0.0), axis=1)
    bmin_ref[...] = jnp.minimum(bmin_ref[...],
                                jnp.min(jnp.where(sel3, wb, BIG), axis=1))
    bmax_ref[...] = jnp.maximum(bmax_ref[...],
                                jnp.max(jnp.where(sel3, wb, -BIG), axis=1))


def lift_compact_pallas(depth: jax.Array, masks: jax.Array,
                        intrinsics: jax.Array, pose: jax.Array, *,
                        stride: int = 1, budget: int, lift_cap: int = 4096,
                        block_t: int = 512, interpret: bool | None = None):
    """Streaming-kernel variant of ``lift_compact_xla`` (same contract).

    The depth tile stream is the only HW-sized traffic: depth + masks pass
    through VMEM once, outputs are [D, budget, 3] + [D, 3] stats.  The
    per-object valid-pixel counts (needed up front by the fused downsample
    indexing) come from one cheap masked reduction outside the kernel.
    """
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    D, H, W = masks.shape
    HW = H * W
    z_flat = depth.reshape(1, HW)
    m_flat = masks.reshape(D, HW)
    counts = jnp.sum(m_flat & (z_flat > Z_EPS), axis=1).astype(jnp.int32)
    nl = jnp.minimum(counts, lift_cap)[:, None]            # [D, 1]
    n_out = jnp.minimum(nl[:, 0], budget)

    pad = (-HW) % block_t
    if pad:
        z_flat = jnp.pad(z_flat, ((0, 0), (0, pad)))
        m_flat = jnp.pad(m_flat, ((0, 0), (0, pad)))
    params = jnp.concatenate([
        jnp.asarray(intrinsics, jnp.float32).reshape(4),
        jnp.asarray(pose, jnp.float32)[:3, :3].reshape(9),
        jnp.asarray(pose, jnp.float32)[:3, 3].reshape(3),
    ])
    grid = ((HW + pad) // block_t,)
    pts, csum, bmin, bmax = pl.pallas_call(
        functools.partial(_kernel, W=W, stride=stride, block_t=block_t,
                          budget=budget, lift_cap=lift_cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t), lambda i: (0, i)),   # depth stream
            pl.BlockSpec((D, block_t), lambda i: (0, i)),   # mask stream
            pl.BlockSpec((D, 1), lambda i: (0, 0)),         # counts resident
            pl.BlockSpec(memory_space=pltpu.SMEM),          # intr + pose
        ],
        out_specs=[
            pl.BlockSpec((D, budget, 3), lambda i: (0, 0, 0)),
            pl.BlockSpec((D, 3), lambda i: (0, 0)),
            pl.BlockSpec((D, 3), lambda i: (0, 0)),
            pl.BlockSpec((D, 3), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, budget, 3), jnp.float32),
            jax.ShapeDtypeStruct((D, 3), jnp.float32),
            jax.ShapeDtypeStruct((D, 3), jnp.float32),
            jax.ShapeDtypeStruct((D, 3), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, 1), jnp.int32)],
        interpret=interpret,
    )(z_flat, m_flat.astype(jnp.int32), nl, params)

    denom = jnp.maximum(n_out, 1).astype(jnp.float32)[:, None]
    nz = (n_out > 0)[:, None]
    return (pts, n_out.astype(jnp.int32), csum / denom,
            jnp.where(nz, bmin, 0.0), jnp.where(nz, bmax, 0.0))
