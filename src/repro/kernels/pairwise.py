"""Blocked nearest-neighbor distance Pallas kernel (association spatial term).

The paper's association step compares each detection's geometry against map
objects by spatial proximity (Sec. 2.3.1).  The GPU-reference pipelines do
per-point loops; the TPU-native form is |a-b|^2 = |a|^2 + |b|^2 - 2 a.b^T —
an MXU matmul per (M-block, N-block) tile with a running min carried across
N blocks.  Point coords are padded from 3 to a lane-friendly width by ops.py.

Grid: (M // Bm, N // Bn) with N innermost, so the [Bm,1] running min in the
output ref accumulates across a full N sweep before the next M block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 1e30


def _kernel(a_ref, b_ref, bv_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, INF)

    a = a_ref[...]                                   # [Bm, D]
    b = b_ref[...]                                   # [Bn, D]
    a2 = jnp.sum(a * a, axis=1, keepdims=True)       # [Bm, 1]
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T     # [1, Bn]
    ab = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    d2 = a2 + b2 - 2.0 * ab                          # [Bm, Bn]
    d2 = jnp.where(bv_ref[...].T > 0, d2, INF)
    tile_min = jnp.min(d2, axis=1, keepdims=True)    # [Bm, 1]
    out_ref[...] = jnp.minimum(out_ref[...], tile_min)


def nearest_dist_pallas(a: jax.Array, b: jax.Array, b_valid: jax.Array, *,
                        block_m: int = 256, block_n: int = 256,
                        interpret: bool | None = None):
    """a: [M, D]; b: [N, D]; b_valid: [N] -> [M] min squared distance.
    ``interpret=None`` keys off the backend via ``ops._interpret()``."""
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    M, D = a.shape
    N = b.shape[0]
    pm, pn = (-M) % block_m, (-N) % block_n
    if pm:
        a = jnp.pad(a, ((0, pm), (0, 0)))
    if pn:
        b = jnp.pad(b, ((0, pn), (0, 0)))
        b_valid = jnp.pad(b_valid, (0, pn))
    bv = b_valid.astype(jnp.float32)[:, None]
    grid = ((M + pm) // block_m, (N + pn) // block_n)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M + pm, 1), jnp.float32),
        interpret=interpret,
    )(a, b, bv)
    return out[:M, 0]
