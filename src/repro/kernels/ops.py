"""jit'd public wrappers over the Pallas kernels.

On this CPU container kernels execute in interpret mode (the Python kernel
body runs per grid step); on TPU the same calls compile to Mosaic.  The
``interpret`` default keys off the backend so the code is deploy-ready.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import lift_compact as _lc
from repro.kernels import pairwise as _pw
from repro.kernels import query_topk as _qt


def _interpret() -> bool:
    """Shared backend key: every kernel entry point resolves its
    ``interpret=None`` default through this helper."""
    return jax.default_backend() != "tpu"


def donate_default() -> bool:
    """Buffer-donation policy, keyed off the backend like ``_interpret``.

    Donating a dead input buffer (``donate_argnums``) lets XLA write the
    output in place — a win on TPU/GPU where dispatch is asynchronous.
    Under CPU dispatch semantics, however, issuing a dispatch that donates
    a buffer BLOCKS the caller until the donated buffer's producer has
    finished, which serializes exactly the overlap the donation was meant
    to cheapen (PR 9 measurement: the overlapped serving loop lost its
    entire win with donation on).  Callers that take ``donate=None``
    ("auto") resolve it here: on for TPU/GPU, off for CPU.  Byte-identity
    between the two settings is asserted in tests/test_serving_loop.py.
    """
    return jax.default_backend() not in ("cpu",)


@partial(jax.jit, static_argnums=(3,))
def query_topk(q, embeds, active, k: int):
    return _qt.query_topk_pallas(q, embeds, active, k,
                                 interpret=_interpret())


@partial(jax.jit, static_argnums=(3,))
def query_topk_multi(qs, embeds, active, k: int):
    """[Q, E] query batch: one embedding-table sweep serves all Q queries."""
    return _qt.query_topk_multi_pallas(qs, embeds, active, k,
                                       interpret=_interpret())


@partial(jax.jit, static_argnums=(3,))
def query_topk_bias(qs, embeds, bias, k: int):
    """[Q, E] queries + [Q, N] score bias (NEG = slot masked out): the
    declarative query engine's fused predicate+score+top-k sweep."""
    return _qt.query_topk_bias_pallas(qs, embeds, bias, k,
                                      interpret=_interpret())


@partial(jax.jit, static_argnames=("stride", "budget", "lift_cap"))
def lift_compact(depth, masks, intrinsics, pose, *, stride: int = 1,
                 budget: int, lift_cap: int = 4096):
    """Fused frame-ingest geometry: lift -> compact -> downsample -> stats
    for all D detections in one pass (the seed ``lift_depth`` +
    ``downsample`` + ``centroid_bbox`` composition, minus the per-object
    argsort and the [D, HW, 3] intermediate).

    On TPU this dispatches the Pallas streaming kernel; elsewhere the
    algorithmically identical XLA gather formulation — the kernel's
    one-hot-matmul scatter only pays for itself on the MXU, and running it
    in interpret mode would forfeit the fusion win the pipeline is built
    around.  Both are parity-tested against ``ref.lift_compact_ref``.
    """
    kw = dict(stride=stride, budget=budget, lift_cap=lift_cap)
    if jax.default_backend() == "tpu":
        return _lc.lift_compact_pallas(depth, masks, intrinsics, pose,
                                       interpret=False, **kw)
    return _lc.lift_compact_xla(depth, masks, intrinsics, pose, **kw)


@jax.jit
def nearest_dist(a, b, b_valid):
    """Pads coords to 8 lanes then runs the blocked kernel."""
    D = a.shape[1]
    padd = (-D) % 8
    if padd:
        a = jnp.pad(a, ((0, 0), (0, padd)))
        b = jnp.pad(b, ((0, 0), (0, padd)))
    return _pw.nearest_dist_pallas(a, b, b_valid, interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0):
    return _fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      softcap=softcap,
                                      interpret=_interpret())
