"""Fused query-score + running top-k Pallas kernels.

SemanticXR's query hot-spot (Sec. 2.3.2 / Fig. 5): score text embeddings
against every object embedding and keep the best k — the per-query cost that
grows with map size.  The jnp path materializes the full [N] similarity
vector in HBM, then runs a full top-k pass (second HBM sweep).  These kernels
stream the embedding table through VMEM once: each grid step matmuls an
[Nb, E] block against the query batch (MXU), adds the block's per-slot score
bias, and folds the block's candidates into a [k]-sized running top-k held in
the output refs — one HBM pass, no [N] intermediate.

The ``bias`` input is how the declarative query engine (core/query.py) rides
the same sweep: predicate masks are injected as ``NEG`` bias (an excluded
slot can never enter the running list) and score-combination terms (e.g. the
proximity bonus) as finite bias.  The [Q, N] bias is computed outside the
kernel and streamed through it alongside the [N, E] table — O(Q*N) extra
traffic, small next to the table's O(N*E) — so a predicate-heavy query
stays within a few percent of the embedding-only dispatch and never pays a
gather/compaction pass over the table.

The block fold is a proper top-k merge: top-k of the block (sort-based,
O(Nb log Nb) work on the VPU) then a [2k] merge with the running list.

The same kernel shape serves BOTH levels of the hierarchical query plan
(repro.index.search): stage 1 streams the [M, E] cluster-summary mean
table with the conservative gate slack as bias (top-m cells by score
upper bound), stage 2 streams the gathered member slab — so a two-stage
query is two instances of this sweep at a fraction of the flat row count.

Variants:
  * ``query_topk_bias_pallas``   — [Q, E] queries + [Q, N] bias (the engine
    entry point; the query batch is resident in VMEM, the table and bias
    stream through HBM once for all Q queries).
  * ``query_topk_multi_pallas``  — active-mask compatibility wrapper
    (bias = 0/NEG from the mask).
  * ``query_topk_pallas``        — the Q=1 special case.

Grids are sequential on TPU, so outputs act as cross-step carries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _merge_topk(run_v, run_i, sim, base, k: int):
    """Fold one block's scores into the running (vals, idx) top-k lists.

    run_v/run_i: [Q, k] running top-k; sim: [Q, Nb] block scores.
    Proper merge: block top-k, then top-k of the [2k] concatenation.
    """
    bv, bloc = jax.lax.top_k(sim, k)                       # [Q, k]
    bi = base + bloc.astype(jnp.int32)
    cand_v = jnp.concatenate([run_v, bv], axis=1)          # [Q, 2k]
    cand_i = jnp.concatenate([run_i, bi], axis=1)
    mv, sel = jax.lax.top_k(cand_v, k)
    mi = jnp.take_along_axis(cand_i, sel, axis=1)
    return mv, mi


def query_topk_pallas(q: jax.Array, embeds: jax.Array, active: jax.Array,
                      k: int, *, block_n: int = 1024,
                      interpret: bool | None = None):
    """q: [E]; embeds: [N, E]; active: [N] -> (scores [k], idx [k]).

    The Q=1 special case of the multi-query kernel below."""
    vals, idx = query_topk_multi_pallas(q[None, :], embeds, active, k,
                                        block_n=block_n, interpret=interpret)
    return vals[0], idx[0]


def _bias_kernel(q_ref, e_ref, b_ref, vals_ref, idx_ref, *, k: int,
                 block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # [Q, E] @ [E, Nb] -> [Q, Nb] on the MXU — one matmul serves all queries
    sim = jnp.dot(q_ref[...], e_ref[...].T,
                  preferred_element_type=jnp.float32)          # [Q, Nb]
    b = b_ref[...]                                             # [Q, Nb]
    # bias == NEG marks a predicate-excluded slot; finite bias is additive
    sim = jnp.where(b > NEG * 0.5, sim + b, NEG)
    base = step * block_n
    mv, mi = _merge_topk(vals_ref[...], idx_ref[...], sim, base, k)
    vals_ref[...] = mv
    idx_ref[...] = mi


def query_topk_bias_pallas(qs: jax.Array, embeds: jax.Array,
                           bias: jax.Array, k: int, *,
                           block_n: int = 1024,
                           interpret: bool | None = None):
    """qs: [Q, E]; embeds: [N, E]; bias: [Q, N] -> ([Q, k], [Q, k]).

    score[q, n] = qs[q] . embeds[n] + bias[q, n], with bias == NEG masking
    slot n out for query q entirely.  The query batch stays resident in
    VMEM; the embedding table and bias stream through once for ALL Q
    queries (vs Q independent sweeps when vmapping a single-query kernel).
    ``interpret=None`` keys off the backend via ``ops._interpret()``.
    """
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    Q, E = qs.shape
    N = embeds.shape[0]
    pad = (-N) % block_n
    if pad:
        embeds = jnp.pad(embeds, ((0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG)
    Np = N + pad
    grid = (Np // block_n,)
    vals, idx = pl.pallas_call(
        functools.partial(_bias_kernel, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, E), lambda i: (0, 0)),            # queries resident
            pl.BlockSpec((block_n, E), lambda i: (i, 0)),      # stream blocks
            pl.BlockSpec((Q, block_n), lambda i: (0, i)),      # stream bias
        ],
        out_specs=[
            pl.BlockSpec((Q, k), lambda i: (0, 0)),
            pl.BlockSpec((Q, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(qs, embeds, bias)
    return vals, idx


def query_topk_multi_pallas(qs: jax.Array, embeds: jax.Array,
                            active: jax.Array, k: int, *,
                            block_n: int = 1024,
                            interpret: bool | None = None):
    """qs: [Q, E]; embeds: [N, E]; active: [N] -> ([Q, k], [Q, k]).

    Active-mask compatibility wrapper over the bias kernel: an inactive
    slot is a NEG bias, an active one a 0 bias (identical scores to the
    seed mask kernel)."""
    Q = qs.shape[0]
    N = embeds.shape[0]
    bias = jnp.broadcast_to(
        jnp.where(active, 0.0, NEG).astype(jnp.float32)[None, :], (Q, N))
    return query_topk_bias_pallas(qs, embeds, bias, k, block_n=block_n,
                                  interpret=interpret)
