"""Fused query-similarity + running top-k Pallas kernels.

SemanticXR's query hot-spot (Sec. 2.3.2 / Fig. 5): score text embeddings
against every object embedding and keep the best k — the per-query cost that
grows with map size.  The jnp path materializes the full [N] similarity
vector in HBM, then runs a full top-k pass (second HBM sweep).  These kernels
stream the embedding table through VMEM once: each grid step matmuls an
[Nb, E] block against the query (MXU), masks inactive slots, and folds the
block's candidates into a [k]-sized running top-k held in the output refs —
one HBM pass, no [N] intermediate.

The block fold is a proper top-k merge: top-k of the block (sort-based,
O(Nb log Nb) work on the VPU) then a [2k] merge with the running list —
instead of the seed's k sequential argmax passes over the [k + Nb]
candidate buffer (O(k·(k+Nb))).

Two variants:
  * ``query_topk_pallas``        — one query [E], grid (N/Nb,).
  * ``query_topk_multi_pallas``  — a [Q, E] query batch resident in VMEM,
    same grid: the embedding table streams through HBM ONCE for all Q
    queries (the serving batch step), instead of Q full sweeps.

Grids are sequential on TPU, so outputs act as cross-step carries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _merge_topk(run_v, run_i, sim, base, k: int):
    """Fold one block's scores into the running (vals, idx) top-k lists.

    run_v/run_i: [Q, k] running top-k; sim: [Q, Nb] block scores.
    Proper merge: block top-k, then top-k of the [2k] concatenation.
    """
    bv, bloc = jax.lax.top_k(sim, k)                       # [Q, k]
    bi = base + bloc.astype(jnp.int32)
    cand_v = jnp.concatenate([run_v, bv], axis=1)          # [Q, 2k]
    cand_i = jnp.concatenate([run_i, bi], axis=1)
    mv, sel = jax.lax.top_k(cand_v, k)
    mi = jnp.take_along_axis(cand_i, sel, axis=1)
    return mv, mi


def query_topk_pallas(q: jax.Array, embeds: jax.Array, active: jax.Array,
                      k: int, *, block_n: int = 1024,
                      interpret: bool = True):
    """q: [E]; embeds: [N, E]; active: [N] -> (scores [k], idx [k]).

    The Q=1 special case of the multi-query kernel below."""
    vals, idx = query_topk_multi_pallas(q[None, :], embeds, active, k,
                                        block_n=block_n, interpret=interpret)
    return vals[0], idx[0]


def _multi_kernel(q_ref, e_ref, m_ref, vals_ref, idx_ref, *, k: int,
                  block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # [Q, E] @ [E, Nb] -> [Q, Nb] on the MXU — one matmul serves all queries
    sim = jnp.dot(q_ref[...], e_ref[...].T,
                  preferred_element_type=jnp.float32)          # [Q, Nb]
    sim = jnp.where(m_ref[...].T > 0, sim, NEG)
    base = step * block_n
    mv, mi = _merge_topk(vals_ref[...], idx_ref[...], sim, base, k)
    vals_ref[...] = mv
    idx_ref[...] = mi


def query_topk_multi_pallas(qs: jax.Array, embeds: jax.Array,
                            active: jax.Array, k: int, *,
                            block_n: int = 1024, interpret: bool = True):
    """qs: [Q, E]; embeds: [N, E]; active: [N] -> ([Q, k], [Q, k]).

    The query batch stays resident in VMEM; the embedding table streams
    through once for ALL Q queries (vs Q independent sweeps when vmapping
    the single-query kernel).
    """
    Q, E = qs.shape
    N = embeds.shape[0]
    pad = (-N) % block_n
    if pad:
        embeds = jnp.pad(embeds, ((0, pad), (0, 0)))
        active = jnp.pad(active, (0, pad))
    Np = N + pad
    mask = active.astype(jnp.float32)[:, None]
    grid = (Np // block_n,)
    vals, idx = pl.pallas_call(
        functools.partial(_multi_kernel, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, E), lambda i: (0, 0)),            # queries resident
            pl.BlockSpec((block_n, E), lambda i: (i, 0)),      # stream blocks
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Q, k), lambda i: (0, 0)),
            pl.BlockSpec((Q, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(qs, embeds, mask)
    return vals, idx
