"""Fused query-similarity + running top-k Pallas kernel.

SemanticXR's query hot-spot (Sec. 2.3.2 / Fig. 5): score one text embedding
against every object embedding and keep the best k — the per-query cost that
grows with map size.  The jnp path materializes the full [N] similarity
vector in HBM, then runs a full top-k pass (second HBM sweep).  This kernel
streams the embedding table through VMEM once: each grid step matmuls an
[Nb, E] block against the query (MXU), masks inactive slots, and folds the
block's candidates into a [k]-sized running top-k held in the output refs —
one HBM pass, no [N] intermediate.

Grid: (N // Nb,), sequential on TPU, so outputs act as cross-step carries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, e_ref, m_ref, vals_ref, idx_ref, *, k: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # [Nb, E] @ [E, 1] -> [Nb, 1] on the MXU
    sim = jnp.dot(e_ref[...], q_ref[...],
                  preferred_element_type=jnp.float32)          # [Nb, 1]
    sim = jnp.where(m_ref[...] > 0, sim, NEG)[:, 0]            # [Nb]
    base = step * block_n
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)

    cand_v = jnp.concatenate([vals_ref[0], sim])               # [k + Nb]
    cand_i = jnp.concatenate([idx_ref[0], gidx])

    # k selection passes over the merged candidates (k is small & static)
    out_v = []
    out_i = []
    for _ in range(k):
        j = jnp.argmax(cand_v)
        out_v.append(cand_v[j])
        out_i.append(cand_i[j])
        cand_v = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 0) == j,
            NEG, cand_v)
    vals_ref[0] = jnp.stack(out_v)
    idx_ref[0] = jnp.stack(out_i)


def query_topk_pallas(q: jax.Array, embeds: jax.Array, active: jax.Array,
                      k: int, *, block_n: int = 1024,
                      interpret: bool = True):
    """q: [E]; embeds: [N, E]; active: [N] -> (scores [k], idx [k])."""
    N, E = embeds.shape
    pad = (-N) % block_n
    if pad:
        embeds = jnp.pad(embeds, ((0, pad), (0, 0)))
        active = jnp.pad(active, (0, pad))
    Np = N + pad
    mask = active.astype(jnp.float32)[:, None]
    grid = (Np // block_n,)
    vals, idx = pl.pallas_call(
        functools.partial(_kernel, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((E, 1), lambda i: (0, 0)),            # query resident
            pl.BlockSpec((block_n, E), lambda i: (i, 0)),      # stream blocks
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(q[:, None], embeds, mask)
    return vals[0], idx[0]
