"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def query_topk_ref(q: jax.Array, embeds: jax.Array, active: jax.Array,
                   k: int):
    """q: [E]; embeds: [N, E]; active: [N] bool -> (scores [k], idx [k])."""
    sim = embeds @ q
    sim = jnp.where(active, sim, -jnp.inf)
    return jax.lax.top_k(sim, k)


def query_topk_multi_ref(qs: jax.Array, embeds: jax.Array, active: jax.Array,
                         k: int):
    """qs: [Q, E]; embeds: [N, E]; active: [N] -> ([Q, k], [Q, k])."""
    return jax.vmap(lambda q: query_topk_ref(q, embeds, active, k))(qs)


def query_topk_bias_ref(qs: jax.Array, embeds: jax.Array, bias: jax.Array,
                        k: int, *, neg: float = -1e30):
    """qs: [Q, E]; embeds: [N, E]; bias: [Q, N] -> ([Q, k], [Q, k]).
    bias == neg masks the slot out; finite bias is additive."""
    sim = qs @ embeds.T
    sim = jnp.where(bias > neg * 0.5, sim + bias, -jnp.inf)
    return jax.lax.top_k(sim, k)


def lift_compact_ref(depth: jax.Array, masks: jax.Array,
                     intrinsics: jax.Array, pose: jax.Array, *,
                     stride: int = 1, budget: int, lift_cap: int = 4096):
    """Seed-composition oracle for kernels/lift_compact.py: per object,
    ``lift_depth`` (argsort compaction) -> ``downsample`` -> ``centroid_bbox``
    exactly as the pre-fusion pipeline ran them.  Returns
    (points [D, budget, 3], n [D], centroid [D, 3], bbox_min, bbox_max)."""
    from repro.core import geometry as geo

    def one(mask):
        pts, n, _ = geo.lift_depth(depth, mask, intrinsics, pose,
                                   stride=stride, max_points=lift_cap)
        pts, n = geo.downsample(pts, n, budget)
        c, mn, mx = geo.centroid_bbox(pts, n)
        return pts, n, c, mn, mx

    return jax.vmap(one)(masks)


def nearest_dist_ref(a: jax.Array, b: jax.Array, b_valid: jax.Array):
    """a: [M, D]; b: [N, D]; b_valid: [N] -> min squared distance per a row.
    (the association/chamfer spatial primitive)"""
    d2 = jnp.sum(jnp.square(a[:, None, :] - b[None, :, :]), axis=-1)
    d2 = jnp.where(b_valid[None, :], d2, jnp.inf)
    return jnp.min(d2, axis=1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q,k,v: [H, S, dh] (single batch slice) -> [H, S, dh]."""
    H, S, dh = q.shape
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)
