"""Distributed-optimization tricks: gradient compression with error feedback,
and collective/compute overlap knobs.

Cross-pod gradient traffic rides the slow inter-pod links, so the trainer can
compress gradients before the (XLA-inserted) all-reduce: int8 quantization
with per-tensor scale + error-feedback residual (1-bit-Adam-style residual
correction keeps convergence).  Compression runs inside the jitted train
step — XLA overlaps the quantize/dequantize with the reduce schedule.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: object      # pytree like grads (fp32)


def init_ef(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_int8(g: jax.Array):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_ef(grads, ef: EFState):
    """Quantize grads+residual to int8; residual carries quantization error
    to the next step (error feedback). Returns (dequantized grads, new EF).

    The dequantized values are what enter the optimizer/all-reduce, so the
    wire format is int8+scale (8.06x smaller than fp32 per tensor)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = compress_int8(x)
        deq = decompress_int8(q, s)
        return deq, x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in outs])
    res = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return deq, EFState(residual=res)


def compressed_bytes(grads) -> int:
    """Wire bytes if shipped int8+scale vs fp32 (for the EXPERIMENTS table)."""
    n = sum(g.size for g in jax.tree.leaves(grads))
    return n + 4 * len(jax.tree.leaves(grads))
