"""Per-arch sharding rules: parameters, optimizer state (ZeRO-1), caches,
inputs.

Axes: "model" = tensor/expert parallel (16-way); "data" (+"pod") = data
parallel.  Rules are path-name driven over the param pytrees produced by
models/*.  Divisibility guards: a dim is only sharded when the *semantic*
unit (heads, kv-heads, experts, d_ff) divides the axis size — otherwise the
leaf is replicated and the cost shows up in the roofline (e.g. minitron's 24
heads on a 16-way model axis; see EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common as cm

DP_AXES = ("pod", "data")   # batch axes (pod present only on multi-pod mesh)


def dp_axes(mesh: Mesh):
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _leaf_name(path) -> str:
    def one(p):
        for attr in ("key", "name", "idx"):
            v = getattr(p, attr, None)
            if v is not None:
                return str(v)
        return str(p)
    return "/".join(one(p) for p in path)


def param_rule(name: str, shape: tuple, cfg: cm.ArchConfig, tp: int,
               dsz: int = 16) -> P:
    """PartitionSpec for one parameter leaf (shape excludes stacking dims)."""
    leaf = name.rsplit("/", 1)[-1]
    nd = len(shape)

    def pad(spec_tail: tuple) -> P:
        return P(*((None,) * (nd - len(spec_tail)) + spec_tail))

    heads_ok = cfg.n_heads % tp == 0
    kv_ok = cfg.n_kv_heads % tp == 0
    ff_ok = cfg.d_ff % tp == 0
    d_ok = cfg.d_model % tp == 0

    if leaf == "embed":
        # vocab-sharded; pjit input shardings require exact divisibility
        return pad(("model", None)) if cfg.vocab_size % tp == 0 \
            else pad((None, "model")) if cfg.d_model % tp == 0 else pad((None, None))
    if leaf == "lm_head":
        return pad((None, "model")) if cfg.vocab_size % tp == 0 \
            else pad((None, None))
    if leaf in ("vis_proj",):
        return pad((None, None))

    # attention
    if leaf == "wq":
        return pad((None, "model")) if heads_ok else pad((None, None))
    rwkv = cfg.mixers[0] == cm.MIXER_RWKV6
    rwkv_rep = rwkv and cfg.rwkv_tm_shard == "replicated"
    if leaf in ("wk", "wv"):
        # rwkv wk/wv live under cmix/mixer too; those are d->d / d->ff
        if rwkv:
            if shape[-1] == cfg.d_ff or shape[-2] == cfg.d_ff:
                return pad((None, "model")) if ff_ok else pad((None, None))
            # time-mix d->d: heads (40) don't divide the model axis, so TP
            # here only buys gathers around the per-head wkv recurrence.
            # Serving replicates the (small) weights (§Perf rwkv6 iteration:
            # decode 3.04 -> 0.10 ms); training keeps them sharded so grad
            # all-reduce stays sharded.
            if rwkv_rep:
                return pad((None, None))
            return pad((None, "model")) if d_ok else pad((None, None))
        return pad((None, "model")) if kv_ok else pad((None, None))
    if leaf == "wo":
        if rwkv:
            return pad((None, None)) if rwkv_rep else (
                pad(("model", None)) if d_ok else pad((None, None)))
        return pad(("model", None)) if heads_ok else pad((None, None))
    if leaf in ("wr", "wg"):                 # rwkv receptance/gate d->d
        if rwkv_rep and shape[-1] == cfg.d_model:
            return pad((None, None))
        return pad((None, "model")) if d_ok else pad((None, None))
    if leaf in ("q_scale", "k_scale"):
        return pad((None,))

    # MLA
    if leaf in ("wq_down", "wkv_down", "q_ln_scale", "kv_ln_scale"):
        return pad((None,) * nd)
    if leaf in ("wq_up", "wk_up", "wv_up"):
        return pad((None, "model")) if heads_ok else pad((None, None))

    # dense MLP
    if leaf in ("wg", "wu"):
        return pad((None, "model")) if ff_ok else pad((None, None))
    if leaf == "wd":
        return pad(("model", None)) if ff_ok else pad((None, None))

    # MoE
    if leaf == "router":
        return pad((None, None))
    if leaf in ("we_g", "we_u", "we_d"):
        # Routed experts dominate MoE params (653B of deepseek-v3's 671B);
        # sharding them over "model" only replicates them across the 16 data
        # shards (81 GB/dev — fatal).  Preference order:
        #   1. full EP: experts over ("data","model") when E divides dp*tp
        #   2. 2D: experts over "model", expert-ff over "data"
        #   3. model-only (small expert counts)
        E = cfg.moe.n_experts
        f = cfg.moe.d_ff_expert
        if cfg.moe_weight_shard == "ep" and E % (tp * dsz) == 0:
            return pad((("data", "model"), None, None))
        # (tested: E-only sharding for small-MoE serving regresses decode
        # peak 4.8 -> 22 GiB without touching the long_500k collectives —
        # refuted; 2D stays the serving fallback. EXPERIMENTS §Perf.)
        fdim = 2 if leaf in ("we_g", "we_u") else 1
        if E % tp == 0 and f % dsz == 0:
            names = [None, None, None]
            names[0] = "model"
            names[fdim] = "data"
            return pad(tuple(names))
        return pad(("model", None, None)) if E % tp == 0 else pad((None,) * nd)
    if leaf in ("ws_g", "ws_u"):
        fs = cfg.moe.n_shared * cfg.moe.d_ff_expert
        return pad((None, "model")) if fs % tp == 0 else pad((None, None))
    if leaf == "ws_d":
        fs = cfg.moe.n_shared * cfg.moe.d_ff_expert
        return pad(("model", None)) if fs % tp == 0 else pad((None, None))

    # mamba (d_inner = expand * d_model, sharded over model)
    if leaf == "in_proj":
        return pad((None, "model")) if d_ok else pad((None, None))
    if leaf in ("conv_w", "x_proj", "out_proj", "A_log"):
        return pad(("model",) + (None,) * (nd - 1)) if d_ok else pad((None,) * nd)
    if leaf == "dt_proj":
        return pad((None, "model")) if d_ok else pad((None, None))
    if leaf in ("conv_bias", "dt_bias", "D"):
        return pad(("model",)) if d_ok else pad((None,))

    # rwkv misc — all feed the head-grouped recurrence (see wk/wv note)
    if leaf in ("decay_w1", "mix_w1", "bonus_u"):
        return pad((None,) * nd)
    if leaf == "decay_w2":
        return pad((None, None)) if rwkv_rep else (
            pad((None, "model")) if d_ok else pad((None, None)))
    if leaf == "mix_w2":
        return pad((None,) * nd) if rwkv_rep else (
            pad((None, None, "model")) if d_ok else pad((None,) * nd))

    # norms / scalars / token-shift mus
    return pad((None,) * nd)


def _stacked(name: str) -> int:
    """Number of leading stacking dims (scan-over-periods adds one).
    Works for raw param paths and for optimizer-state paths (master/body/…)."""
    parts = name.split("/")[:-1]
    return 1 if any(p in ("body", "enc_body", "dec_body", "self_kv")
                    for p in parts) else 0


def param_pspecs(cfg: cm.ArchConfig, specs, mesh: Mesh):
    tp = _axis_size(mesh, "model")
    dsz = _axis_size(mesh, "data")

    def rule(path, leaf):
        name = _leaf_name(path)
        k = _stacked(name)
        inner = param_rule(name, leaf.shape[k:], cfg, tp, dsz)
        return P(*((None,) * k + tuple(inner)))

    return jax.tree_util.tree_map_with_path(rule, specs)


def zero_pspecs(cfg: cm.ArchConfig, specs, mesh: Mesh):
    """Optimizer-state sharding: param sharding + ZeRO-1 over the data axis
    on the first unsharded, divisible dim."""
    base = param_pspecs(cfg, specs, mesh)
    dsize = _axis_size(mesh, "data")

    def add_zero(ps: P, leaf):
        if leaf.ndim == 0:
            return P()
        names = list(tuple(ps)) + [None] * (leaf.ndim - len(tuple(ps)))
        used = set()
        for n in names:
            used.update(n if isinstance(n, tuple) else (n,))
        if "data" in used:
            return P(*names)
        for i, (n, dim) in enumerate(zip(names, leaf.shape)):
            if n is None and dim % dsize == 0 and dim >= dsize:
                names[i] = "data"
                break
        return P(*names)

    flat_s, treedef = jax.tree_util.tree_flatten_with_path(specs)
    flat_b = jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P))
    out = [add_zero(ps, leaf) for (_, leaf), ps in zip(flat_s, flat_b)]
    return jax.tree.unflatten(treedef, out)


def cache_pspecs(cfg: cm.ArchConfig, cache_specs, mesh: Mesh, *,
                 global_batch: int):
    """KV/state cache sharding. Batch over data axes when divisible;
    otherwise (long-context batch=1) shard the sequence axis over "data"
    and heads over "model"."""
    tp = _axis_size(mesh, "model")
    dp = int(np.prod([_axis_size(mesh, a) for a in dp_axes(mesh)])) or 1
    batch_ok = global_batch % dp == 0 and global_batch >= dp
    kv_ok = cfg.n_kv_heads % tp == 0
    dpa = dp_axes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        k = _stacked(name)
        shape = leaf.shape[k:]
        nd = len(shape)
        leafname = name.rsplit("/", 1)[-1]
        names: list = [None] * nd
        if nd == 0:
            return P(*((None,) * k))
        if leafname in ("k", "v", "k_scale", "v_scale"):  # KVCache [B,T,Kv,*]
            if batch_ok:
                names[0] = dpa
            elif shape[1] % _axis_size(mesh, "data") == 0 and shape[1] > 1:
                names[1] = "data"
            if kv_ok:
                names[2] = "model"
            elif names[1] is None and shape[1] % tp == 0 and shape[1] > tp:
                # kv heads don't divide tp: sequence-parallel cache on the
                # model axis (flash-decoding-style partial softmax combine)
                names[1] = "model"
        elif leafname in ("c_kv", "k_rope"):  # MLA [B,T,r]
            if batch_ok:
                names[0] = dpa
            elif shape[1] % _axis_size(mesh, "data") == 0:
                names[1] = "data"
        elif leafname == "conv":              # [B,K-1,d_in]
            if batch_ok:
                names[0] = dpa
            if cfg.d_model % tp == 0:
                names[2] = "model"
        elif leafname == "ssm":               # [B,d_in,N]
            if batch_ok:
                names[0] = dpa
            if cfg.d_model % tp == 0:
                names[1] = "model"
        elif leafname in ("tm_prev", "cm_prev"):
            if batch_ok:
                names[0] = dpa
        elif leafname == "state":             # rwkv [B,h,dk,dv]
            if batch_ok:
                names[0] = dpa
            if cfg.n_heads % tp == 0:
                names[1] = "model"
        elif leafname in ("cross_k", "cross_v"):  # [L,B,S,H,dh]
            if batch_ok:
                names[1] = dpa
            if cfg.n_heads % tp == 0:
                names[3] = "model"
            return P(*names)                  # L dim already included
        return P(*((None,) * k + tuple(names)))

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


def input_pspecs(cfg: cm.ArchConfig, specs, mesh: Mesh, *, global_batch: int):
    dp = int(np.prod([_axis_size(mesh, a) for a in dp_axes(mesh)])) or 1
    batch_ok = global_batch % dp == 0 and global_batch >= dp
    dpa = dp_axes(mesh)

    def rule(path, leaf):
        names = [None] * leaf.ndim
        if leaf.ndim and batch_ok:
            names[0] = dpa
        return P(*names)

    return jax.tree_util.tree_map_with_path(rule, specs)


def shardings_of(pspecs, mesh: Mesh):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def zone_shard_devices(mesh: Mesh, n_zones: int) -> list:
    """Round-robin device placement for the fleet server's spatial zone
    shards (server/zones.py): zone z lives on mesh device z % ndev, so
    per-zone sync collects and queries run where the shard's arrays live.
    On the 1-device container every zone maps to the same device (no-op)."""
    devs = list(mesh.devices.flat)
    return [devs[z % len(devs)] for z in range(n_zones)]


def client_shard_affinity(subscribed: np.ndarray, n_shards: int,
                          zone_shards: np.ndarray | None = None) -> np.ndarray:
    """Assign each client to a session shard by subscribed-zone affinity.

    ``subscribed`` is the fleet's [C, Z] zone-subscription matrix and
    ``zone_shards`` [Z] maps each spatial zone to the session shard whose
    device holds that zone's store arrays (``zone_shard_devices``
    placement: defaults to z % n_shards).  A client is homed on the shard
    that owns the MOST of its subscribed zones — majority vote, lowest
    shard id on ties — so the sharded session tier's sync gathers read
    zone stores resident on the same device.  Clients with no
    subscriptions yet fall back to round-robin (c % n_shards), which
    keeps the partition load-balanced before the first pose arrives.

    Returns [C] int32 shard assignment.  The assignment is computed at
    tier construction; live re-homing of a moving client is a control-
    plane migration (ROADMAP) and is not done per pose update.
    """
    subscribed = np.asarray(subscribed, bool)
    C, Z = subscribed.shape
    if zone_shards is None:
        zone_shards = np.arange(Z) % n_shards
    zone_shards = np.asarray(zone_shards)
    # [C, S] votes: how many of client c's zones live on shard s
    votes = np.zeros((C, n_shards), np.int64)
    for s in range(n_shards):
        votes[:, s] = subscribed[:, zone_shards == s].sum(axis=1)
    assign = votes.argmax(axis=1).astype(np.int32)   # argmax = lowest tie
    none = ~subscribed.any(axis=1)
    assign[none] = (np.arange(C)[none] % n_shards).astype(np.int32)
    return assign
