"""Fault-tolerant sharded checkpointing.

Layout: <dir>/step_<N>/ with one .npz per pytree shard-group plus a JSON
manifest (tree structure, shapes, dtypes, write fingerprint).  Restore is
mesh-agnostic: arrays are written UNSHARDED logical tensors (gathered), so a
restart may use a different mesh/topology — elastic rescale = load + re-shard
with the new in_shardings (tested in tests/test_checkpoint.py).

Durability: writes go to a temp dir, fsync'd, then atomically renamed;
``latest_step`` only ever points at a complete checkpoint, so a crash
mid-write restarts from the previous step (checkpoint/restart fault story).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, arrs = [], []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "name",
                       getattr(p, "idx", p)))) for p in path)
        names.append(key)
        arrs.append(leaf)
    return names, arrs, treedef


def save(ckpt_dir: str | Path, step: int, tree, *, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    names, arrs, _ = _flatten(tree)
    tmp = ckpt_dir / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays = {}
    manifest = {"step": step, "time": time.time(), "leaves": []}
    for name, a in zip(names, arrs):
        host = np.asarray(jax.device_get(a))
        dtype_name = str(host.dtype)
        if dtype_name == "bfloat16":      # npz has no bf16: store the bits
            host = host.view(np.uint16)
        arrays[name.replace("/", "|")] = host
        manifest["leaves"].append({"name": name, "shape": list(host.shape),
                                   "dtype": dtype_name})
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    with open(tmp / "manifest.json", "rb") as f:
        os.fsync(f.fileno())
    final = ckpt_dir / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (ckpt_dir / "latest_step").write_text(str(step))
    # retention
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*"))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    p = Path(ckpt_dir) / "latest_step"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str | Path, step: int, like, *, shardings=None):
    """``like``: pytree of arrays/ShapeDtypeStructs giving the structure.
    ``shardings``: optional matching pytree of NamedShardings — this is where
    elastic rescale happens (same logical tensors, new mesh)."""
    d = Path(ckpt_dir) / f"step_{step}"
    data = np.load(d / "arrays.npz")
    manifest = json.loads((d / "manifest.json").read_text())
    dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    names, leaves, treedef = _flatten(like)
    out = []
    sh_leaves = (jax.tree.leaves(shardings,
                                 is_leaf=lambda x: hasattr(x, "spec"))
                 if shardings is not None else [None] * len(names))
    for name, leaf, sh in zip(names, leaves, sh_leaves):
        host = data[name.replace("/", "|")]
        if dtypes.get(name) == "bfloat16":
            import ml_dtypes
            host = host.view(ml_dtypes.bfloat16)
        assert tuple(host.shape) == tuple(leaf.shape), \
            f"{name}: ckpt {host.shape} vs model {leaf.shape}"
        arr = jnp_asarray(host, leaf.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def jnp_asarray(host, dtype):
    import jax.numpy as jnp
    return jnp.asarray(host, dtype=dtype)
