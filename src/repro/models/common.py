"""Shared building blocks for the model zoo.

Pure-functional JAX: parameters are pytrees of arrays; every layer is an
``init_*`` (or a shape-spec) plus an ``apply``-style function.  No framework
dependency — this substrate is what configs/ and the SemanticXR perception
stack compose.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

# Mixer kinds understood by blocks.py.
MIXER_FULL = "attn_full"          # dense causal attention
MIXER_SWA = "attn_swa"            # sliding-window causal attention
MIXER_GLOBAL = "attn_global"      # gemma2 "global" layer (full, with softcap)
MIXER_MLA = "mla"                 # DeepSeek multi-head latent attention
MIXER_MAMBA = "mamba"             # Mamba-1 selective SSM
MIXER_RWKV6 = "rwkv6"             # RWKV-6 "Finch" time mixing

MLP_DENSE = "dense"
MLP_MOE = "moe"


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536          # 0 => no query compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # Decode-path weight absorption (beyond-paper serving optimization): score
    # queries directly against the latent KV cache instead of re-expanding K/V.
    absorb: bool = False


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 => ceil(d_model / 16)
    chunk: int = 64                  # chunked-scan length (TPU-friendly)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    chunk: int = 64


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 1
    # capacity factor for the GShard-style dense dispatch (baseline path)
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32
    # "dense" = GShard one-hot dispatch einsum (baseline, paper-faithful serving
    # analogue); "ragged" = sort-based dropless grouped matmul (hillclimb).
    dispatch: str = "dense"


@dataclass(frozen=True)
class ArchConfig:
    """One config describes every architecture in the assigned pool."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                       # 0 => d_model // n_heads

    # layer pattern: mixers[i % len(mixers)] / mlps[i % len(mlps)] after the
    # dense prefix of ``n_dense_prefix`` layers (DeepSeek first-k-dense).
    mixers: tuple = (MIXER_FULL,)
    mlps: tuple = (MLP_DENSE,)
    n_dense_prefix: int = 0
    d_ff_dense_prefix: int = 0            # 0 => d_ff

    # attention knobs
    sliding_window: int = 4096
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0       # gemma2: 50.0
    final_logit_softcap: float = 0.0      # gemma2: 30.0
    qk_norm: bool = False

    # family sub-configs
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    rwkv: RWKVConfig | None = None
    moe: MoEConfig | None = None

    # encoder-decoder (whisper): n_layers is the decoder depth
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                   # stub conv frontend output frames

    # modality frontend stub: None | "audio" | "vision"
    frontend: str | None = None
    n_frontend_tokens: int = 0            # vision: patch tokens per image

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                     # mlp activation ("silu"|"gelu")
    dtype: Any = jnp.bfloat16

    # execution knobs
    scan_layers: bool = True              # lax.scan over layer stack
    remat: bool = True                    # activation checkpointing per layer
    attn_chunk: int = 1024                # q-chunk for flash-style jnp attention
    use_pallas: bool = False              # route hot ops through Pallas kernels
    moe_groups: int = 1                   # MoE dispatch groups (align with DP)
    prune_tiles: bool = False             # skip fully-masked attention tiles
    # routed-expert weight layout: "2d" (E@model, ff@data — train-friendly)
    # or "ep" (E@(data,model) full expert-parallel — serving-friendly)
    moe_weight_shard: str = "2d"
    # Megatron-style sequence parallelism: residual stream sharded
    # (batch@act_shard[0], seq@act_shard[1]) between blocks; turns per-layer
    # TP all-reduces into reduce-scatter/all-gather pairs and stores remat'd
    # activations 1/tp-sized.  None = off.  e.g. (("pod","data"), "model")
    # NOTE: measured counterproductive with the group-local MoE dispatch and
    # blocked-attention reshapes (EXPERIMENTS.md §Perf, refuted iterations).
    act_shard: tuple | None = None
    grad_accum: int = 1                   # microbatches per train step
    # rwkv time-mix weights: "model" shards d->d projections (train: grads
    # stay sharded) at the cost of gathers around the head-grouped wkv
    # recurrence; "replicated" removes the gathers (serving: 30x on decode —
    # EXPERIMENTS §Perf)
    rwkv_tm_shard: str = "model"
    # KV cache storage: "bf16" or "int8" (per-token-per-head symmetric
    # quantization — halves the decode KV-read roofline term)
    kv_cache_dtype: str = "bf16"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    @property
    def period(self) -> int:
        return int(np.lcm(len(self.mixers), len(self.mlps)))

    @property
    def n_body_layers(self) -> int:
        return self.n_layers - self.n_dense_prefix

    @property
    def n_periods(self) -> int:
        assert self.n_body_layers % self.period == 0, (
            f"{self.name}: body layers {self.n_body_layers} not divisible by "
            f"period {self.period}")
        return self.n_body_layers // self.period

    def block_kinds(self, slot: int) -> tuple[str, str]:
        """(mixer, mlp) for period slot ``slot``."""
        return (self.mixers[slot % len(self.mixers)],
                self.mlps[slot % len(self.mlps)])

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float64) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, d]; positions: broadcastable to [..., seq]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs      # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]                            # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameter initialization from shape specs
# ---------------------------------------------------------------------------

def _leaf_init(key, path: str, shape, dtype):
    """Init rule by naming convention: *_scale -> zeros (rms uses 1+scale),
    *_bias -> zeros, embeddings & matmuls -> truncated normal / sqrt(fan_in)."""
    if path.endswith("scale") or path.endswith("ln_s"):
        return jnp.zeros(shape, dtype)
    if path.endswith("bias") or path.endswith("ln_b"):
        if path.endswith("ln_b"):
            return jnp.zeros(shape, dtype)
        return jnp.zeros(shape, dtype)
    if path.endswith("A_log"):           # mamba: init A in [1, d_state]
        d_state = shape[-1]
        a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), shape[:-1] + (1,))
        return jnp.log(a).astype(dtype)
    if path.endswith("dt_bias"):
        # mamba dt bias ~ softplus^-1(uniform(1e-3, 1e-1))
        u = jax.random.uniform(key, shape, jnp.float32,
                               minval=math.log(1e-3), maxval=math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if path.endswith("decay_base"):      # rwkv per-channel decay speed
        n = shape[-1]
        base = -6.0 + 5.0 * (jnp.arange(n, dtype=jnp.float32) / max(n - 1, 1)) ** 0.7
        return jnp.broadcast_to(base, shape).astype(dtype)
    if path.endswith("mix_mu"):          # rwkv token-shift mixing in (0,1)
        return jax.random.uniform(key, shape, jnp.float32, 0.3, 0.7).astype(dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    return w.astype(dtype)


def init_from_specs(key: jax.Array, specs) -> Any:
    """specs: pytree of jax.ShapeDtypeStruct; returns initialized params."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, len(leaves))
    out = []
    for (path, spec), k in zip(leaves, keys):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append(_leaf_init(k, name, spec.shape, spec.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in out])


def spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def count_params(specs) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(specs)))
