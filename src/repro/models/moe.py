"""Mixture-of-Experts FFN: shared + routed top-k experts.

Dispatch is sort-based and capacity-bounded (MaxText/MegaBlocks-style) rather
than GShard one-hot: a [T, E, C] dispatch tensor at DeepSeek scale (E=256,
~1M tokens) is ~10^12 elements, so the classic dense-dispatch einsum is a
non-starter.  Here each data-parallel group ranks its token-copies within
their expert via argsort + segment arithmetic (O(T·k) memory), scatters them
into an [E, C, d] buffer, runs batched expert GEMMs, and gathers back.

Sharding intent (see distributed/sharding.py): token/group axes over
("pod","data"); expert axis over "model" (16-way EP); the scatter into the
expert buffer is where XLA inserts the all-to-all equivalent.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def moe_param_specs(cfg: cm.ArchConfig) -> dict:
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.n_experts, mo.d_ff_expert
    p = {
        "router": cm.spec((d, E), jnp.float32),
        "we_g": cm.spec((E, d, f), cfg.dtype),
        "we_u": cm.spec((E, d, f), cfg.dtype),
        "we_d": cm.spec((E, f, d), cfg.dtype),
    }
    if mo.n_shared:
        fs = mo.n_shared * f
        p["ws_g"] = cm.spec((d, fs), cfg.dtype)
        p["ws_u"] = cm.spec((d, fs), cfg.dtype)
        p["ws_d"] = cm.spec((fs, d), cfg.dtype)
    return p


def expert_capacity(tokens_per_group: int, cfg: cm.ArchConfig) -> int:
    mo = cfg.moe
    c = math.ceil(tokens_per_group * mo.top_k * mo.capacity_factor / mo.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


class MoEStats(NamedTuple):
    aux_loss: jax.Array       # Switch-style load-balance loss
    dropped_frac: jax.Array   # fraction of token-copies over capacity


def _route(params, x2d, cfg):
    """x2d: [T, d] -> (weights [T,k], experts [T,k], probs [T,E])."""
    mo = cfg.moe
    logits = x2d.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, mo.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx, probs


def _group_dispatch(xg, wg_, idxg, params, cfg, C):
    """One data-parallel group. xg: [Tg, d]; wg_/idxg: [Tg, k]."""
    mo = cfg.moe
    E, k = mo.n_experts, mo.top_k
    Tg, d = xg.shape
    Tk = Tg * k
    flat_e = idxg.reshape(Tk)
    order = jnp.argsort(flat_e)                      # stable sort by expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)          # [E]
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    rank = jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)  # E*C = drop slot
    tok = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)
    buf = jnp.zeros((E * C, d), xg.dtype).at[slot].set(xg[tok], mode="drop")
    buf = buf.reshape(E, C, d)

    act = cm.act_fn(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", buf, params["we_g"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["we_u"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["we_d"]).reshape(E * C, d)

    gathered = out_buf.at[slot].get(mode="fill", fill_value=0)   # [Tk, d]
    contrib = gathered * (wg_.reshape(Tk, 1) * keep[:, None]).astype(gathered.dtype)
    y = jax.ops.segment_sum(contrib, tok, num_segments=Tg)
    dropped = 1.0 - keep.mean()
    return y, dropped


def moe_apply(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
              n_groups: int = 1):
    """x: [B, S, d]. Returns (y, MoEStats). n_groups should divide B*S and
    align with the data-parallel sharding (tokens stay group-local)."""
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    w, idx, probs = _route(params, x2d, cfg)

    # Switch load-balance aux loss over the full batch
    E = mo.n_experts
    me = probs.mean(axis=0)                                      # [E]
    onehot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=0)
    aux = E * jnp.sum(me * ce)

    g = n_groups
    while T % g:
        g -= 1
    Tg = T // g
    C = expert_capacity(Tg, cfg)
    xg = x2d.reshape(g, Tg, d)
    wgk = w.reshape(g, Tg, mo.top_k)
    idxg = idx.reshape(g, Tg, mo.top_k)
    y, dropped = jax.vmap(
        lambda a, b, c: _group_dispatch(a, b, c, params, cfg, C))(xg, wgk, idxg)
    y = y.reshape(B, S, d)

    if mo.n_shared:
        act = cm.act_fn(cfg.act)
        shared = act(x @ params["ws_g"]) * (x @ params["ws_u"])
        y = y + shared @ params["ws_d"]
    return y, MoEStats(aux_loss=aux, dropped_frac=dropped.mean())
