"""Full language model: embedding -> (prefix blocks + scanned periodic body)
-> final norm -> vocab head.  Covers every assigned arch family.

Layer stacking uses ``lax.scan`` over "periods" (one period = the arch's
repeating block pattern, e.g. gemma2 [local, global], jamba 8-layer
mamba/attn+dense/moe group) with per-slot stacked parameters — compact HLO,
fast compiles at 61 layers, remat-per-period.

Losses are computed with a sequence-chunked cross-entropy so the [B,S,V]
logits tensor (33 GB/device at gemma2's 256k vocab) is never materialized.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import blocks as blk


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _stack_specs(specs, n: int):
    return jax.tree.map(lambda l: cm.spec((n,) + l.shape, l.dtype), specs)


def lm_param_specs(cfg: cm.ArchConfig) -> dict:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": cm.spec((cfg.vocab_size, d), cfg.dtype),
        "final_scale": cm.spec((d,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = cm.spec((d, cfg.vocab_size), cfg.dtype)
    if cfg.n_dense_prefix:
        mk = cfg.mixers[0]
        specs["prefix"] = [
            blk.block_param_specs(cfg, mk, cm.MLP_DENSE,
                                  cfg.d_ff_dense_prefix or cfg.d_ff)
            for _ in range(cfg.n_dense_prefix)]
    specs["body"] = [
        _stack_specs(blk.block_param_specs(cfg, *cfg.block_kinds(s)),
                     cfg.n_periods)
        for s in range(cfg.period)]
    if cfg.frontend == "vision":
        specs["vis_proj"] = cm.spec((d, d), cfg.dtype)
    return specs


def init_lm_params(cfg: cm.ArchConfig, key: jax.Array):
    return cm.init_from_specs(key, lm_param_specs(cfg))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def lm_cache_specs(cfg: cm.ArchConfig, batch: int, max_len: int) -> dict:
    caches: dict[str, Any] = {}
    if cfg.n_dense_prefix:
        caches["prefix"] = [blk.block_cache_specs(cfg, cfg.mixers[0], batch,
                                                  max_len)
                            for _ in range(cfg.n_dense_prefix)]
    caches["body"] = [
        _stack_specs(blk.block_cache_specs(cfg, cfg.block_kinds(s)[0], batch,
                                           max_len), cfg.n_periods)
        for s in range(cfg.period)]
    return caches


def init_lm_cache(cfg: cm.ArchConfig, batch: int, max_len: int) -> dict:
    def init_one(mk):
        return blk.init_block_cache(cfg, mk, batch, max_len)

    caches: dict[str, Any] = {}
    if cfg.n_dense_prefix:
        caches["prefix"] = [init_one(cfg.mixers[0])
                            for _ in range(cfg.n_dense_prefix)]
    caches["body"] = [
        jax.tree.map(lambda l: jnp.broadcast_to(l, (cfg.n_periods,) + l.shape),
                     init_one(cfg.block_kinds(s)[0]))
        for s in range(cfg.period)]
    return caches


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, extra_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma-style embed scale
    if extra_embeds is not None:
        if "vis_proj" in params:
            extra_embeds = extra_embeds @ params["vis_proj"]
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def _run_blocks(params, x, cfg, *, positions, caches=None, n_groups=1):
    """Shared trunk: prefix blocks then scanned body. Returns
    (hidden, aux_loss, new_caches)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches: dict[str, Any] = {}
    decode = caches is not None

    if cfg.n_dense_prefix:
        new_prefix = []
        for i in range(cfg.n_dense_prefix):
            c = caches["prefix"][i] if decode else None
            out = blk.block_apply(
                params["prefix"][i], x, cfg, mixer_kind=cfg.mixers[0],
                mlp_kind=cm.MLP_DENSE, positions=positions, cache=c,
                n_groups=n_groups)
            x, aux = out.x, aux + out.aux_loss
            new_prefix.append(out.cache)
        if decode:
            new_caches["prefix"] = new_prefix

    def _constrain(x):
        if cfg.act_shard is None or x.shape[1] == 1:
            return x
        from jax.sharding import PartitionSpec as P
        batch_axes, seq_axis = cfg.act_shard
        return jax.lax.with_sharding_constraint(
            x, P(batch_axes, seq_axis, None))

    def period_fn(carry, xs):
        x, aux = carry
        x = _constrain(x)
        slot_params = xs[0] if decode else xs
        slot_caches = xs[1] if decode else [None] * cfg.period
        new_slot_caches = []
        for s in range(cfg.period):
            mk, lk = cfg.block_kinds(s)
            out = blk.block_apply(slot_params[s], x, cfg, mixer_kind=mk,
                                  mlp_kind=lk, positions=positions,
                                  cache=slot_caches[s], n_groups=n_groups)
            x, aux = out.x, aux + out.aux_loss
            new_slot_caches.append(out.cache)
        return (x, aux), (new_slot_caches if decode else None)

    xs = (params["body"], caches["body"]) if decode else params["body"]
    if cfg.scan_layers:
        fn = jax.checkpoint(period_fn, prevent_cse=False) if cfg.remat else period_fn
        (x, aux), ys = jax.lax.scan(fn, (x, aux), xs)
        if decode:
            new_caches["body"] = ys
    else:
        body_ys = [[] for _ in range(cfg.period)]
        for i in range(cfg.n_periods):
            sl = jax.tree.map(lambda l: l[i], xs)
            (x, aux), ys = period_fn((x, aux), sl)
            if decode:
                for s in range(cfg.period):
                    body_ys[s].append(ys[s])
        if decode:
            new_caches["body"] = [
                jax.tree.map(lambda *ls: jnp.stack(ls), *body_ys[s])
                for s in range(cfg.period)]
    return x, aux, (new_caches if decode else None)


def forward_hidden(params, tokens, cfg, *, extra_embeds=None):
    x = _embed(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x, aux, _ = _run_blocks(params, x, cfg, positions=positions)
    return cm.rms_norm(x, params["final_scale"], cfg.norm_eps), aux


def _head(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w
    if cfg.final_logit_softcap:
        logits = cm.softcap(logits.astype(jnp.float32),
                            cfg.final_logit_softcap)
    return logits


def forward_logits(params, tokens, cfg, *, extra_embeds=None):
    x, aux = forward_hidden(params, tokens, cfg, extra_embeds=extra_embeds)
    return _head(params, x, cfg), aux


# ---------------------------------------------------------------------------
# Loss (sequence-chunked cross-entropy)
# ---------------------------------------------------------------------------

def lm_loss(params, batch: dict, cfg: cm.ArchConfig, *,
            loss_chunk: int = 512, aux_weight: float = 0.01):
    tokens = batch["tokens"]
    x, aux = forward_hidden(params, tokens, cfg,
                            extra_embeds=batch.get("extra_embeds"))
    n_extra = x.shape[1] - tokens.shape[1]
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
    if n_extra:  # frontend tokens predict nothing
        labels = jnp.concatenate(
            [jnp.full((tokens.shape[0], n_extra), -1, labels.dtype), labels],
            axis=1)
    B, S, d = x.shape
    loss_chunk = min(loss_chunk, S)
    pad = (-S) % loss_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (S + pad) // loss_chunk
    xc = jnp.moveaxis(x.reshape(B, n_chunks, loss_chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n_chunks, loss_chunk), 1, 0)

    def chunk_fn(carry, inp):
        tot, cnt = carry
        xb, lb = inp
        logits = _head(params, xb, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg, caches, *, extra_embeds=None):
    """Fill caches from a prompt; returns (last-token logits, caches)."""
    x = _embed(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x, _, new_caches = _run_blocks(params, x, cfg, positions=positions,
                                   caches=caches)
    x = cm.rms_norm(x[:, -1:], params["final_scale"], cfg.norm_eps)
    return _head(params, x, cfg)[:, 0], new_caches


def decode_step(params, tokens, cfg, caches, *, pos):
    """One decode step. tokens: [B,1]; pos: [] int32 absolute position.
    Returns (logits [B,V], new caches)."""
    x = _embed(params, tokens, cfg)
    positions = jnp.full((1, 1), pos, jnp.int32)
    x, _, new_caches = _run_blocks(params, x, cfg, positions=positions,
                                   caches=caches)
    x = cm.rms_norm(x, params["final_scale"], cfg.norm_eps)
    return _head(params, x, cfg)[:, 0], new_caches
