"""Uniform model API over decoder-only LMs and the enc-dec family.

Everything downstream (training loop, serving, dry-run) talks to this facade:
    api = model_api(cfg)
    api.param_specs() / api.init(key)
    api.loss(params, batch)                     -> (scalar, metrics)
    api.prefill(params, tokens/frames, caches)  -> (logits, caches)
    api.decode(params, tokens, caches, pos)     -> (logits, caches)
    api.cache_specs(batch, max_len)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import lm as lm_mod
from repro.models import encdec as ed_mod


@dataclass(frozen=True)
class ModelAPI:
    cfg: cm.ArchConfig
    param_specs: Callable[[], Any]
    init: Callable[[jax.Array], Any]
    loss: Callable[..., Any]
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    cache_specs: Callable[[int, int], Any]
    init_cache: Callable[[int, int], Any] | None = None


def model_api(cfg: cm.ArchConfig) -> ModelAPI:
    if cfg.encdec:
        def _prefill(params, batch, caches=None):
            enc_out = ed_mod.encode(params, batch["frames"], cfg)
            ck, cv = ed_mod.cross_kv(params, enc_out, cfg)
            kv = caches.self_kv if caches is not None else None
            # decoder prompt: BOS token only; self cache stays empty until decode
            B = batch["frames"].shape[0]
            logits, _ = ed_mod.encdec_decode_step(
                params, jnp.zeros((B, 1), jnp.int32), cfg,
                ed_mod.EncDecCache(kv, ck, cv), pos=0)
            return logits, ed_mod.EncDecCache(kv, ck, cv)

        return ModelAPI(
            cfg=cfg,
            param_specs=lambda: ed_mod.encdec_param_specs(cfg),
            init=lambda key: ed_mod.init_encdec_params(cfg, key),
            loss=lambda params, batch, **kw: ed_mod.encdec_loss(
                params, batch, cfg, **kw),
            forward=lambda params, batch: ed_mod.encode(
                params, batch["frames"], cfg),
            prefill=_prefill,
            decode=lambda params, tokens, caches, pos: ed_mod.encdec_decode_step(
                params, tokens, cfg, caches, pos=pos),
            cache_specs=lambda batch, max_len: ed_mod.encdec_cache_specs(
                cfg, batch, max_len),
        )

    def _loss(params, batch, **kw):
        return lm_mod.lm_loss(params, batch, cfg, **kw)

    def _forward(params, batch):
        logits, _ = lm_mod.forward_logits(
            params, batch["tokens"], cfg,
            extra_embeds=batch.get("extra_embeds"))
        return logits

    def _prefill(params, batch, caches):
        return lm_mod.prefill(params, batch["tokens"], cfg, caches,
                              extra_embeds=batch.get("extra_embeds"))

    return ModelAPI(
        cfg=cfg,
        param_specs=lambda: lm_mod.lm_param_specs(cfg),
        init=lambda key: lm_mod.init_lm_params(cfg, key),
        loss=_loss,
        forward=_forward,
        prefill=_prefill,
        decode=lambda params, tokens, caches, pos: lm_mod.decode_step(
            params, tokens, cfg, caches, pos=pos),
        cache_specs=lambda batch, max_len: lm_mod.lm_cache_specs(
            cfg, batch, max_len),
        init_cache=lambda batch, max_len: lm_mod.init_lm_cache(
            cfg, batch, max_len),
    )
