"""Encoder-decoder transformer (whisper-small backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, n_frames, d_model].  Encoder is
bidirectional; decoder is causal self-attention + cross-attention to the
encoder output.  Cross K/V are computed once at encode time and held as a
fixed part of the serving cache (standard whisper serving layout).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.attention import KVCache, blocked_attention, decode_attention


def _stack(specs, n):
    return jax.tree.map(lambda l: cm.spec((n,) + l.shape, l.dtype), specs)


def _xattn_param_specs(cfg: cm.ArchConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    return {"wq": cm.spec((d, h * dh), cfg.dtype),
            "wk": cm.spec((d, h * dh), cfg.dtype),
            "wv": cm.spec((d, h * dh), cfg.dtype),
            "wo": cm.spec((h * dh, d), cfg.dtype)}


def encdec_param_specs(cfg: cm.ArchConfig) -> dict:
    d = cfg.d_model
    enc_block = {"ln1_scale": cm.spec((d,), cfg.dtype),
                 "mixer": attn.attn_param_specs(cfg),
                 "ln2_scale": cm.spec((d,), cfg.dtype),
                 "mlp": mlp_mod.mlp_param_specs(cfg)}
    dec_block = {"ln1_scale": cm.spec((d,), cfg.dtype),
                 "self": attn.attn_param_specs(cfg),
                 "ln_x_scale": cm.spec((d,), cfg.dtype),
                 "cross": _xattn_param_specs(cfg),
                 "ln2_scale": cm.spec((d,), cfg.dtype),
                 "mlp": mlp_mod.mlp_param_specs(cfg)}
    return {
        "embed": cm.spec((cfg.vocab_size, d), cfg.dtype),
        "enc_body": _stack(enc_block, cfg.n_enc_layers),
        "enc_final_scale": cm.spec((d,), cfg.dtype),
        "dec_body": _stack(dec_block, cfg.n_layers),
        "final_scale": cm.spec((d,), cfg.dtype),
    }


def init_encdec_params(cfg: cm.ArchConfig, key: jax.Array):
    return cm.init_from_specs(key, encdec_param_specs(cfg))


# ---------------------------------------------------------------------------

def encode(params, frames, cfg):
    """frames: [B, S_enc, d] precomputed stub embeddings -> enc hidden."""
    S = frames.shape[1]
    positions = jnp.arange(S)[None, :]

    def layer(x, p):
        h = cm.rms_norm(x, p["ln1_scale"], cfg.norm_eps)
        B, S, _ = h.shape
        H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = (h @ p["mixer"]["wq"]).reshape(B, S, H, dh)
        k = (h @ p["mixer"]["wk"]).reshape(B, S, K, dh)
        v = (h @ p["mixer"]["wv"]).reshape(B, S, K, dh)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        o = blocked_attention(q, k, v, causal=False, q_chunk=cfg.attn_chunk)
        x = x + o.reshape(B, S, H * dh) @ p["mixer"]["wo"]
        h = cm.rms_norm(x, p["ln2_scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp_apply(p["mlp"], h, cfg)
        return x, None

    fn = jax.checkpoint(layer, prevent_cse=False) if cfg.remat else layer
    x, _ = jax.lax.scan(fn, frames.astype(cfg.dtype), params["enc_body"])
    return cm.rms_norm(x, params["enc_final_scale"], cfg.norm_eps)


def _cross_attend(p, h, k_cross, v_cross, cfg):
    B, S, _ = h.shape
    H, dh = cfg.n_heads, cfg.d_head
    q = (h @ p["wq"]).reshape(B, S, H, dh)
    o = blocked_attention(q, k_cross, v_cross, causal=False,
                          q_chunk=cfg.attn_chunk)
    return o.reshape(B, S, H * dh) @ p["wo"]


def cross_kv(params, enc_out, cfg):
    """Per-layer cross K/V: [L, B, S_enc, H, dh] stacked."""
    B, S, _ = enc_out.shape
    H, dh = cfg.n_heads, cfg.d_head

    def one(p):
        k = (enc_out @ p["cross"]["wk"]).reshape(B, S, H, dh)
        v = (enc_out @ p["cross"]["wv"]).reshape(B, S, H, dh)
        return k, v

    return jax.vmap(one)(params["dec_body"])


def decode_train(params, tokens, enc_out, cfg):
    """Teacher-forced decoder forward -> hidden [B, S_dec, d]."""
    x = jnp.take(params["embed"], tokens, axis=0)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def layer(x, p):
        h = cm.rms_norm(x, p["ln1_scale"], cfg.norm_eps)
        y, _ = attn.attention_mixer(p["self"], h, cfg, kind=cm.MIXER_FULL,
                                    positions=positions, cache=None)
        x = x + y
        h = cm.rms_norm(x, p["ln_x_scale"], cfg.norm_eps)
        B, _, _ = h.shape
        H, dh = cfg.n_heads, cfg.d_head
        k = (enc_out @ p["cross"]["wk"]).reshape(B, -1, H, dh)
        v = (enc_out @ p["cross"]["wv"]).reshape(B, -1, H, dh)
        x = x + _cross_attend(p["cross"], h, k, v, cfg)
        h = cm.rms_norm(x, p["ln2_scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp_apply(p["mlp"], h, cfg)
        return x, None

    fn = jax.checkpoint(layer, prevent_cse=False) if cfg.remat else layer
    x, _ = jax.lax.scan(fn, x, params["dec_body"])
    return cm.rms_norm(x, params["final_scale"], cfg.norm_eps)


def encdec_loss(params, batch, cfg, **_):
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = decode_train(params, tokens, enc_out, cfg)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    self_kv: Any        # stacked KVCache over decoder layers
    cross_k: jax.Array  # [L, B, S_enc, H, dh]
    cross_v: jax.Array


def encdec_cache_specs(cfg: cm.ArchConfig, batch: int, max_len: int):
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    kv = _stack(attn.kv_cache_specs(cfg, batch, max_len), L)
    xs = cm.spec((L, batch, cfg.enc_seq, H, dh), cfg.dtype)
    return EncDecCache(self_kv=kv, cross_k=xs, cross_v=xs)


def encdec_decode_step(params, tokens, cfg, caches: EncDecCache, *, pos):
    x = jnp.take(params["embed"], tokens, axis=0)   # [B,1,d]
    positions = jnp.full((1, 1), pos, jnp.int32)

    def layer(x, inp):
        p, kv, ck, cv = inp
        h = cm.rms_norm(x, p["ln1_scale"], cfg.norm_eps)
        y, new_kv = attn.attention_mixer(p["self"], h, cfg,
                                         kind=cm.MIXER_FULL,
                                         positions=positions, cache=kv)
        x = x + y
        h = cm.rms_norm(x, p["ln_x_scale"], cfg.norm_eps)
        x = x + _cross_attend(p["cross"], h, ck, cv, cfg)
        h = cm.rms_norm(x, p["ln2_scale"], cfg.norm_eps)
        x = x + mlp_mod.mlp_apply(p["mlp"], h, cfg)
        return x, new_kv

    x, new_kv = jax.lax.scan(
        layer, x, (params["dec_body"], caches.self_kv, caches.cross_k,
                   caches.cross_v))
    x = cm.rms_norm(x, params["final_scale"], cfg.norm_eps)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, EncDecCache(self_kv=new_kv, cross_k=caches.cross_k,
                               cross_v=caches.cross_v)
