"""Mamba-1 selective SSM mixer (Jamba's attention-free layers).

TPU adaptation: the CUDA selective-scan kernel fuses a sequential recurrence
per thread; here the recurrence is re-blocked for the MXU/VPU as an outer
``lax.scan`` over time chunks carrying the [d_inner, d_state] state, with a
parallel ``associative_scan`` inside each chunk.  Chunk length bounds the
fp32 [chunk, d_inner, d_state] working set (the VMEM budget of the eventual
Pallas port) instead of materializing the full-sequence scan buffer.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def _dims(cfg: cm.ArchConfig):
    mb = cfg.mamba
    d_inner = mb.expand * cfg.d_model
    dt_rank = mb.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank, mb.d_state, mb.d_conv


def mamba_param_specs(cfg: cm.ArchConfig) -> dict:
    d = cfg.d_model
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    return {
        "in_proj": cm.spec((d, 2 * d_in), cfg.dtype),
        "conv_w": cm.spec((d_in, d_conv), cfg.dtype),
        "conv_bias": cm.spec((d_in,), cfg.dtype),
        "x_proj": cm.spec((d_in, dt_rank + 2 * d_state), cfg.dtype),
        "dt_proj": cm.spec((dt_rank, d_in), cfg.dtype),
        "dt_bias": cm.spec((d_in,), jnp.float32),
        "A_log": cm.spec((d_in, d_state), jnp.float32),
        "D": cm.spec((d_in,), jnp.float32),
        "out_proj": cm.spec((d_in, d), cfg.dtype),
    }


class MambaCache(NamedTuple):
    conv: jax.Array   # [B, d_conv-1, d_inner] — last inputs to the causal conv
    ssm: jax.Array    # [B, d_inner, d_state]


def mamba_cache_specs(cfg: cm.ArchConfig, batch: int) -> MambaCache:
    d_in, _, d_state, d_conv = _dims(cfg)
    return MambaCache(conv=cm.spec((batch, d_conv - 1, d_in), cfg.dtype),
                      ssm=cm.spec((batch, d_in, d_state), jnp.float32))


def init_mamba_cache(cfg: cm.ArchConfig, batch: int) -> MambaCache:
    d_in, _, d_state, d_conv = _dims(cfg)
    return MambaCache(conv=jnp.zeros((batch, d_conv - 1, d_in), cfg.dtype),
                      ssm=jnp.zeros((batch, d_in, d_state), jnp.float32))


def _causal_conv(x, w, b, prev):
    """x: [B,S,d_in]; w: [d_in,K]; prev: [B,K-1,d_in] carried inputs."""
    K = w.shape[1]
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[:, i] for i in range(K))
    return y + b, xp[:, -(K - 1):]


def _ssm_chunk(carry, inp, A):
    """One time chunk. carry: h [B,d_in,N] fp32. inp: per-chunk tensors."""
    h0 = carry
    u, B_, C_, dt = inp        # u,dt: [B,C,d_in]; B_,C_: [B,C,N]
    # discretize: decay a = exp(dt*A)  [B,C,d_in,N]; drive b = dt*u ⊗ B
    lam = jnp.exp(dt[..., None] * A)                       # decay factors
    drive = (dt * u)[..., None] * B_[:, :, None, :]        # [B,C,d_in,N]
    # fold h0 into the first step's drive, then parallel prefix over the chunk
    drive = drive.at[:, 0].add(lam[:, 0] * h0)

    def op(a, b):
        return (a[0] * b[0], b[0] * a[1] + b[1])

    _, h_all = jax.lax.associative_scan(op, (lam, drive), axis=1)
    y = jnp.einsum("bcdn,bcn->bcd", h_all, C_)
    return h_all[:, -1], y


def mamba_mixer(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
                cache: MambaCache | None = None):
    """x: [B,S,D]. Prefill/train when cache is None; else single-token decode."""
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    B, S, _ = x.shape
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)

    prev = (jnp.zeros((B, d_conv - 1, d_in), xin.dtype) if cache is None
            else cache.conv)
    xc, conv_state = _causal_conv(xin, params["conv_w"], params["conv_bias"], prev)
    xc = jax.nn.silu(xc)

    dbc = xc @ params["x_proj"]
    dt_low = dbc[..., :dt_rank]
    B_ = dbc[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    C_ = dbc[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                          # [d_in, N]
    u = xc.astype(jnp.float32)

    if cache is None or S > 1:
        Cn = min(cfg.mamba.chunk, S)
        pad = (-S) % Cn
        if pad:
            u, B_, C_, dt = (jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
                             for t in (u, B_, C_, dt))
        n_chunks = (S + pad) // Cn
        def split(t):
            return jnp.moveaxis(t.reshape(B, n_chunks, Cn, *t.shape[2:]), 1, 0)
        h0 = jnp.zeros((B, d_in, d_state), jnp.float32) if cache is None \
            else cache.ssm
        h_last, ys = jax.lax.scan(lambda c, i: _ssm_chunk(c, i, A), h0,
                                  (split(u), split(B_), split(C_), split(dt)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, (S + pad), d_in)[:, :S]
        new_cache = None if cache is None else MambaCache(conv=conv_state,
                                                          ssm=h_last)
    else:
        lam = jnp.exp(dt[:, 0, :, None] * A)
        h = lam * cache.ssm + (dt * u)[:, 0, :, None] * B_[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_[:, 0])[:, None]
        new_cache = MambaCache(conv=conv_state, ssm=h)

    y = y + u * params["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], new_cache
