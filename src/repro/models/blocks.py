"""Decoder block composition: pre-norm mixer + pre-norm MLP/MoE.

A block's (mixer, mlp) kinds come from the arch's period pattern; the cache
pytree type follows the mixer kind.  RWKV blocks own a single fused cache
(token-shift states live in both halves).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod


def block_param_specs(cfg: cm.ArchConfig, mixer_kind: str, mlp_kind: str,
                      d_ff: int | None = None) -> dict:
    p: dict[str, Any] = {"ln1_scale": cm.spec((cfg.d_model,), cfg.dtype)}
    if mixer_kind in (cm.MIXER_FULL, cm.MIXER_SWA, cm.MIXER_GLOBAL):
        p["mixer"] = attn.attn_param_specs(cfg)
    elif mixer_kind == cm.MIXER_MLA:
        p["mixer"] = mla_mod.mla_param_specs(cfg)
    elif mixer_kind == cm.MIXER_MAMBA:
        p["mixer"] = mamba_mod.mamba_param_specs(cfg)
    elif mixer_kind == cm.MIXER_RWKV6:
        p["mixer"] = rwkv_mod.rwkv_tm_param_specs(cfg)
    else:
        raise ValueError(mixer_kind)

    p["ln2_scale"] = cm.spec((cfg.d_model,), cfg.dtype)
    if mixer_kind == cm.MIXER_RWKV6:
        p["mlp"] = rwkv_mod.rwkv_cm_param_specs(cfg)
    elif mlp_kind == cm.MLP_DENSE:
        p["mlp"] = mlp_mod.mlp_param_specs(cfg, d_ff)
    elif mlp_kind == cm.MLP_MOE:
        p["mlp"] = moe_mod.moe_param_specs(cfg)
    else:
        raise ValueError(mlp_kind)
    return p


def block_cache_specs(cfg: cm.ArchConfig, mixer_kind: str, batch: int,
                      max_len: int):
    if mixer_kind in (cm.MIXER_FULL, cm.MIXER_GLOBAL):
        return attn.kv_cache_specs(cfg, batch, max_len)
    if mixer_kind == cm.MIXER_SWA:
        return attn.kv_cache_specs(cfg, batch, max_len, window=True)
    if mixer_kind == cm.MIXER_MLA:
        return mla_mod.mla_cache_specs(cfg, batch, max_len)
    if mixer_kind == cm.MIXER_MAMBA:
        return mamba_mod.mamba_cache_specs(cfg, batch)
    if mixer_kind == cm.MIXER_RWKV6:
        return rwkv_mod.rwkv_cache_specs(cfg, batch)
    raise ValueError(mixer_kind)


def init_block_cache(cfg: cm.ArchConfig, mixer_kind: str, batch: int,
                     max_len: int):
    if mixer_kind in (cm.MIXER_FULL, cm.MIXER_GLOBAL):
        return attn.init_kv_cache(cfg, batch, max_len)
    if mixer_kind == cm.MIXER_SWA:
        return attn.init_kv_cache(cfg, batch, max_len, window=True)
    if mixer_kind == cm.MIXER_MLA:
        return mla_mod.init_mla_cache(cfg, batch, max_len)
    if mixer_kind == cm.MIXER_MAMBA:
        return mamba_mod.init_mamba_cache(cfg, batch)
    if mixer_kind == cm.MIXER_RWKV6:
        return rwkv_mod.init_rwkv_cache(cfg, batch)
    raise ValueError(mixer_kind)


class BlockOut(NamedTuple):
    x: jax.Array
    cache: Any            # updated cache (decode) or None
    aux_loss: jax.Array   # MoE load-balance contribution


def block_apply(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
                mixer_kind: str, mlp_kind: str, positions: jax.Array,
                cache=None, n_groups: int = 1) -> BlockOut:
    aux = jnp.zeros((), jnp.float32)
    h = cm.rms_norm(x, params["ln1_scale"], cfg.norm_eps)

    rwkv_new = None
    if mixer_kind in (cm.MIXER_FULL, cm.MIXER_SWA, cm.MIXER_GLOBAL):
        y, new_cache = attn.attention_mixer(params["mixer"], h, cfg,
                                            kind=mixer_kind,
                                            positions=positions, cache=cache)
    elif mixer_kind == cm.MIXER_MLA:
        y, new_cache = mla_mod.mla_mixer(params["mixer"], h, cfg,
                                         positions=positions, cache=cache)
    elif mixer_kind == cm.MIXER_MAMBA:
        y, new_cache = mamba_mod.mamba_mixer(params["mixer"], h, cfg,
                                             cache=cache)
    elif mixer_kind == cm.MIXER_RWKV6:
        y, (state, tm_prev) = rwkv_mod.rwkv_time_mix(params["mixer"], h, cfg,
                                                     cache=cache)
        rwkv_new = (state, tm_prev)
        new_cache = cache
    else:
        raise ValueError(mixer_kind)
    x = x + y

    h = cm.rms_norm(x, params["ln2_scale"], cfg.norm_eps)
    if mixer_kind == cm.MIXER_RWKV6:
        y, cm_prev = rwkv_mod.rwkv_channel_mix(params["mlp"], h, cfg,
                                               cache=cache)
        state, tm_prev = rwkv_new
        new_cache = None if cache is None else rwkv_mod.RWKVCache(
            tm_prev=tm_prev, cm_prev=cm_prev, state=state)
    elif mlp_kind == cm.MLP_MOE:
        y, stats = moe_mod.moe_apply(params["mlp"], h, cfg,
                                     n_groups=max(n_groups, cfg.moe_groups))
        aux = stats.aux_loss
    else:
        y = mlp_mod.mlp_apply(params["mlp"], h, cfg)
    x = x + y
    return BlockOut(x=x, cache=new_cache, aux_loss=aux)
