from repro.models.common import ArchConfig, MLAConfig, MambaConfig, MoEConfig, RWKVConfig
from repro.models.api import ModelAPI, model_api
