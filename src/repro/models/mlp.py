"""Dense gated-linear-unit MLPs (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def mlp_param_specs(cfg: cm.ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "wg": cm.spec((d, f), cfg.dtype),
        "wu": cm.spec((d, f), cfg.dtype),
        "wd": cm.spec((f, d), cfg.dtype),
    }


def mlp_apply(params: dict, x: jax.Array, cfg: cm.ArchConfig) -> jax.Array:
    act = cm.act_fn(cfg.act)
    h = act(x @ params["wg"]) * (x @ params["wu"])
    return h @ params["wd"]
