"""DeepSeek Multi-head Latent Attention (V2/V3).

Two decode paths:
  * naive  — expand K/V from the latent cache every step (paper-faithful
    baseline for the serving roofline).
  * absorb — fold W_UK into the query and W_UV into the output projection so
    decode scores directly against the [T, kv_lora + rope] latent cache.
    This is the beyond-paper serving optimization exercised in §Perf.

Cache stores only (c_kv [B,T,kv_lora], k_rope [B,T,qk_rope]) — the MLA memory
win that makes deepseek decode shapes feasible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.attention import blocked_attention, NEG_INF


def mla_param_specs(cfg: cm.ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim
    p = {}
    if m.q_lora_rank:
        p["wq_down"] = cm.spec((d, m.q_lora_rank), cfg.dtype)
        p["q_ln_scale"] = cm.spec((m.q_lora_rank,), cfg.dtype)
        p["wq_up"] = cm.spec((m.q_lora_rank, h * (qk + m.qk_rope_head_dim)), cfg.dtype)
    else:
        p["wq"] = cm.spec((d, h * (qk + m.qk_rope_head_dim)), cfg.dtype)
    p["wkv_down"] = cm.spec((d, m.kv_lora_rank + m.qk_rope_head_dim), cfg.dtype)
    p["kv_ln_scale"] = cm.spec((m.kv_lora_rank,), cfg.dtype)
    p["wk_up"] = cm.spec((m.kv_lora_rank, h * qk), cfg.dtype)
    p["wv_up"] = cm.spec((m.kv_lora_rank, h * m.v_head_dim), cfg.dtype)
    p["wo"] = cm.spec((h * m.v_head_dim, d), cfg.dtype)
    return p


class MLACache(NamedTuple):
    c_kv: jax.Array       # [B, T, kv_lora]
    k_rope: jax.Array     # [B, T, qk_rope]
    length: jax.Array


def mla_cache_specs(cfg: cm.ArchConfig, batch: int, max_len: int) -> MLACache:
    m = cfg.mla
    return MLACache(c_kv=cm.spec((batch, max_len, m.kv_lora_rank), cfg.dtype),
                    k_rope=cm.spec((batch, max_len, m.qk_rope_head_dim), cfg.dtype),
                    length=cm.spec((), jnp.int32))


def init_mla_cache(cfg: cm.ArchConfig, batch: int, max_len: int) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), cfg.dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), cfg.dtype),
        length=jnp.zeros((), jnp.int32))


def _queries(params, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    h, qk, qr = cfg.n_heads, m.qk_nope_head_dim, m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = cm.rms_norm(x @ params["wq_down"], params["q_ln_scale"], cfg.norm_eps)
        q = (cq @ params["wq_up"]).reshape(B, S, h, qk + qr)
    else:
        q = (x @ params["wq"]).reshape(B, S, h, qk + qr)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, x, cfg, positions):
    m = cfg.mla
    ckr = x @ params["wkv_down"]
    c_kv = cm.rms_norm(ckr[..., :m.kv_lora_rank], params["kv_ln_scale"],
                       cfg.norm_eps)
    k_rope = ckr[..., m.kv_lora_rank:]
    # shared (MQA-style) rope key: one head broadcast to all query heads
    k_rope = cm.apply_rope(k_rope[:, :, None, :], positions,
                           cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_mixer(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
              positions: jax.Array, cache: MLACache | None = None):
    """Prefill (cache None) or single-token decode. Returns (y, new_cache)."""
    m = cfg.mla
    B, S, _ = x.shape
    h, qk, qr, dv = cfg.n_heads, m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_new, kr_new = _latents(params, x, cfg, positions)

    if cache is None or S > 1:
        # prefill: expand K/V, run blocked attention with per-head keys
        k_nope = (c_new @ params["wk_up"]).reshape(B, S, h, qk)
        v = (c_new @ params["wv_up"]).reshape(B, S, h, dv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_new[:, :, None, :], (B, S, h, qr))],
            axis=-1)
        o = blocked_attention(q, k, v, causal=True, q_chunk=cfg.attn_chunk,
                              prune=cfg.prune_tiles)
        y = o.reshape(B, S, h * dv) @ params["wo"]
        if cache is None:
            return y, None
        T = cache.c_kv.shape[1]
        pad2 = ((0, 0), (0, T - S), (0, 0))
        new_cache = MLACache(
            c_kv=jnp.pad(c_new, pad2).astype(cache.c_kv.dtype),
            k_rope=jnp.pad(kr_new, pad2).astype(cache.k_rope.dtype),
            length=jnp.asarray(S, jnp.int32))
        return y, new_cache

    T = cache.c_kv.shape[1]
    slot = jnp.minimum(cache.length, T - 1)
    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), (0, slot, 0))
    new_len = cache.length + 1
    valid = jnp.arange(T) < new_len
    scale = (qk + qr) ** -0.5

    f32 = jnp.float32
    if m.absorb:
        # fold W_UK into q: q_lat[b,h,r] = sum_d q_nope[b,h,d] * W_UK[r, h, d]
        wk = params["wk_up"].reshape(m.kv_lora_rank, h, qk)
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk,
                           preferred_element_type=f32).astype(c_kv.dtype)
        s = (jnp.einsum("bhr,btr->bht", q_lat, c_kv,
                        preferred_element_type=f32) +
             jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(k_rope.dtype),
                        k_rope, preferred_element_type=f32)) * scale
        s = jnp.where(valid[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bht,btr->bhr", p.astype(c_kv.dtype), c_kv,
                           preferred_element_type=f32)
        wv = params["wv_up"].reshape(m.kv_lora_rank, h, dv)
        o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(wv.dtype), wv,
                       preferred_element_type=f32)
    else:
        # naive: re-expand all K/V from latents every step
        k_nope = (c_kv @ params["wk_up"]).reshape(B, T, h, qk)
        v = (c_kv @ params["wv_up"]).reshape(B, T, h, dv)
        s = (jnp.einsum("bhd,bthd->bht", q_nope[:, 0], k_nope,
                        preferred_element_type=f32) +
             jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(k_rope.dtype),
                        k_rope, preferred_element_type=f32)) * scale
        s = jnp.where(valid[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bht,bthd->bhd", p.astype(v.dtype), v,
                       preferred_element_type=f32)

    y = o.reshape(B, 1, h * dv).astype(x.dtype) @ params["wo"]
    return y, MLACache(c_kv, k_rope, new_len)
