"""Attention mixers: GQA full/sliding-window, gemma2 softcap, decode w/ KV cache.

The train/prefill path is a flash-style two-level blocked softmax written in
pure jnp (lax.scan over KV blocks with running max/sum) so that the lowered
program never materializes an [S, S] score matrix — this is what keeps the
32k-prefill dry-run memory sane and is the jnp oracle for the Pallas kernel
in ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_param_specs(cfg: cm.ArchConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": cm.spec((d, h * dh), cfg.dtype),
        "wk": cm.spec((d, kv * dh), cfg.dtype),
        "wv": cm.spec((d, kv * dh), cfg.dtype),
        "wo": cm.spec((h * dh, d), cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = cm.spec((dh,), cfg.dtype)
        p["k_scale"] = cm.spec((dh,), cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# Core blocked attention (prefill / training)
# ---------------------------------------------------------------------------

def _tile_scores(q, k, scale, softcap_val):
    # q: [B, Cq, K, G, dh]  k: [B, Ck, K, dh] -> s: [B, K, G, Cq, Ck]
    # fp32 accumulation via preferred_element_type (no operand up-cast: the
    # cast would materialize a 2x copy of the KV cache in HBM)
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap_val:
        s = cm.softcap(s, softcap_val)
    return s


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      softcap_val: float = 0.0, q_offset: int = 0,
                      kv_len: jax.Array | None = None,
                      q_chunk: int = 1024, k_chunk: int = 1024,
                      prune: bool = False):
    """q: [B,S,H,dh]; k,v: [B,T,Kv,dh]. window>0 => sliding-window causal.

    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``kv_len``: optional dynamic number of valid kv entries (decode cache).
    ``prune``: skip KV tiles that the causal/window mask would fully zero
    (beyond-paper §Perf optimization — the baseline sweeps every tile).
    Returns [B,S,H,dh].
    """
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // K
    scale = dh ** -0.5
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, T)
    pad_q = (-S) % q_chunk
    pad_k = (-T) % k_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k
    nq, nk = Sp // q_chunk, Tp // k_chunk
    qb = q.reshape(B, nq, q_chunk, K, G, dh)
    kb = k.reshape(B, nk, k_chunk, K, dh)
    vb = v.reshape(B, nk, k_chunk, K, dv)
    valid_t = jnp.asarray(T if kv_len is None else kv_len, jnp.int32)

    def q_block(qi, qtile):
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def tile_update(carry, ki, ktile, vtile):
            m, l, acc = carry
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            s = _tile_scores(qtile, ktile, scale, softcap_val)
            mask = kpos[None, :] < valid_t
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vtile.dtype), vtile,
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, dv), jnp.float32)

        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, inp: (tile_update(c, *inp), None), (m0, l0, a0),
            (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # [B,K,G,Cq,dh] -> [B,Cq,K,G,dh]
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    def q_block_pruned(qi: int):
        """Static causal/window KV band for q block ``qi`` (differentiable:
        bounds are trace-time constants, unlike a dynamic fori_loop)."""
        qtile = qb[:, qi]
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        hi = nk if not causal else min(
            (q_offset + (qi + 1) * q_chunk + k_chunk - 1) // k_chunk, nk)
        lo = 0 if not window else max(
            (q_offset + qi * q_chunk - window + 1) // k_chunk, 0)
        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, dv), jnp.float32)

        def tile_update(carry, ki, ktile, vtile):
            m, l, acc = carry
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            s = _tile_scores(qtile, ktile, scale, softcap_val)
            mask = kpos[None, :] < valid_t
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vtile.dtype), vtile,
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        ks = jnp.arange(lo, hi)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, inp: (tile_update(c, *inp), None), (m0, l0, a0),
            (ks, jnp.moveaxis(kb[:, lo:hi], 1, 0),
             jnp.moveaxis(vb[:, lo:hi], 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    if prune:
        out = jnp.stack([q_block_pruned(i) for i in range(nq)], axis=1)
    else:
        out = jax.lax.map(lambda args: q_block(*args),
                          (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
        out = jnp.moveaxis(out, 0, 1)
    out = out.reshape(B, Sp, K, G, dv)[:, :S]
    return out.reshape(B, S, H, dv).astype(v.dtype)


def decode_attention(q, k, v, *, cache_len, window: int = 0,
                     softcap_val: float = 0.0, ring: bool = False):
    """Single-position decode. q: [B,1,H,dh]; k,v: [B,T,Kv,dh] cache.

    ``cache_len``: number of valid entries *including* the token just written.
    ``ring``: cache is a ring buffer (sliding window) — all T slots valid once
    cache_len >= T; entry ages handled by the window mask being implicit.
    """
    B, _, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // K
    scale = dh ** -0.5
    qh = q.reshape(B, 1, K, G, dh)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qh, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap_val:
        s = cm.softcap(s, softcap_val)
    tpos = jnp.arange(T)
    if ring:
        valid = (tpos < cache_len)  # ring: all < min(cache_len, T) valid
    else:
        valid = tpos < cache_len
        if window:
            valid &= (cache_len - 1 - tpos) < window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dv).astype(v.dtype)


# ---------------------------------------------------------------------------
# Full mixer: projections + rope + attention (+cache plumbing)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # [B, T, Kv, dh]  (bf16, or int8 when quantized)
    v: jax.Array
    length: jax.Array     # [] int32 — entries written so far
    k_scale: jax.Array | None = None   # [B, T, Kv, 1] f32 (int8 mode)
    v_scale: jax.Array | None = None


def _cache_layout(cfg, batch, T):
    shape = (batch, T, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_cache_dtype == "int8":
        return shape, jnp.int8, (batch, T, cfg.n_kv_heads, 1)
    return shape, cfg.dtype, None


def init_kv_cache(cfg: cm.ArchConfig, batch: int, max_len: int,
                  *, window: bool = False) -> KVCache:
    T = min(max_len, cfg.sliding_window) if window else max_len
    shape, dt, sshape = _cache_layout(cfg, batch, T)
    sc = None if sshape is None else jnp.zeros(sshape, jnp.float32)
    return KVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                   length=jnp.zeros((), jnp.int32), k_scale=sc,
                   v_scale=None if sshape is None else jnp.zeros(
                       sshape, jnp.float32))


def kv_cache_specs(cfg: cm.ArchConfig, batch: int, max_len: int,
                   *, window: bool = False) -> KVCache:
    T = min(max_len, cfg.sliding_window) if window else max_len
    shape, dt, sshape = _cache_layout(cfg, batch, T)
    sc = None if sshape is None else cm.spec(sshape, jnp.float32)
    return KVCache(k=cm.spec(shape, dt), v=cm.spec(shape, dt),
                   length=cm.spec((), jnp.int32), k_scale=sc,
                   v_scale=None if sshape is None else cm.spec(sshape,
                                                               jnp.float32))


def _quantize_kv(x):
    """[B,S,K,dh] -> (int8 values, [B,S,K,1] f32 scales)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    return q.astype(jnp.int8), s


def _dequantize_kv(q, s, dtype):
    return (q.astype(jnp.float32) * s).astype(dtype)


def attention_mixer(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
                    kind: str, positions: jax.Array,
                    cache: KVCache | None = None):
    """x: [B,S,D]. Returns (y, new_cache). Prefill when cache is None."""
    B, S, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ params["wq"]).reshape(B, S, H, dh)
    k = (x @ params["wk"]).reshape(B, S, K, dh)
    v = (x @ params["wv"]).reshape(B, S, K, dh)
    if cfg.qk_norm:
        q = cm.rms_norm(q, params["q_scale"], cfg.norm_eps)
        k = cm.rms_norm(k, params["k_scale"], cfg.norm_eps)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window if kind == cm.MIXER_SWA else 0
    cap = cfg.attn_logit_softcap

    if cache is None:
        o = blocked_attention(q, k, v, causal=True, window=window,
                              softcap_val=cap, q_chunk=cfg.attn_chunk,
                              prune=cfg.prune_tiles)
        new_cache = None
    elif S > 1:
        # prefill-fill: run blocked attention, then write k/v into the cache
        o = blocked_attention(q, k, v, causal=True, window=window,
                              softcap_val=cap, q_chunk=cfg.attn_chunk,
                              prune=cfg.prune_tiles)
        T = cache.k.shape[1]
        int8 = cfg.kv_cache_dtype == "int8"
        if int8:
            k, ks = _quantize_kv(k)
            v, vs = _quantize_kv(v)

        def place(x, like_dtype):
            if window and T == window and S >= window:
                # ring cache: keep last `window` entries at slot p % window
                shift = (S - window) % window
                return jnp.roll(x[:, -window:], shift, axis=1).astype(
                    like_dtype)
            pad = ((0, 0), (0, T - S)) + ((0, 0),) * (x.ndim - 2)
            return jnp.pad(x, pad).astype(like_dtype)

        new_cache = KVCache(
            k=place(k, cache.k.dtype), v=place(v, cache.v.dtype),
            length=jnp.asarray(S, jnp.int32),
            k_scale=place(ks, jnp.float32) if int8 else None,
            v_scale=place(vs, jnp.float32) if int8 else None)
    else:
        # decode: S == 1; write into the cache then attend.
        T = cache.k.shape[1]
        is_ring = window > 0 and T == window
        int8 = cfg.kv_cache_dtype == "int8"
        slot = (cache.length % T) if is_ring else jnp.minimum(cache.length, T - 1)
        if int8:
            k, ks = _quantize_kv(k)
            v, vs = _quantize_kv(v)
            ksc = jax.lax.dynamic_update_slice(cache.k_scale, ks,
                                               (0, slot, 0, 0))
            vsc = jax.lax.dynamic_update_slice(cache.v_scale, vs,
                                               (0, slot, 0, 0))
        kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, slot, 0, 0))
        new_len = cache.length + 1
        k_read = _dequantize_kv(kc, ksc, cfg.dtype) if int8 else kc
        v_read = _dequantize_kv(vc, vsc, cfg.dtype) if int8 else vc
        o = decode_attention(q, k_read, v_read, cache_len=new_len,
                             window=window, softcap_val=cap, ring=is_ring)
        new_cache = KVCache(kc, vc, new_len,
                            k_scale=ksc if int8 else None,
                            v_scale=vsc if int8 else None)

    y = o.reshape(B, S, H * dh) @ params["wo"]
    return y, new_cache
