"""RWKV-6 "Finch" time mixing + channel mixing (attention-free).

TPU adaptation: the reference CUDA wkv6 kernel runs the per-head recurrence
   S_t = diag(w_t) S_{t-1} + k_t^T v_t,   y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
one thread per channel.  Here it is re-expressed in the chunked linear-
attention form (GLA-style): an outer ``lax.scan`` over time chunks carries the
[h, dk, dv] state; within a chunk everything is matmuls with all decay
exponents of the form exp(L_a - L_b), a >= b (cumulative log-decay L is
non-increasing), so every exponent is <= 0 — numerically safe without the
CUDA kernel's rescaling passes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm

# token-shift targets for time mixing
_TM_SLOTS = 5   # r, k, v, w, g


def _dims(cfg: cm.ArchConfig):
    rw = cfg.rwkv
    n_heads = cfg.d_model // rw.head_dim
    return n_heads, rw.head_dim


def rwkv_tm_param_specs(cfg: cm.ArchConfig) -> dict:
    d = cfg.d_model
    rw = cfg.rwkv
    h, dh = _dims(cfg)
    return {
        "mix_base/mix_mu": cm.spec((d,), jnp.float32),
        "mix/mix_mu": cm.spec((_TM_SLOTS, d), jnp.float32),
        "mix_w1": cm.spec((d, _TM_SLOTS * rw.mix_lora), cfg.dtype),
        "mix_w2": cm.spec((_TM_SLOTS, rw.mix_lora, d), cfg.dtype),
        "wr": cm.spec((d, d), cfg.dtype),
        "wk": cm.spec((d, d), cfg.dtype),
        "wv": cm.spec((d, d), cfg.dtype),
        "wg": cm.spec((d, d), cfg.dtype),
        "decay_base": cm.spec((d,), jnp.float32),
        "decay_w1": cm.spec((d, rw.decay_lora), cfg.dtype),
        "decay_w2": cm.spec((rw.decay_lora, d), cfg.dtype),
        "bonus_u": cm.spec((h, dh), jnp.float32),
        "ln_x_scale": cm.spec((d,), cfg.dtype),
        "wo": cm.spec((d, d), cfg.dtype),
    }


def rwkv_cm_param_specs(cfg: cm.ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "cmix_k/mix_mu": cm.spec((d,), jnp.float32),
        "cmix_r/mix_mu": cm.spec((d,), jnp.float32),
        "wk": cm.spec((d, f), cfg.dtype),
        "wv": cm.spec((f, d), cfg.dtype),
        "wr": cm.spec((d, d), cfg.dtype),
    }


class RWKVCache(NamedTuple):
    tm_prev: jax.Array    # [B, d] last input to time mixing
    cm_prev: jax.Array    # [B, d] last input to channel mixing
    state: jax.Array      # [B, h, dk, dv] fp32 wkv state


def rwkv_cache_specs(cfg: cm.ArchConfig, batch: int) -> RWKVCache:
    d = cfg.d_model
    h, dh = _dims(cfg)
    return RWKVCache(tm_prev=cm.spec((batch, d), cfg.dtype),
                     cm_prev=cm.spec((batch, d), cfg.dtype),
                     state=cm.spec((batch, h, dh, dh), jnp.float32))


def init_rwkv_cache(cfg: cm.ArchConfig, batch: int) -> RWKVCache:
    d = cfg.d_model
    h, dh = _dims(cfg)
    return RWKVCache(tm_prev=jnp.zeros((batch, d), cfg.dtype),
                     cm_prev=jnp.zeros((batch, d), cfg.dtype),
                     state=jnp.zeros((batch, h, dh, dh), jnp.float32))


def _token_shift(x, prev):
    """returns x_{t-1} sequence given carried prev: [B,S,d], [B,d]."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(params, x, x_prev):
    """Data-dependent token-shift interpolation -> per-slot mixed inputs."""
    xx = x_prev - x
    base = x + xx * params["mix_base/mix_mu"].astype(x.dtype)
    lora = jnp.tanh(base @ params["mix_w1"])
    B, S, _ = x.shape
    lora = lora.reshape(B, S, _TM_SLOTS, -1)
    offs = jnp.einsum("bsli,lid->bsld", lora, params["mix_w2"])
    mus = params["mix/mix_mu"].astype(x.dtype)[None, None] + offs
    return x[:, :, None] + xx[:, :, None] * mus          # [B,S,5,d]


def _wkv_chunk(carry, inp):
    """One chunk of the wkv recurrence. carry S: [B,h,dk,dv] fp32.
    inp r,k,v: [B,C,h,dh]; lw: [B,C,h,dh] log-decay (<=0); u: [h,dh]."""
    S = carry
    r, k, v, lw, u = inp
    L = jnp.cumsum(lw, axis=1)                            # [B,C,h,dk]
    # intra-chunk: A[t,j] = sum_i r[t,i] k[j,i] exp(L[t-1,i] - L[j,i]), j < t.
    # All exponents are differences L_a - L_b with a >= b, hence <= 0: safe.
    r_s = r * jnp.exp(L - lw)                             # r_t exp(L_{t-1})
    Lm1 = L - lw
    # diff[t,j,i] = Lm1[t,i] - L[j,i]  (<= 0 for j <= t-1)
    diff = Lm1[:, :, None] - L[:, None]                  # [B,C,C,h,dk]
    C_ = r.shape[1]
    causal = jnp.tril(jnp.ones((C_, C_), bool), k=-1)
    diff = jnp.where(causal[None, :, :, None, None], diff, -jnp.inf)
    scores = jnp.einsum("bthi,bjhi,btjhi->bhtj", r, k, jnp.exp(diff))
    y = jnp.einsum("bhtj,bjhd->bthd", scores, v)
    # bonus (current token, diagonal u term)
    y += jnp.einsum("bthi,hi,bthi,bthd->bthd", r, u, k, v)
    # inter-chunk: r_t exp(L_{t-1}) @ S_0
    y += jnp.einsum("bthi,bhid->bthd", r_s, S)
    # state update: S_C = exp(L_C) S_0 + sum_j (k_j exp(L_C - L_j)) v_j
    LC = L[:, -1]                                         # [B,h,dk]
    S_new = jnp.exp(LC)[..., None] * S + jnp.einsum(
        "bjhi,bjhd->bhid", k * jnp.exp(LC[:, None] - L), v)
    return S_new, y


def rwkv_time_mix(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
                  cache: RWKVCache | None = None):
    B, S, d = x.shape
    h, dh = _dims(cfg)
    prev = cache.tm_prev if cache is not None else jnp.zeros((B, d), x.dtype)
    x_prev = _token_shift(x, prev)
    xm = _ddlerp(params, x, x_prev)                      # [B,S,5,d]
    xr, xk, xv, xw, xg = (xm[:, :, i] for i in range(_TM_SLOTS))
    r = (xr @ params["wr"]).reshape(B, S, h, dh).astype(jnp.float32)
    k = (xk @ params["wk"]).reshape(B, S, h, dh).astype(jnp.float32)
    v = (xv @ params["wv"]).reshape(B, S, h, dh).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["wg"])
    dec = params["decay_base"] + (
        jnp.tanh(xw @ params["decay_w1"]) @ params["decay_w2"]).astype(jnp.float32)
    lw = -jnp.exp(dec).reshape(B, S, h, dh)              # log-decay, < 0
    u = params["bonus_u"]

    if cache is None or S > 1:
        Cn = min(cfg.rwkv.chunk, S)
        pad = (-S) % Cn
        if pad:
            r, k, v, lw = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                           for t in (r, k, v, lw))
        n_chunks = (S + pad) // Cn
        def split(t):
            return jnp.moveaxis(t.reshape(B, n_chunks, Cn, h, dh), 1, 0)
        S0 = jnp.zeros((B, h, dh, dh), jnp.float32) if cache is None \
            else cache.state
        S_last, ys = jax.lax.scan(
            lambda c, i: _wkv_chunk(c, (*i, u)), S0,
            (split(r), split(k), split(v), split(lw)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S + pad, h, dh)[:, :S]
        new_state, new_prev = S_last, x[:, -1]
    else:
        S0 = cache.state
        y = jnp.einsum("bhi,hi,bhi,bhd->bhd", r[:, 0], u, k[:, 0], v[:, 0])
        y += jnp.einsum("bhi,bhid->bhd", r[:, 0], S0)
        y = y[:, None]
        new_state = jnp.exp(lw[:, 0])[..., None] * S0 + \
            jnp.einsum("bhi,bhd->bhid", k[:, 0], v[:, 0])
        new_prev = x[:, 0]

    # per-head normalization (stands in for the reference GroupNorm ln_x)
    y = y.reshape(B, -1, h, dh)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + 1e-5)
    y = y.reshape(B, -1, d).astype(x.dtype)
    y = y * (1.0 + params["ln_x_scale"]) * g
    out = y @ params["wo"]
    return out, (new_state, new_prev)


def rwkv_channel_mix(params: dict, x: jax.Array, cfg: cm.ArchConfig, *,
                     cache: RWKVCache | None = None):
    B, S, d = x.shape
    prev = cache.cm_prev if cache is not None else jnp.zeros((B, d), x.dtype)
    x_prev = _token_shift(x, prev)
    xx = x_prev - x
    xk = x + xx * params["cmix_k/mix_mu"].astype(x.dtype)
    xr = x + xx * params["cmix_r/mix_mu"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    kv = k @ params["wv"]
    return jax.nn.sigmoid(xr @ params["wr"]) * kv, x[:, -1]
