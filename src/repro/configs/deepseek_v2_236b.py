"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff(expert)=1536 vocab=102400.
First layer dense (HF first_k_dense_replace=1, d_ff 12288)."""
from repro.configs.base import register
from repro.models import common as cm


@register("deepseek-v2-236b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="deepseek-v2-236b",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_head=192,
        d_ff=1536,
        vocab_size=102400,
        mixers=(cm.MIXER_MLA,),
        mlps=(cm.MLP_MOE,),
        n_dense_prefix=1,
        d_ff_dense_prefix=12288,
        mla=cm.MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                         qk_nope_head_dim=128, qk_rope_head_dim=64,
                         v_head_dim=128),
        moe=cm.MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
        rope_theta=10000.0,
        tie_embeddings=False,
    )
