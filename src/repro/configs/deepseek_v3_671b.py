"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8.
[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
First 3 layers use a dense MLP (HF first_k_dense_replace=3, d_ff 18432)."""
from repro.configs.base import register
from repro.models import common as cm


@register("deepseek-v3-671b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="deepseek-v3-671b",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_head=192,                      # qk_nope(128) + qk_rope(64)
        d_ff=2048,
        vocab_size=129280,
        mixers=(cm.MIXER_MLA,),
        mlps=(cm.MLP_MOE,),
        n_dense_prefix=3,
        d_ff_dense_prefix=18432,
        mla=cm.MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                         qk_nope_head_dim=128, qk_rope_head_dim=64,
                         v_head_dim=128),
        moe=cm.MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
        rope_theta=10000.0,
        tie_embeddings=False,
    )
