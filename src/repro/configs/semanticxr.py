"""SemanticXR's own server-side model configs.

The paper composes off-the-shelf perception models (RAM, GroundingDINO,
MobileSAM, MobileCLIP).  Here the equivalents are built from the repro model
zoo: a ~110M captioner LM (the end-to-end training example target) and the
CLIP-like two-tower embedder defined in repro.perception.
"""
from repro.configs.base import register
from repro.models import common as cm


@register("semanticxr-captioner-110m")
def captioner() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="semanticxr-captioner-110m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_head=64,
        d_ff=2048,
        vocab_size=32000,
        rope_theta=10000.0,
        tie_embeddings=True,
        remat=False,
    )
