"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend stub
(precomputed patch embeddings). [hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064."""
from repro.configs.base import register
from repro.models import common as cm


@register("phi-3-vision-4.2b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="phi-3-vision-4.2b",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab_size=32064,
        frontend="vision",
        n_frontend_tokens=576,           # CLIP ViT-L/14 @336px patch tokens
        rope_theta=10000.0,
        tie_embeddings=False,
    )
