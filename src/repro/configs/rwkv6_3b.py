"""rwkv6-3b "Finch" [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536."""
from repro.configs.base import register
from repro.models import common as cm


@register("rwkv6-3b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="rwkv6-3b",
        n_layers=32,
        d_model=2560,
        n_heads=40,                      # d_model / head_dim(64)
        n_kv_heads=40,
        d_head=64,
        d_ff=8960,
        vocab_size=65536,
        mixers=(cm.MIXER_RWKV6,),
        rwkv=cm.RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, chunk=64),
        tie_embeddings=False,
    )
