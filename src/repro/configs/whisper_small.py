"""whisper-small [audio] — enc-dec; conv frontend is a stub (precomputed
frame embeddings). [arXiv:2212.04356; unverified]
12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865."""
from repro.configs.base import register
from repro.models import common as cm


@register("whisper-small")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="whisper-small",
        n_layers=12,                     # decoder
        n_enc_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_head=64,
        d_ff=3072,
        vocab_size=51865,
        encdec=True,
        frontend="audio",
        enc_seq=1500,
        act="gelu",
        tie_embeddings=True,
    )
