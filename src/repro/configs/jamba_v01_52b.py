"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE every other layer.
[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2."""
from repro.configs.base import register
from repro.models import common as cm

_M = cm.MIXER_MAMBA
_A = cm.MIXER_FULL


@register("jamba-v0.1-52b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="jamba-v0.1-52b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=65536,
        # 8-layer jamba block: attention at index 4, mamba elsewhere;
        # MoE replaces the dense MLP on every other layer.
        mixers=(_M, _M, _M, _M, _A, _M, _M, _M),
        mlps=(cm.MLP_DENSE, cm.MLP_MOE),
        moe=cm.MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, n_shared=0),
        mamba=cm.MambaConfig(d_state=16, d_conv=4, expand=2, chunk=32),
        rope_theta=10000.0,
        tie_embeddings=False,
    )
