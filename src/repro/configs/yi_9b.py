"""yi-9b [dense] — llama-arch GQA. [arXiv:2403.04652; hf]
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from repro.configs.base import register
from repro.models import common as cm


@register("yi-9b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="yi-9b",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        tie_embeddings=False,
    )
