"""Assigned architecture configs. Importing this package registers them."""
from repro.configs.base import (SHAPES, ShapeCell, cell_is_runnable,
                                get_config, input_specs, list_configs,
                                make_inputs, smoke_config, SMOKE_CELL)
from repro.configs import (jamba_v01_52b, minitron_4b, gemma2_27b, yi_9b,
                           h2o_danube3_4b, deepseek_v3_671b, deepseek_v2_236b,
                           whisper_small, phi3_vision_4b, rwkv6_3b,
                           semanticxr)

ASSIGNED = [
    "jamba-v0.1-52b", "minitron-4b", "gemma2-27b", "yi-9b",
    "h2o-danube-3-4b", "deepseek-v3-671b", "deepseek-v2-236b",
    "whisper-small", "phi-3-vision-4.2b", "rwkv6-3b",
]
