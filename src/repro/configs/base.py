"""Config registry, the assigned shape cells, input specs, and smoke shrink."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import common as cm

_REGISTRY: dict[str, Callable[[], cm.ArchConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> cm.ArchConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if name.endswith("-smoke"):
        return smoke_config(get_config(name[:-len("-smoke")]))
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Assigned shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs whose attention is quadratic-full everywhere -> skip long_500k
FULL_ATTENTION_ONLY = {
    "minitron-4b", "yi-9b", "deepseek-v3-671b", "deepseek-v2-236b",
    "phi-3-vision-4.2b", "whisper-small",
}


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in FULL_ATTENTION_ONLY:
        return False
    return True


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: cm.ArchConfig, cell: ShapeCell) -> dict:
    """Model inputs for one shape cell, as ShapeDtypeStructs."""
    B, S = cell.global_batch, cell.seq_len
    if cfg.encdec:
        if cell.kind == "train":
            return {"frames": cm.spec((B, S, cfg.d_model), jnp.float32),
                    "tokens": cm.spec((B, 448), jnp.int32)}
        if cell.kind == "prefill":
            return {"frames": cm.spec((B, S, cfg.d_model), jnp.float32)}
        return {"tokens": cm.spec((B, 1), jnp.int32)}

    if cfg.frontend == "vision":
        n_vis = min(cfg.n_frontend_tokens, S // 2)
        if cell.kind == "train":
            return {"tokens": cm.spec((B, S - n_vis), jnp.int32),
                    "extra_embeds": cm.spec((B, n_vis, cfg.d_model),
                                            jnp.float32)}
        if cell.kind == "prefill":
            return {"tokens": cm.spec((B, S - n_vis), jnp.int32),
                    "extra_embeds": cm.spec((B, n_vis, cfg.d_model),
                                            jnp.float32)}
        return {"tokens": cm.spec((B, 1), jnp.int32)}

    if cell.kind in ("train", "prefill"):
        return {"tokens": cm.spec((B, S), jnp.int32)}
    return {"tokens": cm.spec((B, 1), jnp.int32)}


def make_inputs(cfg: cm.ArchConfig, cell: ShapeCell, key: jax.Array) -> dict:
    """Concrete random inputs matching input_specs (smoke tests, examples)."""
    specs = input_specs(cfg, cell)
    out = {}
    for k, sp in specs.items():
        key, sub = jax.random.split(key)
        if sp.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, sp.shape, 0, cfg.vocab_size,
                                        jnp.int32)
        else:
            out[k] = jax.random.normal(sub, sp.shape, sp.dtype)
    return out


# ---------------------------------------------------------------------------
# Smoke shrink: same family, tiny dims, runs a step on CPU
# ---------------------------------------------------------------------------

def smoke_config(cfg: cm.ArchConfig) -> cm.ArchConfig:
    period = cfg.period
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=cfg.n_dense_prefix + period,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256,
        d_ff_dense_prefix=256 if cfg.n_dense_prefix else 0,
        vocab_size=512,
        sliding_window=32,
        attn_chunk=64,
        scan_layers=True,
        remat=False,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4,
                                        top_k=min(cfg.moe.top_k, 2),
                                        d_ff_expert=64)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=(64 if cfg.mla.q_lora_rank else 0),
            kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16,
            v_head_dim=32)
        kw["d_head"] = 48  # nope + rope
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=8, chunk=16)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32, decay_lora=8,
                                         mix_lora=8, chunk=16)
        kw["n_heads"] = 4
        kw["d_head"] = 32
    if cfg.encdec:
        kw["n_layers"] = 2
        kw["n_enc_layers"] = 2
        kw["enc_seq"] = 32
    if cfg.frontend == "vision":
        kw["n_frontend_tokens"] = 8
    return cfg.replace(**kw)


SMOKE_CELL = ShapeCell("smoke", 64, 2, "train")
