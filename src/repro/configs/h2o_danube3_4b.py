"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000."""
from repro.configs.base import register
from repro.models import common as cm


@register("h2o-danube-3-4b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="h2o-danube-3-4b",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_head=120,
        d_ff=10240,
        vocab_size=32000,
        mixers=(cm.MIXER_SWA,),
        sliding_window=4096,
        rope_theta=10000.0,
        tie_embeddings=True,
    )
