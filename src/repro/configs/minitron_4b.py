"""minitron-4b [dense] — pruned nemotron. [arXiv:2407.14679; hf]
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000."""
from repro.configs.base import register
from repro.models import common as cm


@register("minitron-4b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="minitron-4b",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_head=128,
        d_ff=9216,
        vocab_size=256000,
        act="relu2",                     # nemotron squared-ReLU
        rope_theta=10000.0,
        tie_embeddings=False,
    )
