"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]  46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000."""
from repro.configs.base import register
from repro.models import common as cm


@register("gemma2-27b")
def config() -> cm.ArchConfig:
    return cm.ArchConfig(
        name="gemma2-27b",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_head=128,
        d_ff=36864,
        vocab_size=256000,
        mixers=(cm.MIXER_SWA, cm.MIXER_GLOBAL),
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        rope_theta=10000.0,
        tie_embeddings=True,
        act="gelu",
    )
