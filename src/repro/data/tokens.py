"""Token pipeline for LM training examples: templated scene captions.

The captioner in SemanticXR's perception stack describes objects ("a red
chair near the wooden table").  Training data is generated from the same
class vocabulary as the scene generator, giving a small closed world where a
~100M model's loss drops fast enough to validate the training loop in
minutes on CPU.
"""
from __future__ import annotations

import numpy as np

from repro.data.scenes import CLASS_NAMES

_ADJ = ["red", "blue", "green", "small", "large", "wooden", "metal", "old",
        "new", "round"]
_REL = ["near", "under", "above", "beside", "behind", "facing"]
_TMPL = ["a {a} {c1} {r} the {c2}", "the {c1} is {r} the {a} {c2}",
         "there is a {a} {c1} {r} the {c2}", "find the {a} {c1}"]

PAD, BOS = 0, 1
_WORDS = sorted({w for t in _TMPL for w in
                 t.replace("{a}", "").replace("{c1}", "").replace("{c2}", "")
                 .replace("{r}", "").split()} | set(_ADJ) | set(_REL)
                | set(CLASS_NAMES))
VOCAB = {w: i + 2 for i, w in enumerate(_WORDS)}
VOCAB_SIZE = len(VOCAB) + 2


def make_caption(rng: np.random.Generator) -> str:
    t = _TMPL[rng.integers(len(_TMPL))]
    return t.format(a=_ADJ[rng.integers(len(_ADJ))],
                    c1=CLASS_NAMES[rng.integers(len(CLASS_NAMES))],
                    c2=CLASS_NAMES[rng.integers(len(CLASS_NAMES))],
                    r=_REL[rng.integers(len(_REL))])


def encode(text: str) -> list[int]:
    return [VOCAB[w] for w in text.split() if w in VOCAB]


def batch_iterator(batch: int, seq: int, *, seed: int = 0, vocab_size: int):
    """Yield dicts {'tokens': [B, S] int32}; captions packed back-to-back,
    BOS-separated, token ids mapped into the model vocab."""
    rng = np.random.default_rng(seed)
    assert vocab_size >= VOCAB_SIZE
    while True:
        out = np.zeros((batch, seq), np.int32)
        for b in range(batch):
            toks: list[int] = []
            while len(toks) < seq:
                toks.append(BOS)
                toks.extend(encode(make_caption(rng)))
            out[b] = toks[:seq]
        yield {"tokens": out}
