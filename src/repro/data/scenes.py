"""Synthetic Replica-like indoor scenes: RGB-D + pose sequences with
ground-truth instances.

Replica itself cannot ship in this container, so scenes are generated:
N objects (primitive point clouds: boxes / spheres / cylinders, per-class
size priors) placed in a room, observed by a camera orbiting the room
center.  Each frame renders depth + instance masks by point-splatting at
pinhole resolution — enough fidelity for every systems metric the paper
measures (latency, bandwidth, memory, retrieval IoU), with exact GT.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

CLASS_NAMES = [
    "chair", "table", "sofa", "lamp", "book", "cup", "plant", "monitor",
    "keyboard", "door", "window", "cushion", "shelf", "vase", "bottle",
    "clock", "rug", "bin", "picture", "blanket",
]
N_CLASSES = len(CLASS_NAMES)

# per-class (base size m, shape kind)
_CLASS_SIZE = {i: 0.2 + 0.5 * ((i * 2654435761) % 7) / 6 for i in
               range(N_CLASSES)}


@dataclass
class SceneObject:
    oid: int
    class_id: int
    center: np.ndarray          # [3]
    points: np.ndarray          # [P, 3] world


@dataclass
class Scene:
    objects: list
    room_size: float
    rng_seed: int


@dataclass
class Frame:
    idx: int
    depth: np.ndarray           # [H, W] f32 metres (0 = no hit)
    inst: np.ndarray            # [H, W] int32 object id (0 = none)
    pose: np.ndarray            # [4,4] cam->world
    intrinsics: np.ndarray      # [fx, fy, cx, cy]
    visible_ids: np.ndarray     # object ids with enough pixels


def _object_cloud(rng, kind: int, size: float, n: int) -> np.ndarray:
    u = rng.uniform(-1, 1, size=(n, 3))
    if kind == 0:        # box shell
        ax = rng.integers(0, 3, size=n)
        sgn = rng.choice([-1.0, 1.0], size=n)
        u[np.arange(n), ax] = sgn
    elif kind == 1:      # sphere shell
        u /= np.maximum(np.linalg.norm(u, axis=1, keepdims=True), 1e-6)
    else:                # cylinder
        th = rng.uniform(0, 2 * np.pi, size=n)
        u[:, 0], u[:, 2] = np.cos(th), np.sin(th)
    return u * size / 2


def make_scene(n_objects: int = 80, room: float = 8.0, seed: int = 0,
               points_per_object: int = 4096) -> Scene:
    rng = np.random.default_rng(seed)
    objs = []
    for i in range(n_objects):
        cid = int(rng.integers(0, N_CLASSES))
        size = _CLASS_SIZE[cid] * rng.uniform(0.7, 1.3)
        center = np.array([rng.uniform(-room / 2, room / 2),
                           rng.uniform(0.0, 2.0),
                           rng.uniform(-room / 2, room / 2)])
        pts = _object_cloud(rng, cid % 3, size, points_per_object) + center
        objs.append(SceneObject(oid=i + 1, class_id=cid, center=center,
                                points=pts.astype(np.float32)))
    return Scene(objects=objs, room_size=room, rng_seed=seed)


def _look_at(eye, target, up=np.array([0.0, 1.0, 0.0])):
    f = target - eye
    f = f / np.linalg.norm(f)
    r = np.cross(f, up)
    r = r / np.maximum(np.linalg.norm(r), 1e-9)
    u = np.cross(r, f)
    pose = np.eye(4)
    pose[:3, 0], pose[:3, 1], pose[:3, 2], pose[:3, 3] = r, u, f, eye
    return pose


def render_frame(scene: Scene, idx: int, *, h: int = 120, w: int = 160,
                 n_frames: int = 200, min_pixels: int = 12) -> Frame:
    """Point-splat render: nearest point per pixel -> depth + instance."""
    ang = 2 * np.pi * idx / n_frames
    r = scene.room_size * 0.35
    eye = np.array([r * np.cos(ang), 1.5, r * np.sin(ang)])
    pose = _look_at(eye, np.array([0.0, 1.0, 0.0]))
    fx = fy = 0.9 * w
    cx, cy = w / 2, h / 2
    intr = np.array([fx, fy, cx, cy], np.float32)
    return _splat(scene, idx, pose, intr, h, w, min_pixels)


def rerender_frame(scene: Scene, frame: Frame,
                   *, min_pixels: int = 12) -> Frame:
    """Re-render an existing frame's viewpoint against the CURRENT scene:
    same pose / intrinsics / resolution, fresh depth + instance splat.
    This is how a dynamic scene event (spawn / move / remove) becomes
    visible to a mapping frontend that consumes pre-rendered frames — the
    engine re-renders the tick's frame instead of replaying stale pixels.
    Identical to ``render_frame`` when the scene hasn't changed."""
    h, w = frame.depth.shape
    return _splat(scene, frame.idx, frame.pose, frame.intrinsics, h, w,
                  min_pixels)


def _splat(scene: Scene, idx: int, pose: np.ndarray, intr: np.ndarray,
           h: int, w: int, min_pixels: int) -> Frame:
    fx, fy, cx, cy = (float(x) for x in intr)
    depth = np.zeros((h, w), np.float32)
    inst = np.zeros((h, w), np.int32)
    zbuf = np.full((h, w), np.inf, np.float32)
    R, t = pose[:3, :3], pose[:3, 3]
    for obj in scene.objects:
        pc = (obj.points - t) @ R            # world -> cam
        z = pc[:, 2]
        ok = z > 0.05
        if not ok.any():
            continue
        u = (pc[ok, 0] / z[ok]) * fx + cx
        v = (pc[ok, 1] / z[ok]) * fy + cy
        zz = z[ok]
        ui, vi = u.astype(int), v.astype(int)
        inside = (ui >= 0) & (ui < w) & (vi >= 0) & (vi < h)
        ui, vi, zz = ui[inside], vi[inside], zz[inside]
        closer = zz < zbuf[vi, ui]
        vi, ui, zz = vi[closer], ui[closer], zz[closer]
        zbuf[vi, ui] = zz
        depth[vi, ui] = zz
        inst[vi, ui] = obj.oid
    ids, counts = np.unique(inst[inst > 0], return_counts=True)
    visible = ids[counts >= min_pixels]
    return Frame(idx=idx, depth=depth, inst=inst, pose=pose,
                 intrinsics=np.asarray(intr, np.float32),
                 visible_ids=visible.astype(np.int32))


def scene_stream(scene: Scene, n_frames: int = 200, keyframe_interval: int = 5,
                 **kw):
    """Yield keyframes (the paper maps keyframes at interval 5)."""
    for idx in range(0, n_frames, keyframe_interval):
        yield render_frame(scene, idx, n_frames=n_frames, **kw)
