"""End-to-end training driver: the ~110M-parameter SemanticXR captioner LM
trained for a few hundred steps on the scene-caption corpus, with
checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_captioner.py [--steps 200]

(Thin wrapper over repro.launch.train — the same launcher that drives the
production mesh; see also --kill-at for the fault-injection demo.)
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:] or ["--steps", "200", "--batch", "8", "--seq", "256"]
    main(["--arch", "semanticxr-captioner-110m"] + args)
