"""SemanticXR quickstart: build a semantic map of a synthetic room, then ask
"where are my keys?"-style queries against it.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import Knobs, MappingServer, Query, execute_query
from repro.data.scenes import CLASS_NAMES, make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder


def main():
    scene = make_scene(n_objects=30, seed=0)
    classes = {o.oid: o.class_id for o in scene.objects}
    embedder = OracleEmbedder(embed_dim=256)
    knobs = Knobs(server_capacity=256, max_object_points_server=512,
                  max_detections_per_frame=16, min_obs_before_sync=1)
    server = MappingServer(knobs=knobs, embedder=embedder, mode="semanticxr")

    print("mapping the room ...")
    key = jax.random.key(0)
    for i, frame in enumerate(scene_stream(scene, n_frames=60,
                                           keyframe_interval=5, h=240, w=320)):
        t = server.process_frame(frame, classes, jax.random.fold_in(key, i))
        print(f"  keyframe {frame.idx:3d}: {t.total_ms:6.1f} ms, "
              f"{int(np.asarray(server.store.active.sum()))} objects mapped")

    print("\nqueries:")
    mapped = set(np.asarray(server.store.label)[np.asarray(server.store.active)])
    for cid in sorted(mapped)[:6]:
        res = execute_query(server.store,
                            Query(embed=embedder.embed_text(int(cid)), k=5))
        c = np.asarray(server.store.centroid[int(res.slots[0])])
        print(f"  'where is the {CLASS_NAMES[cid]}?' -> object "
              f"#{int(res.oids[0])} at ({c[0]:+.2f}, {c[1]:+.2f}, {c[2]:+.2f})"
              f"  score={float(res.scores[0]):.3f}")


if __name__ == "__main__":
    main()
