"""Train the mini-CLIP two-tower embedder on synthetic scene crops and
report open-vocabulary retrieval accuracy (the learned alternative to the
OracleEmbedder in SemanticXR's perception stack).

    PYTHONPATH=src python examples/train_perception.py [--steps 300]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.scenes import make_scene
from repro.perception import clip as clip_mod
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    ccfg = clip_mod.ClipConfig()
    params = clip_mod.init_clip_params(ccfg, jax.random.key(0))
    ocfg = adamw.AdamWConfig(lr=1e-3, total_steps=args.steps,
                             warmup_steps=20, weight_decay=0.01)
    opt = adamw.init_opt_state(params, ocfg)

    scene = make_scene(n_objects=60, seed=5)
    classes = {o.oid: o.class_id for o in scene.objects}
    it = clip_mod.pair_batches(scene, classes, batch=args.batch)

    @jax.jit
    def step(params, opt, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: clip_mod.clip_loss(p, batch, ccfg), has_aux=True)(params)
        params, opt, om = adamw.adamw_update(g, opt, params, ocfg)
        return params, opt, loss

    for i in range(1, args.steps + 1):
        b = next(it)
        b.pop("class_ids")
        params, opt, loss = step(params, opt, b)
        if i % 50 == 0:
            print(f"step {i:4d} contrastive loss {float(loss):.4f}")

    # retrieval eval: held-out crops vs all class captions
    eval_it = clip_mod.pair_batches(scene, classes, batch=16, seed=99)
    hits = tot = 0
    from repro.data.scenes import N_CLASSES
    all_toks = jnp.asarray(np.stack([clip_mod.class_tokens(c)
                                     for c in range(N_CLASSES)]))
    te = clip_mod.encode_text(params, all_toks, ccfg)
    for _ in range(6):
        b = next(eval_it)
        oe = clip_mod.encode_object(params, b["crops"], b["stats"], ccfg)
        pred = np.asarray(jnp.argmax(oe @ te.T, axis=1))
        hits += int((pred == b["class_ids"]).sum())
        tot += len(pred)
    print(f"open-vocab retrieval top-1: {hits}/{tot} = {hits/tot:.1%} "
          f"(chance {1/N_CLASSES:.1%})")


if __name__ == "__main__":
    main()
