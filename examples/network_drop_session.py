"""End-to-end device-cloud session with a network outage (paper Fig. 1
scenario): the device streams RGB-D, the cloud maps; queries ride
SemanticXR-SQ while the network is up, fail over to SemanticXR-LQ on the
object-level sparse local map during the outage, and the buffered updates
flush on reconnect.  Byte and power accounting printed per phase.

    PYTHONPATH=src python examples/network_drop_session.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Knobs, MappingServer
from repro.core.runtime import (ClientSession, CloudService, DeviceClient,
                                NetworkModel, PowerModel)
from repro.data.scenes import CLASS_NAMES, make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder


def main():
    scene = make_scene(n_objects=25, seed=2)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=256)
    kn = Knobs(server_capacity=256, client_capacity=64,
               max_object_points_server=512, max_object_points_client=128,
               max_detections_per_frame=16, min_obs_before_sync=1)
    srv = MappingServer(knobs=kn, embedder=emb)
    cloud = CloudService(knobs=kn, store_ref=srv)
    dev = DeviceClient(knobs=kn, embed_dim=256)
    net = NetworkModel(rtt_ms=20.0, outages=((4.0, 8.0),))
    pm = PowerModel()

    sess = ClientSession(dev=dev, net=net, knobs=kn)

    key = jax.random.key(0)
    t = 0.0
    print(f"{'t':>5} {'net':>6} {'mode':>4} {'mapped':>6} {'local':>5} "
          f"{'downB':>7}  query")
    for i, fr in enumerate(scene_stream(scene, n_frames=60,
                                        keyframe_interval=5, h=240, w=320)):
        t = i * 1.0
        up = net.is_up(t)
        srv.process_frame(fr, classes, jax.random.fold_in(key, i))
        pkt = cloud.update_tick(network_up=up)
        if pkt is None and up and cloud.buffered:
            pkt = cloud.flush_buffer()
            print(f"{t:5.1f} reconnect: flushed buffered updates "
                  f"({pkt.nbytes} B)")
        # shared per-tick client step (also used by server/fleet.py):
        # outage-aware delivery, ingest, byte accounting, SQ/LQ choice
        mode = sess.step(t, pkt)

        mapped = set(np.asarray(srv.store.label)[np.asarray(srv.store.active)])
        qtext = ""
        if i % 2 == 0 and mapped:
            cid = sorted(mapped)[i // 2 % len(mapped)]
            res = (cloud.query if mode == "SQ" else dev.query)(
                emb.embed_text(int(cid)))
            lat = net.transfer_ms(2 * 256) if mode == "SQ" else 0.12
            qtext = (f"'{CLASS_NAMES[cid]}' -> #{int(res.oids[0])} "
                     f"({mode}, ~{lat:.0f} ms)")
        print(f"{t:5.1f} {'UP' if up else 'DOWN':>6} {mode:>4} "
              f"{int(np.asarray(srv.store.active.sum())):>6} "
              f"{int(np.asarray(dev.local.active.sum())):>5} "
              f"{sess.down_bytes:>7}  {qtext}")

    p = pm.average_power(streaming=True, server_qps=1 / 3)
    print(f"\ndevice power (streaming + SQ @1q/3s): {p:.2f} W "
          f"({(p / pm.idle_w - 1) * 100:.1f}% over idle)")
    print(f"device local-map memory: {dev.memory_bytes() / 2**20:.1f} MiB")


if __name__ == "__main__":
    main()
