"""End-to-end device-cloud session with a network outage (paper Fig. 1
scenario), replayed through the deterministic scenario engine: the device
streams RGB-D, the cloud maps; queries ride SemanticXR-SQ while the network
is up, fail over to SemanticXR-LQ on the object-level sparse local map
during the outage, and the missed updates coalesce into one packet on
reconnect.  Mid-run the scene SHRINKS: the RGB-D stream pauses after tick
8 (the camera looks elsewhere) and two mapped objects are removed — they
propagate as 9-byte tombstone rows that free the device slots.  (The pause
matters: frames rendered from the unchanged scene would immediately
re-detect the removed objects and re-insert them under new ids.)

This driver is a thin wrapper over ``repro.sim``: it only declares the
Scenario (client link + outage window + removal events) and pretty-prints
the resulting MetricsLog.  Run the same Scenario twice and the logs are
bit-identical (tests/test_scenario_engine.py holds the engine to that).

    PYTHONPATH=src python examples/network_drop_session.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import Knobs, MappingServer
from repro.core.runtime import NetworkModel, PowerModel
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder
from repro.sim import (ClientSpec, NetTrace, ObjectEvent, PoseTrack,
                       QueryPlan, Scenario, ScenarioEngine)
from repro.sim.scenario import GridSpec


def main():
    scene = make_scene(n_objects=25, seed=2)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=256)
    kn = Knobs(server_capacity=256, client_capacity=64,
               max_object_points_server=512, max_object_points_client=128,
               max_detections_per_frame=16, min_obs_before_sync=1)
    srv = MappingServer(knobs=kn, embedder=emb)
    frames = list(scene_stream(scene, n_frames=60, keyframe_interval=5,
                               h=240, w=320))

    scenario = Scenario(
        seed=0, n_ticks=len(frames), tick_s=1.0, embed_dim=256, knobs=kn,
        grid=GridSpec(room=scene.room_size, nx=1, nz=1), budget=64,
        clients=(ClientSpec(
            cid=0,
            net=NetTrace(rtt_ms=20.0, outages=((4.0, 8.0),)),
            track=PoseTrack(anchor=(0.0, 1.5, 0.0), orbit_radius=0.0),
            subscribe_radius=scene.room_size),),
        # dynamic scene: two mapped objects vanish after the reconnect —
        # the server prunes them to tombstones, the client frees the slots
        events=(ObjectEvent(tick=9, kind="remove", oid=1),
                ObjectEvent(tick=9, kind="remove", oid=2)),
        query=QueryPlan(prob=0.6, radius=scene.room_size, k=3))

    # stream pauses after tick 8 so the removals are not re-observed
    engine = ScenarioEngine(scenario, mapper=srv, frames=frames[:9],
                            classes=classes, embedder=emb)
    log = engine.run()

    net = NetworkModel(rtt_ms=20.0, outages=((4.0, 8.0),))
    print(f"{'t':>5} {'net':>6} {'mode':>4} {'mapped':>6} {'tomb':>4} "
          f"{'local':>5} {'sentB':>7} {'q_ms':>7}")
    for i in range(log.n_ticks):
        t = i * scenario.tick_s
        up = net.is_up(t)
        mode = {1: "SQ", 0: "LQ", -1: "--"}[int(log.mode_sq[i, 0])]
        q = log.query_ms[i, 0]
        note = ""
        if log.events[i, 2]:
            note = f"  <- {int(log.events[i, 2])} removed (tombstones " \
                   f"{int(log.sent_tomb_bytes[i, 0])} B on the wire)"
        print(f"{t:5.1f} {'UP' if up else 'DOWN':>6} {mode:>4} "
              f"{int(log.server_live[i]):>6} "
              f"{int(log.server_tombstones[i]):>4} "
              f"{int(log.client_live[i, 0]):>5} "
              f"{int(log.sent_bytes[i, 0]):>7} "
              f"{'' if np.isnan(q) else f'{q:7.1f}'}{note}")

    pm = PowerModel()
    mean_p = float(log.power_w[log.client_active[:, 0], 0].mean())
    print(f"\ntotal downstream: {int(log.sent_bytes.sum())} B over "
          f"{log.n_ticks} ticks "
          f"({int(log.delivered.sum())} delivered, "
          f"{int(log.delayed.sum())} delayed packets)")
    print(f"device power (MODEL): {mean_p:.2f} W "
          f"({(mean_p / pm.idle_w - 1) * 100:.1f}% over idle)")
    print(f"device local-map memory: "
          f"{int(log.client_nbytes[-1, 0]) / 2**20:.1f} MiB (fixed cap)")


if __name__ == "__main__":
    main()
