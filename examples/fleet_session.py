"""Multi-tenant fleet session: one mapped scene, many XR clients.

Runs the FleetSimulator — C simulated clients with heterogeneous networks
(mixed RTTs, staggered outages), join/leave churn, poses wandering across
spatial zones — against one MappingServer-driven scene.  The server tick is
one vmapped collect dispatch per dirty zone (never a loop over clients),
and clients receive bytes only for the zones their pose overlaps.
Cross-client SQ queries are declarative `Query` specs (similarity + a
radius-around-the-client spatial predicate) multiplexed through the
continuous-batching scheduler; the epilogue runs zone- and label-filtered
queries straight against the zone-sharded fleet store (shard pruning
before dispatch).

    PYTHONPATH=src python examples/fleet_session.py [n_clients]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Knobs, MappingServer
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder
from repro.server import FleetSimulator, Query, ZoneGrid


def main():
    n_clients = int(sys.argv[1]) if len(sys.argv) > 1 else 24
    n_ticks = 30
    kn = Knobs(server_capacity=256, client_capacity=64,
               max_object_points_server=256, max_object_points_client=64,
               max_detections_per_frame=16, min_obs_before_sync=1)
    emb = OracleEmbedder(embed_dim=128)
    scene = make_scene(n_objects=30, seed=4)
    classes = {o.oid: o.class_id for o in scene.objects}
    mapper = MappingServer(knobs=kn, embedder=emb)
    frames = list(scene_stream(scene, n_frames=n_ticks * 5,
                               keyframe_interval=5, h=120, w=160))

    sim = FleetSimulator(knobs=kn, embed_dim=128, n_clients=n_clients,
                         grid=ZoneGrid.for_room(scene.room_size, nx=2, nz=2),
                         seed=7)
    stats = sim.run(n_ticks=n_ticks, mapper=mapper, frames=frames,
                    embedder=emb, classes=classes, key=jax.random.key(0))

    print(f"fleet of {n_clients} clients, {n_ticks} ticks, "
          f"{sim.grid.n_zones} zones")
    print(f"  mapped objects:          {sim.server.zoned.n_active()}")
    print(f"  active clients at end:   {stats['active_at_end']}")
    print(f"  server tick (mean):      {stats['tick_ms_mean']:.2f} ms "
          f"for all clients")
    print(f"  downstream total:        {stats['down_bytes_total'] / 1e3:.1f}"
          f" kB ({stats['down_bytes_per_client'] / 1e3:.1f} kB/client)")
    print(f"  packets delivered:       {stats['delivered_packets']} "
          f"({stats['delayed_packets']} delivered after their send tick)")
    print(f"  SQ queries served:       {stats['served']} "
          f"(hedged: {stats['hedges']}), LQ fallbacks: "
          f"{stats['lq_fallbacks']}")
    per = np.array([c.session.down_bytes for c in sim.clients])
    print(f"  per-client bytes p50/p95: {np.percentile(per, 50) / 1e3:.1f} / "
          f"{np.percentile(per, 95) / 1e3:.1f} kB")

    # declarative queries straight against the zone-sharded fleet store:
    # zone membership prunes shards BEFORE dispatch, labels/min_points ride
    # the fused top-k as -inf score injection
    labels = sorted(set(classes.values()))
    spec = Query(embed=emb.embed_text(labels[0]),
                 zones=(0,), grid=Query.grid_of(sim.grid),
                 min_points=jnp.asarray(4), k=3)
    res = sim.server.query(spec)
    hits = [(int(o), round(float(s), 3))
            for o, s in zip(res.oids, res.scores) if o]
    print(f"  zone-0 query '{labels[0]}':  {hits}")
    spec = Query(embed=emb.embed_text(labels[1]),
                 near=(jnp.asarray([0.0, 1.5, 0.0]), jnp.asarray(3.0)),
                 labels=(int(labels[1]),), k=3)
    res = sim.server.query(spec)
    hits = [(int(o), round(float(s), 3))
            for o, s in zip(res.oids, res.scores) if o]
    print(f"  near+label '{labels[1]}' within 3 m of origin: {hits}")


if __name__ == "__main__":
    main()
