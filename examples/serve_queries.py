"""Serve a mapped scene to many concurrent clients with continuous batching
and straggler hedging — the serving substrate under the declarative
SemanticXR query engine.

Requests are ``core.query.Query`` specs, not bare embeddings: open-vocab
similarity plus spatial (radius-around-user, in-view AABB) and attribute
(label set, min point count) predicates, all fused into the same top-k
dispatch per scheduler batch.

    PYTHONPATH=src python examples/serve_queries.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Knobs, MappingServer, Query
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder
from repro.serving.batching import BatchScheduler, make_query_step_fn


def main():
    scene = make_scene(n_objects=30, seed=0)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=256)
    kn = Knobs(server_capacity=256, max_object_points_server=256,
               max_detections_per_frame=16, min_obs_before_sync=1)
    srv = MappingServer(knobs=kn, embedder=emb)
    key = jax.random.key(0)
    for i, fr in enumerate(scene_stream(scene, n_frames=40,
                                        keyframe_interval=5, h=120, w=160)):
        srv.process_frame(fr, classes, jax.random.fold_in(key, i))

    # one fused predicate+score+top-k sweep per engine step (same-plan
    # requests stack into a single struct-of-arrays dispatch)
    step_fn = make_query_step_fn(lambda: srv.store, k=5, pad_to=8)
    sched = BatchScheduler(batch_size=8, step_fn=step_fn, hedge_after_ms=50.0)
    mapped = sorted(set(np.asarray(srv.store.label)[
        np.asarray(srv.store.active)]))
    rng = np.random.default_rng(0)
    user = jnp.asarray([0.0, 1.5, 0.0])

    t0 = time.perf_counter()
    n_req = 64
    rids = {}
    for i in range(n_req):
        cid = int(mapped[rng.integers(len(mapped))])
        qe = emb.embed_text(cid)
        if i % 3 == 0:           # "what's near me that looks like <text>?"
            spec = Query(embed=qe, near=(user, jnp.asarray(3.0)),
                         prox_weight=jnp.asarray(0.2), k=5)
        elif i % 3 == 1:         # label-filtered, well-observed objects only
            spec = Query(embed=qe, labels=tuple(int(c) for c in mapped[:4]),
                         min_points=jnp.asarray(8), k=5)
        else:                    # in-view selection: AABB + similarity
            spec = Query(embed=qe,
                         aabb=(jnp.asarray([-4.0, 0.0, -4.0]),
                               jnp.asarray([4.0, 2.5, 4.0])), k=5)
        rids[sched.submit(spec, priority=rng.uniform(0, 2))] = i % 3
    done = sched.drain()
    dt = time.perf_counter() - t0

    kinds = ["near+prox", "labels+min_points", "in-view aabb"]
    print(f"served {len(done)} declarative queries in {dt*1e3:.1f} ms "
          f"({len(done)/dt:.0f} qps, batch=8, hedges={sched.hedge_count})")
    for rid in list(done)[:3]:
        res = done[rid]
        hits = [(int(o), round(float(s), 3))
                for o, s in zip(res.oids, res.scores) if o]
        print(f"  [{kinds[rids[rid]]:18s}] hits: {hits}")

    # cluster-level query through the same compiler: "where is the densest
    # region matching <text>?" — the summaries ARE the results, no object
    # sweep at all (Query(level='cluster') + the coarse-to-fine index)
    from repro.core.query import execute_query
    from repro.index import ClusterIndex

    idx = ClusterIndex.for_target(srv.store, n_cells_target=16,
                                  min_flat_size=1)
    cid = int(mapped[0])
    spec = Query(embed=emb.embed_text(cid),
                 density_weight=jnp.asarray(0.5), k=3, level="cluster")
    cres = execute_query(srv.store, spec, index=idx)
    print(f"densest regions matching class {cid}:")
    for c, s, n, xyz in zip(np.asarray(cres.cells), np.asarray(cres.scores),
                            np.asarray(cres.counts),
                            np.asarray(cres.centroids)):
        if c >= 0:
            print(f"  cell {int(c):3d}: {int(n):2d} objects around "
                  f"({xyz[0]:+.1f}, {xyz[1]:+.1f}, {xyz[2]:+.1f}) "
                  f"score={float(s):.3f}")


if __name__ == "__main__":
    main()
