"""Serve a mapped scene to many concurrent clients with continuous batching
and straggler hedging — the serving substrate under the SemanticXR query
engine.

    PYTHONPATH=src python examples/serve_queries.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import Knobs, MappingServer
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder
from repro.serving.batching import BatchScheduler, make_query_step_fn


def main():
    scene = make_scene(n_objects=30, seed=0)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=256)
    kn = Knobs(server_capacity=256, max_object_points_server=256,
               max_detections_per_frame=16, min_obs_before_sync=1)
    srv = MappingServer(knobs=kn, embedder=emb)
    key = jax.random.key(0)
    for i, fr in enumerate(scene_stream(scene, n_frames=40,
                                        keyframe_interval=5, h=120, w=160)):
        srv.process_frame(fr, classes, jax.random.fold_in(key, i))

    # one fused similarity+top-k sweep per engine step, padded to batch_size
    step_fn = make_query_step_fn(lambda: srv.store, k=5, pad_to=8)
    sched = BatchScheduler(batch_size=8, step_fn=step_fn, hedge_after_ms=50.0)
    mapped = sorted(set(np.asarray(srv.store.label)[
        np.asarray(srv.store.active)]))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    n_req = 64
    for i in range(n_req):
        cid = int(mapped[rng.integers(len(mapped))])
        sched.submit(emb.embed_text(cid), priority=rng.uniform(0, 2))
    done = sched.drain()
    dt = time.perf_counter() - t0
    print(f"served {len(done)} queries in {dt*1e3:.1f} ms "
          f"({len(done)/dt:.0f} qps, batch=8, hedges={sched.hedge_count})")
    hits = [v for v in list(done.values())[:5]]
    print("sample results:", hits)


if __name__ == "__main__":
    main()
