"""Paper Fig. 5: device memory footprint and local query latency vs number
of objects in the local map (synthetic maps, 80 .. 50k objects).

Query latency decomposes into text embedding (map-size independent; the
paper measures MobileCLIP on Jetson ~45 ms — we report the similarity +
top-k part measured here plus that constant, labeled) and per-object
similarity compute (grows with N).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.knobs import Knobs
from repro.core.local_map import init_local_map, local_map_nbytes
from repro.core.query import Query, execute_query

EDIM = 512
TEXT_EMBED_MS = 45.0      # paper-reported MobileCLIP text encode on device
SIZES = [80, 1_000, 5_000, 10_000, 25_000, 50_000]


def _filled_map(n: int, knobs: Knobs):
    m = init_local_map(knobs, EDIM)
    key = jax.random.key(0)
    e = jax.random.normal(key, (n, EDIM), jnp.float32)
    e = e / jnp.linalg.norm(e, axis=1, keepdims=True)
    return m._replace(
        ids=jnp.arange(1, n + 1, dtype=jnp.int32),
        active=jnp.ones((n,), bool),
        embed=e,
        label=jnp.arange(n, dtype=jnp.int32) % 20,
        n_points=jnp.full((n,), knobs.max_object_points_client, jnp.int32),
    )


def run(full: bool = False, use_pallas: bool = False):
    sizes = SIZES if full else SIZES[:4]
    out = {}
    for n in sizes:
        kn = Knobs(client_capacity=n, max_object_points_client=200)
        m = _filled_map(n, kn)
        mem_mb = local_map_nbytes(m) / 2**20
        q = jax.random.normal(jax.random.key(1), (EDIM,))
        fn = jax.jit(lambda mm, qq: execute_query(
            mm, Query(embed=qq, k=5), use_pallas=use_pallas))
        jax.block_until_ready(fn(m, q).scores)      # warm
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(m, q).scores)
        sim_ms = (time.perf_counter() - t0) / reps * 1e3
        total_ms = TEXT_EMBED_MS + sim_ms
        out[n] = {"memory_mb": mem_mb, "sim_ms": sim_ms,
                  "total_ms": total_ms}
        csv_row(f"fig5_local_map[{n}]", sim_ms * 1e3,
                f"memory={mem_mb:.1f}MB;total={total_ms:.1f}ms"
                f";pallas={int(use_pallas)}")
    return out


if __name__ == "__main__":
    run(full=True)
