"""Declarative query-engine latency vs map size and predicate mix.

The paper's query-latency claim (Fig. 4 / Sec. 2.3.2): the server answers
open-vocabulary map queries in well under 100 ms at 10,000 objects.  This
suite measures the compiled engine (`core.query.compile_query`) over
clustered synthetic stores from 1k to 1M objects, across predicate mixes:

  embed_only      cosine top-k, the seed query path's workload
  embed_spatial   + radius-around-user with proximity score combination
  embed_attrs     + label set, min point count, min obs, recency
  full_mix        everything at once (spatial + attributes + zones)
  spatial_only    no embedding at all — pure predicate search

Two execution paths are timed at every size:

  *_flat          the fused single-sweep dispatch (predicates as -inf
                  score injection riding the top-k sweep)
  full_mix_two_stage  the coarse-to-fine plan through a ClusterIndex
                  (repro.index): rank cluster summaries, sweep only the
                  surviving members, certify exactness against the bound

``full_mix`` is the ENGINE DEFAULT path — two-stage once the index
engages (>= min_flat_size live objects), flat below — which is what
``sim.engine.load_lq_curve`` and the serving tier observe.  Correctness
flags recorded per size: ``index_matches_flat`` (two-stage result
byte-equal to the flat sweep) and ``oracle_parity*`` (both paths equal to
a numpy flat-sweep oracle, score-tolerant for tie-breaking).

Markers: ``predicate_overhead_x`` is computed PER SIZE (the seed computed
it from the 10k row only, hiding the 30k regression) and
``fused_within_1_2x`` takes the WORST size >= 10k (1k is dispatch-bound:
predicate fusion cost is invisible next to dispatch overhead there).
``sub_100ms_at_1m`` is the headline: full_mix under 100 ms at 1,000,000
objects on the default path.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.query import Query, compile_query
from repro.core.store import clustered_synthetic_store
from repro.obs import metrics as obs_metrics

EDIM = 256
K = 10
ROOM = 80.0
GRID = (-40.0, -40.0, 40.0, 2, 2)       # (x0, z0, zone_size, nx, nz)
RADIUS = 4.0


def _specs(qe, center):
    radius = jnp.asarray(RADIUS, jnp.float32)
    return {
        "embed_only": Query(embed=qe, k=K),
        "embed_spatial": Query(embed=qe, near=(center, radius),
                               prox_weight=jnp.asarray(0.2, jnp.float32),
                               k=K),
        "embed_attrs": Query(embed=qe, labels=tuple(range(10)),
                             min_points=jnp.asarray(4, jnp.int32),
                             min_obs=jnp.asarray(1, jnp.int32),
                             since=jnp.asarray(0, jnp.int32), k=K),
        "full_mix": Query(embed=qe, near=(center, radius),
                          prox_weight=jnp.asarray(0.2, jnp.float32),
                          labels=tuple(range(10)),
                          min_points=jnp.asarray(4, jnp.int32),
                          min_obs=jnp.asarray(1, jnp.int32),
                          zones=(0, 1, 2, 3), grid=GRID, k=K),
        "spatial_only": Query(near=(center, radius),
                              prox_weight=jnp.asarray(1.0, jnp.float32),
                              labels=tuple(range(10)), k=K),
    }


def _time_plan(plan, target, spec, reps: int) -> float:
    for _ in range(2):                                   # warm the jit
        jax.block_until_ready(plan(target, spec).scores)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(plan(target, spec).scores)
    return (time.perf_counter() - t0) / reps * 1e3


def _reps(n: int, smoke: bool) -> int:
    if smoke:
        return 5
    if n <= 30_000:
        return 20
    if n <= 100_000:
        return 10
    if n <= 300_000:
        return 5
    return 3


def _np_oracle_full_mix(st, qe, center):
    """Flat-sweep numpy oracle for the full_mix spec: f32 score math, k
    best by stable argsort (ascending-slot tie-break, matching the
    engine's documented order).  Returns (oids, scores) [K]."""
    act = np.asarray(st.active)
    sim = np.asarray(st.embed) @ np.asarray(qe)
    d = np.linalg.norm(np.asarray(st.centroid) - np.asarray(center), axis=1)
    ok = (act & (d <= RADIUS)
          & np.isin(np.asarray(st.label), np.arange(10))
          & (np.asarray(st.n_points) >= 4)
          & (np.asarray(st.obs_count) >= 1))
    score = np.where(ok, sim + np.float32(0.2) / (np.float32(1.0) + d),
                     -np.inf).astype(np.float32)
    order = np.argsort(-score, kind="stable")[:K]
    return np.asarray(st.ids)[order], score[order]


def _oracle_parity(res, oracle_scores) -> bool:
    """Engine result == numpy oracle modulo tie-breaking and f32
    accumulation-order noise: the k SCORES must agree to tolerance (equal
    scores may belong to different tied members — documented)."""
    s = np.sort(np.asarray(res.scores))[::-1]
    o = np.sort(np.asarray(oracle_scores))[::-1]
    fin = np.isfinite(o)
    return bool(np.array_equal(fin, np.isfinite(s))
                and np.allclose(s[fin], o[fin], rtol=5e-5, atol=1e-5))


def _results_equal(a, b) -> bool:
    return bool(np.array_equal(np.asarray(a.oids), np.asarray(b.oids))
                and np.array_equal(np.asarray(a.slots), np.asarray(b.slots))
                and np.allclose(np.asarray(a.scores), np.asarray(b.scores),
                                rtol=1e-6, atol=1e-7, equal_nan=True))


def run(full: bool = False, smoke: bool = False, use_pallas: bool = False):
    from repro.index import ClusterIndex
    # smoke keeps a sub-threshold row (flat path) AND a row right at the
    # production engagement threshold (two-stage + certificate + the
    # >=10k overhead marker all run in CI, at the smallest honest shape)
    sizes = [256, 16_384] if smoke else \
        [1_000, 10_000, 30_000, 100_000, 300_000, 1_000_000]
    out = {"k": K, "embed_dim": EDIM, "use_pallas": use_pallas}
    overhead_10k_up = []
    parity_all, match_all = [], []
    for n in sizes:
        reps = _reps(n, smoke)
        # hotspot count scales with n (~2k objects per hotspot at the top
        # end) so per-cell occupancy stays realistic at every size
        st = clustered_synthetic_store(n, n, EDIM, 16, seed=0, room=ROOM,
                                       n_hotspots=max(128, n // 2_000))
        # query AS an object that passes the full_mix label filter, so the
        # top-k is its own hotspot (the realistic ask) at every size
        lab_ok = np.nonzero(np.asarray(st.label) < 10)[0]
        qi = int(lab_ok[len(lab_ok) // 2])
        qe = st.embed[qi]
        center = st.centroid[qi]
        specs = _specs(qe, center)
        row = {}
        for name, spec in specs.items():
            plan = compile_query(spec, st, use_pallas=use_pallas)
            key = "full_mix_flat" if name == "full_mix" else name
            row[key] = _time_plan(plan, st, spec, reps)
            csv_row(f"query_engine[{n},{key}]", row[key] * 1e3,
                    f"k={K};pallas={int(use_pallas)}")

        # the coarse-to-fine path: build (timed) + query through the index
        t0 = time.perf_counter()
        idx = ClusterIndex.for_target(st)
        row["index_build_s"] = time.perf_counter() - t0
        row["index_engaged"] = idx.engaged()
        row["index_n_cells"] = idx.grid.n_cells
        reg = obs_metrics.MetricsRegistry()
        prev = obs_metrics.set_registry(reg)
        try:
            tplan = compile_query(specs["full_mix"], st,
                                  use_pallas=use_pallas, index=idx)
            row["full_mix_two_stage"] = _time_plan(tplan, st,
                                                   specs["full_mix"], reps)
            two_res = tplan(st, specs["full_mix"])
        finally:
            obs_metrics.set_registry(prev)
        h = reg.histograms.get("query_index_candidate_fraction")
        row["candidate_fraction"] = h.summary() if h is not None else None
        esc = reg.counters.get("query_index_escalations_total")
        row["escalations"] = int(esc.total()) if esc is not None else 0
        row["full_mix"] = row["full_mix_two_stage"] if idx.engaged() \
            else row["full_mix_flat"]
        csv_row(f"query_engine[{n},full_mix_two_stage]",
                row["full_mix_two_stage"] * 1e3,
                f"engaged={int(idx.engaged())};"
                f"cells={idx.grid.n_cells}")

        # correctness: two-stage == flat == numpy oracle
        flat_res = compile_query(specs["full_mix"], st,
                                 use_pallas=use_pallas)(st)
        row["index_matches_flat"] = _results_equal(flat_res, two_res)
        _, o_scores = _np_oracle_full_mix(st, qe, center)
        row["oracle_parity_flat"] = _oracle_parity(flat_res, o_scores)
        row["oracle_parity_two_stage"] = _oracle_parity(two_res, o_scores)
        parity_all += [row["oracle_parity_flat"],
                       row["oracle_parity_two_stage"]]
        match_all.append(row["index_matches_flat"])

        # serving amortization: 16 same-plan queries, one fused dispatch
        qs = jnp.tile(qe[None], (16, 1))
        cs = jnp.tile(center[None], (16, 1))
        bspec = Query(embed=qs,
                      near=(cs, jnp.full((16,), RADIUS, jnp.float32)),
                      prox_weight=jnp.full((16,), 0.2, jnp.float32),
                      k=K, batched=True)
        bplan = compile_query(bspec, st, use_pallas=use_pallas,
                              index=idx if idx.engaged() else None)
        bt = _time_plan(bplan, st, bspec, reps)
        row["batched16"] = bt
        row["batched16_per_query"] = bt / 16
        csv_row(f"query_engine[{n},batched16]", bt * 1e3,
                f"per_query_ms={bt / 16:.3f}")

        # per-size fusion overhead on the FLAT path (the marker the seed
        # computed only at 10k, hiding the 30k regression)
        heavy = max(row["embed_spatial"], row["embed_attrs"],
                    row["full_mix_flat"])
        row["predicate_overhead_x"] = heavy / row["embed_only"]
        if n >= 10_000:
            overhead_10k_up.append(row["predicate_overhead_x"])
        out[str(n)] = row

    # worst overhead over the sizes where dispatch cost doesn't dominate
    worst = max(overhead_10k_up) if overhead_10k_up else \
        out[str(sizes[-1])]["predicate_overhead_x"]
    out["predicate_overhead_worst_x"] = worst
    out["fused_within_1_2x"] = bool(worst <= 1.2)
    mid = str(10_000) if "10000" in out else str(sizes[-1])
    out["sub_100ms_at_10k"] = bool(out[mid]["full_mix"] < 100.0)
    big = str(sizes[-1])
    out["sub_100ms_at_1m"] = bool(sizes[-1] >= 1_000_000
                                  and out[big]["full_mix"] < 100.0) \
        if not smoke else bool(out[big]["full_mix"] < 100.0)
    out["oracle_parity_all"] = bool(all(parity_all))
    out["index_matches_flat_all"] = bool(all(match_all))
    csv_row("query_engine[overhead_worst]", worst * 1e6,
            f"fused_within_1.2x={out['fused_within_1_2x']};"
            f"sub_100ms_at_1m={out['sub_100ms_at_1m']};"
            f"oracle={out['oracle_parity_all']}")
    return out


if __name__ == "__main__":
    run()
