"""Declarative query-engine latency vs map size and predicate mix.

The paper's query-latency claim (Fig. 4 / Sec. 2.3.2): the server answers
open-vocabulary map queries in well under 100 ms at 10,000 objects.  This
suite measures the compiled engine (`core.query.compile_query`) over
synthetic stores of 1k / 10k / 30k objects, across predicate mixes:

  embed_only      cosine top-k, the seed query path's workload
  embed_spatial   + radius-around-user with proximity score combination
  embed_attrs     + label set, min point count, min obs, recency
  full_mix        everything at once (spatial + attributes + zones)
  spatial_only    no embedding at all — pure predicate search

Predicates are fused into the top-k dispatch as -inf score injection, so
the acceptance target is predicate-heavy latency within 1.2x of
embed_only at 10k objects (`fused_within_1_2x` in the JSON) — the
predicates ride the same sweep, not a second pass.  A `batched16` row
measures the serving amortization: 16 stacked queries in one dispatch.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.query import Query, compile_query
from repro.core.store import synthetic_store

EDIM = 256
K = 10
GRID = (-8.0, -8.0, 8.0, 2, 2)          # (x0, z0, zone_size, nx, nz)


def _specs(qe, center):
    radius = jnp.asarray(4.0, jnp.float32)
    return {
        "embed_only": Query(embed=qe, k=K),
        "embed_spatial": Query(embed=qe, near=(center, radius),
                               prox_weight=jnp.asarray(0.2, jnp.float32),
                               k=K),
        "embed_attrs": Query(embed=qe, labels=tuple(range(10)),
                             min_points=jnp.asarray(4, jnp.int32),
                             min_obs=jnp.asarray(1, jnp.int32),
                             since=jnp.asarray(0, jnp.int32), k=K),
        "full_mix": Query(embed=qe, near=(center, radius),
                          prox_weight=jnp.asarray(0.2, jnp.float32),
                          labels=tuple(range(10)),
                          min_points=jnp.asarray(4, jnp.int32),
                          min_obs=jnp.asarray(1, jnp.int32),
                          zones=(0, 1, 2, 3), grid=GRID, k=K),
        "spatial_only": Query(near=(center, radius),
                              prox_weight=jnp.asarray(1.0, jnp.float32),
                              labels=tuple(range(10)), k=K),
    }


def _time_plan(plan, target, spec, reps: int) -> float:
    for _ in range(2):                                   # warm the jit
        jax.block_until_ready(plan(target, spec).scores)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(plan(target, spec).scores)
    return (time.perf_counter() - t0) / reps * 1e3


def run(full: bool = False, smoke: bool = False, use_pallas: bool = False):
    sizes = [256] if smoke else [1_000, 10_000, 30_000]
    reps = 5 if smoke else 20
    out = {"k": K, "embed_dim": EDIM, "use_pallas": use_pallas}
    for n in sizes:
        st = synthetic_store(n, n, EDIM, 16, seed=0,
                             centroid_low=(-8.0, 0.0, -8.0),
                             centroid_high=(8.0, 2.0, 8.0))
        qe = st.embed[n // 2]
        center = st.centroid[n // 2]
        row = {}
        for name, spec in _specs(qe, center).items():
            plan = compile_query(spec, st, use_pallas=use_pallas)
            row[name] = _time_plan(plan, st, spec, reps)
            csv_row(f"query_engine[{n},{name}]", row[name] * 1e3,
                    f"k={K};pallas={int(use_pallas)}")
        # serving amortization: 16 same-plan queries, one fused dispatch
        qs = jnp.tile(qe[None], (16, 1))
        cs = jnp.tile(center[None], (16, 1))
        bspec = Query(embed=qs, near=(cs, jnp.full((16,), 4.0, jnp.float32)),
                      prox_weight=jnp.full((16,), 0.2, jnp.float32),
                      k=K, batched=True)
        bplan = compile_query(bspec, st, use_pallas=use_pallas)
        bt = _time_plan(bplan, st, bspec, reps)
        row["batched16"] = bt
        row["batched16_per_query"] = bt / 16
        csv_row(f"query_engine[{n},batched16]", bt * 1e3,
                f"per_query_ms={bt / 16:.3f}")
        heavy = max(row["embed_spatial"], row["embed_attrs"],
                    row["full_mix"])
        row["predicate_overhead_x"] = heavy / row["embed_only"]
        out[str(n)] = row
    mid = str(sizes[min(1, len(sizes) - 1)])
    out["fused_within_1_2x"] = bool(
        out[mid]["predicate_overhead_x"] <= 1.2)
    out["sub_100ms_at_10k"] = bool(out[mid]["full_mix"] < 100.0)
    csv_row("query_engine[overhead@10k]",
            out[mid]["predicate_overhead_x"] * 1e6,
            f"fused_within_1.2x={out['fused_within_1_2x']};"
            f"sub_100ms={out['sub_100ms_at_10k']}")
    return out


if __name__ == "__main__":
    run()
