"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper artifact it reproduces).  ``--json`` additionally writes
``BENCH_<suite>.json`` at the repo root so the perf trajectory is tracked
across PRs (see EXPERIMENTS.md)."""
import argparse
import inspect
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks import (downstream_bw, fault_tolerance, fleet_scale,
                        ingest_tick, local_map_scale, mapping_latency,
                        power_model, query_engine, query_latency, roofline,
                        scenario_suite, serving_loop, upstream_bw)

SUITES = {
    "tab4_fig3_mapping": mapping_latency.run,
    "fig4_query": query_latency.run,
    "fig5_local_map": local_map_scale.run,
    "fig6_downstream": downstream_bw.run,
    "tab5_upstream": upstream_bw.run,
    "fig7_power": power_model.run,
    "roofline": roofline.run,
    "ingest_tick": ingest_tick.run,
    "fleet_scale": fleet_scale.run,
    "serving_loop": serving_loop.run,
    "query_engine": query_engine.run,
    "scenario_suite": scenario_suite.run,
    "fault_tolerance": fault_tolerance.run,
}


def _jsonable(obj):
    """Coerce suite return values (numpy scalars/arrays, dataclasses) to
    plain JSON types; drop anything that won't serialize."""
    import numpy as np
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    return repr(obj)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", "--suite", dest="only", default=None,
                    help="run one suite")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale scenes (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI smoke (suites that support it)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<suite>.json at the repo root")
    ap.add_argument("--git-sha", default=None,
                    help="commit sha stamped into BENCH_history entries "
                         "(caller-supplied; not sampled in-process)")
    ap.add_argument("--date", default=None,
                    help="ISO date stamped into BENCH_history entries")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---")
        kw = {"full": args.full}
        if args.smoke:
            if "smoke" not in inspect.signature(fn).parameters:
                # a suite without a smoke mode would run (and with --json
                # overwrite) its full-shape trajectory — skip it instead
                print(f"# {name}: no smoke mode, skipped")
                continue
            kw["smoke"] = True
        result = fn(**kw)
        if args.json:
            # smoke runs get their own file: never clobber the committed
            # full-shape perf trajectory with tiny-shape numbers
            suffix = "_smoke" if kw.get("smoke") else ""
            out = ROOT / f"BENCH_{name}{suffix}.json"
            payload = _jsonable(result)
            out.write_text(json.dumps(payload, indent=2) + "\n")
            print(f"# wrote {out}")
            # root file stays "latest"; history keeps the trajectory
            from repro.obs.trajectory import append_run
            hist = append_run(name, payload,
                              git_sha=args.git_sha, date=args.date,
                              smoke=bool(kw.get("smoke")))
            print(f"# appended {hist}")


if __name__ == '__main__':
    main()
