"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper artifact it reproduces)."""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import (downstream_bw, local_map_scale, mapping_latency,
                        power_model, query_latency, roofline, upstream_bw)

SUITES = {
    "tab4_fig3_mapping": mapping_latency.run,
    "fig4_query": query_latency.run,
    "fig5_local_map": local_map_scale.run,
    "fig6_downstream": downstream_bw.run,
    "tab5_upstream": upstream_bw.run,
    "fig7_power": power_model.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run one suite")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale scenes (slower)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---")
        fn(full=args.full)


if __name__ == '__main__':
    main()
