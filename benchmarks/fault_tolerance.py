"""Fault-tolerance suite: convergence cost under hostile networks.

Runs the churn workload through the hardened wire protocol (sequence
numbers, cumulative acks, gap-triggered resync, server retransmit) with a
seeded ``FaultModel`` and sweeps packet loss 0% / 1% / 5% / 20%, plus one
crash-recovery arm (a client dies mid-run and rejoins on a fresh epoch).
Per arm it reports: convergence (every client == the server live set after
drain), the tick the fleet quiesced at, downstream/upstream wire bytes,
resync requests, and the fault counters — the operational form of the
paper's Sec. 3.2 claim that queries stay serviceable across network drops.

Writes BENCH_fault_tolerance{,_smoke}.json via ``benchmarks/run.py
--suite fault_tolerance [--smoke] --json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.runtime import FaultModel
from repro.sim import CrashEvent, churn_scenario
from repro.sim.engine import ScenarioEngine

_SEED = 29
# fault-stream seed chosen so losses land even at smoke packet counts
_FSEED = 30


def _run_arm(name: str, *, faults: FaultModel, crashes: tuple = (),
             n_objects: int, n_ticks: int, n_clients: int,
             drain: int) -> dict:
    sc = churn_scenario(seed=_SEED, n_objects=n_objects, n_ticks=n_ticks,
                        n_clients=n_clients, drain_ticks=drain,
                        outage_frac=0.0, query_prob=0.0,
                        faults=faults, crash_events=crashes)
    eng = ScenarioEngine(sc)
    log = eng.run()

    srv = eng.world.live_ids()
    converged = all(
        set(np.asarray(s.dev.local.ids)[
            np.asarray(s.dev.local.active)].tolist()) == srv
        for s in eng.sessions.values())
    # quiesce tick: last tick that still moved bytes downstream — loss
    # pushes it later (retransmits + resync round trips extend the tail)
    busy = np.nonzero(log.sent_bytes.sum(axis=1) > 0)[0]
    quiesce_tick = int(busy[-1]) + 1 if len(busy) else 0
    s = log.summary()["exact"]
    out = {
        "converged": converged,
        "quiesce_tick": quiesce_tick,
        "n_ticks": s["n_ticks"],
        "n_clients": s["n_clients"],
        "down_bytes": s["sent_bytes_total"],
        "up_bytes": s["up_bytes_total"],
        "packets_lost": s["packets_lost"],
        "dup_drops": s["dup_drops"],
        "corrupt_drops": s["corrupt_drops"],
        "resync_requests": s["resync_requests"],
        "tick_ms_mean": float(np.mean(eng.wall_ms)),
    }
    csv_row(f"fault[{name}]", out["tick_ms_mean"] * 1e3,
            f"quiesce={quiesce_tick};downB={out['down_bytes']};"
            f"upB={out['up_bytes']};lost={out['packets_lost']};"
            f"resyncs={out['resync_requests']};converged={converged}")
    return out


def run(full: bool = False, smoke: bool = False):
    if smoke:
        shape = dict(n_objects=10, n_ticks=8, n_clients=2, drain=8)
        losses = (0.0, 0.20)
    else:
        shape = dict(n_objects=24, n_ticks=24, n_clients=4, drain=12)
        losses = (0.0, 0.01, 0.05, 0.20)
        if full:
            shape = dict(n_objects=60, n_ticks=40, n_clients=8, drain=16)

    results = {}
    for p in losses:
        f = FaultModel(seed=_FSEED, loss_prob=p)
        results[f"loss_{p:g}"] = _run_arm(f"loss={p:g}", faults=f, **shape)
    # crash-recovery: client 1 dies mid-run, rejoins on a fresh epoch and
    # must rebuild its map from scratch under 5% loss
    crash = (CrashEvent(tick=shape["n_ticks"] // 2, cid=1, down_ticks=2),)
    results["crash_recovery"] = _run_arm(
        "crash+loss=0.05",
        faults=FaultModel(seed=_FSEED, loss_prob=0.05),
        crashes=crash, **shape)

    for name, r in results.items():
        assert r["converged"], f"{name}: fleet did not converge!"
    # loss costs bytes, never correctness: the lossy tail is never cheaper
    base = results[f"loss_{losses[0]:g}"]
    worst = results[f"loss_{losses[-1]:g}"]
    assert worst["down_bytes"] >= base["down_bytes"]
    return results


if __name__ == "__main__":
    run()
