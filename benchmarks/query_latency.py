"""Paper Fig. 4: SQ vs LQ average query latency under two network
conditions (20 ms and ~66 ms RTT) and outage."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import build_map, csv_row, default_knobs, EDIM
from repro.core.runtime import CloudService, DeviceClient, NetworkModel, choose_mode


def run(full: bool = False):
    srv, emb, scene, _ = build_map(n_objects=40 if not full else 80,
                                   frames=40 if not full else 100)
    kn = default_knobs()
    cloud = CloudService(knobs=kn, store_ref=srv)
    dev = DeviceClient(knobs=kn, embed_dim=EDIM)
    dev.ingest(cloud.update_tick(network_up=True), user_pos=jnp.zeros(3))

    classes = sorted({o.class_id for o in scene.objects})[:8]
    # warm up jits
    cloud.query(emb.embed_text(classes[0]))
    dev.query(emb.embed_text(classes[0]))

    def time_queries(fn):
        t0 = time.perf_counter()
        for cid in classes:
            fn(emb.embed_text(cid))
        return (time.perf_counter() - t0) / len(classes) * 1e3

    # text-embedding constants reflect the paper's hardware asymmetry
    # (Sec. 5.2): the server embeds text far faster than the device.
    TEXT_EMBED_SERVER_MS = 2.0
    TEXT_EMBED_DEVICE_MS = 45.0

    sq_compute = time_queries(cloud.query) + TEXT_EMBED_SERVER_MS
    lq_ms = time_queries(dev.query) + TEXT_EMBED_DEVICE_MS
    out = {}
    for name, net in [("20ms", NetworkModel(rtt_ms=20.0)),
                      ("66ms", NetworkModel(rtt_ms=66.0)),
                      ("outage", NetworkModel(outages=((0.0, 1e9),)))]:
        mode = choose_mode(net, 0.0, kn)
        if mode == "SQ":
            total = sq_compute + net.transfer_ms(2 * EDIM) \
                + net.transfer_ms(6 * kn.max_object_points_client)
            total -= net.rtt_ms  # one RTT covers both legs
        else:
            total = lq_ms
        out[name] = {"mode": mode, "ms": total}
        csv_row(f"fig4_query_latency[{name}]", total * 1e3,
                f"mode={mode};sq_compute={sq_compute:.2f}ms;lq={lq_ms:.2f}ms")
    return out


if __name__ == "__main__":
    run()
