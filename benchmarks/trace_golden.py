"""Run the golden churn scenario with observability attached and dump the
Chrome trace (chrome://tracing / Perfetto) plus the metrics snapshot —
the CI artifacts for eyeballing where a tick's wall time went.

    PYTHONPATH=src python benchmarks/trace_golden.py \
        [--trace BENCH_trace.json] [--metrics BENCH_metrics.json]
"""
import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import (MetricsRegistry, Tracer, set_registry,  # noqa: E402
                       set_tracer)
from repro.sim import churn_scenario, run_scenario  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="BENCH_trace.json")
    ap.add_argument("--metrics", default="BENCH_metrics.json")
    ap.add_argument("--fenced", action="store_true",
                    help="block on fenced pytrees for honest span cost "
                         "attribution (adds syncs)")
    args = ap.parse_args()
    tr, reg = Tracer(fenced=args.fenced), MetricsRegistry()
    set_tracer(tr), set_registry(reg)
    try:
        # the tier-1 golden workload (tests/golden/regen.py)
        log = run_scenario(churn_scenario(
            seed=23, n_objects=20, n_ticks=20, n_clients=3,
            remove_frac=0.25, drain_ticks=8))
    finally:
        set_tracer(None), set_registry(None)
    tr.save(args.trace)
    reg.save(args.metrics)
    wall = log.summary().get("wall", {})
    print(f"wrote {args.trace} ({len(tr)} spans) and {args.metrics}")
    print(f"tick wall ms: p50={wall.get('p50', 0):.2f} "
          f"p95={wall.get('p95', 0):.2f} p99={wall.get('p99', 0):.2f}")


if __name__ == "__main__":
    main()
