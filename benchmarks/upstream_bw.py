"""Paper Tab. 5: upstream bandwidth vs semantic quality across depth
downsampling ratios (the object-level depth-mapping co-design)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_map, csv_row, default_knobs, semantic_quality
from repro.core.depth import upstream_mbps

# paper's sensor: 720x1280 RGB @5 Mbps H.264, 16-bit depth, keyframes at 5
H_FULL, W_FULL = 720, 1280
RATIOS = [1, 2, 3, 4, 5]


def run(full: bool = False):
    out = {}
    for r in RATIOS:
        kn = default_knobs(depth_downsampling_ratio=r,
                           min_mapping_bbox_area=2000 if r > 1 else 0)
        srv, emb, scene, _ = build_map(knobs=kn,
                                       n_objects=30 if not full else 60,
                                       frames=40 if not full else 100)
        q = semantic_quality(srv, emb, scene)
        mbps = upstream_mbps(H_FULL, W_FULL, kn, keyframe_interval=5)
        out[r] = {"mbps": mbps, **q}
        csv_row(f"tab5_upstream[{r}x{r}]", mbps * 1e3,
                f"bw={mbps:.2f}Mbps;F-mIoU={q['F-mIoU']:.1f};"
                f"mAcc={q['mAcc']:.1f};deferred={srv.deferred}")
    red = (1 - out[5]["mbps"] / out[1]["mbps"]) * 100
    csv_row("tab5_bw_reduction_5x", out[5]["mbps"] * 1e3,
            f"reduction={red:.0f}%;paper=~90%")

    # ablation: 5x downsampling WITHOUT the per-object deferral gate —
    # isolates the "mapping co-design" half of Sec. 3.3
    kn = default_knobs(depth_downsampling_ratio=5, min_mapping_bbox_area=0)
    srv, emb, scene, _ = build_map(knobs=kn, n_objects=30 if not full else 60,
                                   frames=40 if not full else 100)
    q = semantic_quality(srv, emb, scene)
    csv_row("tab5_upstream[5x5-nogate]", upstream_mbps(H_FULL, W_FULL, kn) * 1e3,
            f"F-mIoU={q['F-mIoU']:.1f};mAcc={q['mAcc']:.1f};deferred=0")
    return out


if __name__ == "__main__":
    run()
