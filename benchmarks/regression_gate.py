"""Perf-trajectory regression gate over the BENCH_* artifacts.

Compares the current ``BENCH_<suite>[_smoke].json`` files against a
baseline (the committed copy at a git ref, falling back to the latest
``BENCH_history/`` entry) using per-suite declarative tolerances:

- ``latency``        — wall-clock metric; fails when current exceeds
                       baseline by more than the tolerance ratio.
                       Generous by default: CI machines are noisy.
- ``exact``          — deterministic replay output (byte counts, query
                       counts); any drift is a contract break, not noise.
- ``invariant_true`` — boolean acceptance flag that must stay true.
- ``quality``        — accuracy metric; fails when current drops more
                       than the tolerance ratio below baseline.

CLI (nonzero exit on any FAIL, for CI)::

    python benchmarks/regression_gate.py --smoke --dashboard BENCH_gate.md
"""
import argparse
import fnmatch
import json
import subprocess
import sys
from dataclasses import dataclass
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


@dataclass(frozen=True)
class Check:
    pattern: str        # fnmatch over dot-joined key paths
    kind: str           # latency | exact | invariant_true | quality
    tol: float = 0.0    # ratio, for latency/quality


# Wall-clock tolerance is deliberately loose (50%): the gate exists to
# catch order-of-magnitude regressions (a lost fusion, an accidental
# sync), not 10% scheduler jitter on shared CI runners.
LAT = 0.5

SPECS = {
    "scenario_suite": [
        Check("*replay_bit_identical", "invariant_true"),
        Check("*converged", "invariant_true"),
        Check("*tick_ms_mean", "latency", LAT),
        Check("*sent_bytes_total", "exact"),
        Check("*tombstone_bytes", "exact"),
        Check("*sq_queries", "exact"),
        Check("*lq_queries", "exact"),
    ],
    "fault_tolerance": [
        Check("*.converged", "invariant_true"),
        Check("*.down_bytes", "exact"),
        Check("*.up_bytes", "exact"),
        Check("*.resync_requests", "exact"),
        Check("*.tick_ms_mean", "latency", LAT),
    ],
    "query_engine": [
        Check("*.full_mix", "latency", LAT),
        Check("*.embed_only", "latency", LAT),
        Check("*.batched16_per_query", "latency", LAT),
        # worst-over-sizes fusion overhead is tracked as a band, not a
        # fixed 1.2x invariant: the seed's flag was computed from the 10k
        # row only and a hard threshold flaps on dispatch-bound noise
        Check("predicate_overhead_worst_x", "latency", LAT),
        Check("sub_100ms_at_10k", "invariant_true"),
        Check("sub_100ms_at_1m", "invariant_true"),
        # exactness of the coarse-to-fine plan: numpy flat-sweep oracle
        # parity at every size, and two-stage byte-equal to the flat sweep
        Check("oracle_parity_all", "invariant_true"),
        Check("index_matches_flat_all", "invariant_true"),
        Check("*.index_matches_flat", "invariant_true"),
    ],
    "fleet_scale": [
        Check("sweep.*.tick_ms", "latency", LAT),
        Check("sweep.*.tick_ms_p99", "latency", LAT),
        Check("sweep.*.tick_ms_sharded", "latency", LAT),
        Check("sweep.*.tick_ms_sharded_p99", "latency", LAT),
        Check("sweep.*.per_client_bytes", "exact"),
        # the mesh-sharded session tier is a placement change ONLY: its
        # wire packets must stay bit-identical to the single-device path,
        # and the sharded per-tick cost must grow sub-linearly in C
        Check("sweep.*.byte_identical_to_unsharded", "invariant_true"),
        Check("sharding.byte_identical_to_unsharded", "invariant_true"),
        Check("sharding.sublinear", "invariant_true"),
        Check("sublinear", "invariant_true"),
    ],
    "serving_loop": [
        # throughput band: overlapped ticks/s must not drop >50% (noisy
        # CI wall clock; the gate hunts lost overlap, not jitter)
        Check("arms.*.ticks_per_s", "quality", LAT),
        # equal-output contract: the overlap is a scheduling change ONLY
        Check("query_results_equal", "invariant_true"),
        Check("final_store_equal", "invariant_true"),
        Check("sent_bytes_equal", "invariant_true"),
        Check("golden_replay_bit_identical", "invariant_true"),
        # p99 under load must keep being measured over every served query
        Check("p99_under_load_ok", "invariant_true"),
        Check("arms.*.n_queries_served", "exact"),
        # full-scale only (absent from the smoke artifact -> honest SKIP)
        Check("overlap_speedup_ge_1_5", "invariant_true"),
    ],
    "tab4_fig3_mapping": [
        Check("*.total_ms", "latency", LAT),
        Check("*.stage_ms.*", "latency", LAT),
        Check("*.mAcc", "quality", 0.05),
        Check("*.n_mapped", "exact"),
    ],
    "ingest_tick": [
        Check("collect_ms", "latency", LAT),
        Check("ingest_batched_ms", "latency", LAT),
        Check("packet_bytes", "exact"),
    ],
}


# ---------------------------------------------------------------- helpers
def flatten(obj, prefix=""):
    """Dict tree -> {dot.path: leaf} for pattern matching."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten(v, p))
    else:
        out[prefix] = obj
    return out


def compare_suite(checks, baseline, current):
    """Run every check over every matching key path.

    Returns a list of row dicts: suite-agnostic, ready for the dashboard.
    A pattern that matches nothing yields a single SKIP row so silent
    spec/artifact drift is visible.
    """
    base, cur = flatten(baseline), flatten(current)
    rows = []
    for ck in checks:
        keys = sorted(k for k in cur if fnmatch.fnmatch(k, ck.pattern))
        if not keys:
            rows.append(dict(metric=ck.pattern, kind=ck.kind,
                             baseline=None, current=None,
                             status="SKIP", detail="pattern matched nothing"))
            continue
        for k in keys:
            c = cur[k]
            b = base.get(k)
            row = dict(metric=k, kind=ck.kind, baseline=b, current=c)
            if ck.kind == "invariant_true":
                ok = bool(c) is True
                row.update(status="PASS" if ok else "FAIL",
                           detail="" if ok else "invariant is false")
            elif b is None:
                row.update(status="SKIP", detail="no baseline value")
            elif ck.kind == "exact":
                ok = c == b
                row.update(status="PASS" if ok else "FAIL",
                           detail="" if ok else f"{b!r} -> {c!r}")
            elif ck.kind == "latency":
                limit = float(b) * (1.0 + ck.tol)
                ok = float(c) <= limit or float(c) - float(b) < 1e-9
                row.update(status="PASS" if ok else "FAIL",
                           detail="" if ok else
                           f"{float(c):.3f} > {float(b):.3f}*{1 + ck.tol:g}")
            elif ck.kind == "quality":
                floor = float(b) * (1.0 - ck.tol)
                ok = float(c) >= floor
                row.update(status="PASS" if ok else "FAIL",
                           detail="" if ok else
                           f"{float(c):.3f} < {float(b):.3f}*{1 - ck.tol:g}")
            else:
                row.update(status="SKIP", detail=f"unknown kind {ck.kind}")
            rows.append(row)
    return rows


def load_baseline(suite, *, smoke, ref="HEAD", root=None, history_dir=None):
    """Committed artifact at ``ref`` (benchmark runs overwrite the working
    tree copy, so the git object is the true pre-run baseline), else the
    newest BENCH_history entry, else None."""
    root = Path(root) if root is not None else ROOT
    name = f"BENCH_{suite}{'_smoke' if smoke else ''}.json"
    try:
        blob = subprocess.run(
            ["git", "-C", str(root), "show", f"{ref}:{name}"],
            capture_output=True, text=True, check=True).stdout
        return json.loads(blob), f"git:{ref}:{name}"
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        pass
    from repro.obs.trajectory import latest_run
    entry = latest_run(suite, smoke=smoke, history_dir=history_dir)
    if entry is not None:
        return entry["result"], f"history:{entry.get('git_sha')}"
    return None, None


def load_current(suite, *, smoke, root=None):
    root = Path(root) if root is not None else ROOT
    p = root / f"BENCH_{suite}{'_smoke' if smoke else ''}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def run_gate(suites=None, *, smoke=False, ref="HEAD", root=None,
             history_dir=None):
    """Gate every requested suite; returns (all_rows, n_fail)."""
    all_rows = []
    n_fail = 0
    for suite in (suites or SPECS):
        checks = SPECS.get(suite)
        if checks is None:
            all_rows.append((suite, None, [dict(
                metric="-", kind="-", baseline=None, current=None,
                status="SKIP", detail="no spec for suite")]))
            continue
        current = load_current(suite, smoke=smoke, root=root)
        if current is None:
            all_rows.append((suite, None, [dict(
                metric="-", kind="-", baseline=None, current=None,
                status="SKIP", detail="no current artifact")]))
            continue
        baseline, src = load_baseline(suite, smoke=smoke, ref=ref,
                                      root=root, history_dir=history_dir)
        if baseline is None:
            all_rows.append((suite, None, [dict(
                metric="-", kind="-", baseline=None, current=None,
                status="SKIP", detail="no baseline found")]))
            continue
        rows = compare_suite(checks, baseline, current)
        n_fail += sum(r["status"] == "FAIL" for r in rows)
        all_rows.append((suite, src, rows))
    return all_rows, n_fail


def _fmt(v):
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def dashboard_md(all_rows, *, smoke):
    lines = [f"# BENCH regression gate ({'smoke' if smoke else 'full'})", ""]
    for suite, src, rows in all_rows:
        n_fail = sum(r["status"] == "FAIL" for r in rows)
        verdict = "FAIL" if n_fail else "ok"
        lines += [f"## {suite} — {verdict}"
                  + (f"  (baseline: `{src}`)" if src else ""), "",
                  "| metric | kind | baseline | current | status | detail |",
                  "|---|---|---|---|---|---|"]
        for r in rows:
            lines.append(
                f"| {r['metric']} | {r['kind']} | {_fmt(r['baseline'])} "
                f"| {_fmt(r['current'])} | {r['status']} | {r['detail']} |")
        lines.append("")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", action="append", default=None,
                    help="gate one suite (repeatable; default: all specs)")
    ap.add_argument("--smoke", action="store_true",
                    help="gate the *_smoke artifacts")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the baseline artifacts")
    ap.add_argument("--dashboard", default=None,
                    help="write a markdown dashboard to this path")
    args = ap.parse_args(argv)
    all_rows, n_fail = run_gate(args.suite, smoke=args.smoke, ref=args.ref)
    md = dashboard_md(all_rows, smoke=args.smoke)
    if args.dashboard:
        Path(args.dashboard).write_text(md)
    for suite, src, rows in all_rows:
        for r in rows:
            if r["status"] != "PASS":
                print(f"{suite}: {r['status']} {r['metric']} {r['detail']}")
    print(f"regression gate: {n_fail} failure(s)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
