"""Paper Tab. 4 + Fig. 3: server-side mapping latency decomposition and
semantic quality across cumulative configurations:
  B       device-cloud baseline (frame-level execution, uncapped geometry)
  B+P     + object-level parallelism
  B+P+SD  + object-level geometry downsampling (= SemanticXR)
Same perception models in every mode; differences are system organization.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_map, csv_row, default_knobs, semantic_quality

MODES = [("B", "baseline"), ("B+P", "parallel"), ("B+P+SD", "semanticxr")]


def run(full: bool = False):
    n_objects, frames = (80, 100) if full else (30, 40)
    rows = {}
    for label, mode in MODES:
        kn = default_knobs()
        if mode != "semanticxr":
            # baseline carries uncapped per-object geometry into association
            kn = default_knobs(max_object_points_server=2048)
        srv, emb, scene, times = build_map(mode=mode, n_objects=n_objects,
                                           frames=frames, knobs=kn)
        warm = times[2:]                       # drop jit-compile frames
        stage = {
            "detect": np.mean([t.detect_ms for t in warm]),
            "embed": np.mean([t.embed_ms for t in warm]),
            "lift": np.mean([t.lift_ms for t in warm]),
            "associate": np.mean([t.associate_ms for t in warm]),
        }
        total = sum(stage.values())
        q = semantic_quality(srv, emb, scene)
        rows[label] = {"stage_ms": stage, "total_ms": total, **q}
        csv_row(f"fig3_mapping_latency[{label}]", total * 1e3,
                f"mAcc={q['mAcc']:.1f};F-mIoU={q['F-mIoU']:.1f};"
                + ";".join(f"{k}={v:.1f}ms" for k, v in stage.items()))
    speedup = rows["B"]["total_ms"] / rows["B+P+SD"]["total_ms"]
    csv_row("tab4_speedup_BPSD_over_B", rows["B+P+SD"]["total_ms"] * 1e3,
            f"speedup={speedup:.2f}x;paper=2.2x")
    return rows


if __name__ == "__main__":
    run()
