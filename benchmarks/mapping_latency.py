"""Paper Tab. 4 + Fig. 3: server-side mapping latency decomposition and
semantic quality across cumulative configurations:
  B       device-cloud baseline (frame-level execution, uncapped geometry)
  B+P     + object-level parallelism
  B+P+SD  + object-level geometry downsampling (= SemanticXR)
Same perception models in every mode; differences are system organization.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_map, csv_row, default_knobs, semantic_quality
from repro.core import association as assoc

MODES = [("B", "baseline"), ("B+P", "parallel"), ("B+P+SD", "semanticxr")]


def _associate_microbench(srv, kn, reps: int = 20):
    """Batched associate vs the seed sequential-scan path, identical shapes:
    the warm store from the B+P+SD run plus one synthetic full detection
    batch.  This is the tentpole speedup, measured not asserted."""
    D = kn.max_detections_per_frame
    P = srv.store.points.shape[1]
    E = srv.store.embed.shape[1]
    key = jax.random.key(7)
    ke, kp = jax.random.split(key)
    emb = jax.random.normal(ke, (D, E), jnp.float32)
    emb = emb / jnp.linalg.norm(emb, axis=1, keepdims=True)
    det = assoc.Detections(
        embed=emb,
        label=jnp.arange(D, dtype=jnp.int32),
        points=jax.random.normal(kp, (D, P, 3), jnp.float32),
        n_points=jnp.full((D,), P, jnp.int32),
        valid=jnp.ones((D,), bool),
    )
    budget = kn.max_object_points_server
    batched = jax.jit(lambda st, d, fr: assoc.associate(
        st, d, frame=fr, point_budget=budget))
    scan = jax.jit(lambda st, d, fr: assoc.associate_reference(
        st, d, frame=fr, point_budget=budget))

    def timed(fn):
        out = fn(srv.store, det, jnp.asarray(0))    # compile
        jax.block_until_ready(out.active)
        t0 = time.perf_counter()
        for r in range(reps):
            out = fn(srv.store, det, jnp.asarray(r))
            jax.block_until_ready(out.active)
        return (time.perf_counter() - t0) / reps * 1e3

    batched_ms = timed(batched)
    scan_ms = timed(scan)
    return batched_ms, scan_ms


def run(full: bool = False):
    n_objects, frames = (80, 100) if full else (30, 40)
    rows = {}
    for label, mode in MODES:
        kn = default_knobs()
        if mode != "semanticxr":
            # baseline carries uncapped per-object geometry into association
            kn = default_knobs(max_object_points_server=2048)
        srv, emb, scene, times = build_map(mode=mode, n_objects=n_objects,
                                           frames=frames, knobs=kn)
        warm = times[2:]                       # drop jit-compile frames
        stage = {
            "detect": np.mean([t.detect_ms for t in warm]),
            "embed": np.mean([t.embed_ms for t in warm]),
            "lift": np.mean([t.lift_ms for t in warm]),
            "associate": np.mean([t.associate_ms for t in warm]),
        }
        total = sum(stage.values())
        q = semantic_quality(srv, emb, scene)
        rows[label] = {"stage_ms": stage, "total_ms": total, **q}
        csv_row(f"fig3_mapping_latency[{label}]", total * 1e3,
                f"mAcc={q['mAcc']:.1f};F-mIoU={q['F-mIoU']:.1f};"
                + ";".join(f"{k}={v:.1f}ms" for k, v in stage.items()))
    speedup = rows["B"]["total_ms"] / rows["B+P+SD"]["total_ms"]
    csv_row("tab4_speedup_BPSD_over_B", rows["B+P+SD"]["total_ms"] * 1e3,
            f"speedup={speedup:.2f}x;paper=2.2x")

    # tentpole: batched associate vs the seed scan path, identical shapes
    batched_ms, scan_ms = _associate_microbench(srv, kn)
    assoc_speedup = scan_ms / max(batched_ms, 1e-9)
    csv_row("associate_batched_vs_scan", batched_ms * 1e3,
            f"batched={batched_ms:.2f}ms;scan_seed={scan_ms:.2f}ms;"
            f"speedup={assoc_speedup:.2f}x;target>=2x")
    rows["associate_microbench"] = {
        "batched_ms": batched_ms, "scan_seed_ms": scan_ms,
        "speedup": assoc_speedup,
    }
    return rows


if __name__ == "__main__":
    run()
