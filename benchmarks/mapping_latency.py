"""Paper Tab. 4 + Fig. 3: server-side mapping latency decomposition and
semantic quality across cumulative configurations:
  B       device-cloud baseline (frame-level execution, uncapped geometry)
  B+P     + object-level parallelism
  B+P+SD  + object-level geometry downsampling (= SemanticXR)
Same perception models in every mode; differences are system organization.

The B+P+SD arm runs instrumented (per-stage walls) so the Fig. 3 bar
decomposition stays measurable; its lift bar is the fused
lift->compact->downsample->stats kernel (kernels/lift_compact).  A fourth
row, ``B+P+SD (fused)``, is the production path: ONE jitted ingest dispatch
per keyframe.  Two microbenches pin the PR 1 / PR 4 tentpoles at identical
shapes (associate batched-vs-scan, lift fused-vs-seed), and a jaxpr guard
verifies the fused lift never materializes a [D, HW, 3] intermediate —
the seed composition is checked too, as a positive control.
"""
from __future__ import annotations

import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_map, csv_row, default_knobs, semantic_quality
from repro.core import association as assoc
from repro.core import geometry as geo
from repro.core.pipeline import LIFT_BUFFER
from repro.data.scenes import render_frame
from repro.kernels import ops

MODES = [("B", "baseline"), ("B+P", "parallel"), ("B+P+SD", "semanticxr")]


def _max_intermediate_elems(closed_jaxpr) -> int:
    """Largest intermediate (by element count) anywhere in a jaxpr,
    recursing into pjit/scan/cond sub-jaxprs."""
    worst = 0

    def walk(jaxpr):
        nonlocal worst
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                shape = getattr(getattr(v, "aval", None), "shape", None)
                if shape:
                    worst = max(worst, int(np.prod(shape)))
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (list, tuple)) else [val]):
                    if hasattr(sub, "jaxpr") and hasattr(sub.jaxpr, "eqns"):
                        walk(sub.jaxpr)
                    elif hasattr(sub, "eqns"):
                        walk(sub)

    walk(closed_jaxpr.jaxpr)
    return worst


def _timed(fn, args, reps: int):
    out = fn(*args)                                   # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def _seed_lift_composition(stride: int, budget: int):
    """The pre-fusion lift path: vmapped argsort lift + separate downsample
    + per-detection centroid/bbox — the baseline the fused kernel replaces."""
    def fn(depth, masks, intr, pose):
        pts, ns, _ = jax.vmap(
            partial(geo.lift_depth, stride=stride, max_points=LIFT_BUFFER),
            in_axes=(None, 0, None, None))(depth, masks, intr, pose)
        pts, ns = jax.vmap(lambda p, n: geo.downsample(p, n, budget))(pts, ns)
        c, mn, mx = jax.vmap(geo.centroid_bbox)(pts, ns)
        return pts, ns, c, mn, mx
    return fn


def _lift_microbench(scene, classes, srv, kn, *, h, w, frames, reps=30):
    """Fused lift_compact vs the seed composition at identical shapes, plus
    the no-[D, HW, 3]-intermediate guard on both."""
    r = kn.depth_downsampling_ratio
    D = kn.max_detections_per_frame
    fr = render_frame(scene, frames // 2, h=h, w=w, n_frames=frames)
    _, masks_lo = srv._detect(fr, classes)
    pad_m = np.zeros((D,) + masks_lo.shape[1:], bool)
    pad_m[: len(masks_lo)] = masks_lo
    depth_lo = jnp.asarray(fr.depth[::r, ::r] if r > 1 else fr.depth)
    masks = jnp.asarray(pad_m)
    intr = jnp.asarray(fr.intrinsics)
    pose = jnp.asarray(fr.pose, jnp.float32)
    budget = kn.max_object_points_server

    fused = jax.jit(partial(ops.lift_compact, stride=r, budget=budget,
                            lift_cap=LIFT_BUFFER))
    seed = jax.jit(_seed_lift_composition(r, budget))
    args = (depth_lo, masks, intr, pose)
    fused_ms = _timed(fused, args, reps)
    seed_ms = _timed(seed, args, reps)

    # acceptance guard: nothing in the fused jaxpr reaches [D, HW, 3]
    hw = int(np.prod(depth_lo.shape))
    limit = D * hw * 3
    fused_max = _max_intermediate_elems(jax.make_jaxpr(fused)(*args))
    seed_max = _max_intermediate_elems(jax.make_jaxpr(seed)(*args))
    assert fused_max < limit, (
        f"fused lift materializes a {fused_max}-element intermediate "
        f"(>= D*HW*3 = {limit})")
    return {
        "fused_ms": fused_ms, "seed_ms": seed_ms,
        "speedup": seed_ms / max(fused_ms, 1e-9),
        "max_intermediate_elems": {"fused": fused_max, "seed": seed_max},
        "dhw3_elems": limit,
        "fused_materializes_dhw3": bool(fused_max >= limit),
        "seed_materializes_dhw3": bool(seed_max >= limit),
    }


def _associate_microbench(srv, kn, reps: int = 20):
    """Batched associate vs the seed sequential-scan path, identical shapes:
    the warm store from the B+P+SD run plus one synthetic full detection
    batch.  This is the PR 1 tentpole speedup, measured not asserted."""
    D = kn.max_detections_per_frame
    P = srv.store.points.shape[1]
    E = srv.store.embed.shape[1]
    key = jax.random.key(7)
    ke, kp = jax.random.split(key)
    emb = jax.random.normal(ke, (D, E), jnp.float32)
    emb = emb / jnp.linalg.norm(emb, axis=1, keepdims=True)
    det = assoc.Detections(
        embed=emb,
        label=jnp.arange(D, dtype=jnp.int32),
        points=jax.random.normal(kp, (D, P, 3), jnp.float32),
        n_points=jnp.full((D,), P, jnp.int32),
        valid=jnp.ones((D,), bool),
    )
    budget = kn.max_object_points_server
    batched = jax.jit(lambda st, d, fr: assoc.associate(
        st, d, frame=fr, point_budget=budget))
    scan = jax.jit(lambda st, d, fr: assoc.associate_reference(
        st, d, frame=fr, point_budget=budget))

    def timed(fn):
        out = fn(srv.store, det, jnp.asarray(0))    # compile
        jax.block_until_ready(out.active)
        t0 = time.perf_counter()
        for r in range(reps):
            out = fn(srv.store, det, jnp.asarray(r))
            jax.block_until_ready(out.active)
        return (time.perf_counter() - t0) / reps * 1e3

    batched_ms = timed(batched)
    scan_ms = timed(scan)
    return batched_ms, scan_ms


def run(full: bool = False, smoke: bool = False):
    if smoke:
        n_objects, frames, h, w = 10, 20, 120, 160
    elif full:
        n_objects, frames, h, w = 80, 100, 240, 320
    else:
        n_objects, frames, h, w = 30, 40, 240, 320
    rows = {}
    for label, mode in MODES:
        kn = default_knobs()
        if mode != "semanticxr":
            # baseline carries uncapped per-object geometry into association
            kn = default_knobs(max_object_points_server=2048)
        srv, emb, scene, times = build_map(mode=mode, n_objects=n_objects,
                                           frames=frames, h=h, w=w, knobs=kn,
                                           instrument=True)
        warm = times[2:]                       # drop jit-compile frames
        stage = {
            "detect": np.mean([t.detect_ms for t in warm]),
            "embed": np.mean([t.embed_ms for t in warm]),
            "lift": np.mean([t.lift_ms for t in warm]),
            "associate": np.mean([t.associate_ms for t in warm]),
        }
        total = sum(stage.values())
        q = semantic_quality(srv, emb, scene)
        rows[label] = {"stage_ms": stage, "total_ms": total, **q}
        csv_row(f"fig3_mapping_latency[{label}]", total * 1e3,
                f"mAcc={q['mAcc']:.1f};F-mIoU={q['F-mIoU']:.1f};"
                + ";".join(f"{k}={v:.1f}ms" for k, v in stage.items()))
    speedup = rows["B"]["total_ms"] / rows["B+P+SD"]["total_ms"]
    csv_row("tab4_speedup_BPSD_over_B", rows["B+P+SD"]["total_ms"] * 1e3,
            f"speedup={speedup:.2f}x;paper=2.2x")

    # --- production path: one jitted ingest dispatch per keyframe
    srv_f, emb_f, scene_f, times_f = build_map(
        mode="semanticxr", n_objects=n_objects, frames=frames, h=h, w=w,
        knobs=default_knobs())
    warm_f = times_f[2:]
    stage_f = {
        "detect": np.mean([t.detect_ms for t in warm_f]),
        "ingest": np.mean([t.ingest_ms for t in warm_f]),
    }
    qf = semantic_quality(srv_f, emb_f, scene_f)
    rows["B+P+SD (fused)"] = {
        "stage_ms": stage_f, "total_ms": sum(stage_f.values()), **qf,
    }
    csv_row("fig3_mapping_latency[B+P+SD (fused)]",
            rows["B+P+SD (fused)"]["total_ms"] * 1e3,
            f"mAcc={qf['mAcc']:.1f};F-mIoU={qf['F-mIoU']:.1f};"
            + ";".join(f"{k}={v:.1f}ms" for k, v in stage_f.items()))

    # --- tentpole microbenches at identical shapes
    classes = {o.oid: o.class_id for o in scene_f.objects}
    lift = _lift_microbench(scene_f, classes, srv_f, default_knobs(),
                            h=h, w=w, frames=frames)
    csv_row("lift_fused_vs_seed", lift["fused_ms"] * 1e3,
            f"fused={lift['fused_ms']:.2f}ms;seed={lift['seed_ms']:.2f}ms;"
            f"speedup={lift['speedup']:.2f}x;target>=3x;"
            f"no_dhw3={not lift['fused_materializes_dhw3']}")
    rows["lift_microbench"] = lift

    batched_ms, scan_ms = _associate_microbench(srv, kn)
    assoc_speedup = scan_ms / max(batched_ms, 1e-9)
    csv_row("associate_batched_vs_scan", batched_ms * 1e3,
            f"batched={batched_ms:.2f}ms;scan_seed={scan_ms:.2f}ms;"
            f"speedup={assoc_speedup:.2f}x;target>=2x")
    rows["associate_microbench"] = {
        "batched_ms": batched_ms, "scan_seed_ms": scan_ms,
        "speedup": assoc_speedup,
    }
    return rows


if __name__ == "__main__":
    run()
