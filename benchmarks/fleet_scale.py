"""Fleet-scale benchmark: server tick cost and per-client downstream bytes
vs fleet size C (the multi-tenant server subsystem, server/session.py).

At a FIXED map size, one update tick for C clients is a single vmapped
`_collect_fleet` dispatch ([C, N] change detection + priority top-k +
fused gather/downsample).  The headline number is tick latency growth from
C=1 to C=64: sub-linear (<< C×) because the per-client work amortizes into
one dispatch instead of C Python-loop iterations (the seed architecture).
The `seed_loop` row measures exactly that loop — C independent
`collect_updates` calls at identical shapes — so the speedup is measured,
not asserted.

Per-client downstream bytes stay constant in C (each client receives the
same changed set), which is the scaling story: downstream work ∝ per-client
map changes, not fleet size.

Tick latency is reported as exact p50/p95/p99/mean over every timed rep
(folded through a ``repro.obs`` histogram, label C), not a single mean —
tail behaviour is the serving story and a mean hides it.  The sweep runs
to C=4096; the seed-architecture comparison loop (C sequential
single-client collects) is measured up to C=256 and skipped above, where
its Python loop would dominate the suite's wall clock.

Every C >= MESH_SHARDS also times the MESH-SHARDED session tier
(server.mesh.MeshSessionTier): the [C, N] sync state is partitioned
across shard parts and each part runs its own vmapped collect.  Because
every per-client row of the collect is computed independently, a shard's
packet rows must be BIT-identical to the same clients' rows in the
unsharded collect — checked here on fresh sessions (equal seq state)
field-by-field and reported as ``byte_identical_to_unsharded``.  Two
latencies are reported: ``tick_ms_sharded`` runs MESH_SHARDS parts
back-to-back on this container's single device (linear in C by
construction — an honest serial number), and ``tick_ms_mesh_projected``
is the per-device wall clock of a mesh deployment that scales shard
count with the fleet (~MESH_CLIENTS_PER_SHARD clients per device, parts
collecting in parallel, wall clock = slowest part; excludes the
host-side wire-boundary merge).  The ``sharding.sublinear`` flag is the
mesh-projected growth C=256 -> C=4096 in the non-smoke artifact.

Writes BENCH_fleet_scale.json via ``benchmarks/run.py --suite fleet_scale
--json``; smoke mode (CI) runs C ∈ {1, 2, 4} at tiny shapes.
"""
from __future__ import annotations

import gc
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.knobs import Knobs
from repro.core.store import synthetic_store
from repro.core.updates import collect_updates, init_sync
from repro.core.local_map import compute_priority
from repro.obs import metrics as obs_metrics
from repro.server.mesh import ClientRoster, MeshSessionTier
from repro.server.session import SessionManager

SEED_LOOP_MAX_C = 256      # the C-iteration Python loop above this is
#                            minutes of wall clock for a known-linear curve
MESH_SHARDS = 4            # session-tier parts for the serial sharded arm
MESH_CLIENTS_PER_SHARD = 256   # mesh projection: devices scale with C so
#                                every shard serves a bounded client slice


def _time_samples(fn, *, reps: int, warmup: int = 3,
                  rounds: int = 3) -> list:
    """Per-call wall-time samples (ms) over ``rounds`` x ``reps`` calls —
    the container's wall clock is noisy enough (CPU scaling, GC) that a
    single mean can be 5-10x off; keeping every sample gives exact
    nearest-rank percentiles instead.  A collector pass before each round
    keeps Python GC pauses (the suite now allocates whole session tiers
    per C) out of the timed window — they would land as fake p99 tail."""
    for _ in range(warmup):
        fn()
    out = []
    for _ in range(rounds):
        gc.collect()
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            out.append((time.perf_counter() - t0) * 1e3)
    return out


def _time(fn, *, reps: int, warmup: int = 3) -> float:
    """Best-of-3 mean (legacy single-number path, kept for seed_loop)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps * 1e3)
    return best


def _mesh_identical(mesh_pkt, ref_pkt, roster) -> bool:
    """Bit-identity of a sharded tier packet against the unsharded
    reference: assembled wire accounting (counts/nbytes/seqs) plus every
    shard's batch tensors against the same clients' rows in the reference
    batch.  Both packets must come from sessions with equal seq state
    (fresh sessions, first collect)."""
    ok = (np.array_equal(np.asarray(mesh_pkt.counts),
                         np.asarray(ref_pkt.counts))
          and np.array_equal(np.asarray(mesh_pkt.nbytes),
                             np.asarray(ref_pkt.nbytes))
          and np.array_equal(np.asarray(mesh_pkt.seqs),
                             np.asarray(ref_pkt.seqs)))
    for s, pp in enumerate(mesh_pkt.parts):
        if pp is None:
            continue
        m = np.asarray(roster.members[s])
        for a, b in zip(pp.batch, ref_pkt.batch):
            if np.asarray(a).tobytes() != np.asarray(b)[m].tobytes():
                return False
    return ok


def run(full: bool = False, smoke: bool = False):
    if smoke:
        sweep, n_obj, cap, E, P, budget, reps = \
            [1, 2, 4], 24, 64, 32, 32, 16, 3
    elif full:
        sweep, n_obj, cap, E, P, budget, reps = \
            [1, 8, 64, 256, 512, 1024, 2048, 4096], 256, 512, 256, 512, 32, 10
    else:
        sweep, n_obj, cap, E, P, budget, reps = \
            [1, 8, 64, 256, 512, 1024, 2048, 4096], 128, 256, 128, 256, 32, 10
    kn = Knobs(server_capacity=cap, client_capacity=max(budget * 2, 64),
               max_object_points_server=P,
               max_object_points_client=max(P // 4, 16),
               min_obs_before_sync=1)
    store = synthetic_store(n_obj, cap, E, P)

    results = {"map_objects": n_obj, "capacity": cap, "embed_dim": E,
               "budget": budget, "sweep": {}}
    reg = obs_metrics.get_registry() or obs_metrics.MetricsRegistry()
    hist = reg.histogram("fleet_tick_ms",
                         "fleet collect tick wall time by fleet size")
    lat_by_c = {}
    sharded_lat = {}
    mesh_lat = {}
    ident_by_c = {}
    for C in sweep:
        sm = SessionManager(knobs=kn, n_clients=C, capacity=cap,
                            budget=budget)
        fresh = jnp.zeros((C, cap), jnp.int32)

        def tick_once():
            # every rep ships the top-`budget` changed objects to every
            # client: reset the sync rows so per-tick work is constant
            sm.sync = sm.sync._replace(synced_version=fresh)
            pkt = sm.collect(store)
            return pkt

        # big fleets get fewer reps: one rep is slow enough to be stable
        c_reps = reps if C <= 256 else max(reps // 3, 2)
        samples = _time_samples(tick_once, reps=c_reps)
        for s in samples:
            hist.observe(s, C=C)
        pct = obs_metrics.exact_percentiles(samples)
        ms = pct["p50"]
        pkt = tick_once()
        per_client_b = float(pkt.nbytes.mean())

        lat_by_c[C] = ms
        row = {
            "tick_ms": ms,                  # p50 (gate-compared key)
            "tick_ms_p95": pct["p95"],
            "tick_ms_p99": pct["p99"],
            "tick_ms_mean": pct["mean"],
            "tick_samples": pct["n"],
            "per_client_bytes": per_client_b,
            "objects_per_client": float(pkt.counts.mean()),
        }

        if C <= SEED_LOOP_MAX_C:
            # seed architecture at identical shapes: a Python loop of C
            # single-client collect_updates calls
            pri = np.asarray(compute_priority(
                store.embed, store.label, store.centroid,
                user_pos=jnp.zeros(3), knobs=kn))

            def seed_loop():
                for _ in range(C):
                    p, _ = collect_updates(store, init_sync(cap), kn,
                                           tick=0, priorities=pri,
                                           max_updates=budget)
                jax.block_until_ready(p.batch.n_points)

            seed_ms = _time(seed_loop, reps=max(reps // 2, 2))
            row["seed_loop_ms"] = seed_ms
            row["speedup_vs_seed"] = seed_ms / max(ms, 1e-9)
            extra = (f"seed_loop={seed_ms:.2f}ms;"
                     f"speedup={seed_ms / max(ms, 1e-9):.2f}x;")
        else:
            extra = "seed_loop=skipped;"

        if C >= MESH_SHARDS:
            # mesh-sharded tier at the same shapes, always MESH_SHARDS
            # parts: growth across C then compares equal shard counts (a
            # varying part count would measure dispatch count, not C)
            n_sh = MESH_SHARDS
            roster = ClientRoster.round_robin(C, n_sh)
            tier = MeshSessionTier(knobs=kn, capacity=cap, roster=roster,
                                   budget=budget)
            tier.set_all(subscribed=np.ones((C,), bool))
            part_fresh = [jnp.zeros((p.n_clients, cap), jnp.int32)
                          if p is not None else None for p in tier.parts]

            def tier_tick():
                for p, f in zip(tier.parts, part_fresh):
                    if p is not None:
                        p.sync = p.sync._replace(synced_version=f)
                return tier.collect(store)

            s_samples = _time_samples(tier_tick, reps=c_reps)
            s_pct = obs_metrics.exact_percentiles(s_samples)
            # byte-identity on FRESH sessions (equal seq state): the wire
            # packets must be bit-identical to the single-device reference
            sm_ref = SessionManager(knobs=kn, n_clients=C, capacity=cap,
                                    budget=budget)
            tier_ref = MeshSessionTier(knobs=kn, capacity=cap,
                                       roster=roster, budget=budget)
            tier_ref.set_all(subscribed=np.ones((C,), bool))
            ident = _mesh_identical(tier_ref.collect(store),
                                    sm_ref.collect(store), roster)
            sharded_lat[C] = s_pct["p50"]
            ident_by_c[C] = ident
            row["n_shards"] = n_sh
            row["tick_ms_sharded"] = s_pct["p50"]
            row["tick_ms_sharded_p99"] = s_pct["p99"]
            row["byte_identical_to_unsharded"] = bool(ident)

            # mesh-projected per-device wall clock: a real deployment
            # scales shard count with the fleet (~MESH_CLIENTS_PER_SHARD
            # clients per device) and the parts collect in PARALLEL on
            # their own devices, so the tick wall clock is the slowest
            # single part.  This container has one device (the serial
            # number above runs the parts back-to-back); project by
            # timing one part at the scaled roster's part size.  The
            # projection excludes the cross-host wire-boundary merge
            # (host-side numpy accounting, included in the serial number).
            n_mesh = max(MESH_SHARDS, C // MESH_CLIENTS_PER_SHARD)
            part_c = (C + n_mesh - 1) // n_mesh
            sm_part = SessionManager(knobs=kn, n_clients=part_c,
                                     capacity=cap, budget=budget)
            fresh_part = jnp.zeros((part_c, cap), jnp.int32)

            def part_tick():
                sm_part.sync = sm_part.sync._replace(
                    synced_version=fresh_part)
                return sm_part.collect(store)

            m_pct = obs_metrics.exact_percentiles(
                _time_samples(part_tick, reps=c_reps))
            mesh_lat[C] = m_pct["p50"]
            row["mesh_n_shards"] = n_mesh
            row["tick_ms_mesh_projected"] = m_pct["p50"]
            extra += (f"sharded={s_pct['p50']:.2f}ms;"
                      f"mesh={m_pct['p50']:.2f}ms@{n_mesh}sh;"
                      f"identical={ident};")

        results["sweep"][str(C)] = row
        csv_row(f"fleet_tick[C={C}]", ms * 1e3,
                extra + f"p99={pct['p99']:.2f}ms;"
                f"bytes/client={per_client_b:.0f}")

    # bucketed summaries from the obs histogram (what a live deployment
    # would scrape), alongside the exact sample percentiles above
    results["tick_ms_hist"] = {str(C): hist.summary(C=C) for C in sweep}

    c_lo, c_hi = sweep[0], (64 if 64 in lat_by_c else sweep[-1])
    growth = lat_by_c[c_hi] / max(lat_by_c[c_lo], 1e-9)
    sublinear = growth < (c_hi / c_lo)
    results["growth_C%d_over_C%d" % (c_hi, c_lo)] = growth
    results["sublinear"] = bool(sublinear)
    csv_row("fleet_tick_growth", lat_by_c[c_hi] * 1e3,
            f"C{c_lo}->C{c_hi}={growth:.2f}x;"
            f"linear_would_be={c_hi / c_lo:.0f}x;"
            f"sublinear={sublinear}")

    if sharded_lat:
        sh_cs = sorted(sharded_lat)
        s_lo = 256 if 256 in sharded_lat else sh_cs[0]
        s_hi = sh_cs[-1]
        s_growth = sharded_lat[s_hi] / max(sharded_lat[s_lo], 1e-9)
        m_growth = mesh_lat[s_hi] / max(mesh_lat[s_lo], 1e-9)
        # single sharded point (smoke): growth is unmeasurable, the flag
        # degrades to a wiring check — the real curve is the full artifact.
        # The headline sub-linear claim is the MESH projection (devices
        # scale with C, wall clock = slowest part); the serial number is
        # this one-device container running the parts back-to-back, which
        # is linear in C by construction and reported as such.
        s_sub = (s_growth < (s_hi / s_lo)) if s_hi > s_lo else True
        m_sub = (m_growth < (s_hi / s_lo)) if s_hi > s_lo else True
        results["sharding"] = {
            "n_shards": MESH_SHARDS,
            "mesh_clients_per_shard": MESH_CLIENTS_PER_SHARD,
            "byte_identical_to_unsharded": bool(all(ident_by_c.values())),
            "growth_serial_C%d_over_C%d" % (s_hi, s_lo): s_growth,
            "growth_mesh_C%d_over_C%d" % (s_hi, s_lo): m_growth,
            "sublinear": bool(m_sub),
            "sublinear_serial_single_device": bool(s_sub),
        }
        csv_row("fleet_tick_sharded_growth", sharded_lat[s_hi] * 1e3,
                f"C{s_lo}->C{s_hi}: serial={s_growth:.2f}x,"
                f"mesh={m_growth:.2f}x;"
                f"identical={all(ident_by_c.values())};"
                f"sublinear={m_sub}")
    return results


if __name__ == "__main__":
    run()
