"""Fleet-scale benchmark: server tick cost and per-client downstream bytes
vs fleet size C (the multi-tenant server subsystem, server/session.py).

At a FIXED map size, one update tick for C clients is a single vmapped
`_collect_fleet` dispatch ([C, N] change detection + priority top-k +
fused gather/downsample).  The headline number is tick latency growth from
C=1 to C=64: sub-linear (<< C×) because the per-client work amortizes into
one dispatch instead of C Python-loop iterations (the seed architecture).
The `seed_loop` row measures exactly that loop — C independent
`collect_updates` calls at identical shapes — so the speedup is measured,
not asserted.

Per-client downstream bytes stay constant in C (each client receives the
same changed set), which is the scaling story: downstream work ∝ per-client
map changes, not fleet size.

Tick latency is reported as exact p50/p95/p99/mean over every timed rep
(folded through a ``repro.obs`` histogram, label C), not a single mean —
tail behaviour is the serving story and a mean hides it.  The sweep runs
to C=1024; the seed-architecture comparison loop (C sequential
single-client collects) is measured up to C=256 and skipped above, where
its Python loop would dominate the suite's wall clock.

Writes BENCH_fleet_scale.json via ``benchmarks/run.py --suite fleet_scale
--json``; smoke mode (CI) runs C ∈ {1, 2} at tiny shapes.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.knobs import Knobs
from repro.core.store import synthetic_store
from repro.core.updates import collect_updates, init_sync
from repro.core.local_map import compute_priority
from repro.obs import metrics as obs_metrics
from repro.server.session import SessionManager

SEED_LOOP_MAX_C = 256      # the C-iteration Python loop above this is
#                            minutes of wall clock for a known-linear curve


def _time_samples(fn, *, reps: int, warmup: int = 3,
                  rounds: int = 3) -> list:
    """Per-call wall-time samples (ms) over ``rounds`` x ``reps`` calls —
    the container's wall clock is noisy enough (CPU scaling, GC) that a
    single mean can be 5-10x off; keeping every sample gives exact
    nearest-rank percentiles instead."""
    for _ in range(warmup):
        fn()
    out = []
    for _ in range(rounds):
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            out.append((time.perf_counter() - t0) * 1e3)
    return out


def _time(fn, *, reps: int, warmup: int = 3) -> float:
    """Best-of-3 mean (legacy single-number path, kept for seed_loop)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps * 1e3)
    return best


def run(full: bool = False, smoke: bool = False):
    if smoke:
        sweep, n_obj, cap, E, P, budget, reps = [1, 2], 24, 64, 32, 32, 16, 3
    elif full:
        sweep, n_obj, cap, E, P, budget, reps = \
            [1, 8, 64, 256, 512, 1024], 256, 512, 256, 512, 32, 10
    else:
        sweep, n_obj, cap, E, P, budget, reps = \
            [1, 8, 64, 256, 512, 1024], 128, 256, 128, 256, 32, 10
    kn = Knobs(server_capacity=cap, client_capacity=max(budget * 2, 64),
               max_object_points_server=P,
               max_object_points_client=max(P // 4, 16),
               min_obs_before_sync=1)
    store = synthetic_store(n_obj, cap, E, P)

    results = {"map_objects": n_obj, "capacity": cap, "embed_dim": E,
               "budget": budget, "sweep": {}}
    reg = obs_metrics.get_registry() or obs_metrics.MetricsRegistry()
    hist = reg.histogram("fleet_tick_ms",
                         "fleet collect tick wall time by fleet size")
    lat_by_c = {}
    for C in sweep:
        sm = SessionManager(knobs=kn, n_clients=C, capacity=cap,
                            budget=budget)
        fresh = jnp.zeros((C, cap), jnp.int32)

        def tick_once():
            # every rep ships the top-`budget` changed objects to every
            # client: reset the sync rows so per-tick work is constant
            sm.sync = sm.sync._replace(synced_version=fresh)
            pkt = sm.collect(store)
            return pkt

        # big fleets get fewer reps: one rep is slow enough to be stable
        c_reps = reps if C <= 256 else max(reps // 3, 2)
        samples = _time_samples(tick_once, reps=c_reps)
        for s in samples:
            hist.observe(s, C=C)
        pct = obs_metrics.exact_percentiles(samples)
        ms = pct["p50"]
        pkt = tick_once()
        per_client_b = float(pkt.nbytes.mean())

        lat_by_c[C] = ms
        row = {
            "tick_ms": ms,                  # p50 (gate-compared key)
            "tick_ms_p95": pct["p95"],
            "tick_ms_p99": pct["p99"],
            "tick_ms_mean": pct["mean"],
            "tick_samples": pct["n"],
            "per_client_bytes": per_client_b,
            "objects_per_client": float(pkt.counts.mean()),
        }

        if C <= SEED_LOOP_MAX_C:
            # seed architecture at identical shapes: a Python loop of C
            # single-client collect_updates calls
            pri = np.asarray(compute_priority(
                store.embed, store.label, store.centroid,
                user_pos=jnp.zeros(3), knobs=kn))

            def seed_loop():
                for _ in range(C):
                    p, _ = collect_updates(store, init_sync(cap), kn,
                                           tick=0, priorities=pri,
                                           max_updates=budget)
                jax.block_until_ready(p.batch.n_points)

            seed_ms = _time(seed_loop, reps=max(reps // 2, 2))
            row["seed_loop_ms"] = seed_ms
            row["speedup_vs_seed"] = seed_ms / max(ms, 1e-9)
            extra = (f"seed_loop={seed_ms:.2f}ms;"
                     f"speedup={seed_ms / max(ms, 1e-9):.2f}x;")
        else:
            extra = "seed_loop=skipped;"
        results["sweep"][str(C)] = row
        csv_row(f"fleet_tick[C={C}]", ms * 1e3,
                extra + f"p99={pct['p99']:.2f}ms;"
                f"bytes/client={per_client_b:.0f}")

    # bucketed summaries from the obs histogram (what a live deployment
    # would scrape), alongside the exact sample percentiles above
    results["tick_ms_hist"] = {str(C): hist.summary(C=C) for C in sweep}

    c_lo, c_hi = sweep[0], (64 if 64 in lat_by_c else sweep[-1])
    growth = lat_by_c[c_hi] / max(lat_by_c[c_lo], 1e-9)
    sublinear = growth < (c_hi / c_lo)
    results["growth_C%d_over_C%d" % (c_hi, c_lo)] = growth
    results["sublinear"] = bool(sublinear)
    csv_row("fleet_tick_growth", lat_by_c[c_hi] * 1e3,
            f"C{c_lo}->C{c_hi}={growth:.2f}x;"
            f"linear_would_be={c_hi / c_lo:.0f}x;"
            f"sublinear={sublinear}")
    return results


if __name__ == "__main__":
    run()
