"""Shared benchmark utilities: scene setup, semantic-quality metrics.

Quality follows the paper's protocol (Sec. 4.5.2): ground-truth labels
generate text queries against the constructed map; retrieved object point
clouds are scored against GT objects with mean class recall (mAcc) and
frequency-weighted point-IoU (F-mIoU analog, voxelized at 5 cm).
"""
from __future__ import annotations

import sys
import time
from dataclasses import dataclass
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Knobs, MappingServer
from repro.core.query import Query, execute_query
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder

EDIM = 256


def default_knobs(**kw) -> Knobs:
    base = dict(server_capacity=256, client_capacity=128,
                max_object_points_server=512, max_object_points_client=128,
                max_detections_per_frame=16, min_obs_before_sync=1)
    base.update(kw)
    return Knobs(**base)


def build_map(*, mode="semanticxr", n_objects=40, frames=60, interval=5,
              h=240, w=320, knobs=None, seed=0, embedder=None,
              instrument=False):
    scene = make_scene(n_objects=n_objects, seed=seed)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = embedder or OracleEmbedder(embed_dim=EDIM)
    srv = MappingServer(knobs=knobs or default_knobs(), embedder=emb,
                        mode=mode, instrument=instrument)
    key = jax.random.key(seed)
    times = []
    for i, fr in enumerate(scene_stream(scene, n_frames=frames,
                                        keyframe_interval=interval, h=h, w=w)):
        times.append(srv.process_frame(fr, classes,
                                       jax.random.fold_in(key, i)))
    return srv, emb, scene, times


def _voxel_set(pts: np.ndarray, voxel: float = 0.1) -> set:
    return set(map(tuple, np.floor(pts / voxel).astype(np.int64)))


def semantic_quality(srv, emb, scene) -> dict:
    """mAcc (mean class recall of top-1) + frequency-weighted point IoU.
    GT clouds are subsampled to the retrieved cloud's size so the IoU scores
    localization quality, not point density (paper Sec. 4.5.2 analog)."""
    act = np.asarray(srv.store.active)
    labels = np.asarray(srv.store.label)
    gt_by_class: dict[int, list] = {}
    for o in scene.objects:
        gt_by_class.setdefault(o.class_id, []).append(o)

    per_class_acc, weights, ious = [], [], []
    for cid, objs in gt_by_class.items():
        res = execute_query(srv.store, Query(embed=emb.embed_text(cid), k=5))
        slot = int(np.asarray(res.slots[0]))
        ok = act[slot] and labels[slot] == cid
        per_class_acc.append(float(ok))
        weights.append(len(objs))
        if not ok:
            ious.append(0.0)
            continue
        n = int(np.asarray(srv.store.n_points[slot]))
        got = np.asarray(srv.store.points[slot])[:n]
        vox_got = _voxel_set(got)
        best = 0.0
        for o in objs:
            stride = max(1, len(o.points) // max(n, 1))
            vox_gt = _voxel_set(o.points[::stride])
            inter = len(vox_got & vox_gt)
            union = len(vox_got | vox_gt)
            if union:
                best = max(best, inter / union)
        ious.append(best)
    w = np.asarray(weights, np.float64)
    return {
        "mAcc": 100.0 * float(np.mean(per_class_acc)),
        "F-mIoU": 100.0 * float(np.sum(np.asarray(ious) * w) / w.sum()),
        "n_mapped": int(act.sum()),
        "n_gt": len(scene.objects),
    }


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
