"""Dynamic-scene scenario suite: the churn workload through the full
device-cloud loop (sim.ScenarioEngine).

Reports, per scenario size: engine tick wall time, total/tombstone
downstream bytes, convergence (every client == the server's live set after
drain), and the replay-determinism check (two runs, bit-identical
MetricsLogs) — the operational form of the paper's Sec. 3.2 claim that
downstream bandwidth scales with map changes.  ``--smoke`` (CI) runs a
small churn+outage scenario; the golden-replay tier-1 test pins the exact
numbers, this suite tracks the wall-clock trajectory.

Writes BENCH_scenario_suite{,_smoke}.json via ``benchmarks/run.py --suite
scenario_suite [--smoke] --json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.knobs import Knobs
from repro.sim import churn_scenario
from repro.sim.engine import ScenarioEngine

# paper-scale local map for the large arm (73+ live objects must fit the
# client, or convergence is impossible by construction)
_BIG = Knobs(server_capacity=256, client_capacity=128,
             max_object_points_server=64, max_object_points_client=16,
             min_obs_before_sync=1)


def _run_one(name: str, **kw) -> dict:
    sc = churn_scenario(**kw)
    eng = ScenarioEngine(sc)
    log = eng.run()
    log2 = ScenarioEngine(sc).run()

    srv = eng.world.live_ids()
    converged = all(
        set(np.asarray(s.dev.local.ids)[
            np.asarray(s.dev.local.active)].tolist()) == srv
        for s in eng.sessions.values())
    s = log.summary()["exact"]
    out = {
        "replay_bit_identical": log.equals(log2),
        "converged": converged,
        "tick_ms_mean": float(np.mean(eng.wall_ms)),
        "tick_ms_p95": float(np.percentile(eng.wall_ms, 95)),
        "n_ticks": s["n_ticks"],
        "n_clients": s["n_clients"],
        "spawned": s["spawned"],
        "removed": s["removed"],
        "sent_bytes_total": s["sent_bytes_total"],
        "tombstone_bytes": s["tombstone_bytes_total"],   # measured on-wire
        "idle_zero_byte_ticks": s["idle_zero_byte_ticks"],
        "sq_queries": s["sq_queries"],
        "lq_queries": s["lq_queries"],
    }
    csv_row(f"scenario[{name}]", out["tick_ms_mean"] * 1e3,
            f"downB={out['sent_bytes_total']};removed={out['removed']};"
            f"converged={converged};replay={out['replay_bit_identical']}")
    return out


def _obs_overhead(kw, reps: int = 3) -> tuple:
    """Median tick wall time with observability off vs on (tracer +
    registry installed), best-of-``reps`` each.  Best-of-medians makes
    the ratio robust to scheduler noise; the first run warms jit caches
    so compile time never lands in either arm."""
    from repro.obs import MetricsRegistry, Tracer, set_registry, set_tracer
    sc = churn_scenario(**kw)

    def median_tick_ms():
        eng = ScenarioEngine(sc)
        eng.run()
        return float(np.median(eng.wall_ms))

    median_tick_ms()                          # warm-up, discarded
    off = min(median_tick_ms() for _ in range(reps))
    prev_tr = set_tracer(Tracer())
    prev_reg = set_registry(MetricsRegistry())
    try:
        on = min(median_tick_ms() for _ in range(reps))
    finally:
        set_tracer(prev_tr), set_registry(prev_reg)
    return off, on


def run(full: bool = False, smoke: bool = False):
    if smoke:
        sizes = {"smoke": dict(seed=23, n_objects=12, n_ticks=10,
                               n_clients=2, remove_frac=0.25,
                               drain_ticks=5)}
    elif full:
        sizes = {
            "small": dict(seed=23, n_objects=20, n_ticks=20, n_clients=3,
                          remove_frac=0.25, drain_ticks=8),
            "mid": dict(seed=23, n_objects=60, n_ticks=40, n_clients=8,
                        remove_frac=0.3, drain_ticks=8),
            "large": dict(seed=23, n_objects=100, n_ticks=60, n_clients=16,
                          remove_frac=0.3, drain_ticks=10, knobs=_BIG),
        }
    else:
        sizes = {
            "small": dict(seed=23, n_objects=20, n_ticks=20, n_clients=3,
                          remove_frac=0.25, drain_ticks=8),
            "mid": dict(seed=23, n_objects=60, n_ticks=40, n_clients=8,
                        remove_frac=0.3, drain_ticks=8),
        }
    results = {name: _run_one(name, **kw) for name, kw in sizes.items()}
    for r in results.values():
        assert r["replay_bit_identical"], "nondeterministic replay!"
        assert r["converged"], "clients did not converge!"
    if smoke:
        out = results["smoke"]
        # acceptance: observability must cost <5% of tick wall time
        off, on = _obs_overhead(sizes["smoke"])
        pct = 100.0 * (on - off) / max(off, 1e-9)
        out["obs_tick_ms_off"] = off
        out["obs_tick_ms_on"] = on
        out["obs_overhead_pct"] = pct
        csv_row("scenario[obs_overhead]", on * 1e3,
                f"off_ms={off:.3f};overhead_pct={pct:.2f}")
        assert pct < 5.0, \
            f"observability overhead {pct:.2f}% >= 5% budget"
        return out
    return results


if __name__ == "__main__":
    run()
