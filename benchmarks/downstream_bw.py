"""Paper Fig. 6: per-update downstream transfer size vs update index —
object-level incremental updates vs full-scene baseline."""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import csv_row, default_knobs, EDIM
from repro.core import MappingServer
from repro.core.updates import collect_updates, init_sync
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder


def run(full: bool = False):
    n_objects, frames = (60, 120) if full else (30, 60)
    scene = make_scene(n_objects=n_objects, seed=1)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=EDIM)
    kn = default_knobs()
    srv = MappingServer(knobs=kn, embedder=emb, mode="semanticxr")
    sync_inc = init_sync(kn.server_capacity)

    key = jax.random.key(1)
    inc_bytes, full_bytes = [], []
    for i, fr in enumerate(scene_stream(scene, n_frames=frames,
                                        keyframe_interval=5, h=60, w=80)):
        srv.process_frame(fr, classes, jax.random.fold_in(key, i))
        if i % kn.local_map_update_frequency == 0:
            pkt, sync_inc = collect_updates(srv.store, sync_inc, kn, tick=i)
            fpkt, _ = collect_updates(srv.store, init_sync(kn.server_capacity),
                                      kn, tick=i, full_map=True)
            inc_bytes.append(pkt.nbytes)
            full_bytes.append(fpkt.nbytes)

    for j, (a, b) in enumerate(zip(inc_bytes, full_bytes)):
        csv_row(f"fig6_downstream[update{j}]", a, f"incremental={a}B;full={b}B")
    tail = max(1, len(inc_bytes) // 3)
    csv_row("fig6_downstream_tail_ratio",
            float(np.mean(inc_bytes[-tail:])),
            f"full_tail={np.mean(full_bytes[-tail:]):.0f}B;"
            f"ratio={np.mean(full_bytes[-tail:]) / max(np.mean(inc_bytes[-tail:]), 1):.1f}x")
    return {"incremental": inc_bytes, "full": full_bytes}


if __name__ == "__main__":
    run()
