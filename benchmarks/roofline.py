"""§Roofline: aggregate the dry-run JSONs into the per-(arch x shape) table.

Reads experiments/dryrun/pod1/*.json (single-pod, per the assignment) and
emits one CSV row per cell with the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and bytes/device."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def run(full: bool = False, pod: str = "pod1"):
    rows = []
    d = DRYRUN / pod
    if not d.exists():
        csv_row("roofline", 0.0, "dry-run not yet executed")
        return []
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("skipped"):
            csv_row(f"roofline[{rec['arch']}x{rec['shape']}]", 0.0, "SKIP")
            continue
        rl = rec["roofline"]
        mem = rec["memory"]["peak_bytes_per_dev"] / 2**30
        rows.append(rec)
        opt = "|opt" if (rec.get("prune_tiles") or rec.get("mla_absorb")
                         or rec.get("grad_accum", 1) > 1
                         or rec.get("int8_kv") or rec.get("seq_parallel")) \
            else ""
        csv_row(
            f"roofline[{rec['arch']}x{rec['shape']}{opt}]",
            rl["bound_s"] * 1e6,
            f"dominant={rl['dominant']};compute={rl['compute_s']*1e3:.2f}ms;"
            f"memory={rl['memory_s']*1e3:.2f}ms;"
            f"collective={rl['collective_s']*1e3:.2f}ms;"
            f"mfu={rl['roofline_mfu']:.3f};"
            f"useful={rl['useful_ratio']:.2f};peak={mem:.1f}GiB/dev")
    return rows


if __name__ == "__main__":
    run()
