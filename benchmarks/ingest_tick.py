"""Ingest / sync-tick microbenchmark (new in the batched-hot-paths PR).

Measures one full downstream sync tick at steady state:
  collect   server packet build — SoA UpdateBatch via one jitted
            gather+vmapped-downsample (seed: per-object Python loop).
  ingest    device side — one jitted apply_updates_batch + batched
            compute_priority (seed: per-object apply_update dispatches).

Both seed baselines run at identical shapes/knobs so the speedup is measured
against the real thing, not asserted: the seed collect loop (per-object
downsample/centroid dispatches) is reconstructed inline, and the seed ingest
path survives as DeviceClient.ingest_sequential.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_map, csv_row, default_knobs, EDIM
from repro.core.runtime import CloudService, DeviceClient
from repro.core.updates import collect_updates, init_sync


def _time(fn, *, reps: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3


def run(full: bool = False):
    n_objects, frames = (80, 100) if full else (40, 60)
    reps = 20 if full else 10
    kn = default_knobs()
    srv, emb, scene, _ = build_map(n_objects=n_objects, frames=frames,
                                   knobs=kn)
    n_act = int(np.asarray(srv.store.active).sum())
    user_pos = jnp.zeros(3)

    # --- collect: full-map tick (worst case: every active object ships)
    def collect_once():
        pkt, _ = collect_updates(srv.store, init_sync(kn.server_capacity),
                                 kn, tick=0, full_map=True)
        jax.block_until_ready(pkt.batch.n_points)
        return pkt

    collect_ms = _time(collect_once, reps=reps)
    pkt = collect_once()

    # seed collect path, reconstructed: per-object downsample + centroid
    # dispatches in a Python loop (the loop collect_updates used to run)
    from repro.core import geometry as geo
    from repro.core.local_map import ObjectUpdate
    from repro.core.updates import update_nbytes

    def collect_seed():
        active = np.nonzero(np.asarray(srv.store.active))[0]
        Pc = kn.max_object_points_client
        updates, nbytes = [], 0
        for i in active:
            pts, n = geo.downsample(srv.store.points[i],
                                    srv.store.n_points[i], Pc)
            c, _, _ = geo.centroid_bbox(pts, n)
            updates.append(ObjectUpdate(
                oid=srv.store.ids[i], embed=srv.store.embed[i],
                label=srv.store.label[i], points=pts.astype(jnp.float16),
                n_points=n, centroid=c, version=srv.store.version[i]))
            nbytes += update_nbytes(srv.store.embed.shape[1], int(n))
        jax.block_until_ready(updates[-1].points)
        return updates, nbytes

    collect_seed_ms = _time(collect_seed, reps=max(reps // 2, 3))
    _, seed_nbytes = collect_seed()
    assert seed_nbytes == pkt.nbytes, (seed_nbytes, pkt.nbytes)

    # --- ingest: batched (one dispatch) vs seed sequential loop
    dev = DeviceClient(knobs=kn, embed_dim=EDIM)

    def ingest_batched():
        dev.local = dev.local._replace(active=jnp.zeros_like(dev.local.active))
        dev.ingest(pkt, user_pos=user_pos)
        jax.block_until_ready(dev.local.active)

    dev_seq = DeviceClient(knobs=kn, embed_dim=EDIM)

    def ingest_sequential():
        dev_seq.local = dev_seq.local._replace(
            active=jnp.zeros_like(dev_seq.local.active))
        dev_seq.ingest_sequential(pkt, user_pos=user_pos)
        jax.block_until_ready(dev_seq.local.active)

    batched_ms = _time(ingest_batched, reps=reps)
    seq_ms = _time(ingest_sequential, reps=reps)
    speedup = seq_ms / max(batched_ms, 1e-9)

    collect_speedup = collect_seed_ms / max(collect_ms, 1e-9)
    csv_row("ingest_tick_collect", collect_ms * 1e3,
            f"objects={pkt.count};bytes={pkt.nbytes};"
            f"seed_loop={collect_seed_ms:.2f}ms;"
            f"speedup={collect_speedup:.2f}x")
    csv_row("ingest_tick_apply[batched]", batched_ms * 1e3,
            f"objects={pkt.count};dispatches=1")
    csv_row("ingest_tick_apply[sequential_seed]", seq_ms * 1e3,
            f"objects={pkt.count};dispatches={pkt.count}")
    csv_row("ingest_tick_speedup", batched_ms * 1e3,
            f"speedup={speedup:.2f}x;target>=2x")
    return {
        "n_active": n_act,
        "packet_objects": pkt.count,
        "packet_bytes": pkt.nbytes,
        "collect_ms": collect_ms,
        "collect_seed_loop_ms": collect_seed_ms,
        "collect_speedup": collect_speedup,
        "ingest_batched_ms": batched_ms,
        "ingest_sequential_ms": seq_ms,
        "speedup": speedup,
    }


if __name__ == "__main__":
    run()
