"""Paper Fig. 7: XR device power under the four execution regimes.

The container cannot read watts; this is the documented PowerModel from
core/runtime.py, calibrated to the paper's Jetson measurements — reported so
the regime STRUCTURE (offload ~idle, LQ costs ~1.2 W at 1q/3s, worst-case
burst bounded) is reproduced and auditable.
"""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.core.runtime import PowerModel


def run(full: bool = False):
    pm = PowerModel()
    regimes = {
        "on_device_mapping": pm.on_device_mapping_power(),
        "idle": pm.idle_w,
        "semanticxr_sq_streaming": pm.average_power(streaming=True),
        "lq_1_per_3s": pm.average_power(streaming=False, local_qps=1 / 3),
        "lq_continuous_14.7qps": pm.average_power(streaming=False,
                                                  local_qps=14.7),
    }
    for name, w in regimes.items():
        csv_row(f"fig7_power[{name}]", w * 1e3, f"{w:.2f}W")
    over = (regimes["semanticxr_sq_streaming"] / regimes["idle"] - 1) * 100
    csv_row("fig7_power_overhead_normal", over * 1e3,
            f"overhead={over:.1f}%;paper=~2%")
    return regimes


if __name__ == "__main__":
    run()
