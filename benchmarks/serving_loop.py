"""Serving-loop benchmark: synchronous vs overlapped tick at fleet scale.

Two arms run the IDENTICAL seeded workload (same ingest deltas, same pose
streams, same open-loop query arrivals from ``serving.loadgen``) through
``serving.loop.ServingLoop``:

- **sync** — today's driver schedule: fence after every dispatch family,
  non-donated functional ingest (XLA copies the full store per tick).
- **overlapped** — async dispatch end to end: donated in-place ingest
  against the double-buffered store's dead generation, issue-all-then-
  finish zone collects (packet framing deferred one tick — legal because
  the sync state chains on-device), non-blocking query steps resolved
  once per tick.

Because both arms serve every query against the post-previous-tick
snapshot, their per-query results, per-tick sync packets, and final
stores are byte-identical — asserted here, so the speedup is a pure
scheduling + allocation win at EQUAL output.  Headline: overlapped/sync
throughput at C=256 (target >= 1.5x) plus — new with this suite —
p50/p95/p99 query wait and end-to-end latency under load, and the
donated-vs-copy ingest microbenchmark.

The default shape is the paper's regime: a LARGE resident map (131k
server slots — the hierarchical-index PR's scale axis) with bounded
per-tick churn, so the synchronous arm's O(capacity) functional-update
copy dominates its tick while the overlapped arm's donated scatter is
O(churn).  That copy-elision term is host-parallelism-independent; on
multi-core hosts the dispatch pipelining (collect/query overlap) adds on
top, but it contributes ~nothing on the 1-core CI runner — measured and
documented in EXPERIMENTS.md, not assumed.

Golden-replay purity rides along: the scenario engine replayed with
``async_loop=True`` must produce a bit-identical MetricsLog.

Writes BENCH_serving_loop.json via ``benchmarks/run.py --suite
serving_loop --json``; smoke mode (CI) runs C=8 at tiny shapes.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import csv_row
from repro.core.knobs import Knobs
from repro.core.store import SnapshotStore, copy_store, synthetic_store
from repro.obs import metrics as obs_metrics
from repro.serving.loadgen import LoadGenerator, LoadSpec
from repro.serving.loop import (IngestStream, ServingLoop, apply_delta,
                                _apply_delta_donated)
from repro.server.fleet import FleetServer
from repro.server.zones import ZoneGrid, ZoneShardedStore


def _build(cfg: dict, *, overlap: bool) -> ServingLoop:
    kn = Knobs(server_capacity=cfg["cap"],
               client_capacity=max(cfg["budget"] * 2, 64),
               max_object_points_server=cfg["P"],
               max_object_points_client=max(cfg["P"] // 8, 8),
               min_obs_before_sync=1)
    store = synthetic_store(cfg["n_live"], cfg["cap"], cfg["E"], cfg["P"],
                            seed=7, centroid_low=(-7.0, 0.0, -7.0),
                            centroid_high=(7.0, 2.0, 7.0))
    grid = ZoneGrid.for_room(16.0, cfg["nz"], cfg["nz"])
    # zone shards are sized to the LIVE population (plus headroom), not
    # the server store's slot capacity: the default 2*cap/Z headroom
    # would make every per-zone collect scan mostly-empty slots
    zoned = ZoneShardedStore(knobs=kn, embed_dim=cfg["E"], grid=grid,
                             zone_capacity=cfg.get("zcap", 0))
    # per-zone cluster indexes serve core.query's shard planning, which
    # the serving query path (flat sweep over the publish buffer) never
    # touches — keep them off so both arms measure the serving loop only.
    # Session (collect) donation stays OFF in BOTH arms: dispatching a jit
    # that donates a buffer blocks the host until that buffer's producer
    # retires, so donated collects re-serialize the very chain the
    # deferred tick_start/tick_finish pipeline exists to overlap.  Ingest
    # donation is unaffected (ServingLoop's _apply_delta_donated donates a
    # generation whose producer finished a full tick earlier).
    srv = FleetServer(knobs=kn, embed_dim=cfg["E"], n_clients=cfg["C"],
                      grid=grid, budget=cfg["budget"], donate=False,
                      index=False, zoned=zoned)
    lg = LoadGenerator(LoadSpec(n_clients=cfg["C"], n_ticks=cfg["ticks"],
                                base_hz=cfg["base_hz"],
                                burst_hz=cfg["burst_hz"]),
                       embed_dim=cfg["E"])
    ing = IngestStream(n_ticks=cfg["ticks"], n_live=cfg["n_live"],
                       embed_dim=cfg["E"], max_points=cfg["P"],
                       churn=cfg["churn"], seed=11)
    snap = SnapshotStore.of(store) if overlap \
        else SnapshotStore(front=store)
    for c in range(cfg["C"]):
        srv.join(c, lg.pose_at(c, 0), 6.0)
    return ServingLoop(server=srv, store=snap, ingest=ing, loadgen=lg,
                       overlap=overlap, batch_size=cfg["batch"],
                       max_batches_per_tick=cfg["max_batches"])


def _arm(cfg: dict, *, overlap: bool) -> tuple:
    # warmup run compiles this arm's jits (donated variants are distinct
    # executables) so the measured run times steady-state dispatch
    warm_cfg = dict(cfg, ticks=min(6, cfg["ticks"]))
    _build(warm_cfg, overlap=overlap).run(warm_cfg["ticks"])
    loop = _build(cfg, overlap=overlap)
    stats = loop.run(cfg["ticks"])
    return loop, stats


def _donation_microbench(cfg: dict, reps: int = 20) -> dict:
    """Ingest scatter, copy vs donated in-place, same delta same store."""
    store = synthetic_store(cfg["n_live"], cfg["cap"], cfg["E"], cfg["P"],
                            seed=7)
    d = IngestStream(n_ticks=2, n_live=cfg["n_live"], embed_dim=cfg["E"],
                     max_points=cfg["P"], churn=cfg["churn"],
                     seed=11).delta_at(0)
    jax.block_until_ready(apply_delta(store, d).active)       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(apply_delta(store, d).active)
    copy_ms = (time.perf_counter() - t0) / reps * 1e3

    ping = copy_store(store)
    ping = _apply_delta_donated(ping, d)                      # compile
    jax.block_until_ready(ping.active)
    t0 = time.perf_counter()
    for _ in range(reps):
        ping = _apply_delta_donated(ping, d)
    jax.block_until_ready(ping.active)
    donated_ms = (time.perf_counter() - t0) / reps * 1e3
    return {"copy_ingest_ms": copy_ms, "donated_ingest_ms": donated_ms,
            "savings_x": copy_ms / max(donated_ms, 1e-9)}


def _golden_replay_pure() -> bool:
    from repro.sim import churn_scenario, run_scenario
    sc = churn_scenario(seed=23, n_objects=20, n_ticks=20, n_clients=3,
                        remove_frac=0.25, drain_ticks=8)
    return run_scenario(sc).equals(run_scenario(sc, async_loop=True))


def run(full: bool = False, smoke: bool = False):
    if smoke:
        cfg = dict(C=8, ticks=24, n_live=96, cap=128, E=32, P=16, nz=2,
                   churn=16, budget=16, batch=8, max_batches=2,
                   base_hz=2.0, burst_hz=20.0)
    else:
        # paper-regime shape: 131k-slot resident map (the index PR's scale
        # axis), 4k live objects, bounded churn — the synchronous arm's
        # functional update copies the full ~280 MB store every tick while
        # the overlapped arm's donated scatter touches only churned rows
        cfg = dict(C=256, ticks=120, n_live=4096, cap=131072, E=128, P=128,
                   nz=1, zcap=6144, churn=96, budget=32, batch=4,
                   max_batches=2, base_hz=1.0, burst_hz=8.0)
        if full:
            cfg.update(ticks=240)

    results = {"config": cfg, "arms": {}}
    sync_loop, sync_stats = _arm(cfg, overlap=False)
    ovl_loop, ovl_stats = _arm(cfg, overlap=True)
    results["arms"]["sync"] = sync_stats
    results["arms"]["overlapped"] = ovl_stats

    # -- equal-output checks: the speedup must not buy different answers --
    same_rids = set(sync_loop.results) == set(ovl_loop.results)
    same_rows = same_rids and all(
        np.array_equal(sync_loop.results[r].oids, ovl_loop.results[r].oids)
        and np.array_equal(sync_loop.results[r].scores,
                           ovl_loop.results[r].scores)
        for r in sync_loop.results)
    store_eq = all(
        a is None and b is None
        or np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(sync_loop.store.front, ovl_loop.store.front))
    results["query_results_equal"] = bool(same_rows)
    results["final_store_equal"] = bool(store_eq)
    results["sent_bytes_equal"] = \
        sync_stats["sent_bytes_total"] == ovl_stats["sent_bytes_total"]

    speedup = ovl_stats["ticks_per_s"] / max(sync_stats["ticks_per_s"],
                                             1e-9)
    results["overlap_speedup_x"] = speedup
    if not smoke:
        # full-scale acceptance only: at C=8 smoke shapes the tick is
        # dispatch-bound and the ratio is noise, so the smoke gate SKIPs
        results["overlap_speedup_ge_1_5"] = bool(speedup >= 1.5)

    # p99 query latency under load — reported for the first time
    e2e = ovl_stats["e2e_ms"]
    results["p99_under_load_ms"] = e2e["p99"]
    results["p99_under_load_ok"] = bool(
        e2e["n"] == ovl_stats["n_queries_served"] and e2e["n"] > 0
        and np.isfinite(e2e["p99"]))

    results["donation"] = _donation_microbench(cfg)
    results["golden_replay_bit_identical"] = _golden_replay_pure()

    csv_row("serving_tick_sync", sync_stats["tick_ms"]["p50"] * 1e3,
            f"p99={sync_stats['tick_ms']['p99']:.2f}ms;"
            f"tps={sync_stats['ticks_per_s']:.1f}")
    csv_row("serving_tick_overlapped", ovl_stats["tick_ms"]["p50"] * 1e3,
            f"p99={ovl_stats['tick_ms']['p99']:.2f}ms;"
            f"tps={ovl_stats['ticks_per_s']:.1f};"
            f"speedup={speedup:.2f}x;equal={bool(same_rows and store_eq)}")
    csv_row("serving_query_e2e_p99", e2e["p99"] * 1e3,
            f"n={e2e['n']};wait_p99={ovl_stats['wait_ms']['p99']:.2f}ms")
    csv_row("ingest_donation", results["donation"]["donated_ingest_ms"]
            * 1e3, f"copy={results['donation']['copy_ingest_ms']:.2f}ms;"
            f"savings={results['donation']['savings_x']:.1f}x")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, smoke=args.smoke)
