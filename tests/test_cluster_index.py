"""Cluster-summary index (repro.index): exactness of the coarse-to-fine
query plan, bit-identity of incremental maintenance vs from-scratch
rebuilds under random churn, tombstoned-member eviction, and byte-compat
of the deprecated query wrappers through the index-aware compiler."""
from types import SimpleNamespace

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.query import (Query, batched_query_local,
                              batched_query_server, compile_query,
                              execute_query, query_local, query_server)
from repro.core.store import (clustered_synthetic_store, remove_objects,
                              synthetic_store)
from repro.index import (ClusterIndex, ClusterResult, rebuilt,
                         summaries_equal)

E = 64


def _same_topk(a, b, *, rtol=1e-6, atol=1e-7):
    assert np.array_equal(np.asarray(a.oids), np.asarray(b.oids))
    assert np.array_equal(np.asarray(a.slots), np.asarray(b.slots))
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=rtol, atol=atol)


def _store_and_index(n=4096, *, min_flat=1024, seed=0, **kw):
    st = clustered_synthetic_store(n, n, E, 16, seed=seed, room=40.0,
                                   n_hotspots=48)
    idx = ClusterIndex.for_target(st, min_flat_size=min_flat, **kw)
    assert idx.engaged()
    return st, idx


def _specs(st, n):
    qe = st.embed[n // 3]
    center = st.centroid[n // 3]
    return {
        "embed_only": Query(embed=qe, k=8),
        "embed_spatial": Query(embed=qe,
                               near=(center, jnp.asarray(5.0, jnp.float32)),
                               prox_weight=jnp.asarray(0.3, jnp.float32),
                               k=8),
        "attrs": Query(embed=qe, labels=tuple(range(8)),
                       min_points=jnp.asarray(4, jnp.int32),
                       min_obs=jnp.asarray(1, jnp.int32), k=8),
        "negated_sem": Query(embed=qe,
                             sem_weight=jnp.asarray(-1.0, jnp.float32),
                             k=8),
    }


# ---------------------------------------------------------------------------
# two-stage plan exactness vs the flat sweep
# ---------------------------------------------------------------------------
def test_two_stage_matches_flat():
    n = 4096
    st, idx = _store_and_index(n)
    for name, spec in _specs(st, n).items():
        flat = compile_query(spec, st)(st)
        two = compile_query(spec, st, index=idx)(st)
        _same_topk(flat, two)


def test_two_stage_matches_flat_batched():
    n = 4096
    st, idx = _store_and_index(n)
    qs = st.embed[jnp.asarray([1, 7, n // 2, n - 3])]
    spec = Query(embed=qs, k=8, batched=True)
    _same_topk(compile_query(spec, st)(st),
               compile_query(spec, st, index=idx)(st))


def test_two_stage_pallas_parity():
    """The stage-1 kernel path (interpret mode on CPU) agrees with XLA."""
    n = 1024
    st, idx = _store_and_index(n, min_flat=512)
    spec = _specs(st, n)["embed_spatial"]
    ref = compile_query(spec, st, index=idx)(st)
    ker = compile_query(spec, st, use_pallas=True, index=idx)(st)
    _same_topk(ref, ker, rtol=1e-5, atol=1e-6)


def test_small_target_falls_back_flat():
    st = clustered_synthetic_store(128, 128, E, 16, room=10.0)
    idx = ClusterIndex.for_target(st)        # default min_flat: not engaged
    assert not idx.engaged()
    spec = Query(embed=st.embed[3], k=5)
    _same_topk(compile_query(spec, st)(st),
               compile_query(spec, st, index=idx)(st), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# cluster-level mode: the summaries ARE the results
# ---------------------------------------------------------------------------
def test_cluster_level_query():
    n = 4096
    st, idx = _store_and_index(n)
    spec = Query(embed=st.embed[n // 3],
                 density_weight=jnp.asarray(0.5, jnp.float32),
                 k=4, level="cluster")
    res = compile_query(spec, st, index=idx)(st)
    assert isinstance(res, ClusterResult)
    s = np.asarray(res.scores)
    assert s.shape == (4,) and np.all(np.diff(s) <= 0)      # sorted desc
    assert (np.asarray(res.counts) > 0).all()
    assert np.isfinite(np.asarray(res.centroids)).all()
    # the winning cell's members really do sit near its reported centroid
    top = int(np.asarray(res.cells)[0])
    mem = idx.member_slots(top)
    np.testing.assert_allclose(
        np.asarray(st.centroid)[mem].mean(axis=0),
        np.asarray(res.centroids)[0], atol=1e-4)


def test_cluster_level_requires_index():
    st = clustered_synthetic_store(256, 256, E, 16, room=10.0)
    spec = Query(embed=st.embed[0], k=4, level="cluster")
    with pytest.raises(ValueError):
        compile_query(spec, st)(st)


# ---------------------------------------------------------------------------
# incremental maintenance == from-scratch rebuild (bit-exact)
# ---------------------------------------------------------------------------
def test_incremental_equals_rebuild_after_churn():
    n = 2048
    st, idx = _store_and_index(n, min_flat=512)
    rng = np.random.default_rng(7)

    # tombstone a batch
    st = remove_objects(st, rng.choice(np.arange(1, n + 1), 200,
                                       replace=False))
    idx.refresh(st)
    assert summaries_equal(idx.summaries, rebuilt(idx, st).summaries)

    # move a batch across cells (version bump makes the diff see it)
    slots = rng.choice(n, 150, replace=False)
    cent = np.asarray(st.centroid).copy()
    cent[slots] += rng.normal(scale=8.0, size=(150, 3)).astype(np.float32)
    st = st._replace(centroid=jnp.asarray(cent),
                     version=st.version.at[jnp.asarray(slots)].add(1))
    idx.refresh(st)
    assert summaries_equal(idx.summaries, rebuilt(idx, st).summaries)

    # and the O(changes) delta path agrees with the diff path
    idx.update_slots(st, np.arange(n))
    assert summaries_equal(idx.summaries, rebuilt(idx, st).summaries)


def test_tombstoned_members_evicted():
    n = 1024
    st, idx = _store_and_index(n, min_flat=256)
    gone = np.arange(1, n + 1, 3)
    st = remove_objects(st, gone)
    idx.refresh(st)
    live = set(np.nonzero(np.asarray(st.active)
                          & ~np.asarray(st.deleted))[0].tolist())
    members = set()
    for c in range(idx.grid.n_cells):
        members |= set(idx.member_slots(c).tolist())
    assert members == live                  # no tombstone answers a query
    assert idx.n_objects == len(live)


def test_cell_overflow_auto_grows():
    # everything lands in few cells with a tiny cap: must grow, not drop
    st = synthetic_store(512, 512, E, 16, centroid_low=(-1, 0, -1),
                         centroid_high=(1, 1, 1))
    idx = ClusterIndex.for_target(st, n_cells_target=4, cell_cap=8,
                                  min_flat_size=256)
    assert idx.cell_cap > 8
    assert summaries_equal(idx.summaries, rebuilt(idx, st).summaries)
    spec = Query(embed=st.embed[11], k=6)
    _same_topk(compile_query(spec, st)(st),
               compile_query(spec, st, index=idx)(st))


# ---------------------------------------------------------------------------
# deprecated wrappers route through the index-aware compiler (byte compat)
# ---------------------------------------------------------------------------
def test_wrappers_byte_compat():
    n = 2048
    st, idx = _store_and_index(n, min_flat=512)
    qe = st.embed[5]
    qs = st.embed[jnp.asarray([5, 9, 100])]
    carrier = SimpleNamespace(**st._asdict(), cluster_index=idx)

    for target in (st, carrier):
        with pytest.deprecated_call():
            w = query_server(target, qe, k=7)
        d = execute_query(target, Query(embed=qe, k=7))
        _same_topk(w, d, rtol=0, atol=0)
        with pytest.deprecated_call():
            wb = batched_query_server(target, qs, k=7)
        db = execute_query(target, Query(embed=qs, k=7, batched=True))
        _same_topk(wb, db, rtol=0, atol=0)

    # the index-carrying target really took the two-stage plan and still
    # matches the plain flat sweep bit-for-bit on winners
    _same_topk(execute_query(carrier, Query(embed=qe, k=7)),
               execute_query(st, Query(embed=qe, k=7)))

    # local-map shaped wrappers (no obs_count/last_seen columns)
    lm = SimpleNamespace(ids=st.ids, active=st.active, embed=st.embed,
                         label=st.label, n_points=st.n_points,
                         centroid=st.centroid)
    with pytest.deprecated_call():
        w = query_local(lm, qe, k=7)
    _same_topk(w, execute_query(lm, Query(embed=qe, k=7)), rtol=0, atol=0)
    with pytest.deprecated_call():
        w = batched_query_local(lm, qs, k=7)
    _same_topk(w, execute_query(lm, Query(embed=qs, k=7, batched=True)),
               rtol=0, atol=0)


# ---------------------------------------------------------------------------
# churn property: random spawn/move/remove/tombstone streams through the
# full device-cloud loop; every tick the incrementally-maintained
# summaries (server zone shards AND a device-local index) must be
# bit-identical to a from-scratch rebuild, with tombstoned members evicted
# ---------------------------------------------------------------------------
def _assert_index_consistent(idx, target):
    assert summaries_equal(idx.summaries, rebuilt(idx, target).summaries)
    act = np.asarray(target.active)
    dele = getattr(target, "deleted", None)
    live = act & ~np.asarray(dele) if dele is not None else act
    members = set()
    for c in range(idx.grid.n_cells):
        members |= set(idx.member_slots(c).tolist())
    assert members == set(np.nonzero(live)[0].tolist())
    assert idx.n_objects == int(live.sum())


def _engine_with_index_checks(sc):
    from repro.sim.engine import ScenarioEngine

    eng = ScenarioEngine(sc)
    # device-side index on client 0: ingest-fed via touched slots
    eng.sessions[0].dev.enable_index(n_cells_target=4, min_flat_size=4)

    def check(t):
        for z, zidx in eng.server.zoned.indexes.items():
            _assert_index_consistent(zidx, eng.server.zoned.zones[z])
        dev = eng.sessions[0].dev
        if dev.cluster_index is not None:
            _assert_index_consistent(dev.cluster_index, dev.local)

    eng.tick_hook = check
    return eng


def test_churn_deterministic_incremental_equals_rebuild():
    """Seeded churn scenarios (spawn/move/remove/outage) through the full
    device-cloud loop, index consistency asserted after EVERY tick — the
    always-on arm of the hypothesis property below."""
    from repro.sim.scenario import churn_scenario

    for seed in (0, 3):
        sc = churn_scenario(seed=seed, n_objects=16, n_ticks=10,
                            n_clients=1, drain_ticks=3, spawn_late=2,
                            query_prob=0.2)
        _engine_with_index_checks(sc).run()


@pytest.mark.slow
def test_churn_property_incremental_equals_rebuild():
    pytest.importorskip(
        "hypothesis", reason="hypothesis not installed; property test skipped")
    from hypothesis import given, settings, strategies as hst

    from repro.core.knobs import Knobs
    from repro.sim import (ClientSpec, NetTrace, ObjectEvent, PoseTrack,
                           QueryPlan, Scenario)
    from repro.sim.scenario import GridSpec

    KN = Knobs(server_capacity=32, client_capacity=16,
               max_object_points_server=16, max_object_points_client=8,
               min_obs_before_sync=1)
    N_TICKS = 8

    @hst.composite
    def scenarios(draw):
        n_obj = draw(hst.integers(3, 8))
        events = []
        for oid in range(1, n_obj + 1):
            events.append(ObjectEvent(
                tick=draw(hst.integers(0, 2)), kind="spawn", oid=oid,
                class_id=draw(hst.integers(0, 4)),
                pos=(draw(hst.floats(-3, 3)), 1.0, draw(hst.floats(-3, 3))),
                n_points=draw(hst.integers(4, 16))))
        for oid in draw(hst.lists(hst.integers(1, n_obj), max_size=n_obj,
                                  unique=True)):
            events.append(ObjectEvent(tick=draw(hst.integers(3, N_TICKS - 1)),
                                      kind="remove", oid=oid))
        for oid in draw(hst.lists(hst.integers(1, n_obj), max_size=4,
                                  unique=True)):
            events.append(ObjectEvent(tick=draw(hst.integers(1, N_TICKS - 1)),
                                      kind="move", oid=oid,
                                      delta=(draw(hst.floats(-2, 2)), 0.0,
                                             draw(hst.floats(-2, 2)))))
        events.sort(key=lambda e: (e.tick, e.kind, e.oid))
        return Scenario(seed=draw(hst.integers(0, 2**16)), n_ticks=N_TICKS,
                        embed_dim=32, knobs=KN,
                        grid=GridSpec(room=8.0, nx=2, nz=2), budget=16,
                        clients=(ClientSpec(cid=0, net=NetTrace(),
                                            track=PoseTrack(
                                                anchor=(0.0, 1.5, 0.0)),
                                            subscribe_radius=10.0),),
                        events=tuple(events), query=QueryPlan(prob=0.2),
                        drain_ticks=3)

    @settings(max_examples=10, deadline=None)
    @given(scenarios())
    def inner(sc):
        _engine_with_index_checks(sc).run()

    inner()
