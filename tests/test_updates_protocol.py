"""Incremental-update protocol invariants + end-to-end device/cloud session."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Knobs, MappingServer
from repro.core.query import query_local, query_server
from repro.core.runtime import CloudService, DeviceClient, NetworkModel, choose_mode
from repro.core.updates import collect_updates, init_sync
from repro.data.scenes import make_scene, scene_stream
from repro.perception.embedder import OracleEmbedder

KN = Knobs(server_capacity=128, client_capacity=64,
           max_object_points_server=256, max_object_points_client=64,
           max_detections_per_frame=16, min_obs_before_sync=1)


def _mapped_server(n_objects=15, frames=40):
    scene = make_scene(n_objects=n_objects, seed=3)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=64)
    srv = MappingServer(knobs=KN, embedder=emb, mode="semanticxr")
    key = jax.random.key(0)
    for i, fr in enumerate(scene_stream(scene, n_frames=frames,
                                        keyframe_interval=5, h=60, w=80)):
        srv.process_frame(fr, classes, jax.random.fold_in(key, i))
    return srv, emb, scene


def test_incremental_matches_full_sync():
    """Applying incremental packets == applying one full-map packet
    (same retained objects), and repeat ticks with no changes send 0 bytes."""
    srv, emb, _ = _mapped_server()
    sync = init_sync(KN.server_capacity)
    pkt1, sync = collect_updates(srv.store, sync, KN, tick=0)
    assert pkt1.nbytes > 0
    # no changes since -> empty incremental
    pkt2, sync = collect_updates(srv.store, sync, KN, tick=1)
    assert pkt2.nbytes == 0 and len(pkt2.updates) == 0
    # full map == first incremental from empty sync state
    pkt_full, _ = collect_updates(srv.store, init_sync(KN.server_capacity),
                                  KN, tick=0, full_map=True)
    assert {int(u.oid) for u in pkt_full.updates} == \
        {int(u.oid) for u in pkt1.updates}


def test_downstream_bytes_proportional_to_changes():
    """Fig. 6: incremental bytes track changed objects; the full-map baseline
    tracks total scene size."""
    srv, emb, scene = _mapped_server(n_objects=25, frames=60)
    sync = init_sync(KN.server_capacity)
    pkt, sync = collect_updates(srv.store, sync, KN, tick=0)
    n_active = int(np.asarray(srv.store.active.sum()))
    full, _ = collect_updates(srv.store, init_sync(KN.server_capacity), KN,
                              tick=0, full_map=True)
    assert len(full.updates) == n_active
    # second incremental after NO new frames is empty; full stays O(scene)
    pkt2, _ = collect_updates(srv.store, sync, KN, tick=1)
    full2, _ = collect_updates(srv.store, init_sync(KN.server_capacity), KN,
                               tick=1, full_map=True)
    assert pkt2.nbytes == 0
    assert full2.nbytes == full.nbytes


def test_query_under_network_drop():
    """LQ answers during outage; SQ/LQ switch follows the latency threshold;
    buffered updates apply on reconnect."""
    srv, emb, scene = _mapped_server()
    cloud = CloudService(knobs=KN, store_ref=srv)
    dev = DeviceClient(knobs=KN, embed_dim=64)
    net = NetworkModel(rtt_ms=20.0, outages=((10.0, 20.0),))

    # t=0: up -> SQ mode; ship updates
    assert choose_mode(net, 0.0, KN) == "SQ"
    pkt = cloud.update_tick(network_up=net.is_up(0.0))
    dev.ingest(pkt, user_pos=jnp.zeros(3))
    n_before = int(dev.local.active.sum())
    assert n_before > 0

    # t=15: outage -> LQ; local queries still answer
    assert not net.is_up(15.0)
    assert choose_mode(net, 15.0, KN) == "LQ"
    labels = np.asarray(srv.store.label)[np.asarray(srv.store.active)]
    cid = int(labels[0])                   # a class known to be mapped
    res = dev.query(emb.embed_text(cid))
    assert float(res.scores[0]) > 0.5

    # during outage the tick is buffered, not delivered
    pkt_out = cloud.update_tick(network_up=False)
    assert pkt_out is None and len(cloud.buffered) == 1

    # reconnect: flush applies pending state
    pkt3 = cloud.flush_buffer()
    dev.ingest(pkt3, user_pos=jnp.zeros(3))
    assert len(cloud.buffered) == 0


def test_sq_lq_agree_on_top1():
    """With capacity for the full scene, local and server queries agree."""
    srv, emb, scene = _mapped_server()
    cloud = CloudService(knobs=KN, store_ref=srv)
    dev = DeviceClient(knobs=KN, embed_dim=64)
    pkt = cloud.update_tick(network_up=True)
    dev.ingest(pkt, user_pos=jnp.zeros(3))
    labels = np.asarray(srv.store.label)
    ids = np.asarray(srv.store.ids)
    for cid in set(labels[np.asarray(srv.store.active)]):
        sq = cloud.query(emb.embed_text(int(cid)))
        lq = dev.query(emb.embed_text(int(cid)))
        assert int(sq.oids[0]) == int(lq.oids[0])
