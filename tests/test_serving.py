"""Serving substrate: continuous batching, straggler hedging, grad
compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import collectives as coll
from repro.serving.batching import BatchScheduler


def test_continuous_batching_serves_all():
    calls = []

    def step_fn(payloads):
        calls.append(len(payloads))
        return [p * 2 for p in payloads]

    s = BatchScheduler(batch_size=4, step_fn=step_fn)
    rids = [s.submit(i) for i in range(10)]
    done = s.drain()
    assert len(done) == 10
    assert all(done[r] == i * 2 for i, r in enumerate(rids))
    assert max(calls) <= 4


def test_priority_order():
    order = []

    def step_fn(payloads):
        order.extend(payloads)
        return payloads

    s = BatchScheduler(batch_size=1, step_fn=step_fn)
    s.submit("low", priority=0.1)
    s.submit("high", priority=9.0)
    s.submit("mid", priority=1.0)
    s.drain()
    assert order == ["high", "mid", "low"]


def test_straggler_hedging():
    """A request stuck in `running` past the hedge deadline is re-dispatched;
    first completion wins and the duplicate is dropped."""
    def step_fn(payloads):
        return [p for p in payloads]

    s = BatchScheduler(batch_size=2, step_fn=step_fn, hedge_after_ms=0.0)
    rid = s.submit("x")
    # simulate a worker that claimed the request but never finished
    import heapq
    from repro.serving.batching import Request
    req = Request(priority=-1.0, rid=rid, payload="x",
                  started_at=time.perf_counter() - 1.0)
    s.running[rid] = req
    s.waiting.clear()
    out = s.step()           # hedge fires, re-enqueues, completes
    assert s.hedge_count == 1
    assert s.done[rid] == "x"


def test_grad_compression_error_feedback():
    """int8+EF: single-step error is bounded; residual carries it so the
    RUNNING SUM of dequantized grads tracks the true sum (convergence
    property of error feedback)."""
    key = jax.random.key(0)
    grads = {"w": jax.random.normal(key, (256, 64)) * 0.01}
    ef = coll.init_ef(grads)
    true_sum = jnp.zeros_like(grads["w"])
    deq_sum = jnp.zeros_like(grads["w"])
    for i in range(8):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                    (256, 64)) * 0.01}
        deq, ef = coll.compress_grads_ef(g, ef)
        true_sum = true_sum + g["w"]
        deq_sum = deq_sum + deq["w"]
    # cumulative tracking error == current residual (telescoping), which is
    # bounded by one quantization step
    resid = jax.tree.leaves(ef.residual)[0]
    np.testing.assert_allclose(np.asarray(true_sum - deq_sum),
                               np.asarray(resid), rtol=1e-4, atol=1e-6)
    assert float(jnp.abs(resid).max()) < 0.01
    # wire size: int8 is ~4x smaller than fp32
    assert coll.compressed_bytes(grads) < 0.26 * 4 * grads["w"].size
