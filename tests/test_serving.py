"""Serving substrate: continuous batching, straggler hedging, grad
compression."""
import heapq
import time

import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import collectives as coll
from repro.serving.batching import BatchScheduler, Request


def test_continuous_batching_serves_all():
    calls = []

    def step_fn(payloads):
        calls.append(len(payloads))
        return [p * 2 for p in payloads]

    s = BatchScheduler(batch_size=4, step_fn=step_fn)
    rids = [s.submit(i) for i in range(10)]
    done = s.drain()
    assert len(done) == 10
    assert all(done[r] == i * 2 for i, r in enumerate(rids))
    assert max(calls) <= 4


def test_priority_order():
    order = []

    def step_fn(payloads):
        order.extend(payloads)
        return payloads

    s = BatchScheduler(batch_size=1, step_fn=step_fn)
    s.submit("low", priority=0.1)
    s.submit("high", priority=9.0)
    s.submit("mid", priority=1.0)
    s.drain()
    assert order == ["high", "mid", "low"]


def test_straggler_hedging():
    """A request stuck in `running` past the hedge deadline is re-dispatched;
    first completion wins and the duplicate is dropped."""
    def step_fn(payloads):
        return [p for p in payloads]

    s = BatchScheduler(batch_size=2, step_fn=step_fn, hedge_after_ms=0.0)
    rid = s.submit("x")
    # simulate a worker that claimed the request but never finished
    import heapq
    from repro.serving.batching import Request
    req = Request(priority=-1.0, rid=rid, payload="x",
                  started_at=time.perf_counter() - 1.0)
    s.running[rid] = req
    s.waiting.clear()
    out = s.step()           # hedge fires, re-enqueues, completes
    assert s.hedge_count == 1
    assert s.done[rid] == "x"


def _run_adversarial_schedule(n, lost, batch_size, idle_steps):
    """Build a scheduler where the ``lost`` subset was claimed by workers
    that never return, then step until quiescent.  Returns (scheduler,
    emitted rid sequence)."""
    s = BatchScheduler(batch_size=batch_size,
                       step_fn=lambda ps: [p * 10 for p in ps],
                       hedge_after_ms=0.0)
    rids = [s.submit(i) for i in range(n)]
    s.waiting.clear()
    for rid, gone in zip(rids, lost):
        req = Request(priority=-1.0, rid=rid, payload=rid,
                      started_at=time.perf_counter() - 1.0)
        if gone:
            s.running[rid] = req          # claimed, never completes
        else:
            heapq.heappush(s.waiting, req)
    emitted = []
    steps = 0
    while (s.waiting or s.running) and steps < 200:
        emitted.extend(s.step().keys())
        steps += 1
        if steps in idle_steps:           # adversarial idle engine steps
            emitted.extend(s.step().keys())
    return s, rids, emitted


def test_hedging_idempotent_under_adversarial_timing():
    """Property: for any subset of lost workers, any batch size, and any
    interleaving of idle steps — every rid is served exactly once (hedged
    duplicates discarded by rid) and hedge_count counts exactly the lost
    requests."""
    hyp = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 10), st.data(), st.integers(1, 4),
           st.sets(st.integers(1, 20), max_size=4))
    def prop(n, data, batch_size, idle_steps):
        lost = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
        s, rids, emitted = _run_adversarial_schedule(n, lost, batch_size,
                                                     idle_steps)
        assert sorted(emitted) == sorted(rids)          # exactly once each
        assert s.done == {rid: i * 10 for i, rid in enumerate(rids)}
        assert s.hedge_count == sum(lost)
        assert not s.running and not s.waiting
        # the lost worker's duplicate finally shows up: discarded by rid,
        # nothing re-emitted, results unchanged
        for rid, gone in zip(rids, lost):
            if gone:
                heapq.heappush(s.waiting, Request(
                    priority=-1.0, rid=rid, payload=-999))
        late = s.step()
        assert late == {} and s.done == \
            {rid: i * 10 for i, rid in enumerate(rids)}

    prop()


def test_hedging_duplicate_discard_deterministic():
    """Hypothesis-free subset of the property above (always runs): mixed
    lost/healthy requests across batch sizes; exactly-once service, accurate
    hedge_count, late duplicates discarded."""
    for n, lost, bs in [(1, [True], 1), (4, [True, False, True, False], 2),
                        (6, [True] * 6, 3), (5, [False] * 5, 4)]:
        s, rids, emitted = _run_adversarial_schedule(n, lost, bs, set())
        assert sorted(emitted) == sorted(rids)
        assert s.hedge_count == sum(lost)
        assert s.done == {rid: i * 10 for i, rid in enumerate(rids)}
        for rid, gone in zip(rids, lost):
            if gone:
                heapq.heappush(s.waiting, Request(
                    priority=-1.0, rid=rid, payload=-999))
        assert s.step() == {}
        assert s.done == {rid: i * 10 for i, rid in enumerate(rids)}


def test_grad_compression_error_feedback():
    """int8+EF: single-step error is bounded; residual carries it so the
    RUNNING SUM of dequantized grads tracks the true sum (convergence
    property of error feedback)."""
    key = jax.random.key(0)
    grads = {"w": jax.random.normal(key, (256, 64)) * 0.01}
    ef = coll.init_ef(grads)
    true_sum = jnp.zeros_like(grads["w"])
    deq_sum = jnp.zeros_like(grads["w"])
    for i in range(8):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                    (256, 64)) * 0.01}
        deq, ef = coll.compress_grads_ef(g, ef)
        true_sum = true_sum + g["w"]
        deq_sum = deq_sum + deq["w"]
    # cumulative tracking error == current residual (telescoping), which is
    # bounded by one quantization step
    resid = jax.tree.leaves(ef.residual)[0]
    np.testing.assert_allclose(np.asarray(true_sum - deq_sum),
                               np.asarray(resid), rtol=1e-4, atol=1e-6)
    assert float(jnp.abs(resid).max()) < 0.01
    # wire size: int8 is ~4x smaller than fp32
    assert coll.compressed_bytes(grads) < 0.26 * 4 * grads["w"].size
