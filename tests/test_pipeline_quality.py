"""End-to-end mapping pipeline: quality parity across execution modes and
the depth co-design gate (small-scale versions of Fig. 3 / Tab. 5)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import numpy as np
import pytest

from benchmarks.common import build_map, default_knobs, semantic_quality


@pytest.fixture(scope="module")
def maps():
    out = {}
    for mode in ("baseline", "semanticxr"):
        srv, emb, scene, times = build_map(mode=mode, n_objects=20,
                                           frames=40, h=120, w=160)
        out[mode] = (srv, emb, scene, times)
    return out


def test_quality_equivalent_across_modes(maps):
    """Object-level organization must not cost semantic quality (Tab. 4)."""
    qb = semantic_quality(*maps["baseline"][:3])
    qs = semantic_quality(*maps["semanticxr"][:3])
    assert qs["mAcc"] >= qb["mAcc"] - 10.0
    assert qs["F-mIoU"] >= qb["F-mIoU"] - 5.0
    assert qs["mAcc"] >= 80.0


def test_object_level_is_faster(maps):
    """B+P+SD steady-state per-frame latency < baseline (Fig. 3)."""
    tb = [t.total_ms for t in maps["baseline"][3][2:]]
    ts = [t.total_ms for t in maps["semanticxr"][3][2:]]
    assert np.mean(ts) < np.mean(tb)


def test_geometry_capped_at_budget(maps):
    srv = maps["semanticxr"][0]
    n = np.asarray(srv.store.n_points)[np.asarray(srv.store.active)]
    assert (n <= srv.knobs.max_object_points_server).all()


def test_deferral_gate_reduces_detections():
    kn_gate = default_knobs(depth_downsampling_ratio=5,
                            min_mapping_bbox_area=4000)
    srv, _, _, _ = build_map(knobs=kn_gate, n_objects=20, frames=30,
                             h=120, w=160)
    assert srv.deferred > 0
