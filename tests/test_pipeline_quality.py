"""End-to-end mapping pipeline: quality parity across execution modes and
the depth co-design gate (small-scale versions of Fig. 3 / Tab. 5)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import numpy as np
import pytest

from benchmarks.common import build_map, default_knobs, semantic_quality


@pytest.fixture(scope="module")
def maps():
    out = {}
    for mode in ("baseline", "semanticxr"):
        srv, emb, scene, times = build_map(mode=mode, n_objects=20,
                                           frames=40, h=120, w=160)
        out[mode] = (srv, emb, scene, times)
    return out


def test_quality_equivalent_across_modes(maps):
    """Object-level organization must not cost semantic quality (Tab. 4)."""
    qb = semantic_quality(*maps["baseline"][:3])
    qs = semantic_quality(*maps["semanticxr"][:3])
    assert qs["mAcc"] >= qb["mAcc"] - 10.0
    assert qs["F-mIoU"] >= qb["F-mIoU"] - 5.0
    assert qs["mAcc"] >= 80.0


def test_object_level_is_faster(maps):
    """B+P+SD steady-state per-frame latency < baseline (Fig. 3)."""
    tb = [t.total_ms for t in maps["baseline"][3][2:]]
    ts = [t.total_ms for t in maps["semanticxr"][3][2:]]
    assert np.mean(ts) < np.mean(tb)


def test_geometry_capped_at_budget(maps):
    srv = maps["semanticxr"][0]
    n = np.asarray(srv.store.n_points)[np.asarray(srv.store.active)]
    assert (n <= srv.knobs.max_object_points_server).all()


def test_deferral_gate_reduces_detections():
    kn_gate = default_knobs(depth_downsampling_ratio=5,
                            min_mapping_bbox_area=4000)
    srv, _, _, _ = build_map(knobs=kn_gate, n_objects=20, frames=30,
                             h=120, w=160)
    assert srv.deferred > 0


def test_mapping_gate_scales_to_render_resolution():
    """Regression pin for the unified gate (depth.mapping_gate): bbox areas
    measured at a simulated render resolution are rescaled to full-sensor
    (720p) units before comparing against min_mapping_bbox_area, so the
    knob default behaves identically at any resolution."""
    import numpy as np
    from repro.core import depth as depth_mod

    kn = default_knobs(depth_downsampling_ratio=5, min_mapping_bbox_area=2000)
    # at 240x320 the rescale factor is (720*1280)/(240*320) = 12:
    # area 166 -> 1992 (defer), area 167 -> 2004 (keep)
    got = depth_mod.mapping_gate(np.array([166, 167]), kn,
                                 frame_pixels=240 * 320)
    assert got.tolist() == [False, True]
    # at native 720p the knob applies unscaled
    got = depth_mod.mapping_gate(np.array([1999, 2000]), kn,
                                 frame_pixels=720 * 1280)
    assert got.tolist() == [False, True]
    # no depth downsampling -> nothing to defer for, any area passes
    kn_full = default_knobs(depth_downsampling_ratio=1,
                            min_mapping_bbox_area=2000)
    assert bool(depth_mod.mapping_gate(4, kn_full, frame_pixels=240 * 320))


def test_mapping_gate_mask_matches_detect_policy():
    """mapping_gate_mask (mask convenience wrapper) and the pipeline's
    vectorized _detect agree — the gate logic lives in exactly one place."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import depth as depth_mod
    from repro.core import MappingServer
    from repro.data.scenes import make_scene, render_frame
    from repro.perception.embedder import OracleEmbedder

    kn = default_knobs(depth_downsampling_ratio=5, min_mapping_bbox_area=4000)
    scene = make_scene(n_objects=20, seed=0)
    classes = {o.oid: o.class_id for o in scene.objects}
    srv = MappingServer(knobs=kn, embedder=OracleEmbedder(embed_dim=32))
    fr = render_frame(scene, 10, h=120, w=160, n_frames=40)
    before = srv.deferred
    cids, _ = srv._detect(fr, classes)
    want_kept = 0
    for oid in fr.visible_ids:
        mask_full = fr.inst == oid
        if bool(np.asarray(depth_mod.mapping_gate_mask(
                jnp.asarray(mask_full), kn))):
            want_kept += 1
    assert len(cids) == min(want_kept, kn.max_detections_per_frame)
    assert srv.deferred - before == len(fr.visible_ids) - want_kept
