"""Validate the analytic roofline cost model against XLA's cost_analysis on
small UNROLLED variants (no lax.scan over layers, so HloCostAnalysis counts
every op; attention stays loop-free at these shapes via q_chunk >= S).

This is the §Dry-run method check: the analytic model must track compiled
FLOPs within tolerance wherever XLA can count them."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.launch.costs import step_costs
from repro.models.api import model_api

CELL = ShapeCell("val", 128, 4, "prefill")


def _hlo_flops(cfg, cell):
    api = model_api(cfg)
    pspecs = api.param_specs()
    from repro.configs.base import input_specs
    ispecs = input_specs(cfg, cell)

    def fwd(params, batch):
        logits = api.forward(params, batch)
        return logits[:, -1] if logits.ndim == 3 else logits

    compiled = jax.jit(fwd).lower(pspecs, ispecs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):      # some jaxlib versions return [dict]
        ca = ca[0]
    return ca["flops"]


@pytest.mark.parametrize("arch", ["minitron-4b", "yi-9b", "gemma2-27b",
                                  "h2o-danube-3-4b"])
def test_analytic_matches_hlo_dense(arch):
    cfg = get_config(arch + "-smoke").replace(
        scan_layers=False, remat=False, attn_chunk=CELL.seq_len,
        sliding_window=64)
    # forward computes full-seq logits; align the analytic head term
    cc = step_costs(cfg, CELL)
    analytic = cc.breakdown["layers_fwd"] + \
        2.0 * CELL.global_batch * CELL.seq_len * cfg.vocab_size * cfg.d_model
    hlo = _hlo_flops(cfg, CELL)
    ratio = analytic / hlo
    assert 0.7 < ratio < 1.4, f"{arch}: analytic/hlo = {ratio:.2f}"


def test_analytic_matches_hlo_mla():
    cfg = get_config("deepseek-v2-236b-smoke").replace(
        scan_layers=False, remat=False, attn_chunk=CELL.seq_len)
    cc = step_costs(cfg, CELL)
    analytic = cc.breakdown["layers_fwd"] + \
        2.0 * CELL.global_batch * CELL.seq_len * cfg.vocab_size * cfg.d_model
    hlo = _hlo_flops(cfg, CELL)
    ratio = analytic / hlo
    # MoE adds data-dependent dispatch ops the analytic model prices at
    # capacity; allow a wider band
    assert 0.5 < ratio < 1.6, f"analytic/hlo = {ratio:.2f}"
