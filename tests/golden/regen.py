"""Regenerate the committed golden metrics snapshot — run ONLY when a
protocol change intentionally shifts the numbers, and say so in the PR.

    PYTHONPATH=src python tests/golden/regen.py
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

from repro.sim import churn_scenario, run_scenario  # noqa: E402

GOLDEN = Path(__file__).parent / "scenario_churn_v1.json"
SPEC = dict(seed=23, n_objects=20, n_ticks=20, n_clients=3,
            remove_frac=0.25, drain_ticks=8)


def scenario():
    return churn_scenario(**SPEC)


if __name__ == "__main__":
    s = run_scenario(scenario()).summary()
    # wall-clock percentiles are machine-dependent — never golden material
    s.pop("wall", None)
    s["_comment"] = (
        f"Golden metrics snapshot for churn_scenario(**{SPEC}). 'exact' "
        "fields are compared to the digit; 'approx' (MODELed latency/"
        "power) within tolerance. Regenerate ONLY for an intentional "
        "protocol change: PYTHONPATH=src python tests/golden/regen.py")
    GOLDEN.write_text(json.dumps(s, indent=1) + "\n")
    print(f"wrote {GOLDEN}")
