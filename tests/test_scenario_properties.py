"""Property suite (hypothesis) over random dynamic-scene event streams:
bounded client memory, tombstone convergence (including across outages and
bogus/duplicate removals), and downstream bytes that scale with churn —
never with scene size."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.knobs import Knobs
from repro.core.local_map import init_local_map, local_map_nbytes
from repro.core.updates import TOMBSTONE_NBYTES, update_nbytes
from repro.sim import (ClientSpec, NetTrace, ObjectEvent, PoseTrack,
                       QueryPlan, Scenario)
from repro.sim.engine import ScenarioEngine
from repro.sim.scenario import GridSpec

E = 32
# fixed capacities across examples: every draw reuses the same jit cache
KN = Knobs(server_capacity=32, client_capacity=16,
           max_object_points_server=16, max_object_points_client=8,
           min_obs_before_sync=1)
N_TICKS = 8
DRAIN = 5


@st.composite
def scenarios(draw):
    """Random but replayable dynamic scenes: spawns early, moves/removes
    mid-run (duplicates and unknown-oid removes included), 1-2 clients of
    which one may suffer an outage."""
    n_obj = draw(st.integers(3, 8))
    events = []
    for oid in range(1, n_obj + 1):
        events.append(ObjectEvent(
            tick=draw(st.integers(0, 2)), kind="spawn", oid=oid,
            class_id=draw(st.integers(0, 4)),
            pos=(draw(st.floats(-3, 3)), 1.0, draw(st.floats(-3, 3))),
            n_points=draw(st.integers(4, 16))))
    removed = draw(st.lists(st.integers(1, n_obj), max_size=n_obj,
                            unique=True))
    for oid in removed:
        events.append(ObjectEvent(tick=draw(st.integers(3, N_TICKS - 1)),
                                  kind="remove", oid=oid))
    if draw(st.booleans()) and removed:        # duplicate remove: no-op
        events.append(ObjectEvent(tick=N_TICKS - 1, kind="remove",
                                  oid=removed[0]))
    if draw(st.booleans()):                    # unknown-oid remove: no-op
        events.append(ObjectEvent(tick=draw(st.integers(0, N_TICKS - 1)),
                                  kind="remove", oid=999))
    for oid in draw(st.lists(st.integers(1, n_obj), max_size=3,
                             unique=True)):    # moves (maybe of removed)
        events.append(ObjectEvent(tick=draw(st.integers(1, N_TICKS - 1)),
                                  kind="move", oid=oid,
                                  delta=(draw(st.floats(-1, 1)), 0.0,
                                         draw(st.floats(-1, 1)))))
    events.sort(key=lambda e: (e.tick, e.kind, e.oid))

    n_clients = draw(st.integers(1, 2))
    clients = []
    for c in range(n_clients):
        outages = ()
        if draw(st.booleans()):
            a = draw(st.integers(1, N_TICKS - 2))
            outages = ((float(a), float(a + draw(st.integers(1, 3)))),)
        clients.append(ClientSpec(
            cid=c, net=NetTrace(outages=outages),
            track=PoseTrack(anchor=(0.0, 1.5, 0.0)),
            join_tick=draw(st.integers(0, 2)), subscribe_radius=10.0))
    return Scenario(seed=draw(st.integers(0, 2**16)), n_ticks=N_TICKS,
                    embed_dim=E, knobs=KN,
                    grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
                    clients=tuple(clients), events=tuple(events),
                    query=QueryPlan(prob=0.3), drain_ticks=DRAIN)


@settings(max_examples=12, deadline=None)
@given(scenarios())
def test_dynamic_scene_invariants(sc):
    eng = ScenarioEngine(sc)
    log = eng.run()
    C = len(sc.clients)
    cap_bytes = local_map_nbytes(init_local_map(KN, E))

    # --- bounded device memory: never exceeds the fixed capacity/bytes
    assert (log.client_live <= KN.client_capacity).all()
    assert (log.client_nbytes == cap_bytes).all()

    # --- tombstone convergence after packets drain (outages all end
    # before the drain tail): server live set == every client's set, and
    # removed objects are absent everywhere
    srv_live = eng.world.live_ids()
    removed = {e.oid for e in sc.events if e.kind == "remove"}
    for cid in range(C):
        m = eng.sessions[cid].dev.local
        got = set(np.asarray(m.ids)[np.asarray(m.active)].tolist())
        assert got == srv_live, f"client {cid}: {got} != {srv_live}"
        assert not (got & removed)

    # --- quiescence: the drain tail ends with zero-byte ticks
    assert (log.sent_bytes[-2:] == 0).all()

    # --- downstream scales with churn, not scene size: per-client totals
    # are bounded by what the events + a worst-case full catch-up per
    # (re)join could possibly ship, with every row at its byte ceiling
    row_max = update_nbytes(E, KN.max_object_points_client)
    n_spawn = sum(1 for e in sc.events if e.kind == "spawn")
    n_move = sum(1 for e in sc.events if e.kind == "move")
    n_remove = len(removed)
    bound = (n_spawn + n_move) * row_max + n_remove * TOMBSTONE_NBYTES \
        + n_spawn * row_max            # reconnect catch-up re-ships <= map
    assert (log.sent_bytes.sum(axis=0) <= bound).all()

    # --- exact replay (cheap here, and catches nondeterministic drift in
    # corners the golden scenario never reaches)
    log2 = ScenarioEngine(sc).run()
    assert log.equals(log2), log.diff(log2)
