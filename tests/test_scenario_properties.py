"""Property suite (hypothesis) over random dynamic-scene event streams:
bounded client memory, tombstone convergence (including across outages and
bogus/duplicate removals), and downstream bytes that scale with churn —
never with scene size.  Hypothesis-driven tests skip when the package is
absent (this container); the deterministic dynamic-scene tests below run
regardless — seeded draws stand in for @given where needed."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                    # container without hypothesis
    HAS_HYPOTHESIS = False

    class _St:
        """Shim so @st.composite / @given decorations still define the
        (skipped) test functions without the package."""
        def composite(self, f):
            return lambda *a, **k: None

        def integers(self, *a, **k):
            return None

        def floats(self, *a, **k):
            return None

        def booleans(self):
            return None

        def lists(self, *a, **k):
            return None

    st = _St()

    def given(*a, **k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = f.__name__
            return _skipped
        return deco

    def settings(*a, **k):
        return lambda f: f

from repro.core.knobs import Knobs
from repro.core.local_map import init_local_map, local_map_nbytes
from repro.core.updates import TOMBSTONE_NBYTES, update_nbytes
from repro.sim import (ClientSpec, NetTrace, ObjectEvent, PoseTrack,
                       QueryPlan, Scenario)
from repro.sim.engine import ScenarioEngine
from repro.sim.scenario import GridSpec

E = 32
# fixed capacities across examples: every draw reuses the same jit cache
KN = Knobs(server_capacity=32, client_capacity=16,
           max_object_points_server=16, max_object_points_client=8,
           min_obs_before_sync=1)
N_TICKS = 8
DRAIN = 5


@st.composite
def scenarios(draw):
    """Random but replayable dynamic scenes: spawns early, moves/removes
    mid-run (duplicates and unknown-oid removes included), 1-2 clients of
    which one may suffer an outage."""
    n_obj = draw(st.integers(3, 8))
    events = []
    for oid in range(1, n_obj + 1):
        events.append(ObjectEvent(
            tick=draw(st.integers(0, 2)), kind="spawn", oid=oid,
            class_id=draw(st.integers(0, 4)),
            pos=(draw(st.floats(-3, 3)), 1.0, draw(st.floats(-3, 3))),
            n_points=draw(st.integers(4, 16))))
    removed = draw(st.lists(st.integers(1, n_obj), max_size=n_obj,
                            unique=True))
    for oid in removed:
        events.append(ObjectEvent(tick=draw(st.integers(3, N_TICKS - 1)),
                                  kind="remove", oid=oid))
    if draw(st.booleans()) and removed:        # duplicate remove: no-op
        events.append(ObjectEvent(tick=N_TICKS - 1, kind="remove",
                                  oid=removed[0]))
    if draw(st.booleans()):                    # unknown-oid remove: no-op
        events.append(ObjectEvent(tick=draw(st.integers(0, N_TICKS - 1)),
                                  kind="remove", oid=999))
    for oid in draw(st.lists(st.integers(1, n_obj), max_size=3,
                             unique=True)):    # moves (maybe of removed)
        events.append(ObjectEvent(tick=draw(st.integers(1, N_TICKS - 1)),
                                  kind="move", oid=oid,
                                  delta=(draw(st.floats(-1, 1)), 0.0,
                                         draw(st.floats(-1, 1)))))
    events.sort(key=lambda e: (e.tick, e.kind, e.oid))

    n_clients = draw(st.integers(1, 2))
    clients = []
    for c in range(n_clients):
        outages = ()
        if draw(st.booleans()):
            a = draw(st.integers(1, N_TICKS - 2))
            outages = ((float(a), float(a + draw(st.integers(1, 3)))),)
        clients.append(ClientSpec(
            cid=c, net=NetTrace(outages=outages),
            track=PoseTrack(anchor=(0.0, 1.5, 0.0)),
            join_tick=draw(st.integers(0, 2)), subscribe_radius=10.0))
    return Scenario(seed=draw(st.integers(0, 2**16)), n_ticks=N_TICKS,
                    embed_dim=E, knobs=KN,
                    grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
                    clients=tuple(clients), events=tuple(events),
                    query=QueryPlan(prob=0.3), drain_ticks=DRAIN)


@settings(max_examples=12, deadline=None)
@given(scenarios())
def test_dynamic_scene_invariants(sc):
    eng = ScenarioEngine(sc)
    log = eng.run()
    C = len(sc.clients)
    cap_bytes = local_map_nbytes(init_local_map(KN, E))

    # --- bounded device memory: never exceeds the fixed capacity/bytes
    assert (log.client_live <= KN.client_capacity).all()
    assert (log.client_nbytes == cap_bytes).all()

    # --- tombstone convergence after packets drain (outages all end
    # before the drain tail): server live set == every client's set, and
    # removed objects are absent everywhere
    srv_live = eng.world.live_ids()
    removed = {e.oid for e in sc.events if e.kind == "remove"}
    for cid in range(C):
        m = eng.sessions[cid].dev.local
        got = set(np.asarray(m.ids)[np.asarray(m.active)].tolist())
        assert got == srv_live, f"client {cid}: {got} != {srv_live}"
        assert not (got & removed)

    # --- quiescence: the drain tail ends with zero-byte ticks
    assert (log.sent_bytes[-2:] == 0).all()

    # --- downstream scales with churn, not scene size: per-client totals
    # are bounded by what the events + a worst-case full catch-up per
    # (re)join could possibly ship, with every row at its byte ceiling
    row_max = update_nbytes(E, KN.max_object_points_client)
    n_spawn = sum(1 for e in sc.events if e.kind == "spawn")
    n_move = sum(1 for e in sc.events if e.kind == "move")
    n_remove = len(removed)
    bound = (n_spawn + n_move) * row_max + n_remove * TOMBSTONE_NBYTES \
        + n_spawn * row_max            # reconnect catch-up re-ships <= map
    assert (log.sent_bytes.sum(axis=0) <= bound).all()

    # --- exact replay (cheap here, and catches nondeterministic drift in
    # corners the golden scenario never reaches)
    log2 = ScenarioEngine(sc).run()
    assert log.equals(log2), log.diff(log2)


# ---------------------------------------------------------------------------
# mapper-backed dynamic scenes: spawn/move/remove all become VISIBLE through
# the perception path (pre-PR-10 only 'remove' acted; a spawned or moved
# object stayed invisible to mapper-backed frames until an unrelated refresh)
def _mapper_setup(kn, seed=2, n_objects=6, n_frames=None, n_ticks=10):
    from repro.core import MappingServer
    from repro.data.scenes import make_scene, scene_stream
    from repro.perception.embedder import OracleEmbedder
    scene = make_scene(n_objects=n_objects, seed=seed)
    classes = {o.oid: o.class_id for o in scene.objects}
    emb = OracleEmbedder(embed_dim=E)
    mapper = MappingServer(knobs=kn, embedder=emb)
    frames = list(scene_stream(scene, n_frames=n_frames or 5 * n_ticks,
                               keyframe_interval=5, h=60, w=80))
    return scene, classes, emb, mapper, frames


def _mapper_scenario(events, n_ticks=10, seed=11):
    kn = Knobs(server_capacity=64, client_capacity=32,
               max_object_points_server=32, max_object_points_client=8,
               max_detections_per_frame=8, min_obs_before_sync=1)
    return kn, Scenario(
        seed=seed, n_ticks=n_ticks, embed_dim=E, knobs=kn,
        grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
        clients=(ClientSpec(cid=0, net=NetTrace(),
                            track=PoseTrack(anchor=(0.0, 1.5, 0.0)),
                            subscribe_radius=10.0),),
        events=tuple(events), query=QueryPlan(prob=0.0), drain_ticks=4)


def test_mapper_scene_spawn_move_remove_visible():
    """All three event kinds act on a mapper-backed run (the mapper
    assigns its own slot ids, so effects are asserted by label and
    position): a spawned object of a class the scene never contained gets
    mapped near its spawn point, a moved object is re-mapped at its new
    position, and a removed object is tombstoned."""
    kn, _ = _mapper_scenario(())
    scene, classes, emb, mapper, frames = _mapper_setup(kn)
    # a class id no pre-existing scene object uses: its appearance in the
    # store can only come from the spawn event's re-rendered frames
    spawn_cls = min(set(range(20)) - {o.class_id for o in scene.objects})
    center0 = next(o.center for o in scene.objects if o.oid == 1).copy()
    delta = np.array([1.5, 0.0, 0.0])
    events = [
        ObjectEvent(tick=2, kind="spawn", oid=50, class_id=spawn_cls,
                    pos=(0.6, 1.0, 0.2), n_points=256),
        ObjectEvent(tick=4, kind="move", oid=1, delta=tuple(delta)),
        ObjectEvent(tick=6, kind="remove", oid=2),
    ]
    _, sc = _mapper_scenario(events)
    eng = ScenarioEngine(sc, mapper=mapper, frames=frames, scene=scene,
                         classes=classes, embedder=emb)
    log = eng.run()
    assert log.events[:, 0].sum() == 1          # spawn counted
    assert log.events[:, 1].sum() == 1          # move counted
    assert log.events[:, 2].sum() == 1          # remove counted
    assert 50 in {o.oid for o in scene.objects}
    assert 2 not in {o.oid for o in scene.objects}

    st_ = mapper.store
    act = np.asarray(st_.active)
    lab = np.asarray(st_.label)
    cent = np.asarray(st_.centroid)
    # spawn became visible through the perception path: an object of the
    # never-before-seen class is mapped near the spawn point
    hits = act & (lab == spawn_cls)
    assert hits.any()
    d_spawn = np.linalg.norm(cent[hits] - np.array([0.6, 1.0, 0.2]),
                             axis=1).min()
    assert d_spawn < 1.0, d_spawn
    # move: some live object is now mapped near the MOVED position
    d_new = np.linalg.norm(cent[act] - (center0 + delta), axis=1).min()
    assert d_new < 1.0, d_new
    # remove tombstoned the slot (direct store path, unchanged)
    ids = np.asarray(st_.ids)
    assert 2 not in set(ids[act].tolist())
    # the delivered client map converged to the server's live set
    m = eng.sessions[0].dev.local
    got_client = set(np.asarray(m.ids)[np.asarray(m.active)].tolist())
    assert got_client == set(ids[act].tolist())


def test_mapper_scene_replay_is_bit_identical():
    """Dynamic-scene re-rendering stays inside the determinism contract:
    the same scenario (fresh scene + mapper each run) replays to a
    bit-identical MetricsLog, and a no-event run leaves the pre-rendered
    frames byte-identical (rerender_frame is exact)."""
    events = [ObjectEvent(tick=1, kind="spawn", oid=60, class_id=1,
                          pos=(-0.4, 1.0, 0.5), n_points=32),
              ObjectEvent(tick=3, kind="move", oid=60,
                          delta=(0.8, 0.0, -0.4)),
              ObjectEvent(tick=5, kind="remove", oid=60)]
    kn, sc = _mapper_scenario(events, n_ticks=8)

    def run():
        scene, classes, emb, mapper, frames = _mapper_setup(kn, n_ticks=8)
        return ScenarioEngine(sc, mapper=mapper, frames=frames, scene=scene,
                              classes=classes, embedder=emb).run()
    a, b = run(), run()
    assert a.equals(b), a.diff(b)

    # rerender_frame == render_frame on an unchanged scene (golden safety)
    from repro.data.scenes import make_scene, render_frame, rerender_frame
    scene = make_scene(n_objects=5, seed=7)
    f = render_frame(scene, 13, h=60, w=80, n_frames=40)
    g = rerender_frame(scene, f)
    assert np.array_equal(f.depth, g.depth)
    assert np.array_equal(f.inst, g.inst)
    assert np.array_equal(f.visible_ids, g.visible_ids)


@pytest.mark.parametrize("seed,kind_ix", [(3, 0), (17, 1), (40, 2),
                                          (101, 0), (256, 2)])
def test_mapper_scene_event_properties(seed, kind_ix):
    """Property-style sweep (seeded draws — runs without hypothesis): for
    each seed, each event kind alone keeps the run deterministic and its
    effect observable in the mapper store."""
    kind = ("spawn", "move", "remove")[kind_ix]
    kn, _ = _mapper_scenario((), n_ticks=6, seed=seed)
    scene, classes, emb, mapper, frames = _mapper_setup(kn, n_ticks=6)
    spawn_cls = min(set(range(20)) - {o.class_id for o in scene.objects})
    pos = np.array([((seed % 7) - 3) * 0.3, 1.0, 0.2])
    if kind == "spawn":
        events = [ObjectEvent(tick=2, kind="spawn", oid=70,
                              class_id=spawn_cls, pos=tuple(pos),
                              n_points=256)]
    elif kind == "move":
        events = [ObjectEvent(tick=2, kind="move", oid=1 + seed % 4,
                              delta=(1.2, 0.0, 0.0))]
    else:
        events = [ObjectEvent(tick=2, kind="remove", oid=1 + seed % 4)]
    _, sc = _mapper_scenario(events, n_ticks=6, seed=seed)
    eng = ScenarioEngine(sc, mapper=mapper, frames=frames, scene=scene,
                         classes=classes, embedder=emb)
    eng.run()
    ids = np.asarray(mapper.store.ids)
    act = np.asarray(mapper.store.active)
    live = set(ids[act].tolist())
    if kind == "spawn":
        # the mapper assigns its own slot ids; the spawned object shows
        # up as a live row of the never-before-seen class near its pos
        lab = np.asarray(mapper.store.label)
        cent = np.asarray(mapper.store.centroid)
        hits = act & (lab == spawn_cls)
        assert hits.any()
        assert np.linalg.norm(cent[hits] - pos, axis=1).min() < 1.0
    elif kind == "remove":
        assert events[0].oid not in live
    else:
        assert eng._scene_dirty      # the move re-rendered the stream
