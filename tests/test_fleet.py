"""Multi-tenant fleet server: vmapped sync correctness, zone isolation,
convergence under interleaved ticks/outages/joins, and the smoke-scale
benchmark suite."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.knobs import Knobs
from repro.core.local_map import compute_priority
from repro.core.runtime import ClientSession, DeviceClient, NetworkModel
from repro.core.store import synthetic_store
from repro.core.updates import collect_updates, init_sync, update_nbytes
from repro.server import (FleetServer, FleetSimulator, SessionManager,
                          ZoneGrid, ZoneShardedStore)

E = 32
KN = Knobs(server_capacity=64, client_capacity=64,
           max_object_points_server=64, max_object_points_client=16,
           min_obs_before_sync=1)


def synth_store(n, *, cap=64, P=64, seed=0, x_range=(-4, 4)):
    return synthetic_store(
        n, cap, E, P, seed=seed, n_labels=10,
        centroid_low=(x_range[0], 0.0, -4.0),
        centroid_high=(x_range[1], 2.0, 4.0))


def bump_versions(store, slots):
    """Mutate objects in-place: version advance (new geometry angle)."""
    slots = jnp.asarray(np.asarray(slots, np.int64))
    return store._replace(version=store.version.at[slots].add(1))


# ---------------------------------------------------------------------------
def test_fleet_collect_matches_single_client():
    """One vmapped dispatch for C clients == C single-client collect_updates
    calls: same object sets, same exact wire bytes, per client."""
    store = synth_store(30)
    C, budget = 5, 16
    rng = np.random.default_rng(1)
    poses = rng.uniform(-3, 3, size=(C, 3)).astype(np.float32)
    sm = SessionManager(knobs=KN, n_clients=C, capacity=KN.server_capacity,
                        budget=budget, user_pos=poses.copy())
    # desync some rows so clients differ: client c already has objects c..c+4
    synced = np.zeros((C, KN.server_capacity), np.int32)
    for c in range(C):
        synced[c, c:c + 5] = 1
    sm.sync = sm.sync._replace(synced_version=jnp.asarray(synced))

    pkt = sm.collect(store)
    for c in range(C):
        pri = np.asarray(compute_priority(
            store.embed, store.label, store.centroid,
            user_pos=jnp.asarray(poses[c]), knobs=KN))
        single, _ = collect_updates(
            store, init_sync(KN.server_capacity)._replace(
                synced_version=synced[c].copy()),
            KN, tick=0, priorities=pri, max_updates=budget)
        assert single.nbytes == int(pkt.nbytes[c])
        assert single.count == int(pkt.counts[c])
        got = set(np.asarray(pkt.batch.oid[c])[:pkt.counts[c]].tolist())
        assert got == {int(u.oid) for u in single.updates}
        # byte-for-byte payload equality: every field of every row matches
        # the single-client packet (match rows by oid — ordering may
        # differ only among equal priorities)
        cnt = int(pkt.counts[c])
        fleet_row = {int(o): i for i, o in
                     enumerate(np.asarray(pkt.batch.oid[c])[:cnt])}
        for u in single.updates:
            i = fleet_row[int(u.oid)]
            for field in ("embed", "label", "points", "n_points",
                          "centroid", "version"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(pkt.batch, field)[c, i]),
                    np.asarray(getattr(u, field)), err_msg=field)
    # budget-limited catch-up: later ticks drain the remainder, then the
    # fleet quiesces to zero bytes
    shipped = [set(np.asarray(pkt.batch.oid[c])[:pkt.counts[c]].tolist())
               for c in range(C)]
    for _ in range(5):
        nxt = sm.collect(store)
        if (nxt.counts == 0).all():
            break
        for c in range(C):
            shipped[c] |= set(
                np.asarray(nxt.batch.oid[c])[:nxt.counts[c]].tolist())
    pkt2 = sm.collect(store)
    assert (pkt2.nbytes == 0).all() and (pkt2.counts == 0).all()
    for c in range(C):                     # every changed object arrived
        expect = {int(o) for s, o in enumerate(np.asarray(store.ids)[:30])
                  if synced[c][s] < 1}
        assert shipped[c] == expect


def test_fleet_collect_honors_class_point_overrides():
    """Per-class point budgets (Knobs.class_point_overrides) apply inside
    the vmapped fleet gather exactly as in the single-client path: every
    row is clipped to its class budget and the fleet's wire-byte
    accounting matches update_nbytes row for row."""
    kn = Knobs(server_capacity=64, client_capacity=64,
               max_object_points_server=64, max_object_points_client=16,
               min_obs_before_sync=1,
               class_point_overrides=((0, 4), (1, 8), (2, 999)))
    store = synth_store(24, seed=13)
    C, budget = 3, 64
    rng = np.random.default_rng(2)
    poses = rng.uniform(-3, 3, size=(C, 3)).astype(np.float32)
    sm = SessionManager(knobs=kn, n_clients=C, capacity=kn.server_capacity,
                        budget=budget, user_pos=poses.copy())
    pkt = sm.collect(store)
    labels = np.asarray(store.label)
    n_src = np.asarray(store.n_points)
    assert (pkt.counts == 24).all()
    for c in range(C):
        cnt = int(pkt.counts[c])
        oids = np.asarray(pkt.batch.oid[c])[:cnt]
        npts = np.asarray(pkt.batch.n_points[c])[:cnt]
        slot = {int(np.asarray(store.ids)[s]): s for s in range(24)}
        expect_bytes = 0
        saw_override = 0
        for o, n in zip(oids, npts):
            s = slot[int(o)]
            cap = kn.client_points_for(int(labels[s]))
            cap = min(cap, kn.max_object_points_client)
            want = min(int(n_src[s]), cap)
            assert int(n) == want, f"oid {o} class {labels[s]}"
            expect_bytes += update_nbytes(E, want)
            saw_override += int(labels[s]) in (0, 1)
        assert saw_override > 0             # the override classes occurred
        assert int(pkt.nbytes[c]) == expect_bytes
        # byte-for-byte vs the single-client collector under the same knobs
        pri = np.asarray(compute_priority(
            store.embed, store.label, store.centroid,
            user_pos=jnp.asarray(poses[c]), knobs=kn))
        single, _ = collect_updates(
            store, init_sync(kn.server_capacity), kn, tick=0,
            priorities=pri, max_updates=budget)
        assert single.nbytes == int(pkt.nbytes[c])
        for u in single.updates:
            i = int(np.nonzero(oids == int(u.oid))[0][0])
            assert int(npts[i]) == int(u.n_points)
            np.testing.assert_array_equal(
                np.asarray(pkt.batch.points[c, i, :int(u.n_points)]),
                np.asarray(u.points[:int(u.n_points)]))


def test_fleet_sync_advances_only_when_deliverable():
    """A client in outage keeps its sync row; reconnection coalesces every
    missed change into one packet (flush_buffer semantics, fleet-wide)."""
    store = synth_store(10)
    sm = SessionManager(knobs=KN, n_clients=2, capacity=KN.server_capacity,
                        budget=16)
    p0 = sm.collect(store, deliverable=np.array([True, False]))
    assert p0.counts[0] == 10 and p0.counts[1] == 0
    store = bump_versions(store, [0, 1])
    p1 = sm.collect(store, deliverable=np.array([True, True]))
    assert p1.counts[0] == 2          # only the delta
    assert p1.counts[1] == 10         # full coalesced catch-up
    assert int(p1.nbytes[1]) > int(p1.nbytes[0])


def test_zone_isolation_exact_bytes():
    """Acceptance: a client whose pose stays in one zone receives NO bytes
    for objects mutated only in other zones — exact update_nbytes
    accounting."""
    grid = ZoneGrid.for_room(8.0, nx=2, nz=1)   # zone 0: x<0, zone 1: x>=0
    store = synth_store(20, seed=3)
    fs = FleetServer(knobs=KN, embed_dim=E, n_clients=2, grid=grid,
                     budget=32)
    fs.refresh(store)
    fs.join(0, np.array([-2.0, 1.5, 0.0]), 1.0)   # client 0: zone 0 only
    fs.join(1, np.array([2.0, 1.5, 0.0]), 1.0)    # client 1: zone 1 only
    assert fs.subscribed[0].tolist() == [True, False]
    assert fs.subscribed[1].tolist() == [False, True]
    both = np.array([True, True])
    fs.tick(both)                                  # initial sync

    # mutate ONLY zone-1 objects (centroid x >= 0)
    cents = np.asarray(store.centroid)
    act = np.asarray(store.active)
    z1_slots = np.nonzero(act & (cents[:, 0] >= 0))[0]
    assert len(z1_slots) > 0
    store = bump_versions(store, z1_slots)
    fs.refresh(store)
    packets = fs.tick(both)
    per = fs.per_client_nbytes(packets)
    assert per[0] == 0                             # zone-0 client: zero bytes
    # zone-1 client: exactly the mutated objects at exact wire size
    n_pts = np.asarray(store.n_points)[z1_slots]
    expect = sum(update_nbytes(E, min(int(n), KN.max_object_points_client))
                 for n in n_pts)
    assert per[1] == expect


def test_zone_slot_reuse_resets_sync():
    """A freed shard slot must not hide its next occupant behind the old
    occupant's synced version."""
    grid = ZoneGrid(origin=(-4.0, -4.0), zone_size=8.0, nx=1, nz=1)
    store = synth_store(3, seed=5)
    zoned = ZoneShardedStore(knobs=KN, embed_dim=E, grid=grid,
                             zone_capacity=4)
    fs = FleetServer(knobs=KN, embed_dim=E, n_clients=1, grid=grid,
                     budget=8, zoned=zoned)
    fs.refresh(store)
    fs.join(0, np.zeros(3), 1.0)
    fs.tick(np.array([True]))
    # retire object at slot 0, then add a NEW object with a LOWER version
    store = store._replace(active=store.active.at[0].set(False))
    fs.refresh(store)                               # frees the shard slot
    store = store._replace(
        active=store.active.at[0].set(True),
        ids=store.ids.at[0].set(99),
        version=store.version.at[0].set(1))         # version 1 <= synced 1
    fs.refresh(store)
    packets = fs.tick(np.array([True]))
    oids = set()
    for _, pkt in packets:
        p = pkt.packet_for(0)
        if p.count:
            oids |= {int(u.oid) for u in p.updates}
    assert 99 in oids


def test_quiesced_zones_skip_collect():
    """Once a zone's subscribers are fully synced, idle ticks dispatch
    nothing for it; a refresh with changes makes it collect again."""
    grid = ZoneGrid.for_room(8.0, nx=2, nz=1)
    store = synth_store(12, seed=9)
    fs = FleetServer(knobs=KN, embed_dim=E, n_clients=2, grid=grid,
                     budget=32)
    fs.refresh(store)
    fs.join(0, np.array([-2.0, 1.5, 0.0]), 1.0)
    fs.join(1, np.array([2.0, 1.5, 0.0]), 1.0)
    both = np.array([True, True])
    assert len(fs.tick(both)) == 2                 # initial catch-up
    assert len(fs.tick(both)) == 2                 # quiescing tick (0 bytes)
    assert fs.tick(both) == []                     # quiesced: no dispatches
    cents = np.asarray(store.centroid)
    z1 = np.nonzero(np.asarray(store.active) & (cents[:, 0] >= 0))[0]
    store = bump_versions(store, z1[:1])
    fs.refresh(store)
    ticked = fs.tick(both)
    assert [z for z, _ in ticked] == [1]           # only the dirty zone
    # zone-1's subscriber in outage: skipped this tick but still dirty
    assert fs.tick(np.array([True, False])) == []
    assert [z for z, _ in fs.tick(both)] == [1]    # quiescing tick
    assert fs.tick(both) == []


# ---------------------------------------------------------------------------
def _expected_visible(fs, min_obs):
    """Oracle: (oid -> version) of the server store restricted to a zone
    subscription, transient-filtered — what a synced client must hold."""
    out = {}
    for z, zone in enumerate(fs.zoned.zones):
        act = np.asarray(zone.active)
        obs = np.asarray(zone.obs_count)
        ids = np.asarray(zone.ids)
        ver = np.asarray(zone.version)
        for s in np.nonzero(act & (obs >= min_obs))[0]:
            out.setdefault(z, {})[int(ids[s])] = int(ver[s])
    return out


def test_multi_client_convergence_under_interleaving():
    """After an arbitrary interleaving of ticks, outages, joins, and store
    mutations, every client's local map converges to the server store
    restricted to its subscribed zones (settle ticks with the network up)."""
    rng = np.random.default_rng(11)
    grid = ZoneGrid.for_room(8.0, nx=2, nz=1)
    kn = Knobs(server_capacity=64, client_capacity=64,
               max_object_points_server=32, max_object_points_client=16,
               min_obs_before_sync=1)
    C = 4
    store = synth_store(12, P=32, seed=7)
    n_next = 12
    fs = FleetServer(knobs=kn, embed_dim=E, n_clients=C, grid=grid,
                     budget=16)
    # fixed per-client poses -> static zone subscriptions (no removals, no
    # zone moves in this scenario, so set equality is exact)
    poses = np.array([[-2.5, 1.5, 0.0], [2.5, 1.5, 0.0],
                      [-1.0, 1.5, 1.0], [1.5, 1.5, -1.0]], np.float32)
    sessions = [ClientSession(
        dev=DeviceClient(knobs=kn, embed_dim=E),
        net=NetworkModel(), knobs=kn, user_pos=jnp.asarray(poses[c]))
        for c in range(C)]
    joined = np.zeros(C, bool)
    fs.refresh(store)

    def run_tick(t, deliverable):
        packets = fs.tick(deliverable & joined)
        total = 0
        for c in range(C):
            if not joined[c]:
                continue
            for _, pkt in packets:
                sessions[c].step(t, pkt.packet_for(c))
            total += sum(int(pkt.nbytes[c]) for _, pkt in packets)
        return total

    fs.join(0, poses[0], 1.2)
    joined[0] = True
    for t in range(24):
        ev = rng.random()
        if ev < 0.3:                      # mutate some existing objects
            slots = rng.choice(np.nonzero(np.asarray(store.active))[0],
                               size=3, replace=False)
            store = bump_versions(store, slots)
        elif ev < 0.5 and n_next < 40:    # new object appears
            s = n_next
            n_next += 1
            emb = rng.normal(size=(E,)).astype(np.float32)
            store = store._replace(
                ids=store.ids.at[s].set(s + 1),
                active=store.active.at[s].set(True),
                embed=store.embed.at[s].set(emb / np.linalg.norm(emb)),
                centroid=store.centroid.at[s].set(
                    rng.uniform(-3, 3, 3).astype(np.float32)),
                n_points=store.n_points.at[s].set(8),
                obs_count=store.obs_count.at[s].set(2),
                version=store.version.at[s].set(1))
        elif ev < 0.7:                    # a client joins mid-session
            c = int(rng.integers(0, C))
            if not joined[c]:
                fs.join(c, poses[c], 1.2)
                joined[c] = True
        fs.refresh(store)
        deliverable = rng.random(C) > 0.35          # random outages
        run_tick(float(t), deliverable)

    for c in range(C):                    # everyone in by settle time
        if not joined[c]:
            fs.join(c, poses[c], 1.2)
            joined[c] = True
    up = np.ones(C, bool)
    t = 24.0
    for _ in range(10):                   # settle: all links up, no changes
        if run_tick(t, up) == 0:
            break
        t += 1.0
    assert run_tick(t + 1.0, up) == 0     # quiesced

    by_zone = _expected_visible(fs, kn.min_obs_before_sync)
    for c in range(C):
        subs = np.nonzero(fs.subscribed[c])[0]
        assert len(subs) > 0
        expect = {}
        for z in subs:
            expect.update(by_zone.get(int(z), {}))
        m = sessions[c].dev.local
        act = np.asarray(m.active)
        got = {int(i): int(v) for i, v in
               zip(np.asarray(m.ids)[act], np.asarray(m.version)[act])}
        assert got == expect, f"client {c}: {got} != {expect}"


# ---------------------------------------------------------------------------
def test_fleet_simulator_smoke():
    """The full driver runs: churn + outages + zone routing + batched
    queries; per-client byte accounting is consistent."""
    kn = Knobs(server_capacity=64, client_capacity=32,
               max_object_points_server=64, max_object_points_client=16,
               max_detections_per_frame=8, min_obs_before_sync=1)
    from repro.core import MappingServer
    from repro.data.scenes import make_scene, scene_stream
    from repro.perception.embedder import OracleEmbedder
    emb = OracleEmbedder(embed_dim=E)
    scene = make_scene(n_objects=10, seed=2)
    classes = {o.oid: o.class_id for o in scene.objects}
    mapper = MappingServer(knobs=kn, embedder=emb)
    frames = list(scene_stream(scene, n_frames=40, keyframe_interval=5,
                               h=60, w=80))
    sim = FleetSimulator(knobs=kn, embed_dim=E, n_clients=6, seed=3,
                         grid=ZoneGrid.for_room(scene.room_size, 2, 2))
    stats = sim.run(n_ticks=8, mapper=mapper, frames=frames, embedder=emb,
                    classes=classes)
    assert stats["down_bytes_total"] >= 0
    per = sum(c.session.down_bytes for c in sim.clients)
    assert per <= stats["down_bytes_total"]   # in-flight may lag delivery
    assert stats["served"] == stats["sq_queries"]   # full drain: no backlog
    assert stats["unserved"] == 0
    assert stats["dropped_by_full_zone"] == 0


@pytest.mark.slow
def test_bench_fleet_scale_smoke():
    """tier-1-adjacent smoke of the fleet_scale suite (C=2, tiny shapes)."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks import fleet_scale
    res = fleet_scale.run(smoke=True)
    assert set(res["sweep"]) == {"1", "2"}
    for r in res["sweep"].values():
        assert r["tick_ms"] > 0 and r["per_client_bytes"] > 0
    # both clients receive identical bytes (same subscription, same map)
    b = [r["per_client_bytes"] for r in res["sweep"].values()]
    assert b[0] == b[1]


def test_ack_tick_parity_with_per_client_acks():
    """The serving loop's batched same-tick ack (FleetServer.ack_tick) must
    leave the server in exactly the state of routing each framed client's
    ack through the per-client path (FleetServer.ack) — acked vectors,
    drained inflight queues, epoch freshness, and lease bookkeeping."""
    def build():
        srv = FleetServer(knobs=KN, embed_dim=E, n_clients=4,
                          grid=ZoneGrid.for_room(8.0, 2, 1), budget=8)
        rng = np.random.default_rng(3)
        for c in range(4):
            srv.join(c, rng.uniform(-3, 3, size=3).astype(np.float32), 6.0)
        srv.refresh(synth_store(24))
        return srv

    deliverable = np.ones((4,), bool)
    a, b = build(), build()
    for t in range(3):
        pk_a = a.tick(deliverable, tick=t)
        pk_b = b.tick(deliverable, tick=t)
        a.ack_tick(pk_a, tick=t)
        for z, pkt in pk_b:
            for c in np.nonzero(pkt.seqs >= 0)[0]:
                b.ack(int(c), int(z), int(pkt.epoch[c]), int(pkt.seqs[c]),
                      tick=t)
    for sa, sb in zip(a.sessions, b.sessions):
        assert np.array_equal(sa.acked, sb.acked)
        assert all(len(q) == 0 for q in sa.inflight)
        assert all(len(q) == 0 for q in sb.inflight)
    assert np.array_equal(a.epoch_fresh, b.epoch_fresh)
    assert np.array_equal(a.last_ack_tick, b.last_ack_tick)


# ---------------------------------------------------------------------------
# mesh-sharded session tier (server/mesh.py)
def test_mesh_tier_byte_identity_vs_unsharded():
    """MeshSessionTier (client axis split over S session shards) must be
    byte-identical to the single-device SessionManager: every per-client
    packet field, the seq streams, and the host bookkeeping (acked /
    inflight / deletion debt) — across ticks with interleaved mutations,
    acks, rollbacks, resets, and slot reuse."""
    from repro.server.mesh import ClientRoster, MeshSessionTier
    C, N = 12, KN.server_capacity
    store = synth_store(28, cap=N, seed=5)
    rng = np.random.default_rng(2)
    poses = rng.uniform(-3, 3, (C, 3)).astype(np.float32)
    subs = rng.random(C) < 0.85

    ref = SessionManager(knobs=KN, n_clients=C, capacity=N, budget=8,
                         subscribed=subs.copy(), user_pos=poses.copy())
    tier = MeshSessionTier(knobs=KN, capacity=N, budget=8,
                           roster=ClientRoster.round_robin(C, 4))
    tier.set_all(subscribed=subs, user_pos=poses)

    epoch = np.arange(C, dtype=np.int64)
    for t in range(5):
        deliv = rng.random(C) < 0.9
        pa = ref.collect(store, deliverable=deliv, zone=1, epoch=epoch,
                         now=t)
        pb = tier.collect(store, deliverable=deliv, zone=1, epoch=epoch,
                          now=t)
        np.testing.assert_array_equal(pa.counts, pb.counts)
        np.testing.assert_array_equal(pa.nbytes, pb.nbytes)
        np.testing.assert_array_equal(pa.seqs, pb.seqs)
        np.testing.assert_array_equal(pa.tomb_counts(), pb.tomb_counts())
        assert pa.total_nbytes == pb.total_nbytes
        for c in range(C):
            ua, ub = pa.packet_for(c), pb.packet_for(c)
            assert (ua.count, ua.nbytes, ua.tick) \
                == (ub.count, ub.nbytes, ub.tick)
            if ua.count:
                assert (ua.zone, ua.seq, ua.epoch) \
                    == (ub.zone, ub.seq, ub.epoch)
                for f in ("oid", "embed", "label", "points", "n_points",
                          "centroid", "version", "valid"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(ua.batch, f)),
                        np.asarray(getattr(ub.batch, f)), err_msg=f)
        # interleave the control plane identically on both
        for c in range(C):
            if int(pa.seqs[c]) >= 0 and rng.random() < 0.6:
                ref.ack(c, int(pa.seqs[c]))
                tier.ack(c, int(pb.seqs[c]))
        if t == 1:
            ref.rollback(3), tier.rollback(3)
        if t == 2:
            ref.reset_client(5, keep_seq=True)
            tier.reset_client(5, keep_seq=True)
            ref.reset_slots([0, 7]), tier.reset_slots([0, 7])
        if t == 3:
            store = bump_versions(store, [1, 4, 9])
        assert ref.dirty == tier.dirty
        np.testing.assert_array_equal(ref.acked, tier_acked(tier))
        np.testing.assert_array_equal(ref.deletion_debt(store),
                                      tier.deletion_debt(store))
        for c in range(C):
            assert ref.oldest_unacked_tick(c) == tier.oldest_unacked_tick(c)


def tier_acked(tier):
    """Assemble a sharded tier's [C, N] acked mirror for comparison."""
    out = np.zeros((tier.n_clients, tier.capacity), np.int32)
    for s, part in enumerate(tier.parts):
        if part is not None:
            out[tier.roster.members[s]] = part.acked
    return out


def test_mesh_fleet_server_end_to_end_byte_identity():
    """FleetServer(n_session_shards=S) vs the default single-device tier:
    identical wire packets through joins, pose churn (zone crossings), and
    the batched-ack tick loop."""
    def build(shards):
        srv = FleetServer(knobs=KN, embed_dim=E, n_clients=6,
                          grid=ZoneGrid.for_room(8.0, 2, 2), budget=8,
                          n_session_shards=shards)
        rng = np.random.default_rng(4)
        for c in range(6):
            srv.join(c, rng.uniform(-3, 3, 3).astype(np.float32), 2.0)
        return srv

    a, b = build(1), build(3)
    store = synth_store(24, cap=a.zoned.zone_capacity)
    rng = np.random.default_rng(9)
    deliverable = np.ones((6,), bool)
    for t in range(4):
        a.refresh(store), b.refresh(store)
        poses = rng.uniform(-3.5, 3.5, (6, 3)).astype(np.float32)
        a.set_poses(poses, 2.0), b.set_poses(poses, 2.0)
        np.testing.assert_array_equal(a.subscribed, b.subscribed)
        pa = a.tick(deliverable, tick=t)
        pb = b.tick(deliverable, tick=t)
        assert [z for z, _ in pa] == [z for z, _ in pb]
        for (z, qa), (_, qb) in zip(pa, pb):
            np.testing.assert_array_equal(qa.nbytes, qb.nbytes)
            np.testing.assert_array_equal(qa.seqs, qb.seqs)
            for c in range(6):
                ua, ub = qa.packet_for(c), qb.packet_for(c)
                assert (ua.count, ua.nbytes) == (ub.count, ub.nbytes)
                if ua.count:
                    np.testing.assert_array_equal(
                        np.asarray(ua.batch.points),
                        np.asarray(ub.batch.points))
        a.ack_tick(pa, tick=t), b.ack_tick(pb, tick=t)
        store = bump_versions(store, [t, t + 3])
    np.testing.assert_array_equal(a.epoch, b.epoch)
    assert a.blocked_tombstone_oids(tick=5) == b.blocked_tombstone_oids(tick=5)


def test_client_shard_affinity():
    """Zone-affinity partition: a client lands on the shard holding the
    majority of its subscribed zones; unsubscribed clients round-robin."""
    from repro.distributed.sharding import client_shard_affinity
    subs = np.zeros((4, 8), bool)
    subs[0, [0, 2, 4]] = True          # zones 0,2,4 -> shard 0 under z%2
    subs[1, [1, 3]] = True             # -> shard 1
    subs[2, [0, 1, 3]] = True          # majority odd -> shard 1
    # client 3 subscribes nothing -> 3 % 2 = 1
    a = client_shard_affinity(subs, 2)
    assert a.tolist() == [0, 1, 1, 1]
    # explicit zone->shard map overrides the z % S default
    a2 = client_shard_affinity(subs, 2, zone_shards=np.zeros(8, np.int64))
    assert a2.tolist() == [0, 0, 0, 1]


# ---------------------------------------------------------------------------
# satellite bugfix: zone-crossing mid-flight staleness
def _framed_server(n_clients=1):
    srv = FleetServer(knobs=KN, embed_dim=E, n_clients=n_clients,
                      grid=ZoneGrid.for_room(8.0, 2, 1), budget=8)
    return srv


def test_zone_crossing_midflight_never_applies_stale_row():
    """A packet in the air when its client leaves the zone must be DROPPED
    at the device on arrival — never ingested then pruned a tick later.
    The seq stream still advances and the cumulative ack still goes out,
    so re-entry packets (seq continues: the server kept the stream via
    reset_client(keep_seq=True)) are not mistaken for a gap."""
    srv = _framed_server()
    # client in zone 0 (left half of the 2x1 grid)
    srv.join(0, np.array([-2.0, 1.5, 0.0], np.float32), 1.0)
    store = synth_store(20, x_range=(-4, -1))   # all objects in zone 0
    srv.refresh(store)
    sess = ClientSession(dev=DeviceClient(knobs=KN, embed_dim=E),
                         net=NetworkModel(rtt_ms=20.0, bandwidth_mbps=100.0),
                         knobs=KN, cid=0)
    sess.zone_subs = srv.subscribed[0].copy()

    packets = srv.tick(np.ones(1, bool), tick=0)
    assert packets and int(packets[0][1].counts[0]) > 0
    in_air = packets[0][1].packet_for(0)

    # the client crosses to zone 1 BEFORE the packet lands
    srv.set_client_pose(0, np.array([2.0, 1.5, 0.0], np.float32), 1.0)
    sess.zone_subs = srv.subscribed[0].copy()
    assert not sess.zone_subs[0] and sess.zone_subs[1]

    live0 = int(np.asarray(sess.dev.local.active).sum())
    sess._receive(0.0, in_air)
    # dropped at delivery: nothing ingested, no stale-zone row in the map
    assert int(np.asarray(sess.dev.local.active).sum()) == live0 == 0
    assert sess.delivered == 0 and sess.down_bytes == 0
    assert sess.stale_drops == 1
    # ...but the protocol position advanced: ack emitted, seq consumed
    acks = sess.drain_acks()
    assert acks == [(0, int(in_air.epoch), int(in_air.seq))]
    assert sess._expect[0] == in_air.seq + 1

    # re-entry: the client returns to zone 0 — the catch-up re-ships on the
    # SAME seq stream (keep_seq survived the round trip) and applies
    # cleanly, no gap, no resync
    srv.set_client_pose(0, np.array([-2.0, 1.5, 0.0], np.float32), 1.0)
    sess.zone_subs = srv.subscribed[0].copy()
    pk2 = srv.tick(np.ones(1, bool), tick=1)
    delivered_any = False
    for z, pkt in pk2:
        u = pkt.packet_for(0)
        if u.count:
            assert u.seq == in_air.seq + 1   # stream continued, not reset
            sess._receive(1.0, u)
            delivered_any = True
    assert delivered_any
    assert sess.stale_drops == 1            # no further drops
    assert sess.resyncs == 0 and not sess._gap_since
    assert int(np.asarray(sess.dev.local.active).sum()) > 0


def test_zone_gate_off_by_default():
    """Legacy callers that never set zone_subs keep the old behavior:
    framed packets from any zone apply (the gate arms only when the
    subscription view is wired)."""
    srv = _framed_server()
    srv.join(0, np.array([-2.0, 1.5, 0.0], np.float32), 1.0)
    srv.refresh(synth_store(12, x_range=(-4, -1)))
    sess = ClientSession(dev=DeviceClient(knobs=KN, embed_dim=E),
                         net=NetworkModel(rtt_ms=20.0, bandwidth_mbps=100.0),
                         knobs=KN, cid=0)
    assert sess.zone_subs is None
    packets = srv.tick(np.ones(1, bool), tick=0)
    sess._receive(0.0, packets[0][1].packet_for(0))
    assert sess.delivered == 1 and sess.stale_drops == 0
