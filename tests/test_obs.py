"""Observability contract tests.

The load-bearing invariant: attaching the tracer + metrics registry to a
run OBSERVES and never PERTURBS — the golden churn scenario's MetricsLog
stays bit-identical to the committed snapshot with observability on.
Plus: deterministic histogram percentile math (empty/single-sample
edges), Chrome trace-export round-trip, the BENCH trajectory log, and
the regression gate (fails on an injected regression, passes on the
repo's real artifacts)."""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.obs import (Histogram, MetricsRegistry, Tracer, get_registry,
                       get_tracer, set_registry, set_tracer)
from repro.obs.metrics import exact_percentiles
from repro.obs.trajectory import append_run, latest_run, load_history

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

GOLDEN = Path(__file__).parent / "golden" / "scenario_churn_v1.json"


@pytest.fixture
def obs():
    """Install a fresh tracer + registry; restore whatever was there."""
    tr, reg = Tracer(), MetricsRegistry()
    prev_tr, prev_reg = set_tracer(tr), set_registry(reg)
    yield tr, reg
    set_tracer(prev_tr), set_registry(prev_reg)


def _golden_scenario():
    from repro.sim import churn_scenario
    return churn_scenario(seed=23, n_objects=20, n_ticks=20, n_clients=3,
                          remove_frac=0.25, drain_ticks=8)


# ------------------------------------------------------------ replay purity
def test_golden_replay_unperturbed_by_observability(obs):
    """THE acceptance invariant: tracing + metrics on, the golden churn
    scenario's MetricsLog is bit-identical to the observability-off run
    and still matches the committed snapshot."""
    from repro.sim import run_scenario
    tr, reg = obs
    log_on = run_scenario(_golden_scenario())
    assert len(tr) > 0, "tracer saw no spans — instrumentation is dead"
    assert reg.histogram("engine_tick_ms").count() > 0
    set_tracer(None), set_registry(None)
    log_off = run_scenario(_golden_scenario())
    assert log_on.equals(log_off), \
        f"observability perturbed replay: {log_on.diff(log_off)}"
    log_on.assert_matches_snapshot(json.loads(GOLDEN.read_text()))


def test_engine_spans_cover_the_tick_loop(obs):
    from repro.sim import run_scenario
    tr, _ = obs
    run_scenario(_golden_scenario())
    names = {e[0] for e in tr.events}
    assert "engine.tick" in names
    assert "session.collect_fleet" in names
    assert "engine.client_step" in names
    # 20 ticks + 8 drain ticks
    assert len(tr.durations_ms("engine.tick")) == 28


# ------------------------------------------------------- percentile math
def test_exact_percentiles_empty_and_single():
    z = exact_percentiles([])
    assert z == {"n": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                 "mean": 0.0, "max": 0.0}
    s = exact_percentiles([7.5])
    assert s["n"] == 1
    assert s["p50"] == s["p95"] == s["p99"] == s["max"] == 7.5


def test_exact_percentiles_nearest_rank():
    xs = list(range(1, 101))          # 1..100
    p = exact_percentiles(xs)
    assert p["p50"] == 50 and p["p95"] == 95 and p["p99"] == 99
    # nearest-rank returns an observed sample, never an interpolation
    p = exact_percentiles([1.0, 2.0])
    assert p["p50"] == 1.0 and p["p99"] == 2.0


def test_histogram_percentile_edges():
    h = Histogram("t", bounds=(1.0, 10.0, 100.0))
    assert h.percentile(50) == 0.0            # empty series
    h.observe(5.0)
    # single sample: every percentile is its bucket's upper edge
    assert h.percentile(50) == h.percentile(99) == 10.0
    h.observe(500.0)                          # overflow bucket
    assert h.percentile(99) == float("inf")
    assert h.count() == 2


def test_histogram_percentiles_are_bucket_edges_and_deterministic():
    h1 = Histogram("a", bounds=(1.0, 2.0, 4.0, 8.0))
    h2 = Histogram("b", bounds=(1.0, 2.0, 4.0, 8.0))
    samples = [0.5, 1.5, 1.5, 3.0, 3.0, 3.0, 7.0, 7.0, 0.2, 1.0]
    for v in samples:
        h1.observe(v)
    for v in reversed(samples):               # order must not matter
        h2.observe(v)
    for p in (50, 95, 99):
        assert h1.percentile(p) == h2.percentile(p)
        assert h1.percentile(p) in (1.0, 2.0, 4.0, 8.0)
    # cross-check rank math against the raw-sample reference: the bucket
    # edge must be >= the true nearest-rank sample and <= the next edge
    ref = exact_percentiles(samples)
    assert h1.percentile(50) >= ref["p50"]
    assert h1.percentile(95) >= ref["p95"]


def test_histogram_labels_are_independent_series():
    h = Histogram("t", bounds=(1.0, 10.0))
    h.observe(0.5, stage="lift")
    h.observe(5.0, stage="embed")
    assert h.percentile(50, stage="lift") == 1.0
    assert h.percentile(50, stage="embed") == 10.0
    assert h.count() == 0                     # unlabeled series untouched


def test_registry_exports(tmp_path):
    reg = MetricsRegistry()
    reg.counter("bytes_total", "sent bytes").inc(100, client=0)
    reg.counter("bytes_total").inc(50, client=1)
    reg.gauge("live_objects").set(42)
    h = reg.histogram("lat_ms", bounds=(1.0, 10.0))
    h.observe(0.5), h.observe(20.0)
    snap = reg.snapshot()
    assert snap["counters"]["bytes_total"] == {'{client="0"}': 100,
                                               '{client="1"}': 50}
    assert snap["histograms"]["lat_ms"]["_"]["n"] == 2
    prom = reg.to_prometheus()
    assert 'bytes_total{client="0"} 100' in prom
    assert "# TYPE lat_ms histogram" in prom
    assert 'lat_ms_bucket{le="+Inf"} 2' in prom
    assert "lat_ms_count 2" in prom
    p = tmp_path / "m.json"
    reg.save(p)
    assert json.loads(p.read_text()) == snap


# -------------------------------------------------------- trace round-trip
def test_chrome_trace_round_trip(tmp_path):
    tr = Tracer()
    with tr.span("outer", cat="engine", tick=3):
        with tr.span("inner", cat="query"):
            pass
        with tr.span("inner2", cat="sync") as sp:
            sp.set(zone=1)
    p = tmp_path / "trace.json"
    tr.save(p)
    doc = json.loads(p.read_text())           # valid JSON by construction
    evs = doc["traceEvents"]
    assert len(evs) == 3
    assert all(e["ph"] == "X" for e in evs)
    assert all(set(e) >= {"name", "cat", "pid", "tid", "ts", "dur", "args"}
               for e in evs)
    by = {e["name"]: e for e in evs}
    # nesting: children lie inside the parent's [ts, ts+dur] window
    o = by["outer"]
    for name in ("inner", "inner2"):
        c = by[name]
        assert o["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= o["ts"] + o["dur"] + 1e-6
        assert c["args"]["depth"] == o["args"]["depth"] + 1
    assert by["outer"]["args"]["tick"] == 3
    assert by["inner2"]["args"]["zone"] == 1


def test_span_disabled_path_is_noop():
    from repro.obs import span
    assert get_tracer() is None or True       # don't assume global state
    prev = set_tracer(None)
    try:
        sp = span("x")
        with sp as s:
            assert s.fence(123) == 123        # fence passes through
        assert span("y") is sp                # shared singleton
    finally:
        set_tracer(prev)


def test_fenced_tracer_blocks_on_jax_values():
    import jax.numpy as jnp
    tr = Tracer(fenced=True)
    with tr.span("dispatch", cat="test") as sp:
        sp.fence(jnp.arange(8) * 2)
    assert len(tr) == 1
    assert tr.durations_ms("dispatch")[0] >= 0.0


def test_traced_decorator(obs):
    from repro.obs import traced
    tr, _ = obs

    @traced("my.fn", cat="test")
    def f(x):
        return x + 1

    assert f(1) == 2
    assert tr.durations_ms("my.fn")


# ------------------------------------------------------------- trajectory
def test_trajectory_append_and_load(tmp_path):
    h = tmp_path / "hist"
    p1 = append_run("s1", {"tick_ms": 1.0}, git_sha="abc", date="2026-08-08",
                    history_dir=h)
    append_run("s1", {"tick_ms": 2.0}, git_sha="def", date="2026-08-09",
               smoke=True, history_dir=h)
    assert p1 == h / "s1.jsonl"
    assert len(load_history("s1", history_dir=h)) == 2
    assert len(load_history("s1", history_dir=h, smoke=False)) == 1
    last = latest_run("s1", history_dir=h, smoke=True)
    assert last["git_sha"] == "def" and last["result"] == {"tick_ms": 2.0}
    assert latest_run("missing", history_dir=h) is None


# --------------------------------------------------------- regression gate
def _gate():
    from benchmarks import regression_gate
    return regression_gate


def test_gate_fails_on_injected_regression(tmp_path):
    """A 10x latency blow-up and a byte-count drift must both FAIL."""
    g = _gate()
    baseline = {"replay_bit_identical": True, "converged": True,
                "tick_ms_mean": 10.0, "sent_bytes_total": 1000,
                "tombstone_bytes": 50, "sq_queries": 5, "lq_queries": 1}
    bad = dict(baseline, tick_ms_mean=100.0, sent_bytes_total=1001,
               replay_bit_identical=False)
    rows = g.compare_suite(g.SPECS["scenario_suite"], baseline, bad)
    failed = {r["metric"] for r in rows if r["status"] == "FAIL"}
    assert failed == {"replay_bit_identical", "tick_ms_mean",
                      "sent_bytes_total"}
    # end-to-end through run_gate: history-backed baseline, nonzero exit
    hist = tmp_path / "hist"
    append_run("scenario_suite", baseline, git_sha="aaa", date="2026-08-08",
               history_dir=hist)
    (tmp_path / "BENCH_scenario_suite.json").write_text(json.dumps(bad))
    all_rows, n_fail = g.run_gate(["scenario_suite"], root=tmp_path,
                                  history_dir=hist)
    assert n_fail == 3
    md = g.dashboard_md(all_rows, smoke=False)
    assert "FAIL" in md and "tick_ms_mean" in md


def test_gate_passes_on_identical_run(tmp_path):
    g = _gate()
    base = {"replay_bit_identical": True, "converged": True,
            "tick_ms_mean": 10.0, "sent_bytes_total": 1000,
            "tombstone_bytes": 50, "sq_queries": 5, "lq_queries": 1}
    hist = tmp_path / "hist"
    append_run("scenario_suite", base, git_sha="aaa", date="2026-08-08",
               history_dir=hist)
    (tmp_path / "BENCH_scenario_suite.json").write_text(json.dumps(base))
    _, n_fail = g.run_gate(["scenario_suite"], root=tmp_path,
                           history_dir=hist)
    assert n_fail == 0
    # latency wobble inside the tolerance band also passes
    ok = dict(base, tick_ms_mean=10.0 * (1.0 + g.LAT) - 0.01)
    (tmp_path / "BENCH_scenario_suite.json").write_text(json.dumps(ok))
    _, n_fail = g.run_gate(["scenario_suite"], root=tmp_path,
                           history_dir=hist)
    assert n_fail == 0


def test_gate_passes_on_real_artifacts():
    """The committed BENCH artifacts gate cleanly against themselves
    (HEAD baseline == working tree at commit time)."""
    g = _gate()
    _, n_fail = g.run_gate()
    assert n_fail == 0


def test_gate_skips_without_baseline_or_artifact(tmp_path):
    g = _gate()
    all_rows, n_fail = g.run_gate(["scenario_suite"], root=tmp_path,
                                  history_dir=tmp_path / "none")
    assert n_fail == 0
    assert all_rows[0][2][0]["status"] == "SKIP"


# --------------------------------------------------------- LQ latency model
def test_lq_model_interpolates_measured_curve():
    from repro.sim.engine import LQ_MODEL_MS, load_lq_curve, lq_model_ms
    curve = load_lq_curve()
    assert curve is not None, "committed BENCH_query_engine.json missing"
    ns, ms = curve
    assert list(ns) == sorted(ns) and len(ns) >= 2
    # endpoints + clamping
    assert lq_model_ms(int(ns[0]), curve) == pytest.approx(float(ms[0]))
    assert lq_model_ms(int(ns[-1]) * 100, curve) == \
        pytest.approx(float(ms[-1]))
    assert lq_model_ms(1, curve) == pytest.approx(float(ms[0]))
    # interior point lies between its neighbors
    mid = int(np.sqrt(float(ns[0]) * float(ns[1])))
    v = lq_model_ms(mid, curve)
    assert min(ms[0], ms[1]) <= v <= max(ms[0], ms[1])
    # no curve -> documented fallback constant
    assert lq_model_ms(5000, None) == LQ_MODEL_MS


def test_lq_curve_missing_file(tmp_path):
    from repro.sim.engine import load_lq_curve
    assert load_lq_curve(tmp_path / "nope.json") is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert load_lq_curve(bad) is None
