"""Property-based tests (hypothesis) on SemanticXR system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import geometry as geo
from repro.core.knobs import Knobs
from repro.core.local_map import (LocalMap, ObjectUpdate, apply_update,
                                  init_local_map, local_map_nbytes)

KN = Knobs(client_capacity=8, max_object_points_client=16)
EDIM = 8


def _mk_update(oid, pri_seed, version=1):
    rng = np.random.default_rng(oid * 31 + version)
    e = rng.normal(size=(EDIM,)).astype(np.float32)
    e /= np.linalg.norm(e)
    return ObjectUpdate(
        oid=jnp.asarray(oid, jnp.int32), embed=jnp.asarray(e),
        label=jnp.asarray(oid % 5, jnp.int32),
        points=jnp.zeros((16, 3), jnp.float16),
        n_points=jnp.asarray(4, jnp.int32),
        centroid=jnp.zeros((3,), jnp.float32),
        version=jnp.asarray(version, jnp.int32))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 30), st.floats(0.0, 10.0)),
                min_size=1, max_size=40))
def test_local_map_memory_bound(updates):
    """Device memory NEVER grows with scene size: fixed buffers, active count
    <= capacity, nbytes constant (paper Sec. 3.2 / Fig. 5)."""
    m = init_local_map(KN, EDIM)
    base = local_map_nbytes(m)
    for oid, pri in updates:
        m = apply_update(m, _mk_update(oid, pri), jnp.asarray(pri))
        assert int(m.active.sum()) <= KN.client_capacity
        assert local_map_nbytes(m) == base


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.lists(st.tuples(st.integers(1, 50), st.floats(0.0, 1.0)),
                min_size=10, max_size=30))
def test_eviction_removes_lowest_priority(updates):
    """Paper Sec. 3.2: when the map is full, admitting a higher-priority
    update evicts the lowest-priority retained object; lower-priority
    arrivals are rejected."""
    m = init_local_map(KN, EDIM)
    for oid, pri in updates:
        act_b = np.asarray(m.active)
        ids_b = set(np.asarray(m.ids)[act_b].tolist())
        pris_b = np.asarray(m.priority)[act_b]
        was_full = act_b.sum() == KN.client_capacity
        m = apply_update(m, _mk_update(oid, pri), jnp.asarray(pri))
        act_a = np.asarray(m.active)
        ids_a = set(np.asarray(m.ids)[act_a].tolist())
        gone = ids_b - ids_a
        if gone:                        # an eviction happened
            assert was_full and oid not in ids_b
            assert len(gone) == 1
            assert pri > pris_b.min() - 1e-6
        elif was_full and oid not in ids_b:   # rejected newcomer
            assert pri <= pris_b.min() + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 64))
def test_downsample_bounds_and_subset(n, budget):
    """Downsampled cloud: n_out <= budget, every output point is an input
    point (gather, no interpolation), deterministic."""
    rng = np.random.default_rng(n * budget)
    P = 256
    pts = jnp.asarray(rng.normal(size=(P, 3)).astype(np.float32))
    out, n_out = geo.downsample(pts, jnp.asarray(min(n, P)), budget)
    out2, n_out2 = geo.downsample(pts, jnp.asarray(min(n, P)), budget)
    assert int(n_out) <= budget
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    src = set(map(tuple, np.asarray(pts)[:min(n, P)].round(5)))
    got = np.asarray(out)[:int(n_out)].round(5)
    assert all(tuple(p) in src for p in got)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 19), st.integers(2, 7))
def test_update_version_monotone(oid, version):
    """Re-applying an update with the same id refreshes in place (no
    duplicate entries), and the stored version tracks the server's."""
    m = init_local_map(KN, EDIM)
    m = apply_update(m, _mk_update(oid + 1, 0.5, 1), jnp.asarray(0.5))
    m = apply_update(m, _mk_update(oid + 1, 0.5, version), jnp.asarray(0.5))
    act = np.asarray(m.active)
    ids = np.asarray(m.ids)[act]
    assert (ids == oid + 1).sum() == 1
    vstored = np.asarray(m.version)[act][ids == oid + 1][0]
    assert vstored == version


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 30))
def test_bbox_area_bounds(h, w):
    rng = np.random.default_rng(h * w)
    mask = jnp.asarray(rng.random((h, w)) > 0.7)
    area = int(geo.bbox_pixel_area(mask))
    npx = int(np.asarray(mask).sum())
    assert area >= npx
    assert area <= h * w
