"""Map-shrinkage protocol: tombstone rows through collect -> wire -> apply,
slot retirement, per-class packet budgets, and the O(1) outage buffer."""
import numpy as np
import jax.numpy as jnp

from repro.core.knobs import Knobs
from repro.core.local_map import apply_updates_batch, init_local_map
from repro.core.runtime import CloudService, DeviceClient
from repro.core.store import (deleted_mask, release_tombstones,
                              remove_objects, synthetic_store,
                              tombstone_slots)
from repro.core.updates import (TOMBSTONE_NBYTES, collect_updates, init_sync,
                                update_nbytes)
from repro.server import FleetServer, ZoneGrid

E = 32
KN = Knobs(server_capacity=64, client_capacity=64,
           max_object_points_server=64, max_object_points_client=16,
           min_obs_before_sync=1)


def _synced_client(store, kn=KN):
    sync = init_sync(kn.server_capacity)
    dev = DeviceClient(knobs=kn, embed_dim=E)
    pkt, sync = collect_updates(store, sync, kn, tick=0)
    dev.ingest(pkt, user_pos=jnp.zeros(3))
    return dev, sync


def _client_ids(local):
    return set(np.asarray(local.ids)[np.asarray(local.active)].tolist())


# ---------------------------------------------------------------------------
def test_remove_tombstones_and_client_frees_slot():
    """remove_objects -> version-bumped tombstone -> 9-byte wire rows ->
    device frees the slot and retires the id."""
    store = synthetic_store(10, KN.server_capacity, E, 64, seed=0)
    dev, sync = _synced_client(store)
    assert _client_ids(dev.local) == set(range(1, 11))

    store = remove_objects(store, [2, 5, 9])
    assert sorted(tombstone_slots(store)) == [1, 4, 8]
    assert not np.asarray(store.active)[[1, 4, 8]].any()

    pkt, sync = collect_updates(store, sync, KN, tick=1)
    assert pkt.count == 3
    assert pkt.nbytes == 3 * TOMBSTONE_NBYTES      # exact wire accounting
    assert sorted(pkt.deleted_oids) == [2, 5, 9]
    dev.ingest(pkt, user_pos=jnp.zeros(3))
    assert _client_ids(dev.local) == {1, 3, 4, 6, 7, 8, 10}
    # freed slots are reusable: ids retired to 0
    assert int((np.asarray(dev.local.ids) == 0).sum()) >= 3

    # tombstone convergence: nothing more to ship
    pkt2, sync = collect_updates(store, sync, KN, tick=2)
    assert pkt2.nbytes == 0


def test_tombstone_ships_only_to_clients_that_had_it():
    """A client that never synced the object receives no tombstone bytes."""
    store = synthetic_store(5, KN.server_capacity, E, 64, seed=1)
    _, sync_has = _synced_client(store)
    sync_never = init_sync(KN.server_capacity)

    store = remove_objects(store, [3])
    pkt_has, _ = collect_updates(store, sync_has, KN, tick=1)
    assert pkt_has.count == 1 and pkt_has.nbytes == TOMBSTONE_NBYTES
    pkt_nvr, _ = collect_updates(store, sync_never, KN, tick=1)
    assert 3 not in {int(u.oid) for u in pkt_nvr.updates}
    assert not pkt_nvr.deleted_oids


def test_tombstone_slot_not_reused_until_released():
    """associate must not insert into a tombstoned slot; after
    release_tombstones (+ the automatic sync reset) the slot's next
    occupant ships from scratch."""
    from repro.core.association import Detections, associate

    kn = Knobs(server_capacity=4, client_capacity=8,
               max_object_points_server=16, max_object_points_client=8,
               min_obs_before_sync=1)
    store = synthetic_store(3, 4, E, 16, seed=2)
    dev, sync = _synced_client(store, kn)
    store = remove_objects(store, [1])
    pkt, sync = collect_updates(store, sync, kn, tick=1)
    dev.ingest(pkt, user_pos=jnp.zeros(3))

    det = Detections(
        embed=jnp.ones((1, E)) / np.sqrt(E), label=jnp.asarray([7]),
        points=jnp.zeros((1, 16, 3)), n_points=jnp.asarray([4]),
        valid=jnp.asarray([True]))
    st2 = associate(store, det, frame=jnp.asarray(5), match_threshold=2.0,
                    point_budget=16)
    # the insert went to slot 3 (the only non-live, non-tombstoned slot)
    assert int(st2.ids[3]) == int(st2.next_id) - 1
    assert bool(deleted_mask(st2)[0])              # tombstone untouched

    # release the tombstone; the auto sync reset lets a reused slot ship
    st3 = release_tombstones(st2)
    assert not deleted_mask(st3).any()
    pkt2, sync = collect_updates(st3, sync, kn, tick=2)   # resets slot 0
    assert sync.synced_version[0] == 0
    st4 = associate(st3, det, frame=jnp.asarray(6), match_threshold=2.0,
                    point_budget=16)
    assert int(st4.ids[0]) != 0                    # slot 0 reused now
    pkt3, sync = collect_updates(st4, sync, kn, tick=3)
    assert int(st4.ids[0]) in {int(u.oid) for u in pkt3.updates}


def test_apply_tombstone_for_unknown_id_is_noop():
    m = init_local_map(KN, E)
    store = synthetic_store(2, KN.server_capacity, E, 64, seed=3)
    store = remove_objects(store, [1, 2])
    pkt, _ = collect_updates(
        store, init_sync(KN.server_capacity)._replace(
            synced_version=np.ones((KN.server_capacity,), np.int32)),
        KN, tick=0)
    assert pkt.count == 2
    out = apply_updates_batch(m, pkt.batch,
                              jnp.zeros(pkt.batch.oid.shape[0]))
    assert not bool(out.active.any())
    assert int(out.ids.sum()) == 0


def test_fleet_zone_tombstone_propagation():
    """Removal crosses the zone-shard mirror: subscribed clients get the
    tombstone, the shard slot frees after global release, and per-client
    bytes stay exact."""
    store = synthetic_store(12, KN.server_capacity, E, 64, seed=3)
    grid = ZoneGrid.for_room(8.0, nx=2, nz=1)
    fs = FleetServer(knobs=KN, embed_dim=E, n_clients=2, grid=grid,
                     budget=32)
    fs.refresh(store)
    fs.join(0, np.array([-2.0, 1.5, 0.0]), 10.0)     # both zones
    fs.join(1, np.array([2.0, 1.5, 0.0]), 10.0)
    devs = [DeviceClient(knobs=KN, embed_dim=E) for _ in range(2)]
    both = np.array([True, True])
    for _ in range(3):
        for _, pkt in fs.tick(both):
            for c in range(2):
                p = pkt.packet_for(c)
                if p.count:
                    devs[c].ingest(p, user_pos=jnp.zeros(3))
    for c in range(2):
        assert _client_ids(devs[c].local) == set(range(1, 13))

    store = remove_objects(store, [1, 2, 3])
    fs.refresh(store)
    packets = fs.tick(both)
    per = fs.per_client_nbytes(packets)
    assert (per == 3 * TOMBSTONE_NBYTES).all()
    for _, pkt in packets:
        for c in range(2):
            p = pkt.packet_for(c)
            if p.count:
                devs[c].ingest(p, user_pos=jnp.zeros(3))
    for c in range(2):
        assert _client_ids(devs[c].local) == set(range(4, 13))

    # quiesce, then retire: the shard slots free and nothing re-ships
    while True:
        pk = fs.tick(both)
        if not pk or all((p.counts == 0).all() for _, p in pk):
            break
    store = release_tombstones(store)
    fs.refresh(store)
    pk = fs.tick(both)
    assert not pk or all((p.nbytes == 0).all() for _, p in pk)
    assert sum(int(np.asarray(deleted_mask(z)).sum())
               for z in fs.zoned.zones) == 0


# ---------------------------------------------------------------------------
def test_per_class_point_budget_honored():
    """Satellite: Knobs.class_point_overrides caps per-class points in the
    packet (the seed silently shipped max_object_points_client for every
    class) with exact per-row byte accounting."""
    kn = Knobs(server_capacity=64, client_capacity=64,
               max_object_points_server=64, max_object_points_client=16,
               min_obs_before_sync=1,
               class_point_overrides=((3, 4), (1, 8)))
    store = synthetic_store(12, 64, E, 64, seed=5, n_labels=5)
    pkt, _ = collect_updates(store, init_sync(64), kn, tick=0)
    lab = np.asarray(pkt.batch.label)[:pkt.count]
    npts = np.asarray(pkt.batch.n_points)[:pkt.count]
    n_src = np.asarray(store.n_points)[np.asarray(store.active)]
    assert (npts[lab == 3] <= 4).all() and (npts[lab == 3] > 0).all()
    assert (npts[lab == 1] <= 8).all()
    # non-overridden classes keep the default budget
    other = ~np.isin(lab, [1, 3])
    assert (npts[other] <= kn.max_object_points_client).all()
    assert npts[other].max() == min(kn.max_object_points_client,
                                    int(n_src.max()))
    # byte accounting follows the per-row (not per-knob) point counts
    assert pkt.nbytes == sum(update_nbytes(E, int(n)) for n in npts)
    # the device applies the mixed-budget batch unchanged
    dev = DeviceClient(knobs=kn, embed_dim=E)
    dev.ingest(pkt, user_pos=jnp.zeros(3))
    got = {int(i): int(n) for i, n, a in
           zip(np.asarray(dev.local.ids), np.asarray(dev.local.n_points),
               np.asarray(dev.local.active)) if a}
    want = {int(o): int(n) for o, n in
            zip(np.asarray(pkt.batch.oid)[:pkt.count], npts)}
    assert got == want


def test_no_overrides_matches_seed_byte_accounting():
    """With no overrides the dynamic-budget gather is byte-identical to
    the seed static path (regression guard for Fig. 6 numbers)."""
    store = synthetic_store(10, 64, E, 64, seed=6)
    pkt, _ = collect_updates(store, init_sync(64), KN, tick=0)
    n_src = np.asarray(store.n_points)[np.asarray(store.active)]
    expect = sum(update_nbytes(E, min(int(n), KN.max_object_points_client))
                 for n in n_src)
    assert pkt.nbytes == expect


# ---------------------------------------------------------------------------
def test_outage_buffer_is_o1_and_converges():
    """Satellite: CloudService coalesces a long outage into O(1) state and
    the reconnect flush ships one packet that converges the client."""
    class _Ref:                      # minimal MappingServer stand-in
        pass

    ref = _Ref()
    ref.store = synthetic_store(8, KN.server_capacity, E, 64, seed=7)
    cloud = CloudService(knobs=KN, store_ref=ref)
    dev = DeviceClient(knobs=KN, embed_dim=E)

    pkt = cloud.update_tick(network_up=True)
    dev.ingest(pkt, user_pos=jnp.zeros(3))

    # 500-tick outage with churn: buffered state must stay O(1)
    for i in range(500):
        if i == 10:
            ref.store = remove_objects(ref.store, [1, 2])
        if i == 20:
            ref.store = ref.store._replace(
                version=ref.store.version.at[4].add(1))
        assert cloud.update_tick(network_up=False) is None
    assert len(cloud.buffered) == 1          # coalesced, not a packet list
    assert cloud.buffered.ticks == 500

    flush = cloud.flush_buffer()
    assert len(cloud.buffered) == 0
    # ONE packet covers the whole outage: 2 tombstones + 1 refresh
    assert flush.count == 3
    assert sorted(flush.deleted_oids) == [1, 2]
    dev.ingest(flush, user_pos=jnp.zeros(3))
    assert _client_ids(dev.local) == {3, 4, 5, 6, 7, 8}
    srv_ids = set(np.asarray(ref.store.ids)[
        np.asarray(ref.store.active)].tolist())
    assert _client_ids(dev.local) == srv_ids
    assert int(dev.local.version[np.asarray(
        dev.local.ids).tolist().index(5)]) == 2   # the refreshed object
