"""Dry-run smoke: one cheap cell must lower+compile on the 512-device
production mesh.  Runs in a subprocess because the forced device count must
not leak into this test session's jax runtime."""
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.slow          # ~7 min: compiles a 512-device mesh in a subprocess
def test_dryrun_cell_compiles(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-small", "--shape", "decode_32k",
         "--out", str(tmp_path)],
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "dry-run complete" in proc.stdout
    recs = list((tmp_path / "pod1").glob("*.json"))
    assert len(recs) == 1
