"""Dynamic-scene scenario engine: golden replay (bit-identical MetricsLogs
+ committed snapshot) and the churn acceptance scenario — every client
converges to the server's live set after packets drain, removal ticks ship
tombstone-sized packets, idle ticks ship zero bytes."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.knobs import Knobs
from repro.core.updates import TOMBSTONE_NBYTES
from repro.sim import (ClientSpec, NetTrace, ObjectEvent, PoseTrack,
                       QueryPlan, Scenario, churn_scenario, run_scenario)
from repro.sim.scenario import GridSpec

GOLDEN = Path(__file__).parent / "golden" / "scenario_churn_v1.json"

KN = Knobs(server_capacity=64, client_capacity=32,
           max_object_points_server=32, max_object_points_client=8,
           min_obs_before_sync=1)


def _client_ids(session):
    m = session.dev.local
    return set(np.asarray(m.ids)[np.asarray(m.active)].tolist())


def _golden_scenario():
    # MUST match tests/golden/regen.py (the committed snapshot's workload)
    return churn_scenario(seed=23, n_objects=20, n_ticks=20, n_clients=3,
                          remove_frac=0.25, drain_ticks=8)


# ---------------------------------------------------------------------------
def test_golden_replay_bit_identical():
    """Acceptance: a fixed-seed churn scenario (>=20% of objects removed
    mid-run) replayed twice produces bit-identical MetricsLogs."""
    sc = _golden_scenario()
    n_spawned = sum(1 for e in sc.events if e.kind == "spawn")
    n_removed = sum(1 for e in sc.events if e.kind == "remove")
    assert n_removed / n_spawned >= 0.20
    log1 = run_scenario(sc)
    log2 = run_scenario(sc)
    assert log1.equals(log2), f"drift in fields: {log1.diff(log2)}"


def test_golden_snapshot():
    """The committed metrics snapshot catches silent protocol drift:
    counts and byte totals to the digit, MODELed latencies in tolerance."""
    snap = json.loads(GOLDEN.read_text())
    log = run_scenario(_golden_scenario())
    log.assert_matches_snapshot(snap)


def test_churn_convergence_and_byte_scaling():
    """Acceptance: after packets drain, every client holds exactly the
    server's live object set; removal ticks ship tombstone-sized packets;
    idle ticks ship 0 bytes."""
    from repro.sim.engine import ScenarioEngine
    sc = _golden_scenario()
    eng = ScenarioEngine(sc)
    log = eng.run()

    # 1. convergence: every client == the server's live set, tombstones out
    srv = eng.world.live_ids()
    assert len(srv) == int(log.server_live[-1])
    for cid in range(len(sc.clients)):
        assert _client_ids(eng.sessions[cid]) == srv, f"client {cid}"
    removed_oids = {e.oid for e in sc.events if e.kind == "remove"}
    for cid in range(len(sc.clients)):
        assert not (_client_ids(eng.sessions[cid]) & removed_oids)

    # 2. the drain tail is quiescent: zero bytes once everything shipped
    assert (log.sent_bytes[-3:] == 0).all()
    assert log.n_ticks - int((log.sent_bytes.sum(axis=1) > 0).sum()) \
        == log.summary()["exact"]["idle_zero_byte_ticks"]

    # 3. downstream tracks churn: every nonzero tick has an event (or a
    # packet in flight from one) within the catch-up window
    event_ticks = {e.tick for e in sc.events} | {0}
    busy = np.nonzero(log.sent_bytes.sum(axis=1))[0]
    for t in busy:
        assert any(t - 6 <= et <= t for et in event_ticks), t


def test_removal_only_tick_ships_exactly_tombstone_bytes():
    """A tick whose only change is K removals ships exactly
    K * TOMBSTONE_NBYTES to every synced client."""
    events = [ObjectEvent(tick=0, kind="spawn", oid=i, class_id=i % 4,
                          pos=(0.5 * i - 2.0, 1.0, 0.0), n_points=16)
              for i in range(1, 9)]
    events += [ObjectEvent(tick=6, kind="remove", oid=2),
               ObjectEvent(tick=6, kind="remove", oid=5)]
    sc = Scenario(
        seed=3, n_ticks=10, embed_dim=32, knobs=KN,
        grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
        clients=tuple(ClientSpec(cid=c, net=NetTrace(),
                                 track=PoseTrack(anchor=(0.0, 1.5, 0.0)),
                                 subscribe_radius=8.0) for c in range(2)),
        events=tuple(events), query=QueryPlan(prob=0.0), drain_ticks=2)
    log = run_scenario(sc)
    assert (log.sent_bytes[6] == 2 * TOMBSTONE_NBYTES).all()
    # ticks with no events after full sync: exactly zero
    assert (log.sent_bytes[3:6] == 0).all()
    assert (log.sent_bytes[7:] == 0).all()
    assert (log.client_live[-1] == 6).all()


def test_late_joiner_never_sees_removed_objects():
    """A client joining after the removal syncs the post-removal map and
    receives no tombstone bytes for objects it never held."""
    events = [ObjectEvent(tick=0, kind="spawn", oid=i, class_id=0,
                          pos=(float(i) - 3.0, 1.0, 0.0), n_points=8)
              for i in range(1, 7)]
    events += [ObjectEvent(tick=3, kind="remove", oid=1),
               ObjectEvent(tick=3, kind="remove", oid=2)]
    sc = Scenario(
        seed=4, n_ticks=10, embed_dim=32, knobs=KN,
        grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
        clients=(ClientSpec(cid=0, subscribe_radius=8.0),
                 ClientSpec(cid=1, subscribe_radius=8.0, join_tick=6)),
        events=tuple(events), query=QueryPlan(prob=0.0), drain_ticks=2)
    from repro.sim.engine import ScenarioEngine
    eng = ScenarioEngine(sc)
    log = eng.run()
    assert _client_ids(eng.sessions[0]) == {3, 4, 5, 6}
    assert _client_ids(eng.sessions[1]) == {3, 4, 5, 6}
    # the late joiner's catch-up is live rows only — no tombstones
    E = sc.embed_dim
    assert int(log.sent_bytes[6, 1]) == \
        4 * (24 + 2 * E) + 6 * 4 * 8        # 4 live rows, 8 points each


def test_tombstone_convergence_across_outage():
    """A removal during a client's outage still converges: the tombstone
    coalesces into the reconnect catch-up."""
    events = [ObjectEvent(tick=0, kind="spawn", oid=i, class_id=0,
                          pos=(float(i) - 2.0, 1.0, 0.0), n_points=8)
              for i in range(1, 5)]
    events += [ObjectEvent(tick=4, kind="remove", oid=3)]
    sc = Scenario(
        seed=5, n_ticks=10, embed_dim=32, knobs=KN,
        grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
        clients=(ClientSpec(cid=0, subscribe_radius=8.0,
                            net=NetTrace(outages=((3.0, 7.0),))),),
        events=tuple(events), query=QueryPlan(prob=0.0), drain_ticks=2)
    from repro.sim.engine import ScenarioEngine
    eng = ScenarioEngine(sc)
    log = eng.run()
    assert (log.sent_bytes[3:7, 0] == 0).all()     # nothing during outage
    assert _client_ids(eng.sessions[0]) == {1, 2, 4}
    # the reconnect tick carried the tombstone (9 B) — not a re-ship of 3
    assert int(log.sent_bytes[7, 0]) == TOMBSTONE_NBYTES


def test_knob_schedule_and_gc():
    """Knob events apply mid-run; tombstone_ttl retires slots and frees
    them for reuse (gc_released > 0, spawn after GC lands on a freed
    slot and reaches clients)."""
    from repro.sim.scenario import KnobEvent
    kn = Knobs(server_capacity=6, client_capacity=16,
               max_object_points_server=16, max_object_points_client=8,
               min_obs_before_sync=1)
    events = [ObjectEvent(tick=0, kind="spawn", oid=i, class_id=0,
                          pos=(float(i), 1.0, 0.0), n_points=8)
              for i in range(1, 7)]                    # store FULL (cap 6)
    events += [ObjectEvent(tick=2, kind="remove", oid=1),
               ObjectEvent(tick=5, kind="spawn", oid=99, class_id=1,
                           pos=(0.0, 1.0, 1.0), n_points=8)]
    sc = Scenario(
        seed=6, n_ticks=12, embed_dim=32, knobs=kn,
        grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
        clients=(ClientSpec(cid=0, subscribe_radius=8.0),),
        events=tuple(events),
        knob_events=(KnobEvent(tick=1, min_obs=1),),
        query=QueryPlan(prob=0.0), drain_ticks=2, tombstone_ttl=2)
    from repro.sim.engine import ScenarioEngine
    eng = ScenarioEngine(sc)
    log = eng.run()
    assert int(log.gc_released.sum()) == 1
    # oid 99 could only spawn on the GC-freed slot — and it converged
    assert _client_ids(eng.sessions[0]) == {2, 3, 4, 5, 6, 99}


@pytest.mark.slow
def test_bench_scenario_suite_smoke():
    """tier-1-adjacent smoke of the scenario benchmark suite."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks import scenario_suite
    res = scenario_suite.run(smoke=True)
    assert res["replay_bit_identical"] is True
    assert res["converged"] is True
    assert res["sent_bytes_total"] > 0
