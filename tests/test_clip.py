"""Mini-CLIP two-tower embedder: contrastive loss decreases and retrieval
beats chance after a short budget (full training in
examples/train_perception.py reaches ~86% top-1 over 20 classes)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.scenes import make_scene, N_CLASSES
from repro.optim import adamw
from repro.perception import clip as clip_mod


def test_clip_learns():
    ccfg = clip_mod.ClipConfig(width=64, depth=2, embed_dim=32)
    params = clip_mod.init_clip_params(ccfg, jax.random.key(0))
    ocfg = adamw.AdamWConfig(lr=2e-3, total_steps=80, warmup_steps=10,
                             weight_decay=0.01)
    opt = adamw.init_opt_state(params, ocfg)
    scene = make_scene(n_objects=40, seed=4)
    classes = {o.oid: o.class_id for o in scene.objects}
    it = clip_mod.pair_batches(scene, classes, batch=12, h=80, w=100,
                               n_frames=30)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: clip_mod.clip_loss(p, batch, ccfg),
            has_aux=True)(params)
        params, opt, _ = adamw.adamw_update(g, opt, params, ocfg)
        return params, opt, loss

    losses = []
    for _ in range(80):
        b = next(it)
        b.pop("class_ids")
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:10])

    # retrieval beats chance
    all_toks = jnp.asarray(np.stack([clip_mod.class_tokens(c)
                                     for c in range(N_CLASSES)]))
    te = clip_mod.encode_text(params, all_toks, ccfg)
    b = next(it)
    oe = clip_mod.encode_object(params, b["crops"], b["stats"], ccfg)
    pred = np.asarray(jnp.argmax(oe @ te.T, axis=1))
    acc = float((pred == b["class_ids"]).mean())
    assert acc > 3.0 / N_CLASSES, f"retrieval acc {acc:.2f}"
