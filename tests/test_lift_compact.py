"""Fused lift->compact->downsample->stats path (kernels/lift_compact) vs the
seed ``lift_depth`` + ``downsample`` + ``centroid_bbox`` composition:
deterministic sweeps, a hypothesis property over random masks / strides /
budgets, the no-[D, HW, 3]-intermediate guard, and fused-vs-staged pipeline
equivalence."""
import sys
from functools import partial
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import LIFT_BUFFER
from repro.data.scenes import make_scene, render_frame
from repro.kernels import lift_compact as lc
from repro.kernels import ops, ref


def _scene_inputs(*, h=120, w=160, r=5, D=8, seed=3):
    scene = make_scene(n_objects=12, seed=seed)
    fr = render_frame(scene, 7, h=h, w=w, n_frames=40)
    depth = jnp.asarray(fr.depth[::r, ::r] if r > 1 else fr.depth)
    inst_lo = fr.inst[::r, ::r] if r > 1 else fr.inst
    masks = np.zeros((D,) + inst_lo.shape, bool)
    for i, o in enumerate(fr.visible_ids[:D]):
        masks[i] = inst_lo == o
    return (depth, jnp.asarray(masks), jnp.asarray(fr.intrinsics),
            jnp.asarray(fr.pose, jnp.float32))


def _assert_matches_seed(got, want, counts, *, atol=1e-5):
    """Point-for-point, count, centroid and bbox equivalence, normalizing
    the seed's empty-cloud quirk (downsample's max(n, 1) floor reported a
    phantom zero-point for detections with no valid pixels; the fused path
    returns the true n = 0 — see kernels/lift_compact.py)."""
    names = ["points", "n", "centroid", "bbox_min", "bbox_max"]
    want = [np.asarray(a) for a in want]
    want[1] = np.where(counts > 0, want[1], 0)
    for name, g, w in zip(names, got, want):
        np.testing.assert_allclose(np.asarray(g), w, rtol=1e-5, atol=atol,
                                   err_msg=name)


def _counts(depth, masks):
    return np.asarray((np.asarray(masks)
                       & (np.asarray(depth) > lc.Z_EPS)[None]).sum((1, 2)))


@pytest.mark.parametrize("r,budget,cap", [
    (1, 64, 4096), (5, 512, 4096), (2, 128, 256), (3, 32, 64),
    (5, 2048, 4096), (1, 100, 80),
])
def test_fused_matches_seed_composition(r, budget, cap):
    depth, masks, intr, pose = _scene_inputs(r=r)
    want = ref.lift_compact_ref(depth, masks, intr, pose, stride=r,
                                budget=budget, lift_cap=cap)
    got = ops.lift_compact(depth, masks, intr, pose, stride=r, budget=budget,
                           lift_cap=cap)
    _assert_matches_seed(got, want, _counts(depth, masks))


def test_fused_matches_seed_random_property():
    """Random masks / depth holes / strides / budgets / caps: the fused path
    reproduces the seed composition everywhere (including budget > cap,
    cap-truncation, and all-invalid objects)."""
    pytest.importorskip("hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
           st.integers(1, 96), st.integers(8, 160), st.floats(0.2, 0.8))
    def prop(seed, stride, budget, cap, density):
        rng = np.random.default_rng(seed)
        D, H, W = 4, 18, 26
        depth = jnp.asarray(np.where(rng.random((H, W)) > 0.2,
                                     rng.uniform(0.3, 8.0, (H, W)),
                                     0.0).astype(np.float32))
        masks = jnp.asarray(rng.random((D, H, W)) < density)
        intr = jnp.asarray([40.0, 42.0, W / 2, H / 2], jnp.float32)
        q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
        pose = np.eye(4, dtype=np.float32)
        pose[:3, :3] = q.astype(np.float32)
        pose[:3, 3] = rng.uniform(-2, 2, 3).astype(np.float32)
        pose = jnp.asarray(pose)
        want = ref.lift_compact_ref(depth, masks, intr, pose, stride=stride,
                                    budget=budget, lift_cap=cap)
        got = lc.lift_compact_xla(depth, masks, intr, pose, stride=stride,
                                  budget=budget, lift_cap=cap)
        _assert_matches_seed(got, want, _counts(depth, masks), atol=1e-4)

    prop()


def test_empty_mask_reports_true_zero():
    """The documented divergence: no valid pixels -> n = 0 (the seed's
    downsample floor said 1 phantom point at the origin)."""
    depth, _, intr, pose = _scene_inputs()
    masks = jnp.zeros((3,) + depth.shape, bool)
    pts, n, cent, mn, mx = ops.lift_compact(depth, masks, intr, pose,
                                            stride=5, budget=32)
    assert np.asarray(n).tolist() == [0, 0, 0]
    for a in (pts, cent, mn, mx):
        np.testing.assert_array_equal(np.asarray(a), 0.0)


def test_fused_never_materializes_dhw3():
    """Acceptance guard: no intermediate in the fused jaxpr reaches
    [D, HW, 3] elements; the seed composition (positive control) does."""
    from benchmarks.mapping_latency import (_max_intermediate_elems,
                                            _seed_lift_composition)
    r, budget = 5, 512
    depth, masks, intr, pose = _scene_inputs(r=r, D=16)
    D = masks.shape[0]
    hw = int(np.prod(depth.shape))
    limit = D * hw * 3
    fused = jax.jit(partial(ops.lift_compact, stride=r, budget=budget,
                            lift_cap=LIFT_BUFFER))
    seed = jax.jit(_seed_lift_composition(r, budget))
    args = (depth, masks, intr, pose)
    assert _max_intermediate_elems(jax.make_jaxpr(fused)(*args)) < limit
    assert _max_intermediate_elems(jax.make_jaxpr(seed)(*args)) >= limit


def test_pipeline_fused_equals_instrumented():
    """The one-dispatch ingest_frame path and the instrumented staged path
    build identical stores (same math, different dispatch granularity)."""
    from benchmarks.common import build_map
    srv_f, _, _, times_f = build_map(n_objects=12, frames=25, h=120, w=160)
    srv_i, _, _, times_i = build_map(n_objects=12, frames=25, h=120, w=160,
                                     instrument=True)
    for f in ["active", "n_points", "label", "obs_count", "ids", "version"]:
        np.testing.assert_array_equal(np.asarray(getattr(srv_f.store, f)),
                                      np.asarray(getattr(srv_i.store, f)),
                                      err_msg=f)
    np.testing.assert_allclose(np.asarray(srv_f.store.points),
                               np.asarray(srv_i.store.points), atol=1e-5)
    np.testing.assert_allclose(np.asarray(srv_f.store.centroid),
                               np.asarray(srv_i.store.centroid), atol=1e-5)
    # the fused path reports a single ingest wall; the staged path reports
    # the per-stage decomposition — both feed total_ms
    warm_f, warm_i = times_f[2], times_i[2]
    assert warm_f.ingest_ms > 0 and warm_f.lift_ms == 0
    assert warm_i.lift_ms > 0 and warm_i.ingest_ms == 0
