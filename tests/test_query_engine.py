"""Declarative query engine vs a pure-numpy reference oracle.

The oracle recomputes the whole plan — predicate masks, score combination,
top-k — in numpy, for every target kind (LocalMap, ObjectStore,
ZoneShardedStore).  A hypothesis property sweeps randomized stores,
predicate combinations, and k values; deterministic subsets always run.
Also covers: padded-rank masking (the stale-slot-id regression), legacy
wrapper equivalence + DeprecationWarning, Pallas-path parity, batched
stacking, and the serving step-fn carrying Query specs.
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.knobs import Knobs
from repro.core.local_map import init_local_map
from repro.core.query import (Query, QueryResult, compile_query,
                              execute_query, stack_queries)
from repro.core.store import synthetic_store
from repro.server.zones import ZoneGrid, ZoneShardedStore

E, P = 32, 16
ROOM = 8.0


def _store(n, seed, cap=None):
    return synthetic_store(n, cap or n, E, P, seed=seed,
                           centroid_low=(-ROOM / 2, 0.0, -ROOM / 2),
                           centroid_high=(ROOM / 2, 2.0, ROOM / 2))


def _local_map(n, seed):
    """LocalMap with the same columns as _store(n, seed) (no obs/last_seen)."""
    st = _store(n, seed)
    cap = st.ids.shape[0]
    m = init_local_map(Knobs(client_capacity=cap,
                             max_object_points_client=P), E)
    return m._replace(ids=st.ids, active=st.active, embed=st.embed,
                      label=st.label, n_points=st.n_points,
                      centroid=st.centroid)


def _zoned(n, seed, grid=None):
    grid = grid or ZoneGrid.for_room(ROOM, nx=2, nz=2)
    st = _store(n, seed)
    zs = ZoneShardedStore(knobs=Knobs(server_capacity=4 * n,
                                      max_object_points_server=P),
                          embed_dim=E, grid=grid, zone_capacity=n,
                          max_points=P)
    zs.refresh_from(st)
    assert zs.dropped == 0
    return zs, st


# ---------------------------------------------------------------------------
# the numpy oracle: full plan (predicates + scoring + top-k) re-derived
# ---------------------------------------------------------------------------
def _np_scores(spec: Query, target, *, has_obs: bool) -> np.ndarray:
    """[cap] f32 combined score, -inf where any predicate fails."""
    act = np.asarray(target.active)
    ok = act.copy()
    cent = np.asarray(target.centroid, np.float32)
    if spec.labels is not None:
        ok &= np.isin(np.asarray(target.label), np.asarray(spec.labels))
    if spec.zones is not None:
        x0, z0, zs_, nx, nz = spec.grid
        ix = np.clip(np.floor((cent[:, 0] - x0) / zs_), 0, nx - 1)
        iz = np.clip(np.floor((cent[:, 2] - z0) / zs_), 0, nz - 1)
        ok &= np.isin((ix * nz + iz).astype(np.int64),
                      np.asarray(spec.zones))
    if spec.min_points is not None:
        ok &= np.asarray(target.n_points) >= int(spec.min_points)
    if spec.min_obs is not None and has_obs:
        ok &= np.asarray(target.obs_count) >= int(spec.min_obs)
    if spec.since is not None and has_obs:
        ok &= np.asarray(target.last_seen) >= int(spec.since)
    if spec.aabb is not None:
        lo, hi = (np.asarray(x, np.float32) for x in spec.aabb)
        ok &= ((cent >= lo) & (cent <= hi)).all(-1)
    score = np.zeros(act.shape, np.float32)
    if spec.embed is not None:
        score = np.asarray(target.embed, np.float32) @ \
            np.asarray(spec.embed, np.float32)
        if spec.sem_weight is not None:
            score = score * np.float32(spec.sem_weight)
    d = None
    if spec.near is not None:
        c, r = spec.near
        d = np.linalg.norm(cent - np.asarray(c, np.float32), axis=-1)
        ok &= d <= np.float32(r)
        if spec.prox_weight is not None:
            score = score + np.float32(spec.prox_weight) / (1.0 + d)
    return np.where(ok, score, -np.inf).astype(np.float32)


def _check_against_oracle(res: QueryResult, oracle: np.ndarray, k: int,
                          ids: np.ndarray, slots_are_oids: bool = False):
    """res must be exactly the oracle's masked top-k (membership checked on
    oids; scores allclose; padded ranks fully masked)."""
    oids = np.asarray(res.oids)
    scores = np.asarray(res.scores)
    slots = np.asarray(res.slots)
    n_pass = int(np.isfinite(oracle).sum())
    nv = min(k, n_pass)
    # exactly nv live ranks, then fully-masked padding
    assert (slots[:nv] >= 0).all() and (oids[:nv] > 0).all()
    assert (slots[nv:] == -1).all(), "stale slot id surfaced in padding"
    assert (oids[nv:] == 0).all(), "stale object id surfaced in padding"
    assert np.isneginf(scores[nv:]).all()
    # scores at every live rank match the oracle's sorted top-k
    want = np.sort(oracle[np.isfinite(oracle)])[::-1][:nv]
    np.testing.assert_allclose(scores[:nv], want, rtol=1e-5, atol=1e-6)
    # membership: bit-exact on oids when the k-boundary is unambiguous
    fin = np.sort(oracle[np.isfinite(oracle)])[::-1]
    unambiguous = nv == 0 or len(fin) == nv \
        or fin[nv - 1] - fin[nv] > 1e-5
    if unambiguous and nv:
        thresh = fin[nv - 1]
        want_oids = set(ids[np.where(oracle >= thresh)[0]].tolist())
        assert set(oids[:nv].tolist()) == want_oids


def _rand_spec(rng, st, k) -> Query:
    """Random predicate combination (dynamic values drawn from the store so
    predicates pass for a non-trivial subset)."""
    kw = {}
    if rng.random() < 0.8:
        kw["embed"] = st.embed[int(rng.integers(st.ids.shape[0]))]
        if rng.random() < 0.3:
            kw["sem_weight"] = jnp.asarray(rng.uniform(0.5, 2.0),
                                           jnp.float32)
    if rng.random() < 0.5:
        c = st.centroid[int(rng.integers(st.ids.shape[0]))]
        kw["near"] = (c, jnp.asarray(rng.uniform(1.0, 6.0), jnp.float32))
        if rng.random() < 0.5:
            kw["prox_weight"] = jnp.asarray(rng.uniform(0.1, 1.0),
                                            jnp.float32)
    if rng.random() < 0.3:
        kw["aabb"] = (jnp.asarray([-2.0, 0.0, -2.0]),
                      jnp.asarray([3.0, 2.0, 3.0]))
    if rng.random() < 0.4:
        kw["labels"] = tuple(int(x) for x in rng.choice(20, 8, replace=False))
    if rng.random() < 0.3:
        kw["min_points"] = jnp.asarray(int(rng.integers(1, P)), jnp.int32)
    if rng.random() < 0.3:
        kw["min_obs"] = jnp.asarray(int(rng.integers(0, 5)), jnp.int32)
    if rng.random() < 0.2:
        kw["since"] = jnp.asarray(0, jnp.int32)
    if rng.random() < 0.25:
        g = ZoneGrid.for_room(ROOM, nx=2, nz=2)
        kw["zones"] = tuple(int(z) for z in
                            rng.choice(4, int(rng.integers(1, 4)),
                                       replace=False))
        kw["grid"] = Query.grid_of(g)
    if not kw:
        kw["embed"] = st.embed[0]
    return Query(k=k, **kw)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,k,n", [(0, 5, 40), (1, 8, 40), (2, 3, 25),
                                      (3, 12, 30)])
def test_engine_matches_oracle_deterministic(seed, k, n):
    """Always-run oracle sweep over random predicate combos × 3 targets."""
    rng = np.random.default_rng(seed)
    st = _store(n, seed)
    lm = _local_map(n, seed)
    zoned, zst = _zoned(n, seed)
    for trial in range(6):
        spec = _rand_spec(rng, st, k)
        # ObjectStore
        res = execute_query(st, spec)
        _check_against_oracle(res, _np_scores(spec, st, has_obs=True), k,
                              np.asarray(st.ids))
        # LocalMap (obs/recency vacuous)
        res = execute_query(lm, spec)
        _check_against_oracle(res, _np_scores(spec, lm, has_obs=False), k,
                              np.asarray(lm.ids))
        # ZoneShardedStore (oracle over the mirrored flat store)
        res = compile_query(spec, zoned)(zoned)
        _check_against_oracle(res, _np_scores(spec, zst, has_obs=True), k,
                              np.asarray(zst.ids))


def test_engine_matches_oracle_property():
    hyp = pytest.importorskip("hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as hst

    targets = {}          # cache stores across examples (jit reuse)

    def _get(n, seed):
        if (n, seed) not in targets:
            targets[(n, seed)] = (_store(n, seed), _local_map(n, seed),
                                  _zoned(n, seed))
        return targets[(n, seed)]

    @settings(max_examples=25, deadline=None)
    @given(hst.integers(0, 3), hst.integers(0, 10**6),
           hst.sampled_from([8, 33]), hst.integers(1, 12))
    def prop(seed, spec_seed, n, k):
        st, lm, (zoned, zst) = _get(n, seed)
        spec = _rand_spec(np.random.default_rng(spec_seed), st, k)
        res = execute_query(st, spec)
        _check_against_oracle(res, _np_scores(spec, st, has_obs=True), k,
                              np.asarray(st.ids))
        res = execute_query(lm, spec)
        _check_against_oracle(res, _np_scores(spec, lm, has_obs=False), k,
                              np.asarray(lm.ids))
        res = compile_query(spec, zoned)(zoned)
        _check_against_oracle(res, _np_scores(spec, zst, has_obs=True), k,
                              np.asarray(zst.ids))

    prop()


# ---------------------------------------------------------------------------
def test_padded_ranks_masked_regression():
    """k > matching-object count: padded ranks are score=-inf, oid=0,
    slot=-1 — the seed surfaced stale slot ids there."""
    st = _store(3, 0, cap=16)
    res = execute_query(st, Query(embed=st.embed[0], k=8))
    assert (np.asarray(res.slots)[3:] == -1).all()
    assert (np.asarray(res.oids)[3:] == 0).all()
    assert np.isneginf(np.asarray(res.scores)[3:]).all()
    # the live prefix is intact
    assert (np.asarray(res.slots)[:3] >= 0).all()
    assert (np.asarray(res.oids)[:3] > 0).all()
    # k beyond capacity also pads instead of erroring
    res = execute_query(st, Query(embed=st.embed[0], k=24))
    assert res.slots.shape == (24,) and (np.asarray(res.slots)[3:] == -1).all()
    # and an all-predicates-fail query is fully masked
    res = execute_query(st, Query(embed=st.embed[0], labels=(999,), k=4))
    assert (np.asarray(res.slots) == -1).all()
    assert (np.asarray(res.oids) == 0).all()


def test_legacy_wrappers_deprecated_and_equivalent():
    from repro.core.query import (batched_query_server, query_local,
                                  query_server)
    st = _store(30, 1)
    lm = _local_map(30, 1)
    qe = st.embed[4]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r1 = query_server(st, qe, k=5)
        r2 = query_local(lm, qe, k=5)
        r3 = batched_query_server(st, jnp.stack([qe, st.embed[7]]), k=5)
    assert sum(issubclass(x.category, DeprecationWarning) for x in w) == 3
    e1 = execute_query(st, Query(embed=qe, k=5))
    for a, b in zip(r1, e1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    e2 = execute_query(lm, Query(embed=qe, k=5))
    np.testing.assert_array_equal(np.asarray(r2.slots), np.asarray(e2.slots))
    np.testing.assert_array_equal(np.asarray(r3.slots[0]),
                                  np.asarray(r1.slots))


def test_pallas_path_matches_jnp():
    st = _store(40, 2)
    specs = [
        Query(embed=st.embed[3], k=6),
        Query(embed=st.embed[3], near=(st.centroid[3], jnp.asarray(4.0)),
              prox_weight=jnp.asarray(0.3), labels=tuple(range(12)),
              min_points=jnp.asarray(2), k=6),
    ]
    for spec in specs:
        rj = execute_query(st, spec)
        rp = execute_query(st, spec, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(rj.slots),
                                      np.asarray(rp.slots))
        np.testing.assert_array_equal(np.asarray(rj.oids),
                                      np.asarray(rp.oids))
        valid = np.asarray(rj.slots) >= 0
        np.testing.assert_allclose(np.asarray(rj.scores)[valid],
                                   np.asarray(rp.scores)[valid], rtol=1e-5)
        assert np.isneginf(np.asarray(rp.scores)[~valid]).all()


def test_stacked_batch_equals_singles():
    st = _store(40, 3)
    specs = [Query(embed=st.embed[i],
                   near=(st.centroid[i], jnp.asarray(5.0)), k=4)
             for i in range(6)]
    batched = stack_queries(specs, pad_to=8)
    rb = execute_query(st, batched)
    assert rb.slots.shape == (8, 4)
    for i, s in enumerate(specs):
        ri = execute_query(st, s)
        np.testing.assert_array_equal(np.asarray(rb.slots[i]),
                                      np.asarray(ri.slots))
        np.testing.assert_allclose(np.asarray(rb.scores[i]),
                                   np.asarray(ri.scores), rtol=1e-6)
    with pytest.raises(ValueError):
        stack_queries([Query(embed=st.embed[0], k=3),
                       Query(embed=st.embed[1], k=4)])


def test_zone_pruning_before_dispatch():
    grid = ZoneGrid.for_room(ROOM, nx=2, nz=2)
    zoned, zst = _zoned(40, 4, grid)
    spec = Query(embed=zst.embed[0], zones=(1, 2),
                 grid=Query.grid_of(grid), k=5)
    plan = compile_query(spec, zoned)
    assert plan.shards == (1, 2)          # pruned before dispatch
    res = plan(zoned)
    _check_against_oracle(res, _np_scores(spec, zst, has_obs=True), 5,
                          np.asarray(zst.ids))
    # near-predicate pruning: only shards overlapping the circle run
    spec = Query(embed=zst.embed[0],
                 near=(jnp.asarray([-3.0, 1.0, -3.0]), jnp.asarray(1.0)),
                 k=5)
    plan = compile_query(spec, zoned)
    assert len(plan.shards) < grid.n_zones
    _check_against_oracle(plan(zoned), _np_scores(spec, zst, has_obs=True),
                          5, np.asarray(zst.ids))


def test_serving_step_fn_carries_query_specs():
    from repro.serving.batching import BatchScheduler, make_query_step_fn
    st = _store(30, 5)
    step_fn = make_query_step_fn(lambda: st, k=4, pad_to=4)
    sched = BatchScheduler(batch_size=4, step_fn=step_fn)
    spec = Query(embed=st.embed[2], near=(st.centroid[2], jnp.asarray(3.0)),
                 k=4)
    r_spec = sched.submit(spec)
    r_legacy = sched.submit(st.embed[9])          # raw embedding payload
    done = sched.drain()
    res = done[r_spec]
    assert isinstance(res, QueryResult)
    want = execute_query(st, spec)
    np.testing.assert_array_equal(res.slots, np.asarray(want.slots))
    oid, score = done[r_legacy]
    want = execute_query(st, Query(embed=st.embed[9], k=4))
    assert oid == int(want.oids[0])
    assert score == pytest.approx(float(want.scores[0]), rel=1e-6)


def test_compiled_plan_reruns_without_structure_change():
    """A compiled plan re-executes with new dynamic values (radius sweep,
    new embedding) — same structure, same executable."""
    st = _store(30, 6)
    spec = Query(embed=st.embed[1], near=(st.centroid[1], jnp.asarray(2.0)),
                 k=5)
    plan = compile_query(spec, st)
    r1 = plan(st)
    spec2 = Query(embed=st.embed[8], near=(st.centroid[8], jnp.asarray(5.0)),
                  k=5)
    r2 = plan(st, spec2)
    _check_against_oracle(r2, _np_scores(spec2, st, has_obs=True), 5,
                          np.asarray(st.ids))
    assert not np.array_equal(np.asarray(r1.slots), np.asarray(r2.slots)) \
        or True          # values may coincide; the oracle check is the test
