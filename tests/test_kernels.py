"""Per-kernel allclose tests: Pallas (interpret) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,e,k", [(100, 64, 5), (1024, 128, 8),
                                   (3000, 512, 10), (64, 32, 3)])
def test_query_topk(n, e, k):
    kq, ke, ka = jax.random.split(jax.random.key(n + e), 3)
    q = jax.random.normal(kq, (e,), jnp.float32)
    embeds = jax.random.normal(ke, (n, e), jnp.float32)
    active = jax.random.bernoulli(ka, 0.8, (n,))
    sv, si = ops.query_topk(q, embeds, active, k)
    rv, ri = ref.query_topk_ref(q, embeds, active, k)
    np.testing.assert_allclose(np.asarray(sv), np.asarray(rv), rtol=1e-5)
    # indices may differ on exact ties; scores must match at every rank
    assert np.all(np.asarray(active)[np.asarray(si)]), "picked inactive slot"


@pytest.mark.parametrize("m,n,d", [(50, 70, 3), (256, 512, 3), (1000, 333, 3),
                                   (128, 128, 8)])
def test_nearest_dist(m, n, d):
    ka, kb, kv = jax.random.split(jax.random.key(m * n), 3)
    a = jax.random.normal(ka, (m, d), jnp.float32) * 2
    b = jax.random.normal(kb, (n, d), jnp.float32) * 2
    bv = jax.random.bernoulli(kv, 0.9, (n,))
    got = ops.nearest_dist(a, b, bv)
    want = ref.nearest_dist_ref(a, b, bv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h,s,dh,causal,window,softcap,dtype", [
    (2, 128, 64, True, 0, 0.0, jnp.float32),
    (4, 256, 64, True, 64, 0.0, jnp.float32),
    (2, 200, 128, True, 0, 50.0, jnp.float32),
    (1, 128, 64, False, 0, 0.0, jnp.float32),
    (2, 256, 64, True, 0, 0.0, jnp.bfloat16),
])
def test_flash_attention(h, s, dh, causal, window, softcap, dtype):
    kq, kk, kv = jax.random.split(jax.random.key(s + h), 3)
    q = jax.random.normal(kq, (h, s, dh), dtype)
    k = jax.random.normal(kk, (h, s, dh), dtype)
    v = jax.random.normal(kv, (h, s, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_flash_attention_matches_model_blocked():
    """Kernel vs the model-side jnp blocked attention (same math path)."""
    from repro.models.attention import blocked_attention
    kq, kk, kv = jax.random.split(jax.random.key(0), 3)
    B, S, H, dh = 2, 192, 4, 64
    q = jax.random.normal(kq, (B, S, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, dh), jnp.float32)
    want = blocked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    got = jax.vmap(lambda qq, kk_, vv: ops.flash_attention(
        qq.transpose(1, 0, 2), kk_.transpose(1, 0, 2),
        vv.transpose(1, 0, 2)).transpose(1, 0, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_pruned_attention_matches_full():
    """Tile-pruned blocked attention == full sweep (causal + SWA)."""
    from repro.models.attention import blocked_attention
    kq, kk, kv = jax.random.split(jax.random.key(3), 3)
    B, S, H, dh = 2, 384, 4, 32
    q = jax.random.normal(kq, (B, S, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, dh), jnp.float32)
    for window in (0, 128):
        full = blocked_attention(q, k, v, causal=True, window=window,
                                 q_chunk=128, k_chunk=64, prune=False)
        pruned = blocked_attention(q, k, v, causal=True, window=window,
                                   q_chunk=128, k_chunk=64, prune=True)
        np.testing.assert_allclose(np.asarray(pruned), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)
