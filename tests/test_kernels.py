"""Per-kernel allclose tests: Pallas (interpret) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,e,k", [(100, 64, 5), (1024, 128, 8),
                                   (3000, 512, 10), (64, 32, 3)])
def test_query_topk(n, e, k):
    kq, ke, ka = jax.random.split(jax.random.key(n + e), 3)
    q = jax.random.normal(kq, (e,), jnp.float32)
    embeds = jax.random.normal(ke, (n, e), jnp.float32)
    active = jax.random.bernoulli(ka, 0.8, (n,))
    sv, si = ops.query_topk(q, embeds, active, k)
    rv, ri = ref.query_topk_ref(q, embeds, active, k)
    np.testing.assert_allclose(np.asarray(sv), np.asarray(rv), rtol=1e-5)
    # indices may differ on exact ties; scores must match at every rank
    assert np.all(np.asarray(active)[np.asarray(si)]), "picked inactive slot"


@pytest.mark.parametrize("d,h,w,stride,budget,cap,block_t", [
    (4, 24, 32, 1, 64, 4096, 256),
    (8, 48, 64, 5, 512, 4096, 512),
    (3, 20, 26, 2, 16, 32, 128),
    (6, 30, 40, 3, 100, 80, 512),     # budget > cap + non-divisible tiling
])
def test_lift_compact_kernel(d, h, w, stride, budget, cap, block_t):
    """Streaming Pallas lift_compact vs the seed-composition oracle: the
    one-hot MXU scatter + folded stats must reproduce points, counts,
    centroid, and bbox (empty objects excepted: the kernel reports the
    true n = 0 where the seed's downsample floor said 1)."""
    from repro.kernels import lift_compact as lc
    rng = np.random.default_rng(d * h + w)
    depth = jnp.asarray(np.where(rng.random((h, w)) > 0.25,
                                 rng.uniform(0.4, 6.0, (h, w)),
                                 0.0).astype(np.float32))
    masks = jnp.asarray(rng.random((d, h, w)) > 0.5)
    intr = jnp.asarray([0.9 * w, 0.9 * w, w / 2, h / 2], jnp.float32)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    pose = np.eye(4, dtype=np.float32)
    pose[:3, :3] = q.astype(np.float32)
    pose[:3, 3] = rng.uniform(-1, 1, 3).astype(np.float32)
    got = lc.lift_compact_pallas(depth, masks, jnp.asarray(intr),
                                 jnp.asarray(pose), stride=stride,
                                 budget=budget, lift_cap=cap,
                                 block_t=block_t, interpret=True)
    want = [np.asarray(a) for a in ref.lift_compact_ref(
        depth, masks, intr, jnp.asarray(pose), stride=stride, budget=budget,
        lift_cap=cap)]
    counts = np.asarray((np.asarray(masks)
                         & (np.asarray(depth) > lc.Z_EPS)[None]).sum((1, 2)))
    want[1] = np.where(counts > 0, want[1], 0)
    for name, g, w_ in zip(["pts", "n", "cent", "mn", "mx"], got, want):
        np.testing.assert_allclose(np.asarray(g), w_, rtol=1e-4, atol=1e-4,
                                   err_msg=name)


@pytest.mark.parametrize("m,n,d", [(50, 70, 3), (256, 512, 3), (1000, 333, 3),
                                   (128, 128, 8)])
def test_nearest_dist(m, n, d):
    ka, kb, kv = jax.random.split(jax.random.key(m * n), 3)
    a = jax.random.normal(ka, (m, d), jnp.float32) * 2
    b = jax.random.normal(kb, (n, d), jnp.float32) * 2
    bv = jax.random.bernoulli(kv, 0.9, (n,))
    got = ops.nearest_dist(a, b, bv)
    want = ref.nearest_dist_ref(a, b, bv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h,s,dh,causal,window,softcap,dtype", [
    (2, 128, 64, True, 0, 0.0, jnp.float32),
    (4, 256, 64, True, 64, 0.0, jnp.float32),
    (2, 200, 128, True, 0, 50.0, jnp.float32),
    (1, 128, 64, False, 0, 0.0, jnp.float32),
    (2, 256, 64, True, 0, 0.0, jnp.bfloat16),
])
def test_flash_attention(h, s, dh, causal, window, softcap, dtype):
    kq, kk, kv = jax.random.split(jax.random.key(s + h), 3)
    q = jax.random.normal(kq, (h, s, dh), dtype)
    k = jax.random.normal(kk, (h, s, dh), dtype)
    v = jax.random.normal(kv, (h, s, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_flash_attention_matches_model_blocked():
    """Kernel vs the model-side jnp blocked attention (same math path)."""
    from repro.models.attention import blocked_attention
    kq, kk, kv = jax.random.split(jax.random.key(0), 3)
    B, S, H, dh = 2, 192, 4, 64
    q = jax.random.normal(kq, (B, S, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, dh), jnp.float32)
    want = blocked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    got = jax.vmap(lambda qq, kk_, vv: ops.flash_attention(
        qq.transpose(1, 0, 2), kk_.transpose(1, 0, 2),
        vv.transpose(1, 0, 2)).transpose(1, 0, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_pruned_attention_matches_full():
    """Tile-pruned blocked attention == full sweep (causal + SWA)."""
    from repro.models.attention import blocked_attention
    kq, kk, kv = jax.random.split(jax.random.key(3), 3)
    B, S, H, dh = 2, 384, 4, 32
    q = jax.random.normal(kq, (B, S, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, dh), jnp.float32)
    for window in (0, 128):
        full = blocked_attention(q, k, v, causal=True, window=window,
                                 q_chunk=128, k_chunk=64, prune=False)
        pruned = blocked_attention(q, k, v, causal=True, window=window,
                                   q_chunk=128, k_chunk=64, prune=True)
        np.testing.assert_allclose(np.asarray(pruned), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)
