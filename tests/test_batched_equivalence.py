"""Equivalence of the batched hot paths against the seed sequential oracles.

  * associate (batched resolve) == associate_reference (seed scan) on
    randomized conflict-free frames — detections within a frame are distinct
    objects by construction (instance segmentation), which is exactly the
    regime where the two semantics coincide.
  * apply_updates_batch (one jitted scan) == folding apply_update row by
    row, including eviction order on an over-subscribed local map.
  * multi-query Pallas top-k == the jnp reference path, and the batched
    serving query == Q independent single queries.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import association as assoc
from repro.core.knobs import Knobs
from repro.core.local_map import (ObjectUpdate, UpdateBatch, apply_update,
                                  apply_updates_batch, init_local_map)
from repro.core.query import batched_query_local, query_local
from repro.core.store import init_store

CAP, E, P, D = 32, 16, 64, 8


def _assert_stores_equal(a, b, msg=""):
    for name, xa, xb in zip(a._fields, a, b):
        np.testing.assert_allclose(
            np.asarray(xa, np.float64), np.asarray(xb, np.float64),
            rtol=1e-5, atol=1e-6, err_msg=f"{msg} field {name}")


def _random_frame(store, rng, counter, n_match, n_insert):
    """Detections: near-copies of distinct active slots (matches) plus
    globally-unique far-away clusters (inserts) — conflict-free frames."""
    act = np.nonzero(np.asarray(store.active))[0]
    emb = rng.normal(size=(D, E)).astype(np.float32)
    pts = rng.normal(size=(D, P, 3)).astype(np.float32) * 0.1
    for i in range(D):
        counter[0] += 1
        pts[i] += counter[0] * 20.0
    npts = rng.integers(5, P, size=D).astype(np.int32)
    valid = np.zeros(D, bool)
    valid[:n_match + n_insert] = True
    chosen = (rng.choice(act, size=min(n_match, len(act)), replace=False)
              if len(act) else np.zeros((0,), np.int64))
    for i, j in enumerate(chosen):
        emb[i] = np.asarray(store.embed[j]) + rng.normal(size=E) * 0.01
        pts[i] = np.asarray(store.centroid[j]) + rng.normal(size=(P, 3)) * 0.1
    emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
    return assoc.Detections(
        embed=jnp.asarray(emb),
        label=jnp.asarray(rng.integers(0, 5, D), jnp.int32),
        points=jnp.asarray(pts), n_points=jnp.asarray(npts),
        valid=jnp.asarray(valid))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_associate_matches_seed_scan(seed):
    rng = np.random.default_rng(seed)
    counter = [0]
    sa = init_store(CAP, E, P)
    sb = init_store(CAP, E, P)
    for f in range(8):
        det = _random_frame(sa, rng, counter,
                            n_match=int(rng.integers(0, 4)) if f else 0,
                            n_insert=int(rng.integers(1, 4)))
        sa = assoc.associate(sa, det, frame=jnp.asarray(f))
        sb = assoc.associate_reference(sb, det, frame=jnp.asarray(f))
        _assert_stores_equal(sa, sb, f"seed {seed} frame {f}")
    assert int(sa.active.sum()) > 0


def test_associate_full_store_overflow():
    """Inserts past capacity are dropped in detection order, ids advance
    only for performed inserts — exactly like the seed scan."""
    rng = np.random.default_rng(7)
    counter = [0]
    sa = init_store(4, E, P)
    sb = init_store(4, E, P)
    for f in range(4):
        det = _random_frame(sa, rng, counter, n_match=0, n_insert=3)
        sa = assoc.associate(sa, det, frame=jnp.asarray(f))
        sb = assoc.associate_reference(sb, det, frame=jnp.asarray(f))
        _assert_stores_equal(sa, sb, f"overflow frame {f}")
    assert int(sa.active.sum()) == 4
    assert int(sa.next_id) == int(sb.next_id)


def _mk_batch(rng, U, cap_pts, n_valid=None):
    n_valid = U if n_valid is None else n_valid
    emb = rng.normal(size=(U, E)).astype(np.float32)
    emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
    return UpdateBatch(
        oid=jnp.asarray(rng.integers(1, 12, U), jnp.int32),  # dup oids likely
        embed=jnp.asarray(emb),
        label=jnp.asarray(rng.integers(0, 5, U), jnp.int32),
        points=jnp.asarray(rng.normal(size=(U, cap_pts, 3)), jnp.float16),
        n_points=jnp.asarray(rng.integers(1, cap_pts, U), jnp.int32),
        centroid=jnp.asarray(rng.normal(size=(U, 3)), jnp.float32),
        version=jnp.asarray(rng.integers(1, 9, U), jnp.int32),
        valid=jnp.asarray(np.arange(U) < n_valid))


@pytest.mark.parametrize("seed,n_valid", [(0, 24), (1, 24), (2, 17)])
def test_apply_updates_batch_matches_sequential_fold(seed, n_valid):
    """Tiny capacity (8) + 24 updates with duplicate ids -> refreshes,
    evictions, and rejections; the batched scan must reproduce the exact
    sequential fold, padding rows inert."""
    kn = Knobs(client_capacity=8, max_object_points_client=16)
    rng = np.random.default_rng(seed)
    batch = _mk_batch(rng, 24, 16, n_valid)
    pris = jnp.asarray(rng.uniform(0, 2, 24), jnp.float32)

    m_seq = init_local_map(kn, E)
    for i in range(24):
        if not bool(batch.valid[i]):
            continue
        u = ObjectUpdate(oid=batch.oid[i], embed=batch.embed[i],
                         label=batch.label[i], points=batch.points[i],
                         n_points=batch.n_points[i],
                         centroid=batch.centroid[i], version=batch.version[i])
        m_seq = apply_update(m_seq, u, pris[i])

    m_bat = jax.jit(apply_updates_batch)(init_local_map(kn, E), batch, pris)
    for name, xa, xb in zip(m_bat._fields, m_bat, m_seq):
        np.testing.assert_allclose(
            np.asarray(xa, np.float64), np.asarray(xb, np.float64),
            rtol=1e-6, atol=1e-7, err_msg=f"field {name}")
    assert int(m_bat.active.sum()) == kn.client_capacity


@pytest.mark.parametrize("q,k", [(1, 5), (8, 4), (16, 8)])
def test_batched_query_matches_single_queries(q, k):
    """batched_query_local == Q independent query_local calls, and the
    multi-query Pallas kernel returns results identical to the jnp path."""
    kn = Knobs(client_capacity=128, max_object_points_client=16)
    m = init_local_map(kn, E)
    km = jax.random.key(11)
    m = m._replace(
        embed=jax.random.normal(km, (128, E), jnp.float32),
        active=jax.random.bernoulli(jax.random.key(1), 0.7, (128,)),
        ids=jnp.arange(1, 129, dtype=jnp.int32))
    qs = jax.random.normal(jax.random.key(q * 31 + k), (q, E), jnp.float32)

    got = batched_query_local(m, qs, k=k)
    for i in range(q):
        one = query_local(m, qs[i], k=k)
        np.testing.assert_allclose(np.asarray(got.scores[i]),
                                   np.asarray(one.scores), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(got.oids[i]),
                                      np.asarray(one.oids))

    pal = batched_query_local(m, qs, k=k, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pal.scores), np.asarray(got.scores),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(pal.oids), np.asarray(got.oids))
