"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness. The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, SMOKE_CELL, get_config, make_inputs
from repro.models.api import model_api

# ~4-5 min of fwd/bwd compiles across 10 LLM configs — out of tier-1
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_config(arch + "-smoke")
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    batch = make_inputs(cfg, SMOKE_CELL, jax.random.key(1))
    loss, metrics = jax.jit(lambda p, b: api.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one SGD step must also be finite (exercises the backward pass)
    grads = jax.jit(jax.grad(lambda p, b: api.loss(p, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad not finite"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_step(arch):
    cfg = get_config(arch + "-smoke")
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    B, T = 2, 16
    if cfg.encdec:
        frames = jax.random.normal(jax.random.key(1), (B, cfg.enc_seq,
                                                       cfg.d_model))
        from repro.models import encdec as ed
        from repro.models import attention as at
        kv = jax.tree.map(
            lambda l: jnp.zeros(l.shape, l.dtype),
            ed.encdec_cache_specs(cfg, B, T).self_kv)
        enc_out = ed.encode(params, frames, cfg)
        ck, cv = ed.cross_kv(params, enc_out, cfg)
        caches = ed.EncDecCache(kv, ck, cv)
    else:
        caches = api.init_cache(B, T)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches = jax.jit(lambda p, t, c: api.decode(p, t, c, pos=0))(
        params, tok, caches)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # a second step exercises cache-advance plumbing
    logits2, _ = jax.jit(lambda p, t, c: api.decode(p, t, c, pos=1))(
        params, tok, caches)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_int8_kv_cache_close_to_bf16():
    """Quantized KV decode tracks the bf16 path (memory-bound decode lever)."""
    cfg = get_config("yi-9b-smoke")
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    B, T = 2, 16
    tok = jnp.ones((B, 1), jnp.int32)

    def run(c):
        a = model_api(c)
        caches = a.init_cache(B, T)
        logits = None
        for pos in range(4):
            logits, caches = jax.jit(
                lambda p, t, cc, pp: a.decode(p, t, cc, pos=pp),
                static_argnames=())(params, tok, caches, pos)
        return np.asarray(logits, np.float32)

    base = run(cfg)
    quant = run(cfg.replace(kv_cache_dtype="int8"))
    # int8 cache: small relative error on logits
    err = np.abs(base - quant).max() / (np.abs(base).max() + 1e-6)
    assert err < 0.05, f"int8 KV error {err:.3f}"
