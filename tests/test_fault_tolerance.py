"""Hostile-network protocol hardening: chaos property suite + deterministic
regression arms.

The contract under test (ISSUE 6): with the fault-injection transport
(seeded per-packet loss / duplication / reordering / corruption, client
crash-restart) the hardened protocol still converges — after the clean
drain tail every client's map is CONTENT-IDENTICAL to the fault-free
replay, device memory stays bounded, chaos runs replay bit-identically,
and tombstoned server slots are retired exactly when every subscriber's
ACKED sync version covers the deletion (never sooner — the slot-leak arm —
unless the retirement lease expires a permanently partitioned client).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.knobs import Knobs
from repro.core.local_map import (apply_update, init_local_map,
                                  local_map_nbytes)
from repro.core.runtime import ClientSession, DeviceClient, FaultModel, \
    NetworkModel
from repro.core.store import deleted_mask, init_store
from repro.core.updates import collect_updates, init_sync
from repro.sim import (ClientSpec, CrashEvent, NetTrace, ObjectEvent,
                       PoseTrack, QueryPlan, Scenario)
from repro.sim.engine import ScenarioEngine
from repro.sim.scenario import GridSpec

E = 32
# same capacities as test_scenario_properties.py: shared jit cache
KN = Knobs(server_capacity=32, client_capacity=16,
           max_object_points_server=16, max_object_points_client=8,
           min_obs_before_sync=1)
N_TICKS = 8
DRAIN = 8


def _canonical_map(m) -> dict:
    """Content view of a LocalMap keyed by oid: slot order and priority are
    transport-dependent (admission order differs under reordering), the
    object CONTENT must not be."""
    act = np.asarray(m.active)
    out = {}
    for s in np.nonzero(act)[0]:
        oid = int(np.asarray(m.ids)[s])
        out[oid] = (
            int(np.asarray(m.version)[s]),
            int(np.asarray(m.label)[s]),
            int(np.asarray(m.n_points)[s]),
            np.asarray(m.centroid)[s].tobytes(),
            np.asarray(m.embed)[s].tobytes(),
            np.asarray(m.points)[s].tobytes(),
        )
    return out


def _base_scenario(*, seed=7, n_clients=2, outage=None, faults=None,
                   crash_events=(), lease_ticks=None, drain=DRAIN,
                   remove_ticks=(4,), n_obj=5, ttl=2):
    events = [ObjectEvent(tick=0, kind="spawn", oid=oid, class_id=oid % 4,
                          pos=(0.5 * oid - 1.0, 1.0, 0.3 * oid - 0.7),
                          n_points=4 + oid)
              for oid in range(1, n_obj + 1)]
    for k, tk in enumerate(remove_ticks):
        events.append(ObjectEvent(tick=tk, kind="remove", oid=k + 1))
    events.append(ObjectEvent(tick=3, kind="move", oid=n_obj,
                              delta=(0.4, 0.0, -0.2)))
    events.sort(key=lambda e: (e.tick, e.kind, e.oid))
    clients = tuple(ClientSpec(
        cid=c, net=NetTrace(outages=outage if (outage and c == 1) else ()),
        track=PoseTrack(anchor=(0.0, 1.5, 0.0)), subscribe_radius=10.0)
        for c in range(n_clients))
    return Scenario(seed=seed, n_ticks=N_TICKS, embed_dim=E, knobs=KN,
                    grid=GridSpec(room=8.0, nx=1, nz=1), budget=16,
                    clients=clients, events=tuple(events),
                    query=QueryPlan(prob=0.0), drain_ticks=drain,
                    tombstone_ttl=ttl, faults=faults,
                    crash_events=crash_events, lease_ticks=lease_ticks)


# ---------------------------------------------------------------------------
# chaos convergence: the core property, checked for one fault mix
# ---------------------------------------------------------------------------
def _assert_chaos_converges(seed, faults, crashes, remove_ticks):
    """Under the given seeded loss/dup/reorder/corrupt/crash mix: after the
    drain tail the maps match the fault-free replay object-for-object,
    memory stays bounded, the chaos run itself replays bit-identically,
    and no tombstone slot leaks (every deletion acked + retired)."""
    faulty = _base_scenario(seed=seed, faults=faults, crash_events=crashes,
                            remove_ticks=remove_ticks)
    clean = _base_scenario(seed=seed, remove_ticks=remove_ticks)
    eng_f = ScenarioEngine(faulty)
    log_f = eng_f.run()
    eng_c = ScenarioEngine(clean)
    eng_c.run()

    # bounded memory: fixed-capacity map, never over
    assert (log_f.client_live <= KN.client_capacity).all()
    cap_bytes = local_map_nbytes(init_local_map(KN, E))
    assert (log_f.client_nbytes == cap_bytes).all()

    # convergence: content-identical to the fault-free replay, and exactly
    # the server's live set (removed objects gone everywhere)
    srv_live = eng_f.world.live_ids()
    assert srv_live == eng_c.world.live_ids()
    for cid in eng_f.sessions:
        got = _canonical_map(eng_f.sessions[cid].dev.local)
        want = _canonical_map(eng_c.sessions[cid].dev.local)
        assert got == want, f"client {cid} diverged: " \
            f"{sorted(got)} vs {sorted(want)}"
        assert set(got) == srv_live

    # slots never leak: every tombstone was acked (or lease-free clean) and
    # retired by the ack-driven GC before the run ended
    assert int(np.asarray(deleted_mask(eng_f.world.store)).sum()) == 0

    # chaos replay is deterministic: same Scenario -> bit-identical log
    log_f2 = ScenarioEngine(_base_scenario(
        seed=seed, faults=faults, crash_events=crashes,
        remove_ticks=remove_ticks)).run()
    assert log_f.equals(log_f2), log_f.diff(log_f2)
    return log_f


# fixed fault mixes: each arm stresses one failure mode hard, the last
# mixes everything + a crash (runs with or without hypothesis installed)
_CHAOS_ARMS = [
    ("loss", 11, FaultModel(seed=3, loss_prob=0.3), (), (4,)),
    ("dup", 12, FaultModel(seed=2, dup_prob=0.5), (), (3, 5)),
    ("reorder", 13, FaultModel(seed=3, reorder_prob=0.5,
                               reorder_jitter_s=2.5), (), (4,)),
    ("corrupt", 14, FaultModel(seed=4, corrupt_prob=0.3), (), (5,)),
    ("everything+crash", 15,
     FaultModel(seed=2, loss_prob=0.15, dup_prob=0.2, reorder_prob=0.25,
                corrupt_prob=0.1),
     (CrashEvent(tick=4, cid=1, down_ticks=2),), (3, 6)),
]


@pytest.mark.parametrize("name,seed,faults,crashes,removes", _CHAOS_ARMS,
                         ids=[a[0] for a in _CHAOS_ARMS])
def test_chaos_converges_fixed_arms(name, seed, faults, crashes, removes):
    log = _assert_chaos_converges(seed, faults, crashes, removes)
    # each arm must actually exercise its fault mode (draws landed)
    flt = log.faults.sum(axis=(0, 1))          # lost, dup, corrupt, resync
    if faults.loss_prob:
        assert flt[0] > 0
    if faults.dup_prob:
        assert flt[1] > 0
    if faults.corrupt_prob:
        assert flt[2] > 0


# ---------------------------------------------------------------------------
# chaos property suite (hypothesis; random mixes on top of the fixed arms)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    @st.composite
    def chaos(draw):
        faults = FaultModel(
            seed=draw(st.integers(0, 2**16)),
            loss_prob=draw(st.sampled_from([0.0, 0.1, 0.3])),
            dup_prob=draw(st.sampled_from([0.0, 0.2])),
            reorder_prob=draw(st.sampled_from([0.0, 0.3])),
            reorder_jitter_s=2.0,
            corrupt_prob=draw(st.sampled_from([0.0, 0.15])),
            resync_timeout_s=2.0, retx_ticks=3)
        crashes = ()
        if draw(st.booleans()):
            crashes = (CrashEvent(tick=draw(st.integers(2, N_TICKS - 1)),
                                  cid=draw(st.integers(0, 1)),
                                  down_ticks=2),)
        return dict(seed=draw(st.integers(0, 2**16)), faults=faults,
                    crashes=crashes,
                    remove_ticks=tuple(draw(
                        st.lists(st.integers(3, N_TICKS - 1), max_size=2))))

    @settings(max_examples=8, deadline=None)
    @given(chaos())
    def test_chaos_converges_property(cfg):
        _assert_chaos_converges(cfg["seed"], cfg["faults"], cfg["crashes"],
                                cfg["remove_ticks"])


# ---------------------------------------------------------------------------
# deterministic arms
# ---------------------------------------------------------------------------
def test_partitioned_subscriber_blocks_retirement_without_lease():
    """A permanently partitioned subscriber never acks the deletion, so the
    tombstoned slot must NOT be released (no lease): releasing it would
    let the client reconnect into a ghost object it can never delete."""
    horizon = float(N_TICKS + DRAIN + 1)
    sc = _base_scenario(outage=((3.0, horizon),), remove_ticks=(4,),
                        lease_ticks=None)
    eng = ScenarioEngine(sc)
    log = eng.run()
    # the tombstone aged far past ttl yet stays: client 1 never acked it
    assert int(np.asarray(deleted_mask(eng.world.store)).sum()) == 1
    assert int(log.gc_released.sum()) == 0
    # the reachable client converged (deleted + acked), the partitioned one
    # still holds the ghost — exactly the state the tombstone must outlive
    m0 = _canonical_map(eng.sessions[0].dev.local)
    m1 = _canonical_map(eng.sessions[1].dev.local)
    assert 1 not in m0
    assert 1 in m1


def test_lease_expiry_retires_slot_and_forces_fresh_epoch():
    """Same partition, but a retirement lease: after ``lease_ticks`` with
    no acks the partitioned client forfeits its hold — the slot retires,
    and the client is marked for a fresh epoch (full catch-up) so
    correctness survives the forfeit."""
    horizon = float(N_TICKS + DRAIN + 1)
    sc = _base_scenario(outage=((3.0, horizon),), remove_ticks=(4,),
                        lease_ticks=4)
    eng = ScenarioEngine(sc)
    log = eng.run()
    assert int(np.asarray(deleted_mask(eng.world.store)).sum()) == 0
    assert int(log.gc_released.sum()) == 1
    # the forfeited client is flagged: its next deliverable tick restarts
    # the session from scratch instead of trusting its stale sync state
    assert bool(eng.server.needs_fresh[1])


def test_crash_restart_rejoins_with_fresh_epoch():
    """A crashed client loses its map and protocol position; the rejoin
    bumps the epoch with fresh=True and re-ships the whole subscribed
    store — including absorbing a removal that happened while it was
    down (it never sees that tombstone; the fresh catch-up just omits
    the object)."""
    import dataclasses
    sc = _base_scenario(n_clients=1, remove_ticks=(), ttl=None)
    sc = dataclasses.replace(
        sc, events=sc.events + (ObjectEvent(tick=6, kind="remove", oid=2),),
        crash_events=(CrashEvent(tick=5, cid=0, down_ticks=2),))
    eng = ScenarioEngine(sc)
    log = eng.run()
    # down window: inactive, map wiped
    assert not log.client_active[5, 0] and not log.client_active[6, 0]
    assert log.client_live[5, 0] == 0
    # epoch history: initial join + crash rejoin = 2 fresh epochs
    assert int(eng.server.epoch[0]) == 2
    # converged post-rejoin: live set matches, removed-object ghost absent
    got = _canonical_map(eng.sessions[0].dev.local)
    assert set(got) == eng.world.live_ids()
    assert 2 not in got


def test_resync_backoff_doubles_and_caps():
    """Gap detection: resync requests fire at the timeout, then back off
    exponentially up to the cap (a congested server is not hammered)."""
    fm = FaultModel(resync_timeout_s=2.0, resync_backoff_cap_s=8.0)
    sess = ClientSession(dev=DeviceClient(knobs=KN, embed_dim=8),
                         net=NetworkModel(), knobs=KN, dt=1.0, cid=0,
                         faults=fm)
    store = init_store(KN.server_capacity, 8, KN.max_object_points_server)
    store = store._replace(
        ids=store.ids.at[0].set(7), active=store.active.at[0].set(True),
        n_points=store.n_points.at[0].set(4),
        obs_count=store.obs_count.at[0].set(3),
        version=store.version.at[0].set(1))
    pkt, _ = collect_updates(store, init_sync(KN.server_capacity), KN,
                             tick=0)
    pkt.zone, pkt.seq, pkt.epoch = 0, 1, 0      # seq 0 was lost: gap
    sess._receive(0.0, pkt)
    assert sess.delivered == 0                  # buffered, not applied
    fired = []
    for t in range(1, 16):
        sess.step(float(t))
        for kind, _ in sess.drain_ctrl():
            fired.append(t)
    # timeout 2 -> backoff 4 -> 8 -> capped at 8
    assert fired == [2, 6, 14]
    assert sess.resyncs == 3


def test_duplicate_packet_apply_is_byte_identical_noop():
    """Applying the same UpdateBatch twice leaves the local map
    byte-for-byte unchanged — the idempotence the ack machinery (dup
    delivery, resync re-ship) leans on."""
    store = init_store(KN.server_capacity, E, KN.max_object_points_server)
    for s, oid in enumerate([3, 8, 11]):
        store = store._replace(
            ids=store.ids.at[s].set(oid),
            active=store.active.at[s].set(True),
            embed=store.embed.at[s].set(jnp.ones(E) / np.sqrt(float(E))),
            n_points=store.n_points.at[s].set(6 + s),
            obs_count=store.obs_count.at[s].set(3),
            version=store.version.at[s].set(1 + s))
    pkt, _ = collect_updates(store, init_sync(KN.server_capacity), KN,
                             tick=0)
    assert pkt.count == 3
    dev = DeviceClient(knobs=KN, embed_dim=E)
    up = jnp.zeros(3)
    dev.ingest(pkt, user_pos=up)
    once = [np.asarray(x).copy() for x in dev.local]
    dev.ingest(pkt, user_pos=up)
    twice = [np.asarray(x) for x in dev.local]
    for a, b in zip(once, twice):
        assert a.tobytes() == b.tobytes()


def test_stale_version_update_is_dropped():
    """Order tolerance: a row whose version is BELOW the retained entry's
    (a reordered or replayed delivery) must not regress the map."""
    from repro.core.local_map import ObjectUpdate
    m = init_local_map(KN, 8)
    mk = lambda ver, val: ObjectUpdate(        # noqa: E731
        oid=jnp.int32(5), embed=jnp.full((8,), val, jnp.float32),
        label=jnp.int32(1),
        points=jnp.zeros((KN.max_object_points_client, 3), jnp.float16),
        n_points=jnp.int32(4), centroid=jnp.zeros(3), version=jnp.int32(ver))
    m = apply_update(m, mk(3, 0.5), jnp.float32(1.0))
    before = [np.asarray(x).copy() for x in m]
    m = apply_update(m, mk(2, 0.9), jnp.float32(9.0))   # stale: dropped
    for a, b in zip(before, m):
        assert a.tobytes() == np.asarray(b).tobytes()
    assert int(m.version[0]) == 3


# ---------------------------------------------------------------------------
# mesh-sharded session tier: multi-host control-plane routing (ISSUE 10)
# ---------------------------------------------------------------------------
def _shard_scenario(*, seed=21, faults=None, n_clients=4, drain=DRAIN,
                    remove_ticks=(4,)):
    """Multi-zone variant of ``_base_scenario``: 2x1 zone grid with the
    clients spread across both zones so ack/resync routing crosses zone
    sessions AND roster shards."""
    events = [ObjectEvent(tick=0, kind="spawn", oid=oid, class_id=oid % 4,
                          pos=(1.1 * oid - 3.0, 1.0, 0.3 * oid - 0.7),
                          n_points=4 + oid)
              for oid in range(1, 6)]
    for k, tk in enumerate(remove_ticks):
        events.append(ObjectEvent(tick=tk, kind="remove", oid=k + 1))
    events.sort(key=lambda e: (e.tick, e.kind, e.oid))
    clients = tuple(ClientSpec(
        cid=c, net=NetTrace(),
        track=PoseTrack(anchor=(2.0 * c - 3.0, 1.5, 0.0)),
        subscribe_radius=10.0) for c in range(n_clients))
    return Scenario(seed=seed, n_ticks=N_TICKS, embed_dim=E, knobs=KN,
                    grid=GridSpec(room=8.0, nx=2, nz=1), budget=16,
                    clients=clients, events=tuple(events),
                    query=QueryPlan(prob=0.0), drain_ticks=drain,
                    tombstone_ttl=2, faults=faults)


def _toy_store(n_obj=6):
    """A populated ObjectStore with centroids spread across both zones."""
    store = init_store(KN.server_capacity, E, KN.max_object_points_server)
    for s in range(n_obj):
        store = store._replace(
            ids=store.ids.at[s].set(s + 1),
            active=store.active.at[s].set(True),
            embed=store.embed.at[s].set(jnp.ones(E) / np.sqrt(float(E))),
            centroid=store.centroid.at[s].set(
                jnp.array([1.2 * s - 3.0, 1.0, 0.0])),
            n_points=store.n_points.at[s].set(4 + s),
            obs_count=store.obs_count.at[s].set(3),
            version=store.version.at[s].set(1 + s))
    return store


def _shard_server(sc, n_shards):
    from repro.server.fleet import FleetServer
    from repro.server.zones import ZoneGrid
    grid = ZoneGrid.for_room(sc.grid.room, sc.grid.nx, sc.grid.nz)
    return FleetServer(knobs=sc.knobs, embed_dim=sc.embed_dim,
                       n_clients=len(sc.clients), grid=grid,
                       budget=sc.budget,
                       proto=sc.faults is not None, donate=False,
                       n_session_shards=n_shards)


def test_sharded_tier_chaos_byte_identical_to_unsharded():
    """Under a loss+reorder+dup mix (natural resyncs, retransmits, and
    epoch-stale acks in flight) the sharded session tier replays
    BIT-IDENTICALLY to the unsharded server — control-plane messages land
    on the owning shard with the same effect as the single-device path —
    and both converge content-identical to the fault-free run."""
    fm = FaultModel(seed=5, loss_prob=0.2, dup_prob=0.2, reorder_prob=0.3,
                    reorder_jitter_s=2.0)
    sc = _shard_scenario(faults=fm)
    eng_1 = ScenarioEngine(sc, server=_shard_server(sc, 1))
    log_1 = eng_1.run()
    eng_s = ScenarioEngine(_shard_scenario(faults=fm),
                           server=_shard_server(sc, 3))
    log_s = eng_s.run()
    assert log_1.equals(log_s), log_1.diff(log_s)
    assert (eng_s.server.epoch == eng_1.server.epoch).all()

    clean = ScenarioEngine(_shard_scenario(faults=None))
    clean.run()
    assert eng_s.world.live_ids() == clean.world.live_ids()
    for cid in eng_s.sessions:
        got = _canonical_map(eng_s.sessions[cid].dev.local)
        want = _canonical_map(clean.sessions[cid].dev.local)
        assert got == want, f"client {cid} diverged under sharding"
    assert int(np.asarray(deleted_mask(eng_s.world.store)).sum()) == 0


def test_epoch_stale_ack_at_owning_shard_is_dropped():
    """An ack that arrives with a superseded epoch (late over the network,
    routed to the client's owning shard) must be a no-op: it must not
    advance the shard's acked state nor clear the pending-fresh flag."""
    sc = _shard_scenario(faults=FaultModel(seed=1))
    srv = _shard_server(sc, 3)
    srv.refresh(_toy_store())
    for c in range(4):
        srv.join(c, (2.0 * c - 3.0, 1.5, 0.0), 10.0)
    deliver = np.ones(4, bool)
    pkts = srv.tick(deliver, tick=0)
    (z, pkt), = [(z, p) for z, p in pkts if p.seqs[1] >= 0][:1]
    stale_epoch = int(srv.epoch[1])
    stale_seq = int(pkt.seqs[1])
    # the client's ack is delayed; meanwhile a gap forces a resync bump
    srv.request_resync(1)
    tier = srv.sessions[z]
    part, row = tier._route(1)
    before = np.asarray(part.acked[row]).copy()
    fresh_before = bool(srv.epoch_fresh[1])
    srv.ack(1, z, stale_epoch, stale_seq, tick=2)      # stale: dropped
    assert (np.asarray(part.acked[row]) == before).all()
    assert bool(srv.epoch_fresh[1]) == fresh_before
    # a current-epoch ack for the re-shipped packet lands normally
    pkts2 = srv.tick(deliver, tick=1)
    (z2, pkt2), = [(z, p) for z, p in pkts2 if p.seqs[1] >= 0][:1]
    srv.ack(1, z2, int(srv.epoch[1]), int(pkt2.seqs[1]), tick=3)
    part2, row2 = srv.sessions[z2]._route(1)
    assert np.asarray(part2.acked[row2]).any()


def test_resync_rolls_back_only_the_owning_shard_rows():
    """A resync (rollback) for one client must only touch that client's
    row on its owning shard: every other shard's sync state — and every
    other client's row — stays byte-identical."""
    sc = _shard_scenario(faults=FaultModel(seed=1))
    srv = _shard_server(sc, 3)
    srv.refresh(_toy_store())
    for c in range(4):
        srv.join(c, (2.0 * c - 3.0, 1.5, 0.0), 10.0)
    srv.tick(np.ones(4, bool), tick=0)
    tier = srv.sessions[0]
    home = int(tier.roster.assign[2])
    snap = {s: np.asarray(p.sync.synced_version).copy()
            for s, p in enumerate(tier.parts) if p is not None}
    srv.request_resync(2)
    for s, p in enumerate(tier.parts):
        if p is None:
            continue
        now = np.asarray(p.sync.synced_version)
        if s != home:
            assert (now == snap[s]).all(), f"shard {s} perturbed"
        else:
            row = int(tier.roster.row[2])
            keep = np.ones(now.shape[0], bool)
            keep[row] = False
            assert (now[keep] == snap[s][keep]).all()


def test_shard_crash_rebuilds_only_that_shards_clients():
    """A session-shard host dies mid-run: exactly its clients get fresh
    epochs (full catch-up next tick); clients on surviving shards keep
    their epochs and streams.  After the drain the maps are
    content-identical to the crash-free replay."""
    fm = FaultModel(seed=9, loss_prob=0.1)
    sc = _shard_scenario(faults=fm)
    srv = _shard_server(sc, 2)          # round-robin: shard1 = clients 1,3
    eng = ScenarioEngine(_shard_scenario(faults=fm), server=srv)
    state = {"n": 0, "ep": None}

    def hook(t):
        state["n"] += 1
        if state["n"] == 4:                 # end of the 4th tick
            srv.crash_shard(1, tick=4)
            state["ep"] = np.asarray(srv.epoch).copy()
    eng.tick_hook = hook
    eng.run()
    ep_at_crash = state["ep"]
    # only shard-1 clients (1, 3) were bumped by the crash
    bumped = np.asarray(srv.roster.assign) == 1
    assert (ep_at_crash[bumped] >= 2).all()
    assert (np.asarray(srv.epoch)[bumped] >= ep_at_crash[bumped]).all()

    clean = ScenarioEngine(_shard_scenario(faults=fm),
                           server=_shard_server(sc, 2))
    clean.run()
    # surviving-shard clients never saw a crash-driven bump
    assert (np.asarray(srv.epoch)[~bumped]
            == np.asarray(clean.server.epoch)[~bumped]).all()
    for cid in eng.sessions:
        got = _canonical_map(eng.sessions[cid].dev.local)
        want = _canonical_map(clean.sessions[cid].dev.local)
        assert got == want, f"client {cid} diverged after shard crash"
    assert eng.world.live_ids() == clean.world.live_ids()
