"""Knob autotuner: budget satisfaction + quality-maximality + adaptation."""
import dataclasses

from repro.core.autotune import DownstreamTuner, tune_upstream
from repro.core.depth import upstream_mbps
from repro.core.knobs import Knobs


def test_upstream_budget_met_quality_first():
    kn = Knobs()
    for budget in (30.0, 10.0, 5.0, 2.5):
        tuned = tune_upstream(kn, budget_mbps=budget)
        assert upstream_mbps(720, 1280, tuned) <= budget + 1e-6
        # quality-maximal: one step finer would bust the budget (or ratio=1)
        r = tuned.depth_downsampling_ratio
        if r > 1:
            finer = dataclasses.replace(tuned, depth_downsampling_ratio=r - 1)
            assert upstream_mbps(720, 1280, finer) > budget


def test_upstream_monotone_in_budget():
    kn = Knobs()
    rs = [tune_upstream(kn, budget_mbps=b).depth_downsampling_ratio
          for b in (30.0, 10.0, 5.0, 2.5)]
    assert rs == sorted(rs)


def test_downstream_backs_off_and_recovers():
    kn = Knobs(local_map_update_frequency=2)
    t = DownstreamTuner(budget_bytes_per_s=10_000)
    # heavy updates -> interval grows (frequency drops)
    for _ in range(4):
        kn = t.observe(kn, packet_bytes=50_000)
    assert kn.local_map_update_frequency > 2
    # quiet scene -> interval shrinks back toward the floor
    for _ in range(10):
        kn = t.observe(kn, packet_bytes=100)
    assert kn.local_map_update_frequency <= 2
